//! Integration test: the PJRT runtime must reproduce, to fp32 tolerance,
//! the golden outputs python/compile/aot.py computed with the same
//! compressed parameters — proving the HLO-text round trip
//! (jax → text → xla_extension parser → PJRT CPU) preserves numerics.
//!
//! Requires `make artifacts`; self-skips when artifacts are absent so
//! `cargo test` stays green on a fresh checkout.
//!
//! All checks live in ONE test fn: loading the runtime compiles five HLO
//! modules (~70 s) and concurrent PJRT CPU clients in one process can
//! race inside xla_extension — one client, one load, sequential checks.

// The whole test crate needs the PJRT runtime.
#![cfg(feature = "xla")]

use std::path::PathBuf;

use flightllm::runtime::ModelRuntime;

fn artifacts_dir() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    d.join("manifest.json").exists().then_some(d)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

mod goldens {
    use flightllm::runtime::Manifest;

    pub fn blob(m: &Manifest) -> Vec<u8> {
        std::fs::read(m.dir.join("goldens.bin")).expect("goldens.bin")
    }

    pub fn f32s(m: &Manifest, blob: &[u8], name: &str) -> Vec<f32> {
        let e = m.golden(name).unwrap();
        blob[e.offset..e.offset + e.nbytes]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    pub fn i32s(m: &Manifest, blob: &[u8], name: &str) -> Vec<i32> {
        let e = m.golden(name).unwrap();
        blob[e.offset..e.offset + e.nbytes]
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }
}

#[test]
fn runtime_reproduces_python_goldens() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipped: run `make artifacts` first");
        return;
    };
    let rt = ModelRuntime::load(&dir).expect("load runtime");
    let blob = goldens::blob(&rt.manifest);

    // ---- bucket selection (§5.2 length-adaptive reuse) ----------------
    assert_eq!(rt.bucket_for(1).unwrap(), 16);
    assert_eq!(rt.bucket_for(16).unwrap(), 16);
    assert_eq!(rt.bucket_for(17).unwrap(), 32);
    assert_eq!(rt.bucket_for(100).unwrap(), 128);
    assert!(rt.bucket_for(1000).is_err());

    // ---- prefill vs golden ---------------------------------------------
    let tokens = goldens::i32s(&rt.manifest, &blob, "prefill_tokens");
    let want_logits = goldens::f32s(&rt.manifest, &blob, "prefill_logits");
    let want_kv = goldens::f32s(&rt.manifest, &blob, "prefill_kv");
    let p = rt.prefill(&tokens).expect("prefill");
    let d = max_abs_diff(&p.logits, &want_logits);
    assert!(d < 1e-3, "prefill logits diverge: max abs diff {d}");
    let kv = p.kv.to_vec::<f32>().expect("kv to_vec");
    let dkv = max_abs_diff(&kv, &want_kv);
    assert!(dkv < 1e-3, "prefill kv diverges: max abs diff {dkv}");
    eprintln!("prefill golden: logits diff {d:.2e}, kv diff {dkv:.2e}");

    // ---- decode vs golden ----------------------------------------------
    let want_dl = goldens::f32s(&rt.manifest, &blob, "decode_logits");
    let want_dkv = goldens::f32s(&rt.manifest, &blob, "decode_kv");
    let dec_token = goldens::i32s(&rt.manifest, &blob, "decode_token")[0];
    let pos = goldens::i32s(&rt.manifest, &blob, "decode_pos")
        .first()
        .copied()
        .unwrap_or(rt.manifest.golden_prefill_bucket as i32);
    let greedy = ModelRuntime::argmax(&p.logits);
    assert_eq!(greedy, dec_token, "greedy continuation must match python");
    let dout = rt.decode(dec_token, &p.kv, pos).expect("decode");
    let dl = max_abs_diff(&dout.logits, &want_dl);
    assert!(dl < 1e-3, "decode logits diverge: max abs diff {dl}");
    let dkv2 = max_abs_diff(&dout.kv.to_vec::<f32>().unwrap(), &want_dkv);
    assert!(dkv2 < 1e-3, "decode kv diverges: max abs diff {dkv2}");
    eprintln!("decode golden: logits diff {dl:.2e}, kv diff {dkv2:.2e}");

    // ---- multi-step generation stability --------------------------------
    let prompt: Vec<i32> = (0..16).map(|i| (i * 3) % 512).collect();
    let p = rt.prefill(&prompt).expect("prefill");
    let mut tok = ModelRuntime::argmax(&p.logits);
    let mut kv = p.kv;
    let mut pos = 16i32;
    let mut toks = vec![tok];
    for _ in 0..24 {
        let out = rt.decode(tok, &kv, pos).expect("decode step");
        assert!(out.logits.iter().all(|v| v.is_finite()), "logits must stay finite");
        tok = ModelRuntime::argmax(&out.logits);
        assert!((tok as usize) < rt.vocab());
        kv = out.kv;
        pos += 1;
        toks.push(tok);
    }
    let distinct: std::collections::HashSet<i32> = toks.iter().copied().collect();
    assert!(distinct.len() > 3, "generation collapsed: {toks:?}");
}
