//! Integration: the N:M sparse format (§3.2.1) driven through the
//! bit-true CSD-chain datapath with INT8-quantized values — proving the
//! three hardware claims end to end:
//!   1. the sparse MUX + index buffer reproduce the exact SpMV result,
//!   2. dense and sparse passes keep every DSP busy (equal dsp_cycles),
//!   3. the OAU's MSP/LSP split loses no precision on long chains.

use flightllm::quant::{MixedPrecision, QuantizedTensor};
use flightllm::sim::CsdChain;
use flightllm::sparse::{NmBlockPattern, NmMatrix};
use flightllm::util::Rng;

/// Quantize f32 → int8 codes with a shared scale (activation path).
fn quantize_i8(v: &[f32]) -> (Vec<i8>, f32) {
    let amax = v.iter().fold(0f32, |m, &x| m.max(x.abs()));
    let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
    (v.iter().map(|&x| (x / scale).round().clamp(-127.0, 127.0) as i8).collect(), scale)
}

#[test]
fn nm_matrix_through_csd_chain_matches_spmv() {
    let mut rng = Rng::new(11);
    let m = 16usize;
    let (out_dim, in_dim) = (32usize, 64usize);
    let dense: Vec<f32> = (0..out_dim * in_dim).map(|_| rng.f32_sym()).collect();
    let pattern = NmBlockPattern::uniform(2, 4, 4, 16); // 4:16 sparsity
    let nm = NmMatrix::compress(&dense, out_dim, in_dim, pattern);
    let x: Vec<f32> = (0..in_dim).map(|_| rng.f32_sym()).collect();

    // Quantize both sides to INT8 (what the MPE datapath sees).
    let (xq, xs) = quantize_i8(&x);
    let wq: Vec<i8> = nm.vals.iter().map(|&v| (v * 127.0).round().clamp(-127.0, 127.0) as i8).collect();
    let ws = 1.0 / 127.0;

    // Drive each output row's groups through a 4-output CSD chain pass:
    // chain of 8 DGs (16 DSPs), split by RNs into 4 segments of 4 slots
    // — each segment computes one M-group's N=4 MACs.
    let chain = CsdChain::new(2, 8);
    let groups = in_dim / m;
    for r in 0..out_dim {
        let mut acc = 0f32;
        let row_start = nm.row_ptr[r] as usize;
        for gpair in (0..groups).step_by(4) {
            // 4 groups per pass (4 segments).
            let mut weights = Vec::new();
            let mut idx = Vec::new();
            let mut acts = vec![0i8; 4 * m];
            for seg in 0..4 {
                let g = gpair + seg;
                let base = row_start + g * 4; // N=4 kept per group
                weights.push(wq[base..base + 4].to_vec());
                idx.push(
                    nm.idx[base..base + 4]
                        .iter()
                        .map(|&j| seg * m + j as usize)
                        .collect::<Vec<_>>(),
                );
                acts[seg * m..(seg + 1) * m]
                    .copy_from_slice(&xq[g * m..(g + 1) * m]);
            }
            let out = chain.run_sparse(&weights, &idx, &acts);
            for &o in &out.outputs {
                acc += o as f32 * ws * xs;
            }
        }
        // Compare against the f32 SpMV of the quantized operands.
        let want: f32 = {
            let mut s = 0f32;
            let mut cursor = row_start;
            for g in 0..groups {
                for _ in 0..4 {
                    s += (wq[cursor] as f32 * ws)
                        * (xq[g * m + nm.idx[cursor] as usize] as f32 * xs);
                    cursor += 1;
                }
            }
            s
        };
        assert!(
            (acc - want).abs() < 1e-4,
            "row {r}: chain {acc} vs reference {want}"
        );
    }
}

#[test]
fn dense_and_sparse_passes_have_equal_dsp_utilization() {
    // Fig. 6's headline: the configurable cascade keeps all DSPs busy in
    // both modes. 16-DSP chain: dense = one 32-MAC dot; 2:4-style sparse
    // = 4 independent 8-MAC dots. Same cycles, same slot count.
    let chain = CsdChain::new(2, 8);
    let w: Vec<i8> = (0..chain.mac_slots()).map(|i| (i as i8).wrapping_mul(3)).collect();
    let a: Vec<i8> = (0..chain.mac_slots()).map(|i| (i as i8).wrapping_sub(7)).collect();
    let dense = chain.run_dense(&w, &a);

    let seg = chain.mac_slots() / 4;
    let ws: Vec<Vec<i8>> = (0..4).map(|s| w[s * seg..(s + 1) * seg].to_vec()).collect();
    let idx: Vec<Vec<usize>> = (0..4).map(|_| (0..seg).collect()).collect();
    let sparse = chain.run_sparse(&ws, &idx, &a[..seg]);

    assert_eq!(dense.dsp_cycles, sparse.dsp_cycles);
    assert_eq!(sparse.outputs.len(), 4);
    assert_eq!(chain.utilization(chain.mac_slots() as u64), 1.0);
}

#[test]
fn mixed_precision_dequant_feeds_chain_exactly() {
    // 3/4/5-bit groups expand to INT8 (DequantUnit) and accumulate on the
    // chain with zero loss relative to the dequantized f32 reference.
    use flightllm::quant::DequantUnit;

    let mut rng = Rng::new(5);
    let w: Vec<f32> = (0..128).map(|_| rng.f32_sym() * 0.3).collect();
    let plan = MixedPrecision { group: 32, bits: vec![3, 4, 5, 4] };
    let q = QuantizedTensor::quantize(&w, 1, 128, plan);
    let unit = DequantUnit::new(16);
    let groups = unit.expand(&q);
    let acts: Vec<i8> = (0..32).map(|_| (rng.below(200) as i64 - 100) as i8).collect();

    let chain = CsdChain::new(2, 16); // 32 DSPs = 64 slots ≥ 32-wide group
    let deq = q.dequantize();
    for (gi, g) in groups.iter().enumerate() {
        let mut w8 = g.codes.clone();
        w8.resize(chain.mac_slots(), 0);
        let mut a8 = acts.clone();
        a8.resize(chain.mac_slots(), 0);
        let out = chain.run_dense(&w8, &a8);
        let got = out.outputs[0] as f32 * g.scale;
        let want: f32 = deq[gi * 32..(gi + 1) * 32]
            .iter()
            .zip(&acts)
            .map(|(&wv, &a)| wv * a as f32)
            .sum();
        assert!(
            (got - want).abs() < want.abs().max(1.0) * 1e-4,
            "group {gi}: {got} vs {want}"
        );
    }
}
