//! Integration: the complete mapping flow (Fig. 9) over the full
//! model × platform × stage matrix — IR export → optimization → memory
//! assignment → instruction generation → simulation — asserting the
//! cross-cutting invariants that unit tests can't see.

use flightllm::compiler::{lower, CompilerOptions, VecSink};
use flightllm::config::{CompressionConfig, ModelConfig, Platform, Target};
use flightllm::ir::{assign_addresses, passes, Graph, Stage};
use flightllm::isa::Inst;
use flightllm::sim::Engine;

fn targets() -> Vec<Target> {
    vec![
        Target::u280_llama2(),
        Target::u280_opt(),
        Target::vhk158_llama2(),
        Target::u280_tiny(),
    ]
}

fn pipeline(t: &Target, stage: Stage) -> (Graph, Vec<Inst>) {
    let mut g = Graph::from_model(&t.model, &t.compression, stage);
    passes::optimize(&mut g);
    let mut sink = VecSink::default();
    lower(&g, t, CompilerOptions::full(), &mut sink);
    (g, sink.0)
}

#[test]
fn full_flow_runs_for_every_target_and_stage() {
    for t in targets() {
        for stage in [Stage::Decode { ctx: 256 }, Stage::Prefill { n: 128 }] {
            let (g, insts) = pipeline(&t, stage);
            assert!(!insts.is_empty(), "{}: empty stream", t.model.name);
            // Memory assignment must succeed for compressed models.
            let map = assign_addresses(&g, &t.platform).unwrap_or_else(|e| {
                panic!("{} on {}: {e}", t.model.name, t.platform.name)
            });
            assert!(map.hbm_used > 0);
            // Simulate: finite, positive, utilization bounded.
            let rep = Engine::for_target(&t, true).run(&insts);
            assert!(rep.total_ns.is_finite() && rep.total_ns > 0.0);
            assert!(rep.hbm_bw_util >= 0.0 && rep.hbm_bw_util <= 1.0,
                "bw util {}", rep.hbm_bw_util);
            assert!(rep.compute_eff >= 0.0 && rep.compute_eff <= 1.0,
                "compute eff {}", rep.compute_eff);
        }
    }
}

#[test]
fn decode_time_grows_with_context() {
    let t = Target::u280_llama2();
    let mut last = 0.0;
    for ctx in [128u64, 512, 1024, 2048] {
        let (_, insts) = pipeline(&t, Stage::Decode { ctx });
        let rep = Engine::for_target(&t, true).run(&insts);
        assert!(
            rep.total_ns > last,
            "ctx {ctx}: {} should exceed {last}",
            rep.total_ns
        );
        last = rep.total_ns;
    }
}

#[test]
fn prefill_time_superlinear_in_length() {
    let t = Target::u280_llama2();
    let time = |n| {
        let (_, insts) = pipeline(&t, Stage::Prefill { n });
        Engine::for_target(&t, true).run(&insts).total_ns
    };
    let t256 = time(256);
    let t1024 = time(1024);
    // 4× tokens → > 4× time (attention quadratic term).
    assert!(t1024 > 4.0 * t256, "{t1024} vs {t256}");
}

#[test]
fn compression_reduces_simulated_decode_latency() {
    let base = Target::u280_llama2();
    let time = |c: CompressionConfig| {
        let t = Target { compression: c, ..base.clone() };
        let (_, insts) = pipeline(&t, Stage::Decode { ctx: 512 });
        Engine::for_target(&t, true).run(&insts).total_ns
    };
    // Weights at 8-bit dense vs the full recipe: traffic ratio > 1.5.
    let dense8 = time(CompressionConfig {
        quantization: true,
        weight_bits: 8.0,
        act_bits: 8,
        ..CompressionConfig::none()
    });
    let full = time(CompressionConfig::paper_default());
    assert!(
        dense8 / full > 1.5,
        "compression must speed decode: {dense8} vs {full}"
    );
}

#[test]
fn vhk158_outpaces_u280_on_same_stream_shape() {
    let u = Target::u280_llama2();
    let v = Target::vhk158_llama2();
    let (_, iu) = pipeline(&u, Stage::Decode { ctx: 512 });
    let (_, iv) = pipeline(&v, Stage::Decode { ctx: 512 });
    let ru = Engine::for_target(&u, true).run(&iu);
    let rv = Engine::for_target(&v, true).run(&iv);
    assert!(rv.total_ns < ru.total_ns, "819 GB/s must beat 460 GB/s");
}

#[test]
fn stream_bytes_match_compression_accounting() {
    // Bytes the instruction stream moves ≈ the CompressionConfig's
    // analytic weight footprint (within tile padding slack).
    let t = Target::u280_llama2();
    let (_, insts) = pipeline(&t, Stage::Decode { ctx: 1 });
    let streamed: u64 = insts.iter().map(|i| i.offchip_bytes()).sum();
    let slr = t.platform.slr_count as u64;
    let expect =
        t.compression.model_weight_bytes(t.model.param_count()) / slr as f64;
    let ratio = streamed as f64 / expect;
    assert!(
        (0.8..1.6).contains(&ratio),
        "stream {streamed} vs analytic {expect:.0} (ratio {ratio:.2})"
    );
}

#[test]
fn sync_instructions_present_per_layer() {
    let t = Target::u280_llama2();
    let (_, insts) = pipeline(&t, Stage::Decode { ctx: 128 });
    let syncs = insts
        .iter()
        .filter(|i| matches!(i, Inst::Sys { .. }))
        .count();
    // One SLR barrier per layer + host sync at the end.
    assert!(
        syncs as u64 >= t.model.n_layers,
        "expected ≥{} syncs, got {syncs}",
        t.model.n_layers
    );
}
