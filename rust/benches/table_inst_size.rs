//! §5.2 reproduction: the instruction-storage progression (the paper's
//! 1.67 TB → 4.77 GB → 3.25 GB, ~500×), plus per-stream sizes (the
//! paper's 2.9 MB decode / 282.1 MB prefill per SLR per inference).
//! Run: cargo bench --bench table_inst_size

use flightllm::compiler::{lower, storage_report, CompilerOptions, CountSink};
use flightllm::config::Target;
use flightllm::ir::{passes, Graph, Stage};
use flightllm::metrics::format_table;

fn stream_kib(t: &Target, stage: Stage, opt: CompilerOptions) -> f64 {
    let mut g = Graph::from_model(&t.model, &t.compression, stage);
    passes::optimize(&mut g);
    let mut sink = CountSink::default();
    lower(&g, t, opt, &mut sink);
    sink.bytes() as f64 / 1024.0
}

fn main() {
    let t = Target::u280_llama2();

    // Per-inference stream sizes at max length (paper: decode 2.9 MB,
    // prefill 282.1 MB per SLR, with payload-heavier words than our 16 B).
    let fine = CompilerOptions::storage_fine();
    let dec = stream_kib(&t, Stage::Decode { ctx: 2048 }, fine);
    let pre = stream_kib(&t, Stage::Prefill { n: 2048 }, fine);
    println!("per-SLR stream size @2048: decode {dec:.0} KiB, prefill {:.1} MiB", pre / 1024.0);
    println!("(paper: 2.9 MB decode, 282.1 MB prefill; our words are 16 B vs their payload-heavy encoding — ratios below are the target)\n");

    println!("computing the full storage progression...");
    let r = storage_report(&t);
    let rows = vec![
        vec!["naive: all lengths × 3 SLRs, unmerged".into(),
             format!("{:.2}", r.naive_bytes / 1e9), "1677 (1.67 TB)".into(), "1.0x".into()],
        vec!["+ length-adaptive buckets".into(),
             format!("{:.3}", r.bucketed_bytes / 1e9), "—".into(),
             format!("{:.0}x", r.naive_bytes / r.bucketed_bytes)],
        vec!["+ shared stream across SLRs".into(),
             format!("{:.3}", r.shared_bytes / 1e9), "4.77".into(),
             format!("{:.0}x", r.naive_bytes / r.shared_bytes)],
        vec!["+ merged multi-channel LD/ST".into(),
             format!("{:.3}", r.merged_bytes / 1e9), "3.25".into(),
             format!("{:.0}x", r.total_reduction())],
    ];
    println!(
        "{}",
        format_table(
            "§5.2 instruction storage progression",
            &["rung", "ours (GB)", "paper (GB)", "reduction"],
            &rows
        )
    );
    println!(
        "total reduction {:.0}x (paper ~514x); merge rung {:.2}x (paper 1.47x); \
         final size fits U280 DDR: {}",
        r.total_reduction(),
        r.merge_reduction(),
        r.merged_bytes < 32e9
    );
}
