//! Fig. 15 reproduction: multi-batch decode throughput on LLaMA2-7B —
//! FlightLLM's advantage over GPU-opt shrinks as the batch grows because
//! the GPU's bigger bandwidth/compute pool absorbs batches better.
//!
//! Two FlightLLM columns: the analytic single-stream number
//! (`flightllm_batch_tps`) and the same point pushed through the
//! continuous-batching serving engine over the sim backend
//! (`flightllm_serve_batch_tps`) — scheduling, KV admission and bucket
//! drift included, on the deterministic virtual clock.
//!
//! Run: cargo bench --bench fig15_multibatch

use flightllm::baselines::{GpuStack, GpuSystem};
use flightllm::config::Target;
use flightllm::experiments::{flightllm_batch_tps, flightllm_serve_batch_tps};
use flightllm::metrics::format_table;

fn main() {
    let target = Target::u280_llama2();
    let vhk = Target::vhk158_llama2();
    let ctx = 256u64;
    let decode = 32u32;
    let v100 = GpuSystem::v100s(GpuStack::Opt).model();
    let a100 = GpuSystem::a100(GpuStack::Opt).model();
    let mut rows = Vec::new();
    let mut first_ratio = None;
    let mut last_ratio = None;
    let mut served_tps = Vec::new();
    for batch in [1u32, 2, 4, 8] {
        let fl = flightllm_batch_tps(&target, ctx, batch);
        let served = flightllm_serve_batch_tps(&target, ctx, decode, batch);
        let fv = flightllm_batch_tps(&vhk, ctx, batch);
        let gv = v100.batch_tps(&target.model, ctx, batch);
        let ga = a100.batch_tps(&target.model, ctx, batch);
        let ratio = fl / gv;
        if first_ratio.is_none() {
            first_ratio = Some(ratio);
        }
        last_ratio = Some(ratio);
        served_tps.push(served.decode_tps());
        rows.push(vec![
            format!("{batch}"),
            format!("{:.1}", gv),
            format!("{:.1}", ga),
            format!("{:.1}", fl),
            format!("{:.1}", served.decode_tps()),
            format!("{:.1}", fv),
            format!("{:.2}x", ratio),
        ]);
    }
    println!(
        "{}",
        format_table(
            &format!("Fig. 15: multi-batch decode throughput (tokens/s) — LLaMA2-7B @ctx={ctx}"),
            &["batch", "V100S-opt", "A100-opt", "FL-U280", "FL-served", "FL-VHK158", "U280/V100S"],
            &rows
        )
    );
    println!(
        "FlightLLM advantage over V100S-opt: {:.2}x at batch 1 → {:.2}x at batch 8 \
         (paper: advantage gradually decreases)",
        first_ratio.unwrap(),
        last_ratio.unwrap()
    );
    assert!(
        last_ratio.unwrap() < first_ratio.unwrap(),
        "advantage must shrink with batch"
    );
    assert!(
        served_tps.windows(2).all(|w| w[1] > w[0]),
        "served tokens/s must rise with batch: {served_tps:?}"
    );
}
