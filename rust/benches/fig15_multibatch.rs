//! Fig. 15 reproduction: multi-batch decode throughput on LLaMA2-7B —
//! FlightLLM's advantage over GPU-opt shrinks as the batch grows because
//! the GPU's bigger bandwidth/compute pool absorbs batches better.
//! Run: cargo bench --bench fig15_multibatch

use flightllm::baselines::{GpuStack, GpuSystem};
use flightllm::config::Target;
use flightllm::experiments::flightllm_batch_tps;
use flightllm::metrics::format_table;

fn main() {
    let target = Target::u280_llama2();
    let vhk = Target::vhk158_llama2();
    let ctx = 256u64;
    let v100 = GpuSystem::v100s(GpuStack::Opt).model();
    let a100 = GpuSystem::a100(GpuStack::Opt).model();
    let mut rows = Vec::new();
    let mut first_ratio = None;
    let mut last_ratio = None;
    for batch in [1u32, 2, 4, 8] {
        let fl = flightllm_batch_tps(&target, ctx, batch);
        let fv = flightllm_batch_tps(&vhk, ctx, batch);
        let gv = v100.batch_tps(&target.model, ctx, batch);
        let ga = a100.batch_tps(&target.model, ctx, batch);
        let ratio = fl / gv;
        if first_ratio.is_none() {
            first_ratio = Some(ratio);
        }
        last_ratio = Some(ratio);
        rows.push(vec![
            format!("{batch}"),
            format!("{:.1}", gv),
            format!("{:.1}", ga),
            format!("{:.1}", fl),
            format!("{:.1}", fv),
            format!("{:.2}x", ratio),
        ]);
    }
    println!(
        "{}",
        format_table(
            &format!("Fig. 15: multi-batch decode throughput (tokens/s) — LLaMA2-7B @ctx={ctx}"),
            &["batch", "V100S-opt", "A100-opt", "FL-U280", "FL-VHK158", "U280/V100S"],
            &rows
        )
    );
    println!(
        "FlightLLM advantage over V100S-opt: {:.2}x at batch 1 → {:.2}x at batch 8 \
         (paper: advantage gradually decreases)",
        first_ratio.unwrap(),
        last_ratio.unwrap()
    );
    assert!(
        last_ratio.unwrap() < first_ratio.unwrap(),
        "advantage must shrink with batch"
    );
}
