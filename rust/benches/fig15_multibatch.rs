//! Fig. 15 reproduction: multi-batch decode throughput on LLaMA2-7B —
//! FlightLLM's advantage over GPU-opt shrinks as the batch grows because
//! the GPU's bigger bandwidth/compute pool absorbs batches better.
//!
//! Two FlightLLM columns: the analytic single-stream number
//! (`flightllm_batch_tps`) and the same point pushed through the
//! continuous-batching serving engine over the sim backend
//! (`flightllm_serve_batch_tps`) — scheduling, KV admission and bucket
//! drift included, on the deterministic virtual clock.
//!
//! Run: cargo bench --bench fig15_multibatch

use flightllm::baselines::{GpuStack, GpuSystem};
use flightllm::config::Target;
use flightllm::coordinator::RoutePolicy;
use flightllm::experiments::{
    analyze_stage_pricing, flightllm_batch_tps, flightllm_overload_three_way,
    flightllm_serve_batch_tps, flightllm_serve_chunk_sweep, flightllm_serve_prefix,
    flightllm_serve_sharded, FleetSpec,
};
use flightllm::ir::Stage;
use flightllm::metrics::format_table;
use flightllm::verify::shipped_presets;
use flightllm::workload::{
    generate_overload_trace, generate_shared_prefix_trace, MixedBurstConfig, OverloadConfig,
    SharedPrefixConfig,
};

fn main() {
    let target = Target::u280_llama2();
    let vhk = Target::vhk158_llama2();
    let ctx = 256u64;
    let decode = 32u32;
    let v100 = GpuSystem::v100s(GpuStack::Opt).model();
    let a100 = GpuSystem::a100(GpuStack::Opt).model();
    let mut rows = Vec::new();
    let mut first_ratio = None;
    let mut last_ratio = None;
    let mut served_tps = Vec::new();
    for batch in [1u32, 2, 4, 8] {
        let fl = flightllm_batch_tps(&target, ctx, batch);
        let served = flightllm_serve_batch_tps(&target, ctx, decode, batch);
        let fv = flightllm_batch_tps(&vhk, ctx, batch);
        let gv = v100.batch_tps(&target.model, ctx, batch);
        let ga = a100.batch_tps(&target.model, ctx, batch);
        let ratio = fl / gv;
        if first_ratio.is_none() {
            first_ratio = Some(ratio);
        }
        last_ratio = Some(ratio);
        served_tps.push(served.decode_tps());
        rows.push(vec![
            format!("{batch}"),
            format!("{:.1}", gv),
            format!("{:.1}", ga),
            format!("{:.1}", fl),
            format!("{:.1}", served.decode_tps()),
            format!("{:.1}", fv),
            format!("{:.2}x", ratio),
        ]);
    }
    println!(
        "{}",
        format_table(
            &format!("Fig. 15: multi-batch decode throughput (tokens/s) — LLaMA2-7B @ctx={ctx}"),
            &["batch", "V100S-opt", "A100-opt", "FL-U280", "FL-served", "FL-VHK158", "U280/V100S"],
            &rows
        )
    );
    println!(
        "FlightLLM advantage over V100S-opt: {:.2}x at batch 1 → {:.2}x at batch 8 \
         (paper: advantage gradually decreases)",
        first_ratio.unwrap(),
        last_ratio.unwrap()
    );
    assert!(
        last_ratio.unwrap() < first_ratio.unwrap(),
        "advantage must shrink with batch"
    );
    assert!(
        served_tps.windows(2).all(|w| w[1] > w[0]),
        "served tokens/s must rise with batch: {served_tps:?}"
    );

    // Prefix-cache column: the same shared-prefix trace served cache-off
    // and cache-on per batch size — TTFT and peak-KV savings from CoW
    // page sharing, with identical generated tokens.
    let px_cfg = SharedPrefixConfig { n_requests: 16, rate_per_s: 1e3, ..Default::default() };
    let mut px_rows = Vec::new();
    for batch in [1usize, 2, 4, 8] {
        let off = flightllm_serve_prefix(&target, &px_cfg, batch, false);
        let on = flightllm_serve_prefix(&target, &px_cfg, batch, true);
        for a in &off.results {
            let b = on.results.iter().find(|r| r.id == a.id).unwrap();
            assert_eq!(a.tokens, b.tokens, "caching must not change tokens");
        }
        if batch > 1 {
            assert!(on.prefix_hits > 0, "shared prefixes must hit at batch {batch}");
            assert!(
                on.mean_ttft_s() < off.mean_ttft_s(),
                "cache must cut TTFT at batch {batch}"
            );
        }
        px_rows.push(vec![
            format!("{batch}"),
            format!("{:.0}%", on.prefix_hit_rate() * 100.0),
            format!("{:.1}", off.mean_ttft_s() * 1e3),
            format!("{:.1}", on.mean_ttft_s() * 1e3),
            format!("{}", off.peak_kv_pages),
            format!("{}", on.peak_kv_pages),
        ]);
    }
    println!(
        "{}",
        format_table(
            "Prefix caching on a shared-prefix trace (2 system prompts x 96 tokens)",
            &["batch", "hit rate", "TTFT off (ms)", "TTFT on (ms)", "peak KV off", "peak KV on"],
            &px_rows
        )
    );

    // Chunked prefill on a mixed burst: long prompts land while short
    // requests decode.  The sweep serves the SAME trace per chunk size
    // (0 = unchunked) — tokens stay byte-identical, but capping the
    // per-iteration prefill budget cuts the P99 decode inter-token
    // latency the long prefills were inflating.
    let burst = MixedBurstConfig {
        n_decode_heavy: 4,
        decode_heavy_prompt: 32,
        decode_heavy_tokens: 64,
        n_prefill_heavy: 2,
        prefill_heavy_prompt: 1024,
        prefill_heavy_tokens: 8,
        prefill_stagger_s: 1e-6,
        vocab: 512,
        seed: 12,
    };
    let sweep = flightllm_serve_chunk_sweep(&target, &burst, 8, &[0, 64, 128, 256]);
    let baseline = &sweep[0].1;
    let mut chunk_rows = Vec::new();
    for (chunk, stats) in &sweep {
        for a in &baseline.results {
            let b = stats.results.iter().find(|r| r.id == a.id).unwrap();
            assert_eq!(a.tokens, b.tokens, "chunk {chunk} must not change tokens");
        }
        chunk_rows.push(vec![
            if *chunk == 0 { "off".to_string() } else { format!("{chunk}") },
            format!("{:.2}", stats.p99_itl_s() * 1e3),
            format!("{:.2}", stats.max_itl_s() * 1e3),
            format!("{:.1}", stats.mean_ttft_s() * 1e3),
            format!("{}", stats.steps),
        ]);
    }
    println!(
        "{}",
        format_table(
            "Chunked prefill on a mixed burst (4 decoding + 2x 1024-token prompts)",
            &["chunk", "P99 ITL (ms)", "max ITL (ms)", "mean TTFT (ms)", "steps"],
            &chunk_rows
        )
    );
    for (chunk, stats) in &sweep[1..] {
        assert!(
            stats.p99_itl_s() < baseline.p99_itl_s(),
            "chunk {chunk} must cut P99 ITL: {:.4}ms vs {:.4}ms",
            stats.p99_itl_s() * 1e3,
            baseline.p99_itl_s() * 1e3
        );
    }

    // Swap-to-DDR under overload (§4.4 hybrid placement): the same
    // overload trace served with an over-provisioned pool, a small pool
    // spilling to DDR, and the small pool with legacy truncation.  Swap
    // completes every request byte-identically to the big pool and pays
    // for it in served time; the lossy baseline "wins" time only by
    // dropping requests.
    let ov = OverloadConfig {
        n_requests: 8,
        prompt_len: 32,
        decode_len_choices: vec![48, 64, 96],
        rate_per_s: 1e6, // near-simultaneous arrivals: force residency overlap
        vocab: 512,
        seed: 5,
    };
    let (big, swapped, lossy) = flightllm_overload_three_way(&target, &ov, 4, 64, 14, None);
    let mut swap_rows = Vec::new();
    for (label, stats) in [
        ("big pool (64 pg)", &big),
        ("swap ON (14 pg)", &swapped),
        ("swap OFF (14 pg)", &lossy),
    ] {
        let completed = stats
            .results
            .iter()
            .filter(|r| !r.evicted && !r.cancelled)
            .count();
        swap_rows.push(vec![
            label.to_string(),
            format!("{completed}"),
            format!("{}", stats.preempted_truncated()),
            format!("{}", stats.preemptions),
            format!("{}", stats.swapped_out_pages + stats.swapped_in_pages),
            format!("{:.1}", stats.swap_time_s * 1e3),
            format!("{:.3}", stats.served_s),
        ]);
    }
    println!(
        "{}",
        format_table(
            "Swap-to-DDR under overload (8 requests, batch 4, 32-token prompts)",
            &["pool", "done", "truncated", "preempts", "pages moved", "swap ms", "served s"],
            &swap_rows
        )
    );
    for a in &big.results {
        let b = swapped.results.iter().find(|r| r.id == a.id).unwrap();
        assert_eq!(a.tokens, b.tokens, "swap must preserve request {} tokens", a.id);
    }
    assert_eq!(swapped.preempted_truncated(), 0, "swap must not truncate");
    assert!(swapped.preemptions > 0, "the small pool must preempt");
    assert!(lossy.preempted_truncated() > 0, "the legacy baseline loses requests");
    assert!(
        swapped.served_s > big.served_s,
        "spilling must cost served time: {} vs {}",
        swapped.served_s,
        big.served_s
    );

    // Shard sweep (SLR/board replication): the same overload burst on
    // 1/2/4 boards behind the fleet router.  Token streams stay
    // byte-identical at every shard count; queueing delay converts to
    // parallelism, so P99 TTFT falls as boards are added.
    let fleet_ov = OverloadConfig {
        n_requests: 16,
        prompt_len: 32,
        decode_len_choices: vec![32, 48],
        rate_per_s: 1e6,
        vocab: 512,
        seed: 6,
    };
    let fleet_spec = |shards: usize, route: RoutePolicy, prefix_cache: bool| FleetSpec {
        shards,
        route,
        max_batch: 2,
        kv_pages_per_shard: if prefix_cache { 128 } else { 64 },
        prefix_cache,
        vocab: 512,
        lane_threads: shards,
        global_prefix: false,
        migrate: false,
        affinity_spill: 0,
    };
    let mut shard_rows = Vec::new();
    let mut fleet_p99s = Vec::new();
    // The shards=1 iteration doubles as the token-stream reference for
    // the larger fleets.
    let mut solo_results = Vec::new();
    for shards in [1usize, 2, 4] {
        let (per_shard, fleet, _) = flightllm_serve_sharded(
            &target,
            generate_overload_trace(&fleet_ov),
            &fleet_spec(shards, RoutePolicy::LeastLoaded, false),
        );
        if shards == 1 {
            solo_results = fleet.results.clone();
        }
        for a in &solo_results {
            let b = fleet.results.iter().find(|r| r.id == a.id).unwrap();
            assert_eq!(a.tokens, b.tokens, "{shards} shards must not change tokens");
        }
        let busy = per_shard.iter().filter(|s| !s.results.is_empty()).count();
        fleet_p99s.push(fleet.p99_ttft_s());
        shard_rows.push(vec![
            format!("{shards}"),
            format!("{busy}"),
            format!("{:.1}", fleet.p99_ttft_s() * 1e3),
            format!("{:.1}", fleet.p50_ttft_s() * 1e3),
            format!("{:.1}", fleet.mean_latency_s() * 1e3),
            format!("{:.3}", fleet.served_s),
        ]);
    }
    println!(
        "{}",
        format_table(
            "Fleet shard sweep on the overload burst (16 requests, least-loaded routing)",
            &["shards", "busy", "P99 TTFT (ms)", "P50 TTFT (ms)", "mean lat (ms)", "served s"],
            &shard_rows
        )
    );
    assert!(fleet_p99s[1] < fleet_p99s[0], "2 shards must cut P99 TTFT: {fleet_p99s:?}");
    assert!(fleet_p99s[2] <= fleet_p99s[1], "4 shards must not regress P99 TTFT: {fleet_p99s:?}");

    // Routing policies on a shared-prefix trace with per-shard prefix
    // caches: prefix affinity pins each prefix group to one board, so
    // its hit rate is at least round-robin's cache-scattering.
    let fleet_px = SharedPrefixConfig {
        n_groups: 4,
        prefix_len: 96,
        n_requests: 16,
        rate_per_s: 1e3,
        ..Default::default()
    };
    let mut route_rows = Vec::new();
    let mut hit_rates = Vec::new();
    for route in [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded, RoutePolicy::PrefixAffinity] {
        let (_, fleet, _) = flightllm_serve_sharded(
            &target,
            generate_shared_prefix_trace(&fleet_px),
            &fleet_spec(2, route, true),
        );
        hit_rates.push((route, fleet.prefix_hit_rate()));
        route_rows.push(vec![
            route.label().to_string(),
            format!("{:.0}%", fleet.prefix_hit_rate() * 100.0),
            format!("{:.1}", fleet.mean_ttft_s() * 1e3),
            format!("{:.3}", fleet.served_s),
        ]);
    }
    println!(
        "{}",
        format_table(
            "Routing policies, 2 shards, shared-prefix trace (4 groups x 96 tokens)",
            &["route", "prefix hit rate", "mean TTFT (ms)", "served s"],
            &route_rows
        )
    );
    let rr_rate = hit_rates[0].1;
    let affine_rate = hit_rates[2].1;
    assert!(
        affine_rate >= rr_rate,
        "prefix affinity {affine_rate} must be at least round-robin {rr_rate}"
    );

    // The certified stream optimizer priced through the simulator: per
    // compiler preset, the decode stream before and after dead-load /
    // redundant-reload / removable-sync elimination.  The naive preset's
    // off-chip activation schedule reloads shared input vectors, so its
    // row must save bytes strictly; no row may get slower or move more.
    let mut an_rows = Vec::new();
    let mut any_saved = false;
    for (name, opt) in shipped_presets() {
        let p = analyze_stage_pricing(&target, Stage::Decode { ctx }, opt, true);
        assert!(p.certified, "{name}: optimizer output must certify");
        assert!(
            p.bytes_after <= p.bytes_before,
            "{name}: optimization must not add traffic ({} -> {})",
            p.bytes_before,
            p.bytes_after
        );
        assert!(
            p.ns_after <= p.ns_before + 1e-9,
            "{name}: optimization must not slow the step ({} -> {})",
            p.ns_before,
            p.ns_after
        );
        let saved = p.bytes_before - p.bytes_after;
        if name == "naive" {
            assert!(saved > 0, "the naive preset's redundant reloads must be eliminated");
        }
        any_saved |= saved > 0;
        an_rows.push(vec![
            name.to_string(),
            format!("{}", p.insts_before),
            format!("{}", p.insts_after),
            format!("{:.2}", p.bytes_before as f64 / 1e6),
            format!("{:.2}", p.bytes_after as f64 / 1e6),
            format!("{:.2}", saved as f64 / 1e6),
            format!("{:.1}", p.ns_before / 1e3),
            format!("{:.1}", p.ns_after / 1e3),
        ]);
    }
    println!(
        "{}",
        format_table(
            &format!(
                "Analyze: certified stream optimization — LLaMA2-U280 decode @ctx={ctx}"
            ),
            &[
                "preset",
                "insts",
                "insts'",
                "MB moved",
                "MB moved'",
                "MB saved",
                "step us",
                "step us'",
            ],
            &an_rows
        )
    );
    assert!(any_saved, "the analyze sweep must find and eliminate waste somewhere");
}
