//! Fig. 11 reproduction: end-to-end latency AND decode throughput of
//! FlightLLM (U280, VHK158) vs V100S/A100 (naive + vLLM/SmoothQuant) on
//! OPT-6.7B and LLaMA2-7B over the paper's [prefill, decode] grid.
//!
//! Plain-main bench (criterion is not vendored): prints the figure's
//! series as tables. Run: cargo bench --bench fig11_latency

use flightllm::baselines::{GpuStack, GpuSystem};
use flightllm::config::Target;
use flightllm::experiments::flightllm_full;
use flightllm::metrics::{format_table, geomean, paper_grid};

fn main() {
    for target in [Target::u280_opt(), Target::u280_llama2()] {
        let model = &target.model;
        let vhk = Target { model: model.clone(), ..Target::vhk158_llama2() };
        let mut rows = Vec::new();
        let mut speedups_naive = Vec::new();
        let mut speedups_opt = Vec::new();
        for pt in paper_grid() {
            let fl_u280 = flightllm_full(&target, pt);
            let fl_vhk = flightllm_full(&vhk, pt);
            let vn = GpuSystem::v100s(GpuStack::Naive).model().measure(model, pt);
            let vo = GpuSystem::v100s(GpuStack::Opt).model().measure(model, pt);
            let an = GpuSystem::a100(GpuStack::Naive).model().measure(model, pt);
            let ao = GpuSystem::a100(GpuStack::Opt).model().measure(model, pt);
            speedups_naive.push(vn.latency_s / fl_u280.latency_s);
            speedups_opt.push(vo.latency_s / fl_u280.latency_s);
            rows.push(vec![
                pt.label(),
                format!("{:.2}", vn.latency_s),
                format!("{:.2}", vo.latency_s),
                format!("{:.2}", an.latency_s),
                format!("{:.2}", ao.latency_s),
                format!("{:.2}", fl_u280.latency_s),
                format!("{:.2}", fl_vhk.latency_s),
            ]);
        }
        println!(
            "{}",
            format_table(
                &format!("Fig. 11 (latency, s) — {}", model.name),
                &["[prefill,dec]", "V100S-naive", "V100S-opt", "A100-naive",
                  "A100-opt", "FL-U280", "FL-VHK158"],
                &rows
            )
        );
        println!(
            "geomean speedup of FL-U280: {:.2}x vs V100S-naive (paper 1.5-1.6x), \
             {:.2}x vs V100S-opt (paper 1.2-1.3x)\n",
            geomean(&speedups_naive),
            geomean(&speedups_opt)
        );

        // Decode-throughput half of the figure.
        let mut rows = Vec::new();
        for pt in paper_grid() {
            let fl_u280 = flightllm_full(&target, pt);
            let fl_vhk = flightllm_full(&vhk, pt);
            let vo = GpuSystem::v100s(GpuStack::Opt).model().measure(model, pt);
            let ao = GpuSystem::a100(GpuStack::Opt).model().measure(model, pt);
            rows.push(vec![
                pt.label(),
                format!("{:.1}", vo.decode_tps),
                format!("{:.1}", ao.decode_tps),
                format!("{:.1}", fl_u280.decode_tps),
                format!("{:.1}", fl_vhk.decode_tps),
            ]);
        }
        println!(
            "{}",
            format_table(
                &format!("Fig. 11 (decode throughput, tokens/s) — {}", model.name),
                &["[prefill,dec]", "V100S-opt", "A100-opt", "FL-U280", "FL-VHK158"],
                &rows
            )
        );
    }
}
