//! Fig. 14 reproduction: latency breakdown of FlightLLM — naive U280
//! port → + configurable sparse DSP chain → + always-on-chip decode —
//! normalized against V100S like the paper's plot.
//! Run: cargo bench --bench fig14_breakdown

use flightllm::baselines::{GpuStack, GpuSystem};
use flightllm::config::Target;
use flightllm::experiments::fig14_rungs;
use flightllm::metrics::{format_table, EvalPoint};

fn main() {
    let pt = EvalPoint { prefill: 128, decode: 128 };
    for target in [Target::u280_llama2(), Target::u280_opt()] {
        let model = &target.model;
        let v100 = GpuSystem::v100s(GpuStack::Opt).model().measure(model, pt);
        let rungs = fig14_rungs(&target, pt);
        let naive = rungs[0].1.latency_s;
        let mut rows = vec![vec![
            "V100S-opt (normalization)".to_string(),
            format!("{:.2}", v100.latency_s),
            format!("{:.2}", naive / v100.latency_s),
        ]];
        for (label, m) in &rungs {
            rows.push(vec![
                label.clone(),
                format!("{:.2}", m.latency_s),
                format!("{:.2}", naive / m.latency_s),
            ]);
        }
        println!(
            "{}",
            format_table(
                &format!("Fig. 14 breakdown — {} @ {}", model.name, pt.label()),
                &["configuration", "latency (s)", "speedup vs naive"],
                &rows
            )
        );
        let sparse_gain = rungs[0].1.latency_s / rungs[1].1.latency_s;
        let full_gain = rungs[0].1.latency_s / rungs[2].1.latency_s;
        println!(
            "sparse DSP chain: {sparse_gain:.2}x (paper 1.1-1.2x); \
             + always-on-chip decode: {full_gain:.2}x (paper 1.6-1.7x)\n"
        );
    }
}
