//! Fig. 12 reproduction: FlightLLM vs the SOTA accelerators DFX, CTA and
//! FACT (latency and geomean decode throughput) on OPT-6.7B and
//! LLaMA2-7B. Run: cargo bench --bench fig12_accelerators

use flightllm::baselines::{cta, dfx, fact};
use flightllm::config::Target;
use flightllm::experiments::flightllm_full;
use flightllm::metrics::{format_table, geomean, paper_grid};

fn main() {
    for target in [Target::u280_opt(), Target::u280_llama2()] {
        let model = &target.model;
        let vhk = Target { model: model.clone(), ..Target::vhk158_llama2() };
        let mut rows = Vec::new();
        let mut sp_u280 = Vec::new();
        let mut sp_vhk = Vec::new();
        let mut tp_u280 = Vec::new();
        let mut tp_vhk = Vec::new();
        for pt in paper_grid() {
            let d = dfx().measure(model, pt);
            let c = cta().measure(model, pt);
            let f = fact().measure(model, pt);
            let u = flightllm_full(&target, pt);
            let v = flightllm_full(&vhk, pt);
            sp_u280.push(d.latency_s / u.latency_s);
            sp_vhk.push(d.latency_s / v.latency_s);
            tp_u280.push(u.decode_tps / d.decode_tps);
            tp_vhk.push(v.decode_tps / d.decode_tps);
            rows.push(vec![
                pt.label(),
                format!("{:.2}", d.latency_s),
                format!("{:.2}", c.latency_s),
                format!("{:.2}", f.latency_s),
                format!("{:.2}", u.latency_s),
                format!("{:.2}", v.latency_s),
            ]);
        }
        println!(
            "{}",
            format_table(
                &format!("Fig. 12(a) latency (s) — {}", model.name),
                &["[prefill,dec]", "DFX", "CTA", "FACT", "FL-U280", "FL-VHK158"],
                &rows
            )
        );
        println!(
            "geomean latency speedup vs DFX: U280 {:.2}x (paper 2.7x), VHK158 {:.2}x (paper 4.6x)",
            geomean(&sp_u280),
            geomean(&sp_vhk)
        );
        println!(
            "geomean throughput speedup vs DFX: U280 {:.2}x (paper 2.6x), VHK158 {:.2}x (paper 4.6x)\n",
            geomean(&tp_u280),
            geomean(&tp_vhk)
        );
    }
}
