//! Perf bench for the L3 hot paths (feeds EXPERIMENTS.md §Perf and the
//! `BENCH_sim_hotpath.json` trajectory at the repo root):
//! - simulator instruction throughput (instructions/s through Engine)
//! - compiler lowering throughput (instructions/s generated)
//! - ISA encode/decode throughput
//! - serving-step pricing + sampling throughput: dense `CostTable` +
//!   `Logits::Peak` vs the legacy memoised-HashMap + materialized-row
//!   path (the PR-7 hot-path speedup)
//! - 8-shard fleet over a day-scale diurnal trace, sequential vs
//!   parallel lane ticks (byte-identical streams asserted)
//! - flight-recorder overhead on the serving path: the same trace
//!   served recorder-off vs recorder-on (min-of-N walls, bit-identical
//!   stats asserted; full mode enforces <5% overhead)
//!
//! Run: cargo bench --bench sim_hotpath
//! `SIM_HOTPATH_SMOKE=1` shrinks every rep count so CI can run the
//! whole thing in seconds; the JSON records which mode produced it.

use std::path::Path;
use std::time::Instant;

use flightllm::compiler::{lower, CompilerOptions, CountSink, VecSink};
use flightllm::config::Target;
use flightllm::coordinator::{
    Logits, ModelBackend, RoutePolicy, Sampler, SchedulerConfig, SeqSlot, SeqWork, Server,
    ShardedService, SimBackend,
};
use flightllm::ir::{passes, Graph, Stage};
use flightllm::isa::{decode_stream, encode_stream};
use flightllm::obs::Recorder;
use flightllm::sim::Engine;
use flightllm::util::Json;
use flightllm::workload::{generate_day_trace, generate_trace, DayTraceConfig, TraceConfig};

fn main() {
    let smoke = std::env::var("SIM_HOTPATH_SMOKE").is_ok();
    let t = Target::u280_llama2();
    let mut g = Graph::from_model(&t.model, &t.compression, Stage::Decode { ctx: 1024 });
    passes::optimize(&mut g);
    let mut sink = VecSink::default();
    lower(&g, &t, CompilerOptions::full(), &mut sink);
    let insts = sink.0;
    println!("decode stream: {} instructions", insts.len());

    // --- engine throughput -------------------------------------------
    let reps = if smoke { 20 } else { 200 };
    let t0 = Instant::now();
    let mut total_ns = 0.0;
    for _ in 0..reps {
        let rep = Engine::for_target(&t, true).run(&insts);
        total_ns += rep.total_ns;
    }
    let el = t0.elapsed().as_secs_f64();
    let engine_minst = reps as f64 * insts.len() as f64 / el / 1e6;
    println!(
        "engine: {:.2} M inst/s ({:.1} µs per simulated decode step; sim total {:.3} ms)",
        engine_minst,
        el / reps as f64 * 1e6,
        total_ns / reps as f64 / 1e6,
    );

    // --- lowering throughput -----------------------------------------
    let t0 = Instant::now();
    let reps2 = if smoke { 20 } else { 200 };
    let mut n = 0u64;
    for _ in 0..reps2 {
        let mut c = CountSink::default();
        lower(&g, &t, CompilerOptions::full(), &mut c);
        n += c.count;
    }
    let el = t0.elapsed().as_secs_f64();
    let lowering_minst = n as f64 / el / 1e6;
    println!(
        "lowering: {:.2} M inst/s generated ({:.1} µs per decode stream)",
        lowering_minst,
        el / reps2 as f64 * 1e6
    );

    // --- ISA encode/decode --------------------------------------------
    let bytes = encode_stream(&insts);
    let t0 = Instant::now();
    let reps3 = if smoke { 50 } else { 500 };
    for _ in 0..reps3 {
        let d = decode_stream(&bytes).unwrap();
        assert_eq!(d.len(), insts.len());
    }
    let el = t0.elapsed().as_secs_f64();
    let isa_minst = reps3 as f64 * insts.len() as f64 / el / 1e6;
    let isa_gib = reps3 as f64 * bytes.len() as f64 / el / (1 << 30) as f64;
    println!("isa decode: {isa_minst:.2} M inst/s ({isa_gib:.2} GiB/s)");

    // --- serving-step pricing + sampling ------------------------------
    // One continuous-batching iteration at LLaMA2 scale: price an
    // 8-slot decode batch and greedy-sample every row.  The dense path
    // is a `CostTable` ordinal lookup plus `Logits::Peak` (three
    // scalars); the legacy path hashes into the step-cost memo and
    // materializes each 32K-vocab row dense before the sampler scans
    // it — exactly what the serving loop did before this table existed.
    let vocab = t.model.vocab;
    let slots: Vec<SeqSlot> = (0..8)
        .map(|i| SeqSlot {
            seq: i,
            work: SeqWork::Decode { last: (i * 7 + 3) as i32, pos: 900 + i as i32 },
        })
        .collect();
    let mut sampler = Sampler::greedy();

    let mut dense = SimBackend::new(t.clone()).with_max_batch(8);
    let reps_dense: u64 = if smoke { 2_000 } else { 200_000 };
    let t0 = Instant::now();
    for _ in 0..reps_dense {
        let out = dense.step(&slots).unwrap();
        for l in out.logits.iter().flatten() {
            std::hint::black_box(sampler.sample(l));
        }
    }
    let dense_steps_per_s = reps_dense as f64 / t0.elapsed().as_secs_f64();

    let mut memo = SimBackend::new(t.clone()).without_cost_table();
    let reps_memo: u64 = if smoke { 200 } else { 2_000 };
    let t0 = Instant::now();
    for _ in 0..reps_memo {
        let out = memo.step(&slots).unwrap();
        for l in out.logits.iter().flatten() {
            // Pre-table serving sampled from a dense Vec<f32> row.
            let row = Logits::Dense(l.to_dense());
            std::hint::black_box(sampler.sample(&row));
        }
    }
    let memo_steps_per_s = reps_memo as f64 / t0.elapsed().as_secs_f64();
    let (table_entries, fallback_pricings) = dense.cost_table_stats();
    let step_speedup = dense_steps_per_s / memo_steps_per_s;
    println!(
        "serving step (batch 8, vocab {vocab}): {dense_steps_per_s:.0} steps/s dense table, \
         {memo_steps_per_s:.0} steps/s memo+materialize ({step_speedup:.1}x); \
         {table_entries} table entries, {fallback_pricings} fallback pricings"
    );
    assert_eq!(fallback_pricings, 0, "dense table must cover the bench batch");

    // --- 8-shard fleet over a day-scale diurnal trace -----------------
    // The same trace through the same fleet twice: lane ticks in place
    // (threads=1) and on one worker per lane.  Streams must be
    // byte-identical either way; the JSON records both wall times.
    // (With the sim backend a tick is sub-microsecond, so the parallel
    // number mostly prices thread fan-out overhead — the lanes exist
    // for expensive real backends.)
    let tiny = Target::u280_tiny();
    let day = DayTraceConfig {
        horizon_s: if smoke { 600.0 } else { 86_400.0 },
        base_rate_per_s: 0.2,
        peak_rate_per_s: 2.0,
        prompt_len_choices: vec![16, 32, 64],
        decode_len_choices: vec![16, 32],
        vocab: 64,
        seed: 42,
    };
    let trace = generate_day_trace(&day);
    let shards = 8usize;
    let cfg = SchedulerConfig {
        max_batch: 8,
        kv_pages: 8 * 256,
        page_tokens: 16,
        max_seq: 256,
        ..Default::default()
    };
    let proto = SimBackend::with_vocab(tiny, 64).with_max_batch(8);
    let mut run = |threads: usize| {
        let mut fleet = ShardedService::new(
            shards,
            RoutePolicy::LeastLoaded,
            cfg.clone(),
            Sampler::greedy(),
            |_| proto.clone(),
        )
        .with_lane_threads(threads);
        let t0 = Instant::now();
        let stats = fleet.run_trace(trace.clone()).unwrap();
        (stats, t0.elapsed().as_secs_f64())
    };
    let (seq_stats, seq_wall) = run(1);
    let (par_stats, par_wall) = run(shards);
    assert_eq!(seq_stats.results.len(), par_stats.results.len());
    assert_eq!(
        seq_stats.served_s.to_bits(),
        par_stats.served_s.to_bits(),
        "parallel lanes must serve byte-identically"
    );
    println!(
        "fleet day trace ({} shards, {} requests over {:.0}s): {seq_wall:.2}s sequential, \
         {par_wall:.2}s with one worker per lane; {} engine steps, {:.1}s simulated serving",
        shards,
        trace.len(),
        day.horizon_s,
        par_stats.steps,
        par_stats.served_s,
    );

    // --- flight-recorder overhead on the serving path -----------------
    // The same burst trace through the same Server twice per round:
    // recorder off, then on (bounded ring; every emission only READS
    // engine state).  Min-of-N walls absorb scheduler noise; the stats
    // must come out bit-identical, which is the recorder's contract.
    let rec_target = Target::u280_tiny();
    let rec_trace = generate_trace(&TraceConfig {
        n_requests: if smoke { 64 } else { 512 },
        vocab: 64,
        prompt_len_choices: vec![16, 32, 64],
        decode_len_choices: vec![16, 32],
        rate_per_s: 1e6, // near-simultaneous: the engine loop is the cost
        ..Default::default()
    });
    let rec_cfg = SchedulerConfig {
        max_batch: 8,
        kv_pages: 512,
        page_tokens: 16,
        max_seq: 256,
        ..Default::default()
    };
    let serve_once = |record: bool| {
        let backend = SimBackend::with_vocab(rec_target.clone(), 64).with_max_batch(8);
        let mut server = Server::new(backend, rec_cfg.clone(), Sampler::greedy());
        if record {
            server.set_recorder(Recorder::new());
        }
        let t0 = Instant::now();
        let stats = server.run_trace(rec_trace.clone()).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let events = server.take_event_log().map_or(0, |l| l.events.len());
        (stats, wall, events)
    };
    let rounds = if smoke { 3 } else { 7 };
    let (mut base_wall, mut rec_wall) = (f64::INFINITY, f64::INFINITY);
    let (mut base_stats, mut rec_stats, mut rec_events) = (None, None, 0usize);
    for _ in 0..rounds {
        let (s, w, _) = serve_once(false);
        base_wall = base_wall.min(w);
        base_stats = Some(s);
        let (s, w, e) = serve_once(true);
        rec_wall = rec_wall.min(w);
        rec_stats = Some(s);
        rec_events = e;
    }
    let (base_stats, rec_stats) = (base_stats.unwrap(), rec_stats.unwrap());
    assert_eq!(
        base_stats.served_s.to_bits(),
        rec_stats.served_s.to_bits(),
        "recording must not move the virtual clock"
    );
    assert_eq!(base_stats.steps, rec_stats.steps);
    for (a, b) in base_stats.results.iter().zip(&rec_stats.results) {
        assert_eq!(a.tokens, b.tokens, "recording must not change token streams");
    }
    let recorder_overhead = rec_wall / base_wall;
    println!(
        "recorder overhead ({} requests, {} events): {:.2} ms off, {:.2} ms on \
         ({recorder_overhead:.3}x, min of {rounds} rounds)",
        rec_trace.len(),
        rec_events,
        base_wall * 1e3,
        rec_wall * 1e3,
    );
    if !smoke {
        // Smoke rounds are too short to time honestly; the full bench
        // enforces the acceptance bound.
        assert!(
            recorder_overhead < 1.05,
            "flight recorder must cost <5% on the serving step loop, got {recorder_overhead:.3}x"
        );
    }

    // --- JSON trajectory ----------------------------------------------
    let json = Json::obj(vec![
        ("bench", Json::str("sim_hotpath")),
        ("mode", Json::str(if smoke { "smoke" } else { "full" })),
        ("engine", Json::obj(vec![("m_inst_per_s", Json::num(engine_minst))])),
        ("lowering", Json::obj(vec![("m_inst_per_s", Json::num(lowering_minst))])),
        (
            "isa_decode",
            Json::obj(vec![
                ("m_inst_per_s", Json::num(isa_minst)),
                ("gib_per_s", Json::num(isa_gib)),
            ]),
        ),
        (
            "serving_step",
            Json::obj(vec![
                ("reps", Json::num(reps_dense as f64)),
                ("batch", Json::num(slots.len() as f64)),
                ("vocab", Json::num(vocab as f64)),
                ("dense_steps_per_s", Json::num(dense_steps_per_s)),
                ("memo_steps_per_s", Json::num(memo_steps_per_s)),
                ("speedup", Json::num(step_speedup)),
                ("table_entries", Json::num(table_entries as f64)),
                ("fallback_pricings", Json::num(fallback_pricings as f64)),
            ]),
        ),
        (
            "fleet_day_trace",
            Json::obj(vec![
                ("shards", Json::num(shards as f64)),
                ("requests", Json::num(trace.len() as f64)),
                ("horizon_s", Json::num(day.horizon_s)),
                ("sequential_wall_s", Json::num(seq_wall)),
                ("parallel_wall_s", Json::num(par_wall)),
                ("parallel_speedup", Json::num(seq_wall / par_wall)),
                ("served_s", Json::num(par_stats.served_s)),
                ("steps", Json::num(par_stats.steps as f64)),
            ]),
        ),
        (
            "recorder_overhead",
            Json::obj(vec![
                ("requests", Json::num(rec_trace.len() as f64)),
                ("rounds", Json::num(rounds as f64)),
                ("events", Json::num(rec_events as f64)),
                ("base_wall_s", Json::num(base_wall)),
                ("recorded_wall_s", Json::num(rec_wall)),
                ("overhead_x", Json::num(recorder_overhead)),
            ]),
        ),
    ]);
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("BENCH_sim_hotpath.json");
    std::fs::write(&path, json.to_string_pretty() + "\n").expect("write bench json");
    println!("wrote {}", path.display());
}
