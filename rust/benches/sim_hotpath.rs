//! Perf bench for the L3 hot paths (feeds EXPERIMENTS.md §Perf):
//! - simulator instruction throughput (instructions/s through Engine)
//! - compiler lowering throughput (instructions/s generated)
//! - ISA encode/decode throughput
//! Run: cargo bench --bench sim_hotpath

use std::time::Instant;

use flightllm::compiler::{lower, CompilerOptions, CountSink, VecSink};
use flightllm::config::Target;
use flightllm::ir::{passes, Graph, Stage};
use flightllm::isa::{decode_stream, encode_stream};
use flightllm::sim::Engine;

fn main() {
    let t = Target::u280_llama2();
    let mut g = Graph::from_model(&t.model, &t.compression, Stage::Decode { ctx: 1024 });
    passes::optimize(&mut g);
    let mut sink = VecSink::default();
    lower(&g, &t, CompilerOptions::full(), &mut sink);
    let insts = sink.0;
    println!("decode stream: {} instructions", insts.len());

    // --- engine throughput -------------------------------------------
    let reps = 200;
    let t0 = Instant::now();
    let mut total_ns = 0.0;
    for _ in 0..reps {
        let rep = Engine::for_target(&t, true).run(&insts);
        total_ns += rep.total_ns;
    }
    let el = t0.elapsed().as_secs_f64();
    println!(
        "engine: {:.2} M inst/s ({:.1} µs per simulated decode step; sim total {:.3} ms)",
        reps as f64 * insts.len() as f64 / el / 1e6,
        el / reps as f64 * 1e6,
        total_ns / reps as f64 / 1e6,
    );

    // --- lowering throughput -----------------------------------------
    let t0 = Instant::now();
    let reps2 = 200;
    let mut n = 0u64;
    for _ in 0..reps2 {
        let mut c = CountSink::default();
        lower(&g, &t, CompilerOptions::full(), &mut c);
        n += c.count;
    }
    let el = t0.elapsed().as_secs_f64();
    println!(
        "lowering: {:.2} M inst/s generated ({:.1} µs per decode stream)",
        n as f64 / el / 1e6,
        el / reps2 as f64 * 1e6
    );

    // --- ISA encode/decode --------------------------------------------
    let bytes = encode_stream(&insts);
    let t0 = Instant::now();
    let reps3 = 500;
    for _ in 0..reps3 {
        let d = decode_stream(&bytes).unwrap();
        assert_eq!(d.len(), insts.len());
    }
    let el = t0.elapsed().as_secs_f64();
    println!(
        "isa decode: {:.2} M inst/s ({:.2} GiB/s)",
        reps3 as f64 * insts.len() as f64 / el / 1e6,
        reps3 as f64 * bytes.len() as f64 / el / (1 << 30) as f64
    );
}
