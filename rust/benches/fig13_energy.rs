//! Fig. 13 reproduction: energy efficiency (Token/J) of FlightLLM vs
//! V100S/A100 at naive and opt stacks, plus the Fig. 1 / §6.2.4 cost
//! efficiency summary. Run: cargo bench --bench fig13_energy

use flightllm::baselines::{GpuStack, GpuSystem};
use flightllm::config::Target;
use flightllm::experiments::flightllm_full;
use flightllm::metrics::{format_table, geomean, paper_grid};

fn main() {
    for target in [Target::u280_opt(), Target::u280_llama2()] {
        let model = &target.model;
        let mut rows = Vec::new();
        let mut r_vs = [Vec::new(), Vec::new(), Vec::new(), Vec::new()];
        for pt in paper_grid() {
            let fl = flightllm_full(&target, pt);
            let systems = [
                GpuSystem::v100s(GpuStack::Naive).model().measure(model, pt),
                GpuSystem::v100s(GpuStack::Opt).model().measure(model, pt),
                GpuSystem::a100(GpuStack::Naive).model().measure(model, pt),
                GpuSystem::a100(GpuStack::Opt).model().measure(model, pt),
            ];
            for (i, s) in systems.iter().enumerate() {
                r_vs[i].push(fl.tokens_per_joule() / s.tokens_per_joule());
            }
            rows.push(vec![
                pt.label(),
                format!("{:.3}", systems[0].tokens_per_joule()),
                format!("{:.3}", systems[1].tokens_per_joule()),
                format!("{:.3}", systems[2].tokens_per_joule()),
                format!("{:.3}", systems[3].tokens_per_joule()),
                format!("{:.3}", fl.tokens_per_joule()),
            ]);
        }
        println!(
            "{}",
            format_table(
                &format!("Fig. 13 energy efficiency (Token/J) — {}", model.name),
                &["[prefill,dec]", "V100S-naive", "V100S-opt", "A100-naive",
                  "A100-opt", "FL-U280"],
                &rows
            )
        );
        println!(
            "geomean FL-U280 advantage: {:.1}x vs V100S-naive (paper 6.0-6.7x), \
             {:.1}x vs V100S-opt (paper 5.5-6.0x), {:.1}x vs A100-naive (paper 4.4-4.6x), \
             {:.1}x vs A100-opt (paper 3.8-4.2x)",
            geomean(&r_vs[0]),
            geomean(&r_vs[1]),
            geomean(&r_vs[2]),
            geomean(&r_vs[3])
        );

        // §6.2.4 cost efficiency (Token/s/$).
        let pt = flightllm::metrics::EvalPoint { prefill: 128, decode: 512 };
        let fl = flightllm_full(&target, pt);
        let vo = GpuSystem::v100s(GpuStack::Opt).model().measure(model, pt);
        let ao = GpuSystem::a100(GpuStack::Opt).model().measure(model, pt);
        println!(
            "cost efficiency at {}: {:.2}x vs V100S-opt (paper 1.9-2.3x), {:.2}x vs A100-opt (paper 1.4-1.5x)\n",
            pt.label(),
            fl.tokens_per_s_per_dollar() / vo.tokens_per_s_per_dollar(),
            fl.tokens_per_s_per_dollar() / ao.tokens_per_s_per_dollar()
        );
    }
}
