//! Table 5 reproduction: decode-stage bandwidth utilization across
//! platforms — V100S / A100 (naive + opt) and FlightLLM on U280 /
//! VHK158 — plus the §4.1 claim (35.6% → 65.9% from the always-on-chip
//! decode scheme). Run: cargo bench --bench table5_bandwidth

use flightllm::baselines::{GpuStack, GpuSystem};
use flightllm::config::Target;
use flightllm::experiments::{flightllm_measure, FlightConfig};
use flightllm::metrics::{format_table, EvalPoint};

fn main() {
    let pt = EvalPoint { prefill: 128, decode: 512 };
    let t_u280 = Target::u280_llama2();
    let t_vhk = Target::vhk158_llama2();

    let fl_u280 = flightllm_measure(&t_u280, pt, FlightConfig::Full);
    let fl_vhk = flightllm_measure(&t_vhk, pt, FlightConfig::Full);
    let naive_u280 = flightllm_measure(&t_u280, pt, FlightConfig::Naive);

    let rows = vec![
        vec!["V100S".into(), "None".into(),
             format!("{:.1}%", GpuSystem::v100s(GpuStack::Naive).model().bw_eff * 100.0),
             "42.5%".into()],
        vec!["V100S".into(), "Opt.".into(),
             format!("{:.1}%", GpuSystem::v100s(GpuStack::Opt).model().bw_eff * 100.0),
             "65.5%".into()],
        vec!["A100".into(), "None".into(),
             format!("{:.1}%", GpuSystem::a100(GpuStack::Naive).model().bw_eff * 100.0),
             "28.6%".into()],
        vec!["A100".into(), "Opt.".into(),
             format!("{:.1}%", GpuSystem::a100(GpuStack::Opt).model().bw_eff * 100.0),
             "57.4%".into()],
        vec!["U280".into(), "Ours".into(),
             format!("{:.1}%", fl_u280.bw_util * 100.0), "65.9%".into()],
        vec!["VHK158".into(), "Ours".into(),
             format!("{:.1}%", fl_vhk.bw_util * 100.0), "64.8%".into()],
    ];
    println!(
        "{}",
        format_table(
            "Table 5: decode bandwidth utilization",
            &["platform", "solution", "measured", "paper"],
            &rows
        )
    );
    println!(
        "compiled-schedule ablation on U280: {:.1}% (naive schedule) → {:.1}% (fused)",
        naive_u280.bw_util * 100.0,
        fl_u280.bw_util * 100.0
    );

    // §4.1's 35.6% → 65.9% is about access *granularity*: without fusing
    // the decode ops, every operand is fetched in fine-grained bursts
    // that pay HBM latency per burst. Demonstrate the mechanism at the
    // memory-model level: stream the same 1 GiB per channel-group in
    // 1 KiB bursts (per-op operand fetches) vs 512 KiB tiles (fused
    // weight streaming).
    use flightllm::config::Platform;
    use flightllm::isa::MemSpace;
    use flightllm::sim::MemorySystem;

    let p = Platform::u280();
    let total: u64 = 1 << 30;
    let util_for = |burst: u64| -> f64 {
        let mut mem = MemorySystem::new(p.hbm.clone(), p.ddr.clone());
        let per_ch = total / 32;
        let mut done = 0.0f64;
        for ch in 0..32u8 {
            let mut off = 0;
            while off < per_ch {
                done = done.max(mem.transfer(0.0, MemSpace::Hbm { channel: ch }, burst));
                off += burst;
            }
        }
        mem.hbm_bw_utilization(mem.quiescent())
    };
    let fine = util_for(1 << 10);
    let fused = util_for(1 << 19);
    println!(
        "§4.1 access-granularity mechanism: 1 KiB per-op bursts → {:.1}% vs \
         512 KiB fused streams → {:.1}% (paper: 35.6% → 65.9%)",
        fine * 100.0,
        fused * 100.0
    );
}
