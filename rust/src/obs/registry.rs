//! Counters, gauges and fixed-bucket histograms with Prometheus text
//! exposition.
//!
//! [`MetricsRegistry`] is deliberately tiny: string-keyed maps with
//! deterministic (sorted) iteration so the exposition text is stable
//! across runs. `ServeStats::metrics_registry()` populates one from a
//! finished run and `ServeStats::summary()` reads every number it
//! prints back out of the registry, so the human summary and the
//! `--metrics-out` Prometheus text can never drift apart.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A histogram with fixed upper-bound buckets (Prometheus
/// `le`-style: cumulative on exposition, one overflow bucket).
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    /// Ascending finite upper bounds; an implicit `+Inf` bucket
    /// follows the last.
    bounds: Vec<f64>,
    /// Per-bucket (non-cumulative) counts, `bounds.len() + 1` long.
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

/// Default latency buckets in seconds (1 ms .. 10 s, roughly
/// log-spaced) — fits TTFT, per-request latency and ITL on every
/// shipped target.
pub const LATENCY_BUCKETS_S: &[f64] = &[
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
];

impl Histogram {
    pub fn new(bounds: &[f64]) -> Histogram {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        Histogram {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
            count: 0,
        }
    }

    pub fn observe(&mut self, v: f64) {
        let idx = self.bounds.iter().position(|b| v <= *b).unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Cumulative count at each bound plus the `+Inf` total, in
    /// exposition order.
    fn cumulative(&self) -> Vec<u64> {
        let mut acc = 0u64;
        self.counts
            .iter()
            .map(|c| {
                acc += c;
                acc
            })
            .collect()
    }
}

/// String-keyed metrics store. Counter names should end in `_total`
/// and histogram/gauge names should carry their unit as a suffix
/// (`_seconds`, `_pages`) per Prometheus convention; nothing enforces
/// it, but `ServeStats` follows it.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
    help: BTreeMap<String, String>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Attach `# HELP` text to a metric name (any kind).
    pub fn help(&mut self, name: &str, text: &str) {
        self.help.insert(name.to_string(), text.to_string());
    }

    pub fn counter_add(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    /// Current counter value (0 if never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_set(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Current gauge value (0.0 if never set).
    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges.get(name).copied().unwrap_or(0.0)
    }

    pub fn has_gauge(&self, name: &str) -> bool {
        self.gauges.contains_key(name)
    }

    /// Observe `v` into histogram `name`, creating it with `bounds`
    /// on first touch (later calls reuse the existing buckets).
    pub fn observe(&mut self, name: &str, bounds: &[f64], v: f64) {
        self.histograms
            .entry(name.to_string())
            .or_insert_with(|| Histogram::new(bounds))
            .observe(v);
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// Prometheus text exposition format, version 0.0.4 — ready for
    /// a scrape endpoint or `cli serve --metrics-out`.
    pub fn prometheus_text(&self) -> String {
        fn write_num(out: &mut String, v: f64) {
            if v.is_nan() {
                out.push_str("NaN");
            } else if v == f64::INFINITY {
                out.push_str("+Inf");
            } else if v == f64::NEG_INFINITY {
                out.push_str("-Inf");
            } else {
                let _ = write!(out, "{v}");
            }
        }
        let mut out = String::new();
        for (name, v) in &self.counters {
            if let Some(h) = self.help.get(name) {
                let _ = writeln!(out, "# HELP {name} {h}");
            }
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, v) in &self.gauges {
            if let Some(h) = self.help.get(name) {
                let _ = writeln!(out, "# HELP {name} {h}");
            }
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = write!(out, "{name} ");
            write_num(&mut out, *v);
            out.push('\n');
        }
        for (name, hist) in &self.histograms {
            if let Some(h) = self.help.get(name) {
                let _ = writeln!(out, "# HELP {name} {h}");
            }
            let _ = writeln!(out, "# TYPE {name} histogram");
            let cum = hist.cumulative();
            for (i, c) in cum.iter().enumerate() {
                let _ = write!(out, "{name}_bucket{{le=\"");
                if i < hist.bounds.len() {
                    write_num(&mut out, hist.bounds[i]);
                } else {
                    out.push_str("+Inf");
                }
                let _ = writeln!(out, "\"}} {c}");
            }
            let _ = write!(out, "{name}_sum ");
            write_num(&mut out, hist.sum);
            out.push('\n');
            let _ = writeln!(out, "{name}_count {}", hist.count);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_are_cumulative_with_overflow() {
        let mut h = Histogram::new(&[0.01, 0.1, 1.0]);
        for v in [0.005, 0.005, 0.05, 0.5, 5.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.cumulative(), vec![2, 3, 4, 5]);
        assert!((h.sum() - 5.56).abs() < 1e-9);
        // Boundary value lands in its bucket (le semantics).
        h.observe(0.01);
        assert_eq!(h.cumulative(), vec![3, 4, 5, 6]);
    }

    #[test]
    fn prometheus_text_is_stable_and_well_formed() {
        let mut m = MetricsRegistry::new();
        m.counter_add("flightllm_requests_completed_total", 3);
        m.counter_add("flightllm_requests_completed_total", 1);
        m.help("flightllm_requests_completed_total", "Requests retired normally.");
        m.gauge_set("flightllm_decode_tokens_per_second", 123.5);
        m.observe("flightllm_ttft_seconds", &[0.01, 0.1], 0.05);
        m.observe("flightllm_ttft_seconds", &[0.01, 0.1], 0.2);
        let text = m.prometheus_text();
        let expected = "\
# HELP flightllm_requests_completed_total Requests retired normally.
# TYPE flightllm_requests_completed_total counter
flightllm_requests_completed_total 4
# TYPE flightllm_decode_tokens_per_second gauge
flightllm_decode_tokens_per_second 123.5
# TYPE flightllm_ttft_seconds histogram
flightllm_ttft_seconds_bucket{le=\"0.01\"} 0
flightllm_ttft_seconds_bucket{le=\"0.1\"} 1
flightllm_ttft_seconds_bucket{le=\"+Inf\"} 2
flightllm_ttft_seconds_sum 0.25
flightllm_ttft_seconds_count 2
";
        assert_eq!(text, expected);
        assert_eq!(m.counter("flightllm_requests_completed_total"), 4);
        assert_eq!(m.gauge("missing"), 0.0);
    }

    #[test]
    fn non_finite_gauges_use_prometheus_tokens() {
        let mut m = MetricsRegistry::new();
        m.gauge_set("g_nan", f64::NAN);
        m.gauge_set("g_inf", f64::INFINITY);
        let text = m.prometheus_text();
        assert!(text.contains("g_nan NaN\n"));
        assert!(text.contains("g_inf +Inf\n"));
    }
}
