//! Serving-stack flight recorder (the observability layer).
//!
//! A deterministic, zero-cost-when-disabled event recorder for the
//! continuous-batching serving stack. A [`Recorder`] is a bounded ring
//! of [`Stamped`] events on the serving virtual clock; the engine and
//! scheduler thread an `Option<&Recorder>` through the hot path and
//! emit nothing when it is `None`. Recording NEVER touches the clock,
//! stats, sampler RNG, or any scheduling decision, so served token
//! streams and `ServeStats` are bit-identical with the recorder on or
//! off (pinned by acceptance tests in `experiments` and the
//! `sim_hotpath` bench, which also pins recorder overhead).
//!
//! The ring is bounded ([`Recorder::DEFAULT_CAPACITY`] events) so a
//! long-lived `LiveService` with an always-on recorder stays flat:
//! once full, the oldest events are overwritten and counted in
//! [`EventLog::dropped`].
//!
//! Submodules:
//! - [`perfetto`]: Chrome `trace_events` JSON export — open the file
//!   written by `cli serve --trace-out` in <https://ui.perfetto.dev>.
//!   One track per shard lane with step slices named by phase, async
//!   spans per request lifetime, and counter tracks for KV pages,
//!   queue depth and swap traffic.
//! - [`registry`]: [`MetricsRegistry`] — counters, gauges and
//!   fixed-bucket histograms with Prometheus text exposition
//!   (`cli serve --metrics-out`). `ServeStats::summary()` is rebuilt
//!   on top of it so the printed numbers and the exposition text have
//!   exactly one source.

use std::cell::RefCell;

pub mod perfetto;
pub mod registry;

pub use perfetto::perfetto_trace;
pub use registry::{Histogram, MetricsRegistry};

/// What a serving step spent its time on: pure chunked prefill, pure
/// batched decode, or a mixed iteration with both kinds of slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
    Mixed,
}

impl Phase {
    /// Stable lower-case label (trace slice names, metrics labels).
    pub fn label(self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
            Phase::Mixed => "mixed",
        }
    }
}

/// One typed serving event. Request-lifecycle variants carry the
/// request id; `Step` describes one engine iteration; the rest are
/// lane-level signals.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// Request entered the engine (stamped at its pinned arrival).
    Submitted { id: u64, prompt_len: u32 },
    /// Scheduler admitted the request into the running set;
    /// `cached_tokens` is the prefix-cache hit charged at admission.
    Admitted { id: u64, cached_tokens: u32 },
    /// One prefill chunk `[start, end)` of the prompt finished.
    PrefillChunk { id: u64, start: u32, end: u32 },
    /// Prefill completed and the first output token streamed.
    FirstToken { id: u64 },
    /// KV exhaustion parked the request (swap-to-DDR preemption).
    Preempted { id: u64 },
    /// This lane installed `pages` prefix pages another lane
    /// materialized (fleet prefix directory), priced as inter-board
    /// transfer instead of re-prefilling.
    PrefixAdopted { id: u64, from_lane: u32, pages: u64 },
    /// A parked request migrated across shards (work stealing):
    /// `pages` is the DDR image footprint copied over the inter-board
    /// link.  Recorded on the RECEIVING lane's ring.
    Migrated { id: u64, from_lane: u32, to_lane: u32, pages: u64 },
    /// Pages moved HBM -> DDR since the last swap sample.
    SwapOut { pages: u64 },
    /// Pages moved DDR -> HBM since the last swap sample.
    SwapIn { pages: u64 },
    /// Request completed normally with `tokens` generated.
    Retired { id: u64, tokens: u32 },
    /// Request cancelled mid-flight (or while queued/parked).
    Cancelled { id: u64 },
    /// Request rejected at admission (queue shed).
    Rejected { id: u64 },
    /// Request terminally evicted (KV-truncated, unresumable).
    Evicted { id: u64 },
    /// One engine step: stamped at the step START on the virtual
    /// clock; `step_s` is the priced duration, `kv_pages` /
    /// `queue_depth` are sampled at the step boundary (after
    /// admission and swap-ins, before this step's decode appends).
    Step { lane: u32, phase: Phase, batch: u32, step_s: f64, kv_pages: u32, queue_depth: u32 },
    /// Backend cost-model posture (dense-table coverage) at the end
    /// of a run; emitted by `SimBackend::record_cost_model`.
    CostModel { lane: u32, table_entries: u64, fallback_pricings: u64 },
    /// Engine-level error (live service loop stopped). Headless runs
    /// keep this even though stderr is gone.
    EngineError { detail: String },
}

impl Event {
    /// Stable lower-snake-case kind label (golden-sequence tests,
    /// metrics label values).
    pub fn kind(&self) -> &'static str {
        match self {
            Event::Submitted { .. } => "submitted",
            Event::Admitted { .. } => "admitted",
            Event::PrefillChunk { .. } => "prefill_chunk",
            Event::FirstToken { .. } => "first_token",
            Event::Preempted { .. } => "preempted",
            Event::PrefixAdopted { .. } => "prefix_adopted",
            Event::Migrated { .. } => "migrated",
            Event::SwapOut { .. } => "swap_out",
            Event::SwapIn { .. } => "swap_in",
            Event::Retired { .. } => "retired",
            Event::Cancelled { .. } => "cancelled",
            Event::Rejected { .. } => "rejected",
            Event::Evicted { .. } => "evicted",
            Event::Step { .. } => "step",
            Event::CostModel { .. } => "cost_model",
            Event::EngineError { .. } => "engine_error",
        }
    }
}

/// An event stamped on the serving virtual clock. `seq` is the
/// recorder's monotone emission index (it keeps counting across ring
/// overwrites, so gaps reveal exactly where drops happened).
#[derive(Debug, Clone, PartialEq)]
pub struct Stamped {
    pub t_s: f64,
    pub seq: u64,
    pub event: Event,
}

#[derive(Debug, Default)]
struct Ring {
    buf: Vec<Stamped>,
    /// Overwrite cursor once `buf` reached capacity.
    head: usize,
    next_seq: u64,
    dropped: u64,
    /// Last swap totals seen by [`Recorder::swap_totals`], so swap
    /// events carry per-sample deltas without the engine keeping
    /// recorder-only state.
    last_swap_out: u64,
    last_swap_in: u64,
}

/// Bounded-ring event recorder for one engine lane. Interior-mutable
/// (`&self` recording) so the engine can hand `Option<&Recorder>`
/// down into the scheduler while itself borrowed; single-threaded per
/// lane by construction (each fleet lane owns its recorder, so the
/// scoped lane workers never share one).
#[derive(Debug)]
pub struct Recorder {
    lane: u32,
    capacity: usize,
    inner: RefCell<Ring>,
}

impl Default for Recorder {
    fn default() -> Self {
        Self::new()
    }
}

impl Recorder {
    /// Default ring capacity; at one `Step` + a few lifecycle events
    /// per iteration this is hours of live serving before overwrite.
    pub const DEFAULT_CAPACITY: usize = 65_536;

    pub fn new() -> Self {
        Self::with_capacity(Self::DEFAULT_CAPACITY)
    }

    /// `capacity` is clamped to at least 1 event.
    pub fn with_capacity(capacity: usize) -> Self {
        Recorder {
            lane: 0,
            capacity: capacity.max(1),
            inner: RefCell::new(Ring::default()),
        }
    }

    /// Tag this recorder with a fleet lane index (stamped into `Step`
    /// events and the exported track name).
    pub fn for_lane(mut self, lane: u32) -> Self {
        self.lane = lane;
        self
    }

    pub fn lane(&self) -> u32 {
        self.lane
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Append one event stamped at virtual time `t_s`, overwriting
    /// the oldest event when the ring is full.
    pub fn record(&self, t_s: f64, event: Event) {
        let mut r = self.inner.borrow_mut();
        let seq = r.next_seq;
        r.next_seq += 1;
        let s = Stamped { t_s, seq, event };
        if r.buf.len() < self.capacity {
            r.buf.push(s);
        } else {
            let head = r.head;
            r.buf[head] = s;
            r.head = (head + 1) % self.capacity;
            r.dropped += 1;
        }
    }

    /// Record swap traffic from *cumulative* pool totals: emits
    /// `SwapOut` / `SwapIn` deltas against the last sample and only
    /// when pages actually moved.
    pub fn swap_totals(&self, t_s: f64, out_pages: u64, in_pages: u64) {
        let (d_out, d_in) = {
            let mut r = self.inner.borrow_mut();
            let d_out = out_pages.saturating_sub(r.last_swap_out);
            let d_in = in_pages.saturating_sub(r.last_swap_in);
            r.last_swap_out = out_pages;
            r.last_swap_in = in_pages;
            (d_out, d_in)
        };
        if d_out > 0 {
            self.record(t_s, Event::SwapOut { pages: d_out });
        }
        if d_in > 0 {
            self.record(t_s, Event::SwapIn { pages: d_in });
        }
    }

    /// Events currently held (<= capacity).
    pub fn len(&self) -> usize {
        self.inner.borrow().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events overwritten by ring wrap since the last drain.
    pub fn dropped(&self) -> u64 {
        self.inner.borrow().dropped
    }

    /// Copy the ring out in chronological order without resetting it.
    pub fn snapshot(&self) -> EventLog {
        let r = self.inner.borrow();
        let mut events = Vec::with_capacity(r.buf.len());
        events.extend_from_slice(&r.buf[r.head..]);
        events.extend_from_slice(&r.buf[..r.head]);
        EventLog { lane: self.lane, events, dropped: r.dropped }
    }

    /// Take the ring contents (chronological order) and reset the
    /// recorder for reuse; swap-delta memory survives so a drained
    /// live recorder keeps emitting correct deltas.
    pub fn drain(&self) -> EventLog {
        let log = self.snapshot();
        let mut r = self.inner.borrow_mut();
        r.buf.clear();
        r.head = 0;
        r.dropped = 0;
        log
    }
}

/// A drained (or snapshotted) event ring from one lane.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EventLog {
    pub lane: u32,
    pub events: Vec<Stamped>,
    pub dropped: u64,
}

impl EventLog {
    /// Kind labels in order — the golden-sequence test fixture.
    pub fn kinds(&self) -> Vec<&'static str> {
        self.events.iter().map(|s| s.event.kind()).collect()
    }

    /// Count of events of one kind (by label).
    pub fn count(&self, kind: &str) -> usize {
        self.events.iter().filter(|s| s.event.kind() == kind).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_bounded_and_chronological() {
        let r = Recorder::with_capacity(4);
        for i in 0..10u64 {
            r.record(i as f64, Event::Submitted { id: i, prompt_len: 1 });
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.dropped(), 6);
        let log = r.snapshot();
        let seqs: Vec<u64> = log.events.iter().map(|s| s.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9], "oldest overwritten, order preserved");
        // Drain resets the ring but keeps counting seq.
        let drained = r.drain();
        assert_eq!(drained.events.len(), 4);
        assert_eq!(r.len(), 0);
        assert_eq!(r.dropped(), 0);
        r.record(10.0, Event::FirstToken { id: 0 });
        assert_eq!(r.snapshot().events[0].seq, 10);
    }

    #[test]
    fn swap_totals_emit_deltas_only_when_pages_move() {
        let r = Recorder::new();
        r.swap_totals(0.0, 0, 0);
        assert!(r.is_empty(), "no traffic, no events");
        r.swap_totals(1.0, 8, 0);
        r.swap_totals(2.0, 8, 0);
        r.swap_totals(3.0, 12, 8);
        let log = r.drain();
        assert_eq!(
            log.kinds(),
            vec!["swap_out", "swap_out", "swap_in"],
            "one event per direction per sample with movement"
        );
        assert_eq!(log.events[0].event, Event::SwapOut { pages: 8 });
        assert_eq!(log.events[1].event, Event::SwapOut { pages: 4 });
        assert_eq!(log.events[2].event, Event::SwapIn { pages: 8 });
        // Delta memory survives the drain.
        r.swap_totals(4.0, 13, 8);
        assert_eq!(r.snapshot().events[0].event, Event::SwapOut { pages: 1 });
    }

    #[test]
    fn kind_labels_are_stable() {
        let ev = Event::Step {
            lane: 0,
            phase: Phase::Mixed,
            batch: 2,
            step_s: 1e-3,
            kv_pages: 4,
            queue_depth: 1,
        };
        assert_eq!(ev.kind(), "step");
        assert_eq!(Phase::Prefill.label(), "prefill");
        assert_eq!(Event::EngineError { detail: "x".into() }.kind(), "engine_error");
        assert_eq!(
            Event::PrefixAdopted { id: 1, from_lane: 0, pages: 2 }.kind(),
            "prefix_adopted"
        );
        assert_eq!(
            Event::Migrated { id: 1, from_lane: 0, to_lane: 1, pages: 3 }.kind(),
            "migrated"
        );
    }
}
