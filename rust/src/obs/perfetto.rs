//! Chrome `trace_events` JSON export for drained [`EventLog`]s.
//!
//! The output is the classic Chrome/Perfetto JSON trace format: drop
//! the file written by `cli serve --trace-out` onto
//! <https://ui.perfetto.dev> (or `chrome://tracing`). Layout:
//!
//! - one *thread* track per shard lane (`pid` 0, `tid` = lane index)
//!   carrying complete `ph:"X"` slices per engine step, named by
//!   phase (`prefill` / `decode` / `mixed`) with batch size and KV
//!   pages in `args`;
//! - one *async* span (`ph:"b"` / `ph:"e"`, category `request`) per
//!   request lifetime from `Submitted` to its terminal event, with
//!   async-instant (`ph:"n"`) marks for admission, prefill chunks,
//!   first token, preemption, prefix adoption (fleet directory) and
//!   cross-shard migration;
//! - counter tracks (`ph:"C"`) per lane for live KV pages, queue
//!   depth, cumulative swapped-out/in pages, and cumulative migrated
//!   pages on lanes that receive migrated requests.
//!
//! Timestamps are the serving virtual clock converted to
//! microseconds (the unit the trace format requires).

use crate::util::Json;

use super::{Event, EventLog};

/// Microseconds timestamp for the trace format.
fn us(t_s: f64) -> Json {
    Json::num(t_s * 1e6)
}

fn counter(tid: u32, name: &str, t_s: f64, value: f64) -> Json {
    Json::obj(vec![
        ("ph", Json::str("C")),
        ("name", Json::str(format!("lane{tid} {name}"))),
        ("pid", Json::num(0.0)),
        ("tid", Json::num(tid as f64)),
        ("ts", us(t_s)),
        ("args", Json::obj(vec![(name, Json::num(value))])),
    ])
}

fn async_event(ph: &str, id: u64, name: &str, t_s: f64, args: Option<Json>) -> Json {
    let mut pairs = vec![
        ("ph", Json::str(ph)),
        ("cat", Json::str("request")),
        ("id", Json::str(format!("{id}"))),
        ("name", Json::str(name)),
        ("pid", Json::num(0.0)),
        ("ts", us(t_s)),
    ];
    if let Some(a) = args {
        pairs.push(("args", a));
    }
    Json::obj(pairs)
}

/// Build the full trace document from per-lane event logs.
///
/// Pass one log per lane (a single-engine run is just one log with
/// lane 0). The result serializes with `Json::to_string_pretty` and
/// needs nothing but `util::json` — no serde.
pub fn perfetto_trace(logs: &[EventLog]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    let mut total_dropped = 0u64;
    for log in logs {
        let tid = log.lane;
        total_dropped += log.dropped;
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("thread_name")),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(tid as f64)),
            ("args", Json::obj(vec![("name", Json::str(format!("shard lane {tid}")))])),
        ]));
        let mut swap_out_total = 0u64;
        let mut swap_in_total = 0u64;
        let mut migrated_total = 0u64;
        for s in &log.events {
            match &s.event {
                Event::Step { lane, phase, batch, step_s, kv_pages, queue_depth } => {
                    events.push(Json::obj(vec![
                        ("ph", Json::str("X")),
                        ("name", Json::str(phase.label())),
                        ("cat", Json::str("step")),
                        ("pid", Json::num(0.0)),
                        ("tid", Json::num(*lane as f64)),
                        ("ts", us(s.t_s)),
                        ("dur", Json::num(step_s * 1e6)),
                        (
                            "args",
                            Json::obj(vec![
                                ("batch", Json::num(*batch as f64)),
                                ("kv_pages", Json::num(*kv_pages as f64)),
                                ("queue_depth", Json::num(*queue_depth as f64)),
                            ]),
                        ),
                    ]));
                    // Counters are sampled after the step completes.
                    let t_end = s.t_s + step_s;
                    events.push(counter(tid, "kv_pages", t_end, *kv_pages as f64));
                    events.push(counter(tid, "queue_depth", t_end, *queue_depth as f64));
                }
                Event::Submitted { id, prompt_len } => {
                    events.push(async_event(
                        "b",
                        *id,
                        "request",
                        s.t_s,
                        Some(Json::obj(vec![("prompt_len", Json::num(*prompt_len as f64))])),
                    ));
                }
                Event::Admitted { id, cached_tokens } => {
                    events.push(async_event(
                        "n",
                        *id,
                        "admitted",
                        s.t_s,
                        Some(Json::obj(vec![(
                            "cached_tokens",
                            Json::num(*cached_tokens as f64),
                        )])),
                    ));
                }
                Event::PrefillChunk { id, start, end } => {
                    events.push(async_event(
                        "n",
                        *id,
                        "prefill_chunk",
                        s.t_s,
                        Some(Json::obj(vec![
                            ("start", Json::num(*start as f64)),
                            ("end", Json::num(*end as f64)),
                        ])),
                    ));
                }
                Event::FirstToken { id } => {
                    events.push(async_event("n", *id, "first_token", s.t_s, None));
                }
                Event::Preempted { id } => {
                    events.push(async_event("n", *id, "preempted", s.t_s, None));
                }
                Event::PrefixAdopted { id, from_lane, pages } => {
                    events.push(async_event(
                        "n",
                        *id,
                        "prefix_adopted",
                        s.t_s,
                        Some(Json::obj(vec![
                            ("from_lane", Json::num(*from_lane as f64)),
                            ("pages", Json::num(*pages as f64)),
                        ])),
                    ));
                }
                Event::Migrated { id, from_lane, to_lane, pages } => {
                    events.push(async_event(
                        "n",
                        *id,
                        "migrated",
                        s.t_s,
                        Some(Json::obj(vec![
                            ("from_lane", Json::num(*from_lane as f64)),
                            ("to_lane", Json::num(*to_lane as f64)),
                            ("pages", Json::num(*pages as f64)),
                        ])),
                    ));
                    migrated_total += pages;
                    events.push(counter(tid, "migrated_pages", s.t_s, migrated_total as f64));
                }
                Event::Retired { id, tokens } => {
                    events.push(async_event(
                        "e",
                        *id,
                        "request",
                        s.t_s,
                        Some(Json::obj(vec![("tokens", Json::num(*tokens as f64))])),
                    ));
                }
                Event::Cancelled { id } => {
                    events.push(async_event(
                        "e",
                        *id,
                        "request",
                        s.t_s,
                        Some(Json::obj(vec![("outcome", Json::str("cancelled"))])),
                    ));
                }
                Event::Rejected { id } => {
                    // A rejected request never opened a span; emit a
                    // zero-length one so it is still visible.
                    events.push(async_event("b", *id, "request", s.t_s, None));
                    events.push(async_event(
                        "e",
                        *id,
                        "request",
                        s.t_s,
                        Some(Json::obj(vec![("outcome", Json::str("rejected"))])),
                    ));
                }
                Event::Evicted { id } => {
                    events.push(async_event(
                        "e",
                        *id,
                        "request",
                        s.t_s,
                        Some(Json::obj(vec![("outcome", Json::str("evicted"))])),
                    ));
                }
                Event::SwapOut { pages } => {
                    swap_out_total += pages;
                    events.push(counter(tid, "swapped_out_pages", s.t_s, swap_out_total as f64));
                }
                Event::SwapIn { pages } => {
                    swap_in_total += pages;
                    events.push(counter(tid, "swapped_in_pages", s.t_s, swap_in_total as f64));
                }
                Event::CostModel { lane, table_entries, fallback_pricings } => {
                    events.push(Json::obj(vec![
                        ("ph", Json::str("i")),
                        ("s", Json::str("g")),
                        ("name", Json::str("cost_model")),
                        ("cat", Json::str("backend")),
                        ("pid", Json::num(0.0)),
                        ("tid", Json::num(*lane as f64)),
                        ("ts", us(s.t_s)),
                        (
                            "args",
                            Json::obj(vec![
                                ("table_entries", Json::num(*table_entries as f64)),
                                ("fallback_pricings", Json::num(*fallback_pricings as f64)),
                            ]),
                        ),
                    ]));
                }
                Event::EngineError { detail } => {
                    events.push(Json::obj(vec![
                        ("ph", Json::str("i")),
                        ("s", Json::str("g")),
                        ("name", Json::str("engine_error")),
                        ("cat", Json::str("error")),
                        ("pid", Json::num(0.0)),
                        ("tid", Json::num(tid as f64)),
                        ("ts", us(s.t_s)),
                        ("args", Json::obj(vec![("detail", Json::str(detail.clone()))])),
                    ]));
                }
            }
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
        (
            "otherData",
            Json::obj(vec![
                ("generator", Json::str("flightllm obs::perfetto")),
                ("lanes", Json::num(logs.len() as f64)),
                ("dropped_events", Json::num(total_dropped as f64)),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::super::{Phase, Recorder};
    use super::*;

    fn sample_log() -> EventLog {
        let r = Recorder::new().for_lane(1);
        r.record(0.0, Event::Submitted { id: 7, prompt_len: 16 });
        r.record(0.0, Event::Admitted { id: 7, cached_tokens: 0 });
        r.record(0.0, Event::Step {
            lane: 1,
            phase: Phase::Prefill,
            batch: 1,
            step_s: 2e-3,
            kv_pages: 1,
            queue_depth: 0,
        });
        r.record(2e-3, Event::PrefillChunk { id: 7, start: 0, end: 16 });
        r.record(2e-3, Event::FirstToken { id: 7 });
        r.swap_totals(3e-3, 4, 2);
        r.record(4e-3, Event::Retired { id: 7, tokens: 3 });
        r.drain()
    }

    #[test]
    fn trace_round_trips_through_util_json() {
        let doc = perfetto_trace(&[sample_log()]);
        let text = doc.to_string_pretty();
        let back = Json::parse(&text).expect("trace JSON parses");
        let evs = back.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
        assert!(!evs.is_empty());
        // Every event carries the required keys.
        for e in evs {
            assert!(e.get("ph").and_then(Json::as_str).is_some(), "ph on {e:?}");
            assert!(e.get("pid").is_some());
        }
        assert_eq!(back.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
    }

    #[test]
    fn request_spans_balance_and_counters_accumulate() {
        let doc = perfetto_trace(&[sample_log()]);
        let evs = match doc.get("traceEvents") {
            Some(Json::Arr(v)) => v.clone(),
            other => panic!("traceEvents missing: {other:?}"),
        };
        let ph = |p: &str| {
            evs.iter()
                .filter(|e| e.get("ph").and_then(Json::as_str) == Some(p))
                .count()
        };
        assert_eq!(ph("b"), 1, "one request span opened");
        assert_eq!(ph("e"), 1, "one request span closed");
        assert_eq!(ph("X"), 1, "one step slice");
        // kv_pages + queue_depth after the step, one per swap direction.
        assert_eq!(ph("C"), 4);
        let slice = evs
            .iter()
            .find(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .unwrap();
        assert_eq!(slice.get("name").and_then(Json::as_str), Some("prefill"));
        assert_eq!(slice.get("dur").and_then(Json::as_f64), Some(2e-3 * 1e6));
        assert_eq!(slice.get("tid").and_then(Json::as_f64), Some(1.0));
    }

    /// Adoption and migration render as instant markers on the request
    /// span, and migrated pages accumulate on a per-lane counter track.
    #[test]
    fn adoption_and_migration_render_markers_and_counter() {
        let r = Recorder::new().for_lane(1);
        r.record(0.0, Event::Submitted { id: 9, prompt_len: 48 });
        r.record(1e-3, Event::PrefixAdopted { id: 9, from_lane: 0, pages: 2 });
        r.record(2e-3, Event::Migrated { id: 9, from_lane: 0, to_lane: 1, pages: 3 });
        r.record(3e-3, Event::Migrated { id: 9, from_lane: 2, to_lane: 1, pages: 4 });
        r.record(4e-3, Event::Retired { id: 9, tokens: 5 });
        let doc = perfetto_trace(&[r.drain()]);
        let evs = match doc.get("traceEvents") {
            Some(Json::Arr(v)) => v.clone(),
            other => panic!("traceEvents missing: {other:?}"),
        };
        let named = |n: &str| {
            evs.iter()
                .filter(|e| e.get("name").and_then(Json::as_str) == Some(n))
                .cloned()
                .collect::<Vec<_>>()
        };
        let adopted = named("prefix_adopted");
        assert_eq!(adopted.len(), 1);
        assert_eq!(adopted[0].get("ph").and_then(Json::as_str), Some("n"));
        let args = adopted[0].get("args").expect("adoption args");
        assert_eq!(args.get("from_lane").and_then(Json::as_f64), Some(0.0));
        assert_eq!(args.get("pages").and_then(Json::as_f64), Some(2.0));
        let migrated = named("migrated");
        assert_eq!(migrated.len(), 2, "one marker per migration");
        assert!(migrated
            .iter()
            .all(|e| e.get("ph").and_then(Json::as_str) == Some("n")));
        assert_eq!(
            migrated[1].get("args").and_then(|a| a.get("to_lane")).and_then(Json::as_f64),
            Some(1.0)
        );
        let counters = named("lane1 migrated_pages");
        assert_eq!(counters.len(), 2, "one counter sample per migration");
        let values: Vec<f64> = counters
            .iter()
            .map(|e| {
                e.get("args")
                    .and_then(|a| a.get("migrated_pages"))
                    .and_then(Json::as_f64)
                    .unwrap()
            })
            .collect();
        assert_eq!(values, vec![3.0, 7.0], "counter is cumulative");
    }
}
