//! Accelerator organization + the §5.3 analytical RTL-generation model.
//!
//! The RTL generator sizes the design for a given board from these
//! parameters; `ResourceEstimate` reproduces Table 3's utilization
//! numbers from the paper's closed-form expressions:
//!
//!   DSP  = (p_m · p_k · p_n) · MPU · MPE
//!   URAM = (p_m · p_k · act_width / uram_width) · MPU · MPE
//!   BRAM = (weight_buf + global_buf + index_buf) · MPE
//!   BW   = (MPU/8 + 2) · MPE · 14.4 GB/s


use super::platform::{OnChipBudget, Platform};

#[derive(Debug, Clone)]
pub struct AcceleratorConfig {
    /// Computing cores — one per SLR (§6.1 implementation).
    pub mpe: u32,
    /// Matrix Processing Units per MPE.
    pub mpu_per_mpe: u32,
    /// Computational parallelism of one MPU (§3.2.2): p_m × p_k × p_n.
    pub p_m: u32,
    pub p_k: u32,
    pub p_n: u32,
    /// DSP48s per DSP-group on the CSD-chain (paper Fig. 5(d): 2, each
    /// packing two INT8 MACs).
    pub dsp_per_group: u32,
    /// Activation datapath width in bits (INT8 after dequant).
    pub act_width_bits: u32,
    /// Per-MPE buffer sizing in BRAM36 blocks.
    pub weight_buf_bram: u32,
    pub global_buf_bram: u32,
    pub index_buf_bram: u32,
    /// SFU count per core and its DSP cost (Table 3: 201 DSP total).
    pub sfu_dsp: u32,
    /// On-chip activation buffer capacity per core, KiB (URAM-backed).
    pub act_buffer_kib: u32,
}

impl AcceleratorConfig {
    /// The U280 build of Table 3: 3 SLR cores, 6144 MPE DSPs.
    pub fn for_u280() -> Self {
        Self {
            mpe: 3,
            mpu_per_mpe: 8,
            p_m: 8,
            p_k: 32,
            p_n: 1,
            dsp_per_group: 2,
            act_width_bits: 8,
            weight_buf_bram: 192,
            global_buf_bram: 64,
            index_buf_bram: 16,
            sfu_dsp: 201,
            act_buffer_kib: 2048,
        }
    }

    /// VHK158: 2 cores, same MPU shape, more bandwidth per channel.
    pub fn for_vhk158() -> Self {
        Self { mpe: 2, mpu_per_mpe: 12, ..Self::for_u280() }
    }

    /// Per-core buffer capacities implied by this organization — must
    /// agree with the platform's `OnChipBudget` (BRAM36 = 4 KiB usable).
    pub fn onchip_budget(&self) -> OnChipBudget {
        OnChipBudget {
            weight_bytes: self.weight_buf_bram as u64 * 4096,
            activation_bytes: self.act_buffer_kib as u64 * 1024,
            global_bytes: self.global_buf_bram as u64 * 4096,
            index_bytes: self.index_buf_bram as u64 * 4096,
        }
    }

    /// MACs per cycle of the whole accelerator in dense mode.
    /// Each DSP48 packs two INT8 MACs (wp486), so this is 2× DSP count.
    pub fn macs_per_cycle(&self) -> u64 {
        2 * self.dsp_total()
    }

    /// §5.3: DSP = (p_m·p_k·p_n)·MPU·MPE (+ SFU).
    pub fn dsp_total(&self) -> u64 {
        (self.p_m as u64) * (self.p_k as u64) * (self.p_n as u64)
            * (self.mpu_per_mpe as u64)
            * (self.mpe as u64)
    }

    /// §5.3 URAM estimate. URAM datapath width is 72 bits; +4 blocks per
    /// MPU cover the double-buffer margin the implementation uses.
    pub fn uram_total(&self) -> u64 {
        let per_mpu = (self.p_m as u64 * self.p_k as u64
            * self.act_width_bits as u64)
            .div_ceil(72)
            + 4;
        per_mpu * self.mpu_per_mpe as u64 * self.mpe as u64
    }

    /// §5.3 BRAM estimate.
    pub fn bram_total(&self) -> u64 {
        (self.weight_buf_bram as u64
            + self.global_buf_bram as u64
            + self.index_buf_bram as u64)
            * self.mpe as u64
    }

    /// §5.3 theoretical peak HBM bandwidth of the design's AXI ports:
    /// (MPU/8 + 2) · MPE · 14.4 GB/s — each A/global buffer bundle drives
    /// 8 pseudo-channels of 14.4 GB/s (paper formula, verbatim).  The
    /// simulator's memory model uses Platform.hbm instead; this estimate
    /// only feeds the RTL-generator report.
    pub fn peak_bandwidth_gbs(&self) -> f64 {
        (self.mpu_per_mpe as f64 / 8.0 + 2.0) * self.mpe as f64 * 14.4 * 8.0
    }

    /// Peak INT8 throughput at `freq_mhz`, TOPS.
    pub fn peak_tops(&self, freq_mhz: f64) -> f64 {
        self.macs_per_cycle() as f64 * 2.0 * freq_mhz * 1e6 / 1e12
    }

    pub fn resources(&self) -> ResourceEstimate {
        // Fixed-function blocks calibrated from the Table 3 implementation
        // report: controller, interconnect, buffer and SFU fabric costs.
        const LUT_CTRL: u64 = 162_000;
        const LUT_ICN: u64 = 150_000;
        const LUT_BUF: u64 = 42_000;
        const LUT_SFU: u64 = 30_000;
        const FF_CTRL: u64 = 156_000;
        const FF_ICN: u64 = 316_000;
        const FF_BUF: u64 = 75_000;
        const FF_SFU: u64 = 36_000;
        ResourceEstimate {
            dsp: self.dsp_total() + self.sfu_dsp as u64,
            bram: self.bram_total()
                + 24  /* SFU tables */
                + 408 /* controller */
                + 4   /* interconnect */,
            uram: self.uram_total(),
            // MPE fabric cost per DSP from the report: ~31 LUT, ~59 FF.
            lut: self.dsp_total() * 31 + LUT_CTRL + LUT_ICN + LUT_BUF + LUT_SFU,
            ff: self.dsp_total() * 59 + FF_CTRL + FF_ICN + FF_BUF + FF_SFU,
        }
    }

    /// Check the build fits the board; returns utilization fractions.
    pub fn utilization(&self, p: &Platform) -> ResourceUtilization {
        let r = self.resources();
        ResourceUtilization {
            dsp: r.dsp as f64 / p.dsp_total as f64,
            bram: r.bram as f64 / p.bram36_total as f64,
            uram: r.uram as f64 / p.uram_total as f64,
            lut: r.lut as f64 / p.lut_total as f64,
            ff: r.ff as f64 / p.ff_total as f64,
        }
    }
}

/// Absolute resource usage (Table 3 rows).
#[derive(Debug, Clone, Copy)]
pub struct ResourceEstimate {
    pub dsp: u64,
    pub bram: u64,
    pub uram: u64,
    pub lut: u64,
    pub ff: u64,
}

/// Fractional board utilization (Table 3 percentages).
#[derive(Debug, Clone, Copy)]
pub struct ResourceUtilization {
    pub dsp: f64,
    pub bram: f64,
    pub uram: f64,
    pub lut: f64,
    pub ff: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u280_dsp_matches_table3() {
        let a = AcceleratorConfig::for_u280();
        // Table 3: MPE = 6144 DSPs, total 6345 with SFU.
        assert_eq!(a.dsp_total(), 6144);
        assert_eq!(a.resources().dsp, 6345);
    }

    #[test]
    fn u280_utilization_matches_table3() {
        let a = AcceleratorConfig::for_u280();
        let p = Platform::u280();
        let u = a.utilization(&p);
        // Table 3 totals: DSP 70.2%, BRAM 62.1%, URAM 82.5%, LUT 44%, FF 36%.
        assert!((u.dsp - 0.702).abs() < 0.01, "dsp {:.3}", u.dsp);
        assert!((u.bram - 0.621).abs() < 0.05, "bram {:.3}", u.bram);
        assert!((u.uram - 0.825).abs() < 0.06, "uram {:.3}", u.uram);
        assert!((u.lut - 0.44).abs() < 0.05, "lut {:.3}", u.lut);
        assert!((u.ff - 0.362).abs() < 0.05, "ff {:.3}", u.ff);
    }

    #[test]
    fn peak_tops_is_about_25() {
        // Fig. 14 discussion: U280 peak ≈ 25 TOPS (vs V100S 130 TOPS).
        let a = AcceleratorConfig::for_u280();
        let tops = a.peak_tops(225.0);
        assert!(tops > 4.0 && tops < 30.0, "tops = {tops}");
    }

    #[test]
    fn onchip_budget_matches_platform_presets() {
        assert_eq!(AcceleratorConfig::for_u280().onchip_budget(), Platform::u280().onchip);
        assert_eq!(
            AcceleratorConfig::for_vhk158().onchip_budget(),
            Platform::vhk158().onchip
        );
    }

    #[test]
    fn fits_on_board() {
        let a = AcceleratorConfig::for_u280();
        let p = Platform::u280();
        let u = a.utilization(&p);
        for f in [u.dsp, u.bram, u.uram, u.lut, u.ff] {
            assert!(f < 1.0, "over budget: {f}");
        }
    }
}
