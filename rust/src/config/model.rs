//! LLM architecture descriptions. The 7B-scale configs drive the compiler
//! and simulator analytically (shapes only — weights never materialize);
//! the tiny config matches the runnable python/compile model exactly.


/// Feed-forward network flavor: OPT uses a 2-matrix ReLU FFN, LLaMA a
/// 3-matrix SwiGLU (gate/up/down).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FfnKind {
    Relu2,
    SwiGlu3,
}

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: u64,
    pub dim: u64,
    pub n_layers: u64,
    pub n_heads: u64,
    pub ffn_dim: u64,
    pub max_seq: u64,
    pub ffn: FfnKind,
}

impl ModelConfig {
    pub fn llama2_7b() -> Self {
        Self {
            name: "LLaMA2-7B".into(),
            vocab: 32000,
            dim: 4096,
            n_layers: 32,
            n_heads: 32,
            ffn_dim: 11008,
            max_seq: 2048,
            ffn: FfnKind::SwiGlu3,
        }
    }

    pub fn opt_6_7b() -> Self {
        Self {
            name: "OPT-6.7B".into(),
            vocab: 50272,
            dim: 4096,
            n_layers: 32,
            n_heads: 32,
            ffn_dim: 16384,
            max_seq: 2048,
            ffn: FfnKind::Relu2,
        }
    }

    /// Matches python/compile/model.py `TINY` (the runnable model).
    pub fn tiny() -> Self {
        Self {
            name: "tiny-llama".into(),
            vocab: 512,
            dim: 256,
            n_layers: 4,
            n_heads: 8,
            ffn_dim: 512,
            max_seq: 256,
            ffn: FfnKind::SwiGlu3,
        }
    }

    pub fn head_dim(&self) -> u64 {
        self.dim / self.n_heads
    }

    /// Number of FFN weight matrices (2 for ReLU FFN, 3 for SwiGLU).
    pub fn ffn_mats(&self) -> u64 {
        match self.ffn {
            FfnKind::Relu2 => 2,
            FfnKind::SwiGlu3 => 3,
        }
    }

    /// Dense parameter count (weights only, incl. embeddings + head).
    pub fn param_count(&self) -> u64 {
        let attn = 4 * self.dim * self.dim;
        let ffn = self.ffn_mats() * self.dim * self.ffn_dim;
        self.n_layers * (attn + ffn) + 2 * self.vocab * self.dim
    }

    /// Per-layer linear shapes as (out, in) pairs — what the compiler maps
    /// to MM/MV instructions.
    pub fn layer_linears(&self) -> Vec<(String, u64, u64)> {
        let d = self.dim;
        let f = self.ffn_dim;
        let mut v = vec![
            ("wq".into(), d, d),
            ("wk".into(), d, d),
            ("wv".into(), d, d),
            ("wo".into(), d, d),
        ];
        match self.ffn {
            FfnKind::Relu2 => {
                v.push(("w1".into(), f, d));
                v.push(("w2".into(), d, f));
            }
            FfnKind::SwiGlu3 => {
                v.push(("w1".into(), f, d));
                v.push(("w3".into(), f, d));
                v.push(("w2".into(), d, f));
            }
        }
        v
    }

    /// KV-cache bytes for one sequence of length `seq` at `bytes_per_elem`
    /// precision (2 = fp16, 1 = int8).
    pub fn kv_bytes(&self, seq: u64, bytes_per_elem: u64) -> u64 {
        self.n_layers * 2 * seq * self.dim * bytes_per_elem
    }

    /// Sum of 2·out·in over one layer's linears (MACs×2 per token).
    fn layer_linear_flops(&self) -> u64 {
        self.layer_linears().iter().map(|(_, o, i)| 2 * o * i).sum()
    }

    /// FLOPs for one decode step at context length `ctx` (2*params for
    /// the matvecs + attention term), the standard decode cost model.
    pub fn decode_flops(&self, ctx: u64) -> u64 {
        let lin = self.n_layers * self.layer_linear_flops();
        // attention: q·K^T and att·V over ctx positions, all heads
        let attn = self.n_layers * 2 * 2 * ctx * self.dim;
        let head = 2 * self.vocab * self.dim;
        lin + attn + head
    }

    /// FLOPs for a full prefill of length `n` (dense attention).
    pub fn prefill_flops(&self, n: u64) -> u64 {
        let lin = self.n_layers * self.layer_linear_flops() * n;
        let attn = self.n_layers * 2 * 2 * n * n * self.dim;
        lin + attn + 2 * self.vocab * self.dim * n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama2_7b_param_count_is_about_6_7b() {
        let c = ModelConfig::llama2_7b();
        let p = c.param_count();
        assert!(p > 6_400_000_000 && p < 7_000_000_000, "params = {p}");
    }

    #[test]
    fn opt_param_count_is_about_6_9b() {
        let c = ModelConfig::opt_6_7b();
        let p = c.param_count();
        // OPT-6.7B with tied-ish embeddings lands around 6.7-7.1B here.
        assert!(p > 6_200_000_000 && p < 7_300_000_000, "params = {p}");
    }

    #[test]
    fn tiny_matches_python_model() {
        let c = ModelConfig::tiny();
        assert_eq!(c.dim, 256);
        assert_eq!(c.n_layers, 4);
        assert_eq!(c.head_dim(), 32);
        assert_eq!(c.layer_linears().len(), 7);
    }

    #[test]
    fn kv_cache_scales_linearly() {
        let c = ModelConfig::llama2_7b();
        assert_eq!(c.kv_bytes(2048, 2), 2 * c.kv_bytes(1024, 2));
        // 2048-token fp16 KV cache of LLaMA2-7B ~ 1.07 GB
        let gb = c.kv_bytes(2048, 2) as f64 / 1e9;
        assert!(gb > 1.0 && gb < 1.2, "kv = {gb} GB");
    }

    #[test]
    fn decode_flops_close_to_2x_params() {
        let c = ModelConfig::llama2_7b();
        let f = c.decode_flops(512) as f64;
        let p = c.param_count() as f64;
        assert!(f > 1.8 * p && f < 2.4 * p, "flops={f}, 2p={}", 2.0 * p);
    }
}
