//! Hardware platform descriptions — Table 2 of the paper, plus the
//! memory-system details (§4.4) the simulator needs.


/// One off-chip memory system (HBM or DDR).
#[derive(Debug, Clone)]
pub struct MemoryConfig {
    pub capacity_gb: f64,
    /// Peak aggregate bandwidth, GB/s (Table 2).
    pub bandwidth_gbs: f64,
    /// Number of independent channels (U280 HBM: 32 pseudo-channels).
    pub channels: u32,
    /// First-word access latency in ns. HBM latency is *higher* than DDR
    /// (§4.4, citing Shuhai [46]) — that asymmetry is why FlightLLM puts
    /// small-access data on DDR.
    pub latency_ns: f64,
    /// Efficiency of a perfectly-streamed large burst (0..1): row-refresh
    /// and protocol overhead keep even ideal streams below peak.
    pub burst_efficiency: f64,
}

impl MemoryConfig {
    /// Effective time (ns) to move `bytes` in a single contiguous access.
    pub fn access_ns(&self, bytes: u64) -> f64 {
        self.latency_ns + bytes as f64 / (self.bandwidth_gbs * self.burst_efficiency)
    }

    pub fn per_channel_gbs(&self) -> f64 {
        self.bandwidth_gbs / self.channels as f64
    }

    /// Capacity in bytes (decimal GB, matching Table 2).
    pub fn capacity_bytes(&self) -> u64 {
        (self.capacity_gb * 1e9) as u64
    }
}

/// Per-core on-chip buffer capacities in bytes — the budgets the stream
/// verifier holds LD/compute occupancy against.  Weight/global/index
/// buffers are BRAM36-backed (4 KiB usable per block, §5.3); the
/// activation buffer is URAM-backed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OnChipBudget {
    pub weight_bytes: u64,
    pub activation_bytes: u64,
    pub global_bytes: u64,
    pub index_bytes: u64,
}

impl OnChipBudget {
    /// U280 build (Table 3 sizing): 192/64/16 BRAM36 + 2 MiB URAM.
    pub fn u280() -> Self {
        Self {
            weight_bytes: 192 * 4096,
            activation_bytes: 2048 * 1024,
            global_bytes: 64 * 4096,
            index_bytes: 16 * 4096,
        }
    }

    /// VHK158 inherits the U280 per-core buffer sizing (§6.1: same MPU
    /// shape, more bandwidth per channel).
    pub fn vhk158() -> Self {
        Self::u280()
    }
}

/// An FPGA (or, for the GPU baselines, a `GpuConfig` instead).
#[derive(Debug, Clone)]
pub struct Platform {
    pub name: String,
    pub freq_mhz: f64,
    pub dsp_total: u32,
    /// Super Logic Regions (dies). Cross-SLR paths bound the clock; the
    /// accelerator instantiates one computing core per SLR (§6.1).
    pub slr_count: u32,
    pub hbm: MemoryConfig,
    pub ddr: MemoryConfig,
    /// Per-core on-chip buffer capacities (verifier occupancy budgets).
    pub onchip: OnChipBudget,
    pub bram36_total: u32,
    pub uram_total: u32,
    pub lut_total: u32,
    pub ff_total: u32,
    /// Board power budget / measured-at-load power, W (xbutil-style).
    pub power_w: f64,
    pub price_usd: f64,
}

impl Platform {
    /// Xilinx Alveo U280 (16nm): 8 GB HBM @ 460 GB/s + 32 GB DDR @ 38 GB/s.
    pub fn u280() -> Self {
        Self {
            name: "U280".into(),
            freq_mhz: 225.0,
            dsp_total: 9024,
            slr_count: 3,
            hbm: MemoryConfig {
                capacity_gb: 8.0,
                bandwidth_gbs: 460.0,
                channels: 32,
                latency_ns: 107.0,
                burst_efficiency: 0.88,
            },
            ddr: MemoryConfig {
                capacity_gb: 32.0,
                bandwidth_gbs: 38.0,
                channels: 2,
                latency_ns: 63.0,
                burst_efficiency: 0.90,
            },
            onchip: OnChipBudget::u280(),
            bram36_total: 2016,
            uram_total: 960,
            lut_total: 1_304_000,
            ff_total: 2_607_000,
            power_w: 45.0,
            price_usd: 8000.0,
        }
    }

    /// Xilinx Versal VHK158 (7nm): 32 GB HBM @ 819 GB/s + 32 GB DDR @ 51 GB/s.
    pub fn vhk158() -> Self {
        Self {
            name: "VHK158".into(),
            freq_mhz: 225.0,
            dsp_total: 7392,
            slr_count: 2,
            hbm: MemoryConfig {
                capacity_gb: 32.0,
                bandwidth_gbs: 819.0,
                channels: 32,
                latency_ns: 107.0,
                burst_efficiency: 0.88,
            },
            ddr: MemoryConfig {
                capacity_gb: 32.0,
                bandwidth_gbs: 51.0,
                channels: 2,
                latency_ns: 63.0,
                burst_efficiency: 0.90,
            },
            onchip: OnChipBudget::vhk158(),
            bram36_total: 5063,
            uram_total: 1301,
            lut_total: 1_802_000,
            ff_total: 3_604_000,
            power_w: 60.0,
            price_usd: 14000.0,
        }
    }
}

/// GPU baselines of Table 2. `eff_*` factors are the measured-utilization
/// coefficients of the roofline model (see baselines::gpu for how the
/// naive and vLLM+SmoothQuant stacks differ).
#[derive(Debug, Clone)]
pub struct GpuConfig {
    pub name: String,
    pub freq_mhz: f64,
    pub tensor_cores: u32,
    pub mem_gb: f64,
    pub bandwidth_gbs: f64,
    /// Peak dense FP16 tensor throughput, TFLOPS.
    pub peak_fp16_tflops: f64,
    /// Peak INT8 tensor throughput, TOPS (SmoothQuant path).
    pub peak_int8_tops: f64,
    pub tdp_w: f64,
    pub price_usd: f64,
}

impl GpuConfig {
    pub fn v100s() -> Self {
        Self {
            name: "V100S".into(),
            freq_mhz: 1245.0,
            tensor_cores: 640,
            mem_gb: 32.0,
            bandwidth_gbs: 1134.0,
            peak_fp16_tflops: 130.0,
            peak_int8_tops: 130.0, // Volta tensor cores have no INT8 double-rate
            tdp_w: 250.0,
            price_usd: 12000.0,
        }
    }

    pub fn a100() -> Self {
        Self {
            name: "A100".into(),
            freq_mhz: 1065.0,
            tensor_cores: 432,
            mem_gb: 80.0,
            bandwidth_gbs: 1935.0,
            peak_fp16_tflops: 312.0,
            peak_int8_tops: 624.0,
            tdp_w: 400.0,
            price_usd: 17000.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u280_matches_table2() {
        let p = Platform::u280();
        assert_eq!(p.dsp_total, 9024);
        assert_eq!(p.slr_count, 3);
        assert!((p.hbm.bandwidth_gbs - 460.0).abs() < 1e-9);
        assert!((p.ddr.bandwidth_gbs - 38.0).abs() < 1e-9);
        assert!((p.hbm.capacity_gb - 8.0).abs() < 1e-9);
    }

    #[test]
    fn vhk158_matches_table2() {
        let p = Platform::vhk158();
        assert_eq!(p.dsp_total, 7392);
        assert!((p.hbm.bandwidth_gbs - 819.0).abs() < 1e-9);
    }

    #[test]
    fn hbm_latency_exceeds_ddr_latency() {
        // The §4.4 asymmetry that motivates the hybrid placement.
        let p = Platform::u280();
        assert!(p.hbm.latency_ns > p.ddr.latency_ns);
    }

    #[test]
    fn small_access_favors_ddr_large_favors_hbm() {
        let p = Platform::u280();
        // ~100 B SFU-style access: DDR wins on latency.
        assert!(p.ddr.access_ns(128) < p.hbm.access_ns(128));
        // ~MB MPE-style access: HBM wins on bandwidth.
        assert!(p.hbm.access_ns(4 << 20) < p.ddr.access_ns(4 << 20));
    }

    #[test]
    fn onchip_budget_is_positive_and_weight_buf_dominates() {
        for p in [Platform::u280(), Platform::vhk158()] {
            let b = p.onchip;
            assert!(b.weight_bytes > 0 && b.global_bytes > 0 && b.index_bytes > 0);
            // Weight streaming needs the largest BRAM budget (§5.3).
            assert!(b.weight_bytes > b.global_bytes);
            assert!(b.global_bytes > b.index_bytes);
            // URAM activation buffer is the largest overall.
            assert!(b.activation_bytes > b.weight_bytes);
        }
    }

    #[test]
    fn gpu_presets_match_table2() {
        let v = GpuConfig::v100s();
        assert!((v.bandwidth_gbs - 1134.0).abs() < 1e-9);
        assert_eq!(v.tensor_cores, 640);
        let a = GpuConfig::a100();
        assert!((a.bandwidth_gbs - 1935.0).abs() < 1e-9);
        assert_eq!(a.tensor_cores, 432);
    }
}
