//! Compression recipe (§6.2.1): block-sparse attention + N:M weight
//! pruning + mixed-precision quantization, with the knobs Table 4 and
//! Fig. 14 toggle.


#[derive(Debug, Clone)]
pub struct CompressionConfig {
    /// Enable N:M weight pruning on the linear layers.
    pub weight_pruning: bool,
    /// N:M group size M (paper: 16, so the sparse block is 16×16).
    pub nm_m: u32,
    /// Average kept fraction N/M across blocks (the gradient-based
    /// analysis assigns different N per block; this is the mean density).
    pub weight_density: f64,
    /// Enable block-sparse attention in prefill.
    pub sparse_attention: bool,
    /// Attention block edge (paper: 64×64).
    pub attn_block: u32,
    /// Fraction of attention blocks computed under the mask, relative to
    /// the full causal lower triangle.
    pub attn_density: f64,
    /// Enable mixed-precision weight quantization.
    pub quantization: bool,
    /// Average weight bit-width (paper: 3.5-bit average from the 3/4/5-bit
    /// gradient-assigned mix).
    pub weight_bits: f64,
    /// Activation bit-width (paper: 8).
    pub act_bits: u32,
}

impl CompressionConfig {
    /// The paper's full recipe ("All" row of Table 4).
    pub fn paper_default() -> Self {
        Self {
            weight_pruning: true,
            nm_m: 16,
            weight_density: 0.5,
            sparse_attention: true,
            attn_block: 64,
            attn_density: 0.45,
            quantization: true,
            weight_bits: 3.5,
            act_bits: 8,
        }
    }

    /// No compression (the "None" row / the naive U280 port of Fig. 14).
    pub fn none() -> Self {
        Self {
            weight_pruning: false,
            nm_m: 16,
            weight_density: 1.0,
            sparse_attention: false,
            attn_block: 64,
            attn_density: 1.0,
            quantization: false,
            weight_bits: 16.0,
            act_bits: 16,
        }
    }

    pub fn only_sparse_attention() -> Self {
        Self { sparse_attention: true, attn_density: 0.45, ..Self::none() }
    }

    pub fn only_weight_pruning() -> Self {
        Self { weight_pruning: true, weight_density: 0.5, ..Self::none() }
    }

    pub fn only_quantization() -> Self {
        Self { quantization: true, weight_bits: 3.5, act_bits: 8, ..Self::none() }
    }

    /// Effective density of linear-layer compute after pruning.
    pub fn effective_weight_density(&self) -> f64 {
        if self.weight_pruning { self.weight_density } else { 1.0 }
    }

    /// Effective attention-block density in prefill.
    pub fn effective_attn_density(&self) -> f64 {
        if self.sparse_attention { self.attn_density } else { 1.0 }
    }

    /// Bytes per weight element as stored off-chip, including the N:M
    /// index overhead (log2(M) bits per kept element).
    pub fn weight_bytes_per_elem(&self) -> f64 {
        let value_bits =
            if self.quantization { self.weight_bits } else { 16.0 };
        let index_bits = if self.weight_pruning {
            (self.nm_m as f64).log2()
        } else {
            0.0
        };
        (value_bits + index_bits) / 8.0
    }

    /// Total off-chip bytes for a model's weights.
    pub fn model_weight_bytes(&self, params: u64) -> f64 {
        params as f64 * self.effective_weight_density() * self.weight_bytes_per_elem()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_recipe_averages_3_5_bits() {
        let c = CompressionConfig::paper_default();
        assert!((c.weight_bits - 3.5).abs() < 1e-9);
        assert_eq!(c.act_bits, 8);
    }

    #[test]
    fn weight_bytes_accounts_for_index_overhead() {
        let c = CompressionConfig::paper_default();
        // 3.5 value bits + 4 index bits = 7.5 bits ≈ 0.9375 B/elem
        assert!((c.weight_bytes_per_elem() - 0.9375).abs() < 1e-9);
        let none = CompressionConfig::none();
        assert!((none.weight_bytes_per_elem() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn compressed_llama_fits_u280_hbm() {
        // The always-on-chip decode scheme requires weights+KV in 8 GB HBM.
        let c = CompressionConfig::paper_default();
        let m = crate::config::ModelConfig::llama2_7b();
        let wb = c.model_weight_bytes(m.param_count());
        let kv = m.kv_bytes(2048, 1) as f64; // int8 KV
        assert!(
            (wb + kv) / 1e9 < 8.0,
            "weights {wb:.2e} + kv {kv:.2e} exceed HBM"
        );
        // ...while the uncompressed model does not fit.
        let none = CompressionConfig::none();
        assert!(none.model_weight_bytes(m.param_count()) / 1e9 > 8.0);
    }

    #[test]
    fn ablation_presets_toggle_one_axis() {
        assert!(CompressionConfig::only_quantization().quantization);
        assert!(!CompressionConfig::only_quantization().weight_pruning);
        assert!(CompressionConfig::only_weight_pruning().weight_pruning);
        assert!(!CompressionConfig::only_weight_pruning().sparse_attention);
    }
}
