//! Configuration: hardware platforms (Table 2), accelerator organization
//! (§5.3 RTL-generator parameters), LLM model architectures, and
//! compression recipes (§6.2.1).
//!
//! The paper's exact setups ship as built-in presets
//! (`Platform::u280()`, `ModelConfig::llama2_7b()`, ...); experiment
//! reports are emitted as JSON via `crate::util::json`.

mod accelerator;
mod compression;
mod model;
mod platform;

pub use accelerator::{AcceleratorConfig, ResourceEstimate};
pub use compression::CompressionConfig;
pub use model::{FfnKind, ModelConfig};
pub use platform::{GpuConfig, MemoryConfig, OnChipBudget, Platform};

/// A fully-specified experiment target: which board, how the accelerator
/// is organized on it, which model, and which compression recipe.
#[derive(Debug, Clone)]
pub struct Target {
    pub platform: Platform,
    pub accel: AcceleratorConfig,
    pub model: ModelConfig,
    pub compression: CompressionConfig,
}

impl Target {
    /// FlightLLM-on-U280 running LLaMA2-7B with the full compression
    /// recipe — the paper's headline configuration.
    pub fn u280_llama2() -> Self {
        Self {
            platform: Platform::u280(),
            accel: AcceleratorConfig::for_u280(),
            model: ModelConfig::llama2_7b(),
            compression: CompressionConfig::paper_default(),
        }
    }

    pub fn u280_opt() -> Self {
        Self { model: ModelConfig::opt_6_7b(), ..Self::u280_llama2() }
    }

    pub fn vhk158_llama2() -> Self {
        Self {
            platform: Platform::vhk158(),
            accel: AcceleratorConfig::for_vhk158(),
            model: ModelConfig::llama2_7b(),
            compression: CompressionConfig::paper_default(),
        }
    }

    pub fn vhk158_opt() -> Self {
        Self { model: ModelConfig::opt_6_7b(), ..Self::vhk158_llama2() }
    }

    /// The runnable tiny model (matches python/compile/model.py TINY).
    pub fn u280_tiny() -> Self {
        Self { model: ModelConfig::tiny(), ..Self::u280_llama2() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_consistent() {
        for t in [
            Target::u280_llama2(),
            Target::u280_opt(),
            Target::vhk158_llama2(),
            Target::u280_tiny(),
        ] {
            assert!(t.model.dim % t.model.n_heads == 0);
            assert!(t.platform.hbm.bandwidth_gbs > 0.0);
            assert!(t.accel.dsp_total() > 0);
        }
    }

    #[test]
    fn tiny_target_uses_tiny_model() {
        let t = Target::u280_tiny();
        assert_eq!(t.model.dim, 256);
        assert_eq!(t.platform.name, "U280");
    }
}
