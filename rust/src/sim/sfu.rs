//! SFU timing model (§3.3): MISC operations on the Special Function Unit.
//!
//! Element-wise ops stream one element per lane per cycle; two-phase ops
//! (softmax, layer/rms-norm) read the whole vector twice (reduce, then
//! normalize).  The remote-SFU path (sharing partial results between
//! SLRs without an HBM round-trip) is modeled as a fixed inter-SLR hop.

use crate::isa::MiscOp;

#[derive(Debug, Clone)]
pub struct SfuModel {
    pub freq_mhz: f64,
    /// Element lanes per SFU (fp16 ALUs).
    pub lanes: u32,
    /// Fixed issue overhead per MISC instruction, cycles.
    pub issue_cycles: u32,
    /// Inter-SLR hop for remote-SFU sharing, cycles.
    pub remote_hop_cycles: u32,
}

impl SfuModel {
    /// Calibrated to the Table 3 SFU (201 DSPs ≈ 64 fp16 lanes at 225 MHz).
    pub fn for_u280() -> Self {
        Self { freq_mhz: 225.0, lanes: 64, issue_cycles: 4, remote_hop_cycles: 24 }
    }

    fn ns_per_cycle(&self) -> f64 {
        1e3 / self.freq_mhz
    }

    /// ns for one MISC op over `len` elements.
    pub fn misc_ns(&self, op: MiscOp, len: u64) -> f64 {
        let passes = if op.is_two_phase() { 2 } else { 1 };
        let cycles = passes * len.div_ceil(self.lanes as u64)
            + self.issue_cycles as u64;
        cycles as f64 * self.ns_per_cycle()
    }

    /// ns for the remote-SFU broadcast of a `len`-element partial vector
    /// to `slrs` peers (§3.3: "the result could be sent to all other PEs
    /// without writing back to HBM").
    pub fn remote_share_ns(&self, len: u64, slrs: u32) -> f64 {
        if slrs <= 1 {
            return 0.0;
        }
        let cycles = self.remote_hop_cycles as u64
            + len.div_ceil(self.lanes as u64);
        // Broadcast is pipelined across SLRs: one hop extra per peer.
        (cycles + (slrs as u64 - 2) * self.remote_hop_cycles as u64 / 2) as f64
            * self.ns_per_cycle()
    }

    /// The §3.3 fine-granularity trick: a MISC op after a single-head MV
    /// is broken into `chunks` sub-vectors so it hides under compute;
    /// the visible (non-hidden) time is one sub-vector's worth.
    pub fn misc_visible_ns(&self, op: MiscOp, len: u64, chunks: u64) -> f64 {
        self.misc_ns(op, len.div_ceil(chunks.max(1)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_phase_costs_two_passes() {
        let s = SfuModel::for_u280();
        let ew = s.misc_ns(MiscOp::EltwiseAdd, 4096);
        let sm = s.misc_ns(MiscOp::Softmax, 4096);
        assert!(sm > 1.8 * ew && sm < 2.2 * ew, "softmax {sm} vs eltwise {ew}");
    }

    #[test]
    fn misc_scales_with_length() {
        let s = SfuModel::for_u280();
        let a = s.misc_ns(MiscOp::Silu, 1024);
        let b = s.misc_ns(MiscOp::Silu, 4096);
        assert!(b > 3.0 * a && b < 4.5 * a);
    }

    #[test]
    fn remote_share_cheaper_than_hbm_roundtrip() {
        // The §3.3 claim: SFU-to-SFU sharing beats writing the vector to
        // HBM and reading it back on the peer SLR.
        let s = SfuModel::for_u280();
        let p = crate::config::Platform::u280();
        let len = 4096u64;
        let share = s.remote_share_ns(len, 3);
        // A vector write-back + read-back crosses one HBM pseudo-channel.
        let ch_bw = p.hbm.per_channel_gbs() * p.hbm.burst_efficiency;
        let hbm_roundtrip =
            2.0 * (p.hbm.latency_ns + (len * 2) as f64 / ch_bw);
        assert!(share < hbm_roundtrip, "{share} vs {hbm_roundtrip}");
    }

    #[test]
    fn chunked_visible_time_is_fraction() {
        let s = SfuModel::for_u280();
        let full = s.misc_ns(MiscOp::EltwiseMul, 4096);
        let visible = s.misc_visible_ns(MiscOp::EltwiseMul, 4096, 8);
        assert!(visible < full / 4.0);
    }

    #[test]
    fn single_slr_share_is_free() {
        let s = SfuModel::for_u280();
        assert_eq!(s.remote_share_ns(1024, 1), 0.0);
    }
}
