//! Instruction-driven execution engine.
//!
//! Executes one SLR's instruction stream in order against three resource
//! timelines — memory channels (`MemorySystem`), the MPE, and the SFU.
//! Because LD/ST advance the memory timeline independently of compute,
//! the double-buffer overlap of §3.2.2 emerges naturally: while MM(i)
//! runs, LD(i+1) streams, and the end-to-end time converges to
//! max(T_mem, T_cmp) per tile, +fills.
//!
//! The accelerator is SLR-symmetric (model parallelism, §3.1): the
//! compiler emits the stream for one SLR covering 1/SLR of every output
//! dimension; `Sys(SyncSlr)` charges the synchronization that stitches
//! layers back together.

use crate::config::Target;
use crate::isa::{Inst, MiscOp, SysOp};

use super::memory::MemorySystem;
use super::mpe::MpeModel;
use super::sfu::SfuModel;

/// Cycles charged per SLR barrier (cross-die handshake + pipeline drain).
const SYNC_SLR_CYCLES: u64 = 64;
/// Cycles for a host round-trip (PCIe doorbell) at inference end.
const SYNC_HOST_CYCLES: u64 = 2_000;

#[derive(Debug, Clone)]
pub struct Engine {
    pub mem: MemorySystem,
    pub mpe: MpeModel,
    pub sfu: SfuModel,
    pub slr_count: u32,
    freq_mhz: f64,
    /// Machine-safety verification context applied to every `run_ref`
    /// stream in debug builds (channels, encoding, address capacity — the
    /// checks a malformed stream would need to pass on real hardware).
    precheck: Option<crate::verify::VerifyContext>,
}

impl Engine {
    /// The stream describes ONE SLR's share of the work; the other SLRs
    /// run the same stream concurrently (base-address-register reuse,
    /// §5.2).  HBM channels are shared board-wide, so every memory leg is
    /// inflated by the SLR count; the MPE/SFU timelines are per-SLR (the
    /// MpeModel below is configured with one SLR's resources).
    fn mem_scale(&self) -> u64 {
        self.slr_count.max(1) as u64
    }
}

/// What one stream execution produced.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    /// End-to-end time, ns.
    pub total_ns: f64,
    /// Busy time per resource, ns.
    pub mpe_busy_ns: f64,
    pub sfu_busy_ns: f64,
    /// Off-chip traffic.
    pub hbm_bytes: u64,
    pub ddr_bytes: u64,
    /// Useful MACs executed.
    pub macs: u64,
    /// Achieved HBM bandwidth / peak (Table 5 metric).
    pub hbm_bw_util: f64,
    /// Useful MACs / (cycles × peak MACs-per-cycle) — runtime DSP
    /// utilization (the §3.2 computation-efficiency metric).
    pub compute_eff: f64,
    /// Instruction count executed (after merge expansion: stored count).
    pub inst_count: u64,
}

impl SimReport {
    /// Tokens/s when this report covers one decode step.
    pub fn tokens_per_s(&self) -> f64 {
        if self.total_ns <= 0.0 {
            return 0.0;
        }
        1e9 / self.total_ns
    }
}

impl Engine {
    /// Build an engine for a target, optionally disabling the CSD chain
    /// (Fig. 14's "naive" rung).
    pub fn for_target(t: &Target, csd_chain: bool) -> Self {
        let freq = t.platform.freq_mhz;
        // Per-SLR compute resources: the instruction stream covers one
        // SLR's share (see mem_scale()).
        let slr = t.platform.slr_count.max(1);
        let accel_slr = crate::config::AcceleratorConfig {
            mpe: (t.accel.mpe / slr).max(1),
            ..t.accel.clone()
        };
        Self {
            mem: MemorySystem::new(t.platform.hbm.clone(), t.platform.ddr.clone()),
            mpe: MpeModel::new(accel_slr, freq, csd_chain),
            sfu: SfuModel { freq_mhz: freq, ..SfuModel::for_u280() },
            slr_count: t.platform.slr_count,
            freq_mhz: freq,
            precheck: Some(crate::verify::VerifyContext::machine_safety(t)),
        }
    }

    fn ns(&self, cycles: u64) -> f64 {
        cycles as f64 * 1e3 / self.freq_mhz
    }

    /// Execute a stream without consuming the engine: clones for fresh
    /// per-run channel state.  The serving backend replays memoised
    /// streams through this repeatedly.
    ///
    /// Debug builds first run the machine-safety subset of the stream
    /// verifier — a stream the hardware could not execute (channel out of
    /// range, unencodable word, address past memory) panics here instead
    /// of producing a plausible-looking latency.
    pub fn run_ref(&self, insts: &[Inst]) -> SimReport {
        if cfg!(debug_assertions) {
            if let Some(ctx) = &self.precheck {
                let diags = crate::verify::verify_stream(insts, ctx);
                assert!(
                    diags.is_empty(),
                    "stream fails machine-safety verification: {diags:?}"
                );
            }
        }
        self.clone().run(insts)
    }

    /// Execute one instruction stream; the engine is consumed per run
    /// (fresh channel state per inference).
    pub fn run(mut self, insts: &[Inst]) -> SimReport {
        let mut report = SimReport { inst_count: insts.len() as u64, ..Default::default() };
        // Resource-ready times (ns).
        let mut mpe_ready = 0.0f64;
        let mut sfu_ready = 0.0f64;
        // Completion time of the latest LD whose data compute consumes.
        let mut data_ready = 0.0f64;
        // Stream-issue cursor for memory ops (double buffering: memory
        // runs ahead of compute, bounded by one tile of lookahead which
        // the per-channel serialization already enforces).
        let mut mem_issue = 0.0f64;

        for inst in insts {
            match inst {
                Inst::Ld { .. } | Inst::LdMerged { .. } => {
                    let done = self.mem.issue_scaled(mem_issue, inst, self.mem_scale());
                    // Issue rate: one LD dispatched per cycle; transfers
                    // queue per channel inside MemorySystem, so loads on
                    // different channel groups overlap (double buffering).
                    mem_issue += self.ns(1);
                    data_ready = data_ready.max(done);
                }
                Inst::St { .. } | Inst::StMerged { .. } => {
                    // Stores wait for the producing compute.
                    let start = mem_issue.max(mpe_ready).max(sfu_ready);
                    let done = self.mem.issue_scaled(start, inst, self.mem_scale());
                    mem_issue = mem_issue.max(done - self.ns(1));
                }
                Inst::Mm { m, k, n, sparsity } => {
                    let dur = self.mpe.mm_ns(*m as u64, *k as u64, *n as u64, *sparsity);
                    let start = mpe_ready.max(data_ready);
                    mpe_ready = start + dur;
                    report.mpe_busy_ns += dur;
                    report.macs += inst.macs();
                }
                Inst::Mv { k, n, sparsity } => {
                    let dur = self.mpe.mv_ns(*k as u64, *n as u64, *sparsity);
                    let start = mpe_ready.max(data_ready);
                    mpe_ready = start + dur;
                    report.mpe_busy_ns += dur;
                    report.macs += inst.macs();
                }
                Inst::Misc { op, len } => {
                    let dur = self.sfu.misc_ns(*op, *len as u64);
                    // Two-phase ops need the producing vector complete;
                    // element-wise ops stream behind the MPE (fine-grained
                    // hiding, §3.3) — charge only the issue overhead on
                    // the critical path.
                    if op.is_two_phase() {
                        let start = sfu_ready.max(mpe_ready);
                        sfu_ready = start + dur;
                        mpe_ready = mpe_ready.max(sfu_ready);
                    } else {
                        let start = sfu_ready.max(mpe_ready);
                        sfu_ready = start + dur;
                        mpe_ready = mpe_ready.max(start + self.ns(self.sfu.issue_cycles as u64));
                    }
                    report.sfu_busy_ns += dur;
                }
                Inst::Sys { op } => {
                    let everyone = mpe_ready.max(sfu_ready).max(mem_issue).max(data_ready);
                    let pause = match op {
                        SysOp::SyncSlr => self.ns(SYNC_SLR_CYCLES),
                        SysOp::SyncHost => self.ns(SYNC_HOST_CYCLES),
                    };
                    mpe_ready = everyone + pause;
                    sfu_ready = everyone + pause;
                    mem_issue = everyone + pause;
                    data_ready = everyone + pause;
                }
            }
        }
        let total = mpe_ready
            .max(sfu_ready)
            .max(self.mem.quiescent());
        report.total_ns = total;
        report.hbm_bytes = self.mem.hbm_bytes;
        report.ddr_bytes = self.mem.ddr_bytes;
        report.hbm_bw_util = self.mem.hbm_bw_utilization(total);
        // Per-SLR MACs against the per-SLR MPE model == board efficiency;
        // scale MACs afterwards so totals are board-wide.
        report.compute_eff = self.mpe.compute_efficiency(report.macs, total);
        report.macs *= self.mem_scale();
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Target;
    use crate::isa::{MemSpace, OnChipBuf, Sparsity};

    fn engine() -> Engine {
        Engine::for_target(&Target::u280_llama2(), true)
    }

    fn weight_stream(tiles: u32, bytes_per_tile: u32, k: u32, n: u32) -> Vec<Inst> {
        let mut v = Vec::new();
        for i in 0..tiles {
            v.push(Inst::LdMerged {
                first_channel: ((i * 8) % 32) as u8,
                channels: 8,
                dst: OnChipBuf::Weight,
                addr: i as u64 * bytes_per_tile as u64,
                bytes: bytes_per_tile / 8,
            });
            v.push(Inst::Mv { k, n, sparsity: Sparsity::Dense });
        }
        v
    }

    #[test]
    fn empty_stream_is_zero() {
        let r = engine().run(&[]);
        assert_eq!(r.total_ns, 0.0);
    }

    #[test]
    fn load_compute_overlap_is_max_not_sum() {
        // Memory-bound MV stream: total ≈ T_mem, not T_mem + T_cmp.
        let insts = weight_stream(64, 1 << 20, 4096, 256);
        let r = engine().run(&insts);
        let mem_only: Vec<Inst> = insts
            .iter()
            .filter(|i| i.is_memory())
            .cloned()
            .collect();
        let r_mem = engine().run(&mem_only);
        let compute_only: Vec<Inst> =
            insts.iter().filter(|i| i.is_compute()).cloned().collect();
        let r_cmp = engine().run(&compute_only);
        let lower = r_mem.total_ns.max(r_cmp.total_ns);
        assert!(r.total_ns >= lower * 0.99);
        assert!(
            r.total_ns < 1.25 * lower,
            "overlap broken: total {} vs max(mem {}, cmp {})",
            r.total_ns,
            r_mem.total_ns,
            r_cmp.total_ns
        );
    }

    #[test]
    fn sync_slr_serializes() {
        let mut insts = weight_stream(4, 1 << 18, 1024, 256);
        let r_nosync = engine().run(&insts);
        for i in (2..insts.len() + insts.len() / 2).step_by(3).rev() {
            if i < insts.len() {
                insts.insert(i, Inst::Sys { op: SysOp::SyncSlr });
            }
        }
        let r_sync = engine().run(&insts);
        assert!(r_sync.total_ns > r_nosync.total_ns);
    }

    #[test]
    fn two_phase_misc_on_critical_path_eltwise_hidden() {
        let base = weight_stream(8, 1 << 18, 1024, 1024);
        let mut with_softmax = base.clone();
        let mut with_eltwise = base.clone();
        for i in (0..8).rev() {
            with_softmax.insert(i * 2 + 2, Inst::Misc { op: MiscOp::Softmax, len: 4096 });
            with_eltwise.insert(i * 2 + 2, Inst::Misc { op: MiscOp::EltwiseAdd, len: 4096 });
        }
        let r_base = engine().run(&base);
        let r_soft = engine().run(&with_softmax);
        let r_elt = engine().run(&with_eltwise);
        let soft_cost = r_soft.total_ns - r_base.total_ns;
        let elt_cost = r_elt.total_ns - r_base.total_ns;
        assert!(
            soft_cost > 1.5 * elt_cost,
            "softmax (two-phase) must hurt more: {soft_cost} vs {elt_cost}"
        );
    }

    #[test]
    fn run_ref_is_repeatable_and_matches_run() {
        let insts = weight_stream(4, 1 << 18, 1024, 256);
        let e = engine();
        let a = e.run_ref(&insts);
        let b = e.run_ref(&insts);
        let c = engine().run(&insts);
        assert_eq!(a.total_ns.to_bits(), b.total_ns.to_bits());
        assert_eq!(a.total_ns.to_bits(), c.total_ns.to_bits());
        assert_eq!(a.hbm_bytes, c.hbm_bytes);
    }

    #[test]
    fn report_accounts_traffic_and_macs() {
        // The stream is one SLR's share; totals are board-wide (×3 SLRs).
        let r = engine().run(&weight_stream(4, 1 << 20, 4096, 256));
        assert_eq!(r.hbm_bytes, 3 * 4 * (1 << 20) as u64);
        assert_eq!(r.macs, 3 * 4 * 4096 * 256);
        assert!(r.hbm_bw_util > 0.0 && r.hbm_bw_util <= 1.0);
    }
}
