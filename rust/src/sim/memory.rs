//! Off-chip memory timing model: HBM pseudo-channels + DDR (§4.4).
//!
//! Each LD/ST is charged `latency + bytes / effective_channel_bw` on the
//! channels it touches.  Merged multi-channel transfers run their legs
//! concurrently (the §5.2 decoder expansion), which is exactly how the
//! instruction optimization recovers HBM bandwidth.  The model also
//! tracks totals so the engine can report end-to-end bandwidth
//! utilization (Table 5) and the memory-busy fraction.

use crate::config::MemoryConfig;
use crate::isa::{Inst, MemSpace};

/// Timing + accounting for one platform's HBM + DDR.
#[derive(Debug, Clone)]
pub struct MemorySystem {
    pub hbm: MemoryConfig,
    pub ddr: MemoryConfig,
    /// Ready time per HBM channel (ns) — transfers on different channels
    /// overlap; transfers on one channel serialize.
    hbm_channel_ready: Vec<f64>,
    ddr_ready: f64,
    /// Totals.
    pub hbm_bytes: u64,
    pub ddr_bytes: u64,
    pub hbm_accesses: u64,
    pub ddr_accesses: u64,
}

impl MemorySystem {
    pub fn new(hbm: MemoryConfig, ddr: MemoryConfig) -> Self {
        let ch = hbm.channels as usize;
        Self {
            hbm,
            ddr,
            hbm_channel_ready: vec![0.0; ch],
            ddr_ready: 0.0,
            hbm_bytes: 0,
            ddr_bytes: 0,
            hbm_accesses: 0,
            ddr_accesses: 0,
        }
    }

    fn hbm_channel_bw(&self) -> f64 {
        self.hbm.per_channel_gbs() * self.hbm.burst_efficiency
    }

    /// Issue a single-channel transfer at `now`; returns completion time.
    pub fn transfer(&mut self, now: f64, space: MemSpace, bytes: u64) -> f64 {
        match space {
            MemSpace::Hbm { channel } => {
                let ch = (channel as usize) % self.hbm_channel_ready.len();
                let start = now.max(self.hbm_channel_ready[ch]);
                let dur = self.hbm.latency_ns + bytes as f64 / self.hbm_channel_bw();
                self.hbm_channel_ready[ch] = start + dur;
                self.hbm_bytes += bytes;
                self.hbm_accesses += 1;
                start + dur
            }
            MemSpace::Ddr => {
                let start = now.max(self.ddr_ready);
                let dur = self.ddr.latency_ns
                    + bytes as f64
                        / (self.ddr.bandwidth_gbs * self.ddr.burst_efficiency);
                self.ddr_ready = start + dur;
                self.ddr_bytes += bytes;
                self.ddr_accesses += 1;
                start + dur
            }
        }
    }

    /// Issue any LD/ST instruction (merged forms expand to concurrent
    /// per-channel legs); returns completion time of the slowest leg.
    pub fn issue(&mut self, now: f64, inst: &Inst) -> f64 {
        self.issue_scaled(now, inst, 1)
    }

    /// Issue with a traffic multiplier: `scale` SLRs run the same stream
    /// concurrently over the shared channels, so each leg carries
    /// `scale×` the bytes (engine::mem_scale).
    ///
    /// Merged forms are walked channel-by-channel inline rather than via
    /// `Inst::expand()` — this is the simulator's hottest loop and the
    /// per-instruction Vec allocation was its top cost (§Perf).
    pub fn issue_scaled(&mut self, now: f64, inst: &Inst, scale: u64) -> f64 {
        match *inst {
            Inst::Ld { src, bytes, .. } => self.transfer(now, src, bytes as u64 * scale),
            Inst::St { dst, bytes, .. } => self.transfer(now, dst, bytes as u64 * scale),
            Inst::LdMerged { first_channel, channels, bytes, .. }
            | Inst::StMerged { first_channel, channels, bytes, .. } => {
                // Legs all start at `now` on distinct channels —
                // concurrency is captured by per-channel ready times.
                // u32 math mod 256: matches Inst::expand(), never
                // overflow-panics on a run the verifier would reject.
                let mut done = now;
                for c in 0..channels {
                    let channel = ((first_channel as u32 + c as u32) % 256) as u8;
                    done = done.max(self.transfer(
                        now,
                        MemSpace::Hbm { channel },
                        bytes as u64 * scale,
                    ));
                }
                done
            }
            _ => now,
        }
    }

    /// Earliest time every channel is idle.
    pub fn quiescent(&self) -> f64 {
        self.hbm_channel_ready
            .iter()
            .fold(self.ddr_ready, |m, &t| m.max(t))
    }

    /// Achieved HBM bandwidth over a window of `total_ns`, as a fraction
    /// of peak (Table 5's metric).
    pub fn hbm_bw_utilization(&self, total_ns: f64) -> f64 {
        if total_ns <= 0.0 {
            return 0.0;
        }
        let achieved = self.hbm_bytes as f64 / total_ns; // GB/s
        achieved / self.hbm.bandwidth_gbs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Platform;
    use crate::isa::OnChipBuf;

    fn mem() -> MemorySystem {
        let p = Platform::u280();
        MemorySystem::new(p.hbm, p.ddr)
    }

    #[test]
    fn large_transfer_time_tracks_bandwidth() {
        let mut m = mem();
        let bytes = 1 << 20; // 1 MiB on one channel
        let done = m.transfer(0.0, MemSpace::Hbm { channel: 0 }, bytes);
        let bw = m.hbm.per_channel_gbs() * m.hbm.burst_efficiency;
        let expect = m.hbm.latency_ns + bytes as f64 / bw;
        assert!((done - expect).abs() < 1e-6);
    }

    #[test]
    fn same_channel_serializes_different_channels_overlap() {
        let mut m = mem();
        let b = 1 << 20;
        let t1 = m.transfer(0.0, MemSpace::Hbm { channel: 0 }, b);
        let t2 = m.transfer(0.0, MemSpace::Hbm { channel: 0 }, b);
        assert!(t2 > t1 * 1.9, "same channel must serialize");
        let mut m2 = mem();
        let u1 = m2.transfer(0.0, MemSpace::Hbm { channel: 0 }, b);
        let u2 = m2.transfer(0.0, MemSpace::Hbm { channel: 1 }, b);
        assert!((u1 - u2).abs() < 1e-9, "different channels overlap");
    }

    #[test]
    fn merged_ld_is_faster_than_serial_lds() {
        // The §5.2 optimization: 8 concurrent channel legs vs 8 serial
        // accesses on one channel.
        let total = 8 * (1 << 18);
        let mut m1 = mem();
        let merged = Inst::LdMerged {
            first_channel: 0,
            channels: 8,
            dst: OnChipBuf::Weight,
            addr: 0,
            bytes: (total / 8) as u32,
        };
        let t_merged = m1.issue(0.0, &merged);
        let mut m2 = mem();
        let mut t_serial = 0.0;
        for _ in 0..8 {
            let ld = Inst::Ld {
                src: MemSpace::Hbm { channel: 3 },
                dst: OnChipBuf::Weight,
                addr: 0,
                bytes: (total / 8) as u32,
            };
            t_serial = m2.issue(t_serial, &ld);
        }
        assert!(
            t_merged < t_serial / 6.0,
            "merged {t_merged:.0} ns vs serial {t_serial:.0} ns"
        );
    }

    #[test]
    fn small_access_prefers_ddr() {
        // §4.4: at ~128 B the DDR (lower latency) beats HBM.
        let mut m = mem();
        let t_hbm = m.transfer(0.0, MemSpace::Hbm { channel: 0 }, 128);
        let mut m2 = mem();
        let t_ddr = m2.transfer(0.0, MemSpace::Ddr, 128);
        assert!(t_ddr < t_hbm);
    }

    #[test]
    fn bandwidth_utilization_bounded() {
        let mut m = mem();
        let done = m.issue(
            0.0,
            &Inst::LdMerged {
                first_channel: 0,
                channels: 32,
                dst: OnChipBuf::Weight,
                addr: 0,
                bytes: 1 << 20,
            },
        );
        let util = m.hbm_bw_utilization(done);
        assert!(util > 0.5 && util <= 1.0, "util = {util}");
    }

    #[test]
    fn accounting_accumulates() {
        let mut m = mem();
        m.transfer(0.0, MemSpace::Hbm { channel: 0 }, 1000);
        m.transfer(0.0, MemSpace::Ddr, 500);
        assert_eq!(m.hbm_bytes, 1000);
        assert_eq!(m.ddr_bytes, 500);
        assert_eq!(m.hbm_accesses, 1);
        assert_eq!(m.ddr_accesses, 1);
    }
}
