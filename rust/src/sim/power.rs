//! Power model (xbutil-style, §6.1): board power decomposed into static,
//! compute-proportional, and memory-traffic-proportional parts.
//!
//! Calibrated so a U280 at full decode load draws ≈ 45 W (the class of
//! numbers xbutil reports for this design) and energy efficiency lands in
//! the Token/J regime of Fig. 13.

use crate::config::Platform;

use super::engine::SimReport;

#[derive(Debug, Clone)]
pub struct PowerModel {
    /// Idle/static board power, W.
    pub static_w: f64,
    /// Dynamic power at 100% DSP activity, W.
    pub compute_w: f64,
    /// Dynamic power at 100% HBM bandwidth, W.
    pub memory_w: f64,
    /// Peak MACs/ns of the configuration (to normalize compute activity).
    peak_macs_per_ns: f64,
    hbm_peak_gbs: f64,
}

impl PowerModel {
    pub fn for_platform(p: &Platform, macs_per_cycle: u64) -> Self {
        // FPGA split: roughly 40% static + IO, 35% DSP/logic, 25% HBM at
        // full load, scaled to the board's power envelope.
        Self {
            static_w: 0.40 * p.power_w,
            compute_w: 0.35 * p.power_w,
            memory_w: 0.25 * p.power_w,
            peak_macs_per_ns: macs_per_cycle as f64 * p.freq_mhz * 1e-3,
            hbm_peak_gbs: p.hbm.bandwidth_gbs,
        }
    }

    /// Average power over a simulated window, W.
    pub fn avg_watts(&self, r: &SimReport) -> f64 {
        if r.total_ns <= 0.0 {
            return self.static_w;
        }
        let compute_act =
            (r.macs as f64 / r.total_ns) / self.peak_macs_per_ns;
        let mem_act = (r.hbm_bytes as f64 / r.total_ns) / self.hbm_peak_gbs;
        self.static_w
            + self.compute_w * compute_act.min(1.0)
            + self.memory_w * mem_act.min(1.0)
    }

    /// Energy for the window, joules.
    pub fn energy_j(&self, r: &SimReport) -> f64 {
        self.avg_watts(r) * r.total_ns * 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AcceleratorConfig, Platform};

    fn model() -> PowerModel {
        let p = Platform::u280();
        let a = AcceleratorConfig::for_u280();
        PowerModel::for_platform(&p, a.macs_per_cycle())
    }

    fn report(macs: u64, bytes: u64, ns: f64) -> SimReport {
        SimReport { total_ns: ns, macs, hbm_bytes: bytes, ..Default::default() }
    }

    #[test]
    fn idle_draws_static_only() {
        let m = model();
        let w = m.avg_watts(&report(0, 0, 1e6));
        assert!((w - m.static_w).abs() < 1e-9);
    }

    #[test]
    fn full_load_approaches_board_power() {
        let m = model();
        // 1 ms at peak compute and peak bandwidth.
        let ns = 1e6;
        let macs = (m.peak_macs_per_ns * ns) as u64;
        let bytes = (m.hbm_peak_gbs * ns) as u64;
        let w = m.avg_watts(&report(macs, bytes, ns));
        let total = m.static_w + m.compute_w + m.memory_w;
        assert!((w - total).abs() / total < 0.01, "w = {w}, envelope = {total}");
        assert!((total - 45.0).abs() < 1.0, "U280 envelope ≈ 45 W");
    }

    #[test]
    fn energy_scales_with_time() {
        let m = model();
        let e1 = m.energy_j(&report(0, 0, 1e6));
        let e2 = m.energy_j(&report(0, 0, 2e6));
        assert!((e2 / e1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn memory_bound_decode_power_below_envelope() {
        // Decode is bandwidth-heavy, compute-light: power should sit
        // between static and full load.
        let m = model();
        let ns = 1e6;
        let bytes = (0.66 * m.hbm_peak_gbs * ns) as u64; // 66% BW util
        let macs = (0.10 * m.peak_macs_per_ns * ns) as u64; // 10% compute
        let w = m.avg_watts(&report(macs, bytes, ns));
        assert!(w > m.static_w && w < 0.9 * (m.static_w + m.compute_w + m.memory_w));
    }
}
