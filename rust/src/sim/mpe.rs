//! MPE timing model (§3.2): MM and MV mode cycle counts for the unified
//! Matrix Processing Engine, including the CSD-chain sparse efficiency
//! and the §3.2.2 MV-mode reparallelization.

use crate::config::AcceleratorConfig;
use crate::isa::Sparsity;

/// Timing model of one accelerator's worth of MPEs.
#[derive(Debug, Clone)]
pub struct MpeModel {
    pub accel: AcceleratorConfig,
    pub freq_mhz: f64,
    /// Whether the configurable sparse DSP chain is present.  Without it
    /// (the Fig. 14 "naive" rung) sparse matrices are computed at dense
    /// cost — the GPU-like behaviour the paper contrasts against.
    pub csd_chain: bool,
}

/// Efficiency knobs calibrated once against the paper's utilization data.
/// Dense MM keeps ~95% of peak (pipeline fill, edge tiles); the CSD-chain
/// keeps ~88% under N:M (DG mismatch, RN overhead) — the residual loss
/// the paper attributes to data mismatch between DGs.
const DENSE_EFF: f64 = 0.95;
const SPARSE_EFF: f64 = 0.88;
/// MV mode cannot use the p_m dimension (§3.2.2): utilization of the
/// compute array is p_k·p_n / (p_m·p_k·p_n), but the re-tiled [p_k', p_n']
/// recovers most lanes for weight-parallel work; the decode stage is
/// memory-bound anyway. This factor is the fraction of peak MACs usable
/// in MV mode after re-parallelization.
const MV_ARRAY_FRACTION: f64 = 0.5;

impl MpeModel {
    pub fn new(accel: AcceleratorConfig, freq_mhz: f64, csd_chain: bool) -> Self {
        Self { accel, freq_mhz, csd_chain }
    }

    fn ns_per_cycle(&self) -> f64 {
        1e3 / self.freq_mhz
    }

    /// Dense-equivalent MACs/cycle of the whole device.
    fn peak_macs_per_cycle(&self) -> f64 {
        self.accel.macs_per_cycle() as f64
    }

    /// Effective density: without the CSD chain, sparsity gives no
    /// speedup (unstructured-sparsity-on-GPU effect from §1).
    fn effective_density(&self, s: Sparsity) -> (f64, f64) {
        match s {
            Sparsity::Dense => (1.0, DENSE_EFF),
            _ if !self.csd_chain => (1.0, DENSE_EFF),
            Sparsity::Nm { .. } => (s.density(), SPARSE_EFF),
            Sparsity::BlockSparse { .. } => (s.density(), SPARSE_EFF),
        }
    }

    /// ns of compute for an MM of shape (m × k) · (k × n).
    pub fn mm_ns(&self, m: u64, k: u64, n: u64, sparsity: Sparsity) -> f64 {
        let (density, eff) = self.effective_density(sparsity);
        let macs = (m * k * n) as f64 * density;
        let cycles = macs / (self.peak_macs_per_cycle() * eff);
        // Pipeline fill: one pass of the systolic-ish MPU per output tile.
        let fill = (k as f64 / self.accel.p_k as f64).ceil();
        (cycles + fill) * self.ns_per_cycle()
    }

    /// ns of compute for an MV of shape (1 × k) · (k × n) (§3.2.2).
    pub fn mv_ns(&self, k: u64, n: u64, sparsity: Sparsity) -> f64 {
        let (density, eff) = self.effective_density(sparsity);
        let macs = (k * n) as f64 * density;
        let peak = self.peak_macs_per_cycle() * MV_ARRAY_FRACTION;
        let cycles = macs / (peak * eff);
        (cycles + self.accel.p_k as f64) * self.ns_per_cycle()
    }

    /// Useful MACs per ns in MV mode — used by the engine to decide
    /// whether a layer is memory- or compute-bound.
    pub fn mv_macs_per_ns(&self) -> f64 {
        self.peak_macs_per_cycle() * MV_ARRAY_FRACTION * self.freq_mhz * 1e-3
    }

    /// Achieved-vs-peak compute efficiency for a workload of
    /// `useful_macs` that took `ns` (runtime DSP utilization, the §3.2
    /// metric improved 1.6× by the CSD chain).
    pub fn compute_efficiency(&self, useful_macs: u64, ns: f64) -> f64 {
        if ns <= 0.0 {
            return 0.0;
        }
        let cycles = ns / self.ns_per_cycle();
        useful_macs as f64 / (cycles * self.peak_macs_per_cycle())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;

    fn model(csd: bool) -> MpeModel {
        MpeModel::new(AcceleratorConfig::for_u280(), 225.0, csd)
    }

    #[test]
    fn dense_mm_near_peak() {
        let m = model(true);
        let ns = m.mm_ns(512, 4096, 4096, Sparsity::Dense);
        let macs = 512u64 * 4096 * 4096;
        let eff = m.compute_efficiency(macs, ns);
        assert!(eff > 0.85 && eff <= 1.0, "eff = {eff}");
    }

    #[test]
    fn nm_sparsity_cuts_mm_time_with_csd_chain() {
        let m = model(true);
        let dense = m.mm_ns(512, 4096, 4096, Sparsity::Dense);
        let sparse = m.mm_ns(512, 4096, 4096, Sparsity::Nm { n: 8, m: 16 });
        let speedup = dense / sparse;
        assert!(
            speedup > 1.6 && speedup < 2.1,
            "8:16 should give ~1.8x, got {speedup}"
        );
    }

    #[test]
    fn without_csd_chain_sparsity_gives_nothing() {
        // The §1 observation: 75% unstructured sparsity → no end-to-end
        // speedup on architectures without sparse datapaths.
        let m = model(false);
        let dense = m.mm_ns(512, 4096, 4096, Sparsity::Dense);
        let sparse = m.mm_ns(512, 4096, 4096, Sparsity::Nm { n: 4, m: 16 });
        assert!((dense - sparse).abs() / dense < 1e-9);
    }

    #[test]
    fn mv_mode_slower_per_mac_than_mm() {
        let m = model(true);
        let k = 4096u64;
        let n = 4096u64;
        let mm = m.mm_ns(128, k, n, Sparsity::Dense) / 128.0;
        let mv = m.mv_ns(k, n, Sparsity::Dense);
        assert!(mv > mm, "per-token MV {mv} should exceed amortized MM {mm}");
    }

    #[test]
    fn block_sparse_scales_sddmm() {
        let m = model(true);
        let full = m.mm_ns(2048, 128, 2048, Sparsity::Dense);
        let half = m.mm_ns(2048, 128, 2048, Sparsity::BlockSparse { density_256: 128 });
        let ratio = full / half;
        assert!(ratio > 1.7 && ratio < 2.2, "ratio = {ratio}");
    }
}
