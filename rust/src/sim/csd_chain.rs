//! Bit-true functional + cycle model of the Configurable Sparse DSP chain
//! (CSD-Chain, §3.2.1, Fig. 5(d)/6).
//!
//! A VPU is one CSD-chain: a sequence of DSP groups (DGs), each holding
//! `dsp_per_group` DSP48 cores cascaded in a fixed path.  Between DGs the
//! cascade is *configurable*:
//!
//! - **Sparse MUX** — selects, for each DSP input, the activation matching
//!   the weight's stored in-group index, so only nonzeros enter the MACs.
//! - **Reduction Node (RN)** — can break the chain after a DG so the chain
//!   produces N partial outputs per pass (N:M mode) instead of one.
//! - **Overflow Adjust Unit (OAU)** — splits the running 18-bit cascade
//!   accumulation into MSP/LSP so long chains never overflow; the MSP is
//!   recombined at the next RN.  Skipped for chains of ≤ 8 DSPs.
//!
//! The functional model here is integer-exact (INT8 × INT8 → 18-bit
//! accumulate with MSP/LSP splitting) and verified against a plain i64
//! dot product — this is the architectural claim of Fig. 6: dense and
//! sparse modes both use every DSP every cycle.

/// One DSP48: two packed INT8 MACs per cycle (wp486 packing).
pub const MACS_PER_DSP: u64 = 2;

/// Max DSPs on a chain before the OAU must be active (18-bit guard: a
/// 18-bit accumulator never overflows when ≤ 8 16-bit products are summed).
pub const OAU_FREE_CHAIN: usize = 8;

/// 18-bit accumulator limits of the DSP48 cascade path we model.
const ACC_BITS: u32 = 18;
const ACC_MAX: i32 = (1 << (ACC_BITS - 1)) - 1;
const ACC_MIN: i32 = -(1 << (ACC_BITS - 1));

/// Configurable sparse DSP chain.
#[derive(Debug, Clone)]
pub struct CsdChain {
    /// DSP48 cores per DSP group (paper: 2).
    pub dsp_per_group: usize,
    /// DSP groups on the chain.
    pub groups: usize,
}

/// Result of driving the chain for one pass.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChainOutput {
    /// Partial-sum outputs produced at reduction nodes (1 in dense mode,
    /// N in N:M sparse mode).
    pub outputs: Vec<i64>,
    /// DSP-cycles consumed (all DSPs active every cycle — the Fig. 6
    /// full-utilization property; checked by tests).
    pub dsp_cycles: u64,
    /// Whether the OAU was engaged (chain longer than OAU_FREE_CHAIN).
    pub oau_active: bool,
}

impl CsdChain {
    pub fn new(dsp_per_group: usize, groups: usize) -> Self {
        assert!(dsp_per_group >= 1 && groups >= 1);
        Self { dsp_per_group, groups }
    }

    /// Total DSP48 cores on the chain.
    pub fn dsps(&self) -> usize {
        self.dsp_per_group * self.groups
    }

    /// MAC slots per pass (2 INT8 MACs per DSP).
    pub fn mac_slots(&self) -> usize {
        self.dsps() * MACS_PER_DSP as usize
    }

    /// Dense mode: one dot product of length `mac_slots()`.
    ///
    /// weights/acts: exactly `mac_slots()` INT8 values. The chain
    /// cascades group to group; the OAU splits the accumulation into
    /// MSP/LSP when the chain exceeds `OAU_FREE_CHAIN` DSPs and the final
    /// RN recombines — returning the exact sum.
    pub fn run_dense(&self, weights: &[i8], acts: &[i8]) -> ChainOutput {
        assert_eq!(weights.len(), self.mac_slots());
        assert_eq!(acts.len(), self.mac_slots());
        let oau = self.dsps() > OAU_FREE_CHAIN;
        let mut lsp: i32 = 0; // cascaded low part (stays in 18 bits)
        let mut msp: i64 = 0; // accumulated high part (recombined at RN)
        let per_group = self.dsp_per_group * MACS_PER_DSP as usize;
        for g in 0..self.groups {
            for s in 0..per_group {
                let i = g * per_group + s;
                lsp += weights[i] as i32 * acts[i] as i32;
            }
            if oau {
                // OAU: keep the low ACC_BITS on the cascade, push the
                // overflowed part to the MSP path.
                while lsp > ACC_MAX {
                    lsp -= 1 << ACC_BITS;
                    msp += 1;
                }
                while lsp < ACC_MIN {
                    lsp += 1 << ACC_BITS;
                    msp -= 1;
                }
            }
        }
        let total = msp * (1i64 << ACC_BITS) + lsp as i64;
        ChainOutput {
            outputs: vec![total],
            dsp_cycles: self.dsps() as u64,
            oau_active: oau,
        }
    }

    /// N:M sparse mode (Fig. 6(b)): the chain is split by reduction nodes
    /// into `n_outputs` segments; each segment computes an independent
    /// MAC over its own gathered activations (the sparse MUX gathers
    /// `acts[idx]`), producing `n_outputs` results in one pass.
    ///
    /// `weights[o]`/`idx[o]` hold segment o's kept values and in-group
    /// activation indices; `acts` is the shared M-wide activation window.
    pub fn run_sparse(
        &self,
        weights: &[Vec<i8>],
        idx: &[Vec<usize>],
        acts: &[i8],
    ) -> ChainOutput {
        let n_outputs = weights.len();
        assert_eq!(idx.len(), n_outputs);
        assert!(n_outputs >= 1 && self.groups % n_outputs == 0,
            "reduction nodes must split the chain evenly: {} groups / {} outputs",
            self.groups, n_outputs);
        let seg_slots = self.mac_slots() / n_outputs;
        let seg_dsps = self.dsps() / n_outputs;
        let oau = seg_dsps > OAU_FREE_CHAIN;
        let mut outputs = Vec::with_capacity(n_outputs);
        for o in 0..n_outputs {
            assert!(
                weights[o].len() <= seg_slots,
                "segment {o} holds {} > {} slots",
                weights[o].len(),
                seg_slots
            );
            let mut lsp: i32 = 0;
            let mut msp: i64 = 0;
            for (k, &w) in weights[o].iter().enumerate() {
                // Sparse MUX: route the indexed activation to this MAC.
                lsp += w as i32 * acts[idx[o][k]] as i32;
                if oau && (lsp > ACC_MAX || lsp < ACC_MIN) {
                    while lsp > ACC_MAX {
                        lsp -= 1 << ACC_BITS;
                        msp += 1;
                    }
                    while lsp < ACC_MIN {
                        lsp += 1 << ACC_BITS;
                        msp -= 1;
                    }
                }
            }
            outputs.push(msp * (1i64 << ACC_BITS) + lsp as i64);
        }
        ChainOutput { outputs, dsp_cycles: self.dsps() as u64, oau_active: oau }
    }

    /// Runtime DSP utilization of a pass that performed `useful_macs`
    /// MACs: the Fig. 6 claim is that both dense and N:M passes keep this
    /// at 1.0 when the segments are fully packed.
    pub fn utilization(&self, useful_macs: u64) -> f64 {
        useful_macs as f64 / self.mac_slots() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    fn i64_dot(w: &[i8], a: &[i8]) -> i64 {
        w.iter().zip(a).map(|(&x, &y)| x as i64 * y as i64).sum()
    }

    #[test]
    fn dense_matches_exact_dot_short_chain() {
        // 4 DSPs (≤ 8): OAU skipped.
        let c = CsdChain::new(2, 2);
        let w: Vec<i8> = vec![127, -128, 100, -5, 33, 7, -90, 55];
        let a: Vec<i8> = vec![-128, 127, 99, 2, -1, 13, 44, -66];
        let out = c.run_dense(&w, &a);
        assert!(!out.oau_active);
        assert_eq!(out.outputs, vec![i64_dot(&w, &a)]);
    }

    #[test]
    fn dense_long_chain_engages_oau_and_stays_exact() {
        // 32 DSPs: worst-case accumulation far exceeds 18 bits; the
        // MSP/LSP split must still recombine to the exact value.
        let c = CsdChain::new(2, 16);
        let w: Vec<i8> = vec![127; c.mac_slots()];
        let a: Vec<i8> = vec![-128; c.mac_slots()];
        let out = c.run_dense(&w, &a);
        assert!(out.oau_active);
        assert_eq!(out.outputs, vec![i64_dot(&w, &a)]);
    }

    #[test]
    fn sparse_mode_produces_n_exact_outputs() {
        // 8 groups split by RNs into 4 segments (2:4-style for 4 outputs).
        let c = CsdChain::new(2, 8);
        let acts: Vec<i8> = (0..16).map(|i| (i * 7 - 50) as i8).collect();
        let weights: Vec<Vec<i8>> = (0..4)
            .map(|o| (0..8).map(|k| ((o * 13 + k * 5) % 120) as i8).collect())
            .collect();
        let idx: Vec<Vec<usize>> =
            (0..4).map(|o| (0..8).map(|k| (o + k * 2) % 16).collect()).collect();
        let out = c.run_sparse(&weights, &idx, &acts);
        assert_eq!(out.outputs.len(), 4);
        for o in 0..4 {
            let want: i64 = weights[o]
                .iter()
                .zip(&idx[o])
                .map(|(&w, &i)| w as i64 * acts[i] as i64)
                .sum();
            assert_eq!(out.outputs[o], want, "output {o}");
        }
    }

    #[test]
    fn dense_and_sparse_use_all_dsps() {
        // The headline Fig. 6 property: same dsp_cycles either way.
        let c = CsdChain::new(2, 8);
        let w: Vec<i8> = vec![1; c.mac_slots()];
        let a: Vec<i8> = vec![1; c.mac_slots()];
        let dense = c.run_dense(&w, &a);
        let seg = c.mac_slots() / 4;
        let ws: Vec<Vec<i8>> = (0..4).map(|_| vec![1i8; seg]).collect();
        let idx: Vec<Vec<usize>> = (0..4).map(|_| (0..seg).collect()).collect();
        let sparse = c.run_sparse(&ws, &idx, &vec![1i8; seg]);
        assert_eq!(dense.dsp_cycles, sparse.dsp_cycles);
        assert_eq!(c.utilization(c.mac_slots() as u64), 1.0);
    }

    #[test]
    fn property_dense_exactness() {
        proptest::check("csd dense == i64 dot", |r| {
            let groups = [2usize, 4, 8, 16][r.below(4) as usize];
            let c = CsdChain::new(2, groups);
            let w: Vec<i8> =
                (0..c.mac_slots()).map(|_| (r.below(256) as i64 - 128) as i8).collect();
            let a: Vec<i8> =
                (0..c.mac_slots()).map(|_| (r.below(256) as i64 - 128) as i8).collect();
            assert_eq!(c.run_dense(&w, &a).outputs[0], i64_dot(&w, &a));
        });
    }

    #[test]
    fn property_sparse_exactness() {
        proptest::check("csd sparse == gathered dot", |r| {
            let n_out = [1usize, 2, 4][r.below(3) as usize];
            let c = CsdChain::new(2, 8);
            let m = 16usize;
            let acts: Vec<i8> =
                (0..m).map(|_| (r.below(256) as i64 - 128) as i8).collect();
            let seg = c.mac_slots() / n_out;
            let weights: Vec<Vec<i8>> = (0..n_out)
                .map(|_| {
                    (0..seg).map(|_| (r.below(256) as i64 - 128) as i8).collect()
                })
                .collect();
            let idx: Vec<Vec<usize>> = (0..n_out)
                .map(|_| (0..seg).map(|_| r.below(m as u64) as usize).collect())
                .collect();
            let out = c.run_sparse(&weights, &idx, &acts);
            for o in 0..n_out {
                let want: i64 = weights[o]
                    .iter()
                    .zip(&idx[o])
                    .map(|(&w, &i)| w as i64 * acts[i] as i64)
                    .sum();
                assert_eq!(out.outputs[o], want);
            }
        });
    }
}
