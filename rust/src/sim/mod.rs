//! Cycle-approximate simulator of the FlightLLM accelerator (§3, §4).
//!
//! The simulator executes the same ISA the compiler emits, against the
//! platform + accelerator organization from `config`.  It is the stand-in
//! for the U280 board / VHK158 RTL-verified simulator of §6.1 (see
//! DESIGN.md §Substitutions): absolute nanoseconds are approximate, the
//! *relationships* (who wins, ablation deltas, bandwidth utilization) are
//! what it is calibrated to reproduce.
//!
//! Structure:
//! - `csd_chain` — bit-true functional + cycle model of the configurable
//!   sparse DSP chain (sparse MUX, reduction nodes, overflow adjust).
//! - `mpe` — MM/MV timing on the Matrix Processing Engine.
//! - `sfu` — MISC timing (two-phase reductions, element-wise ops).
//! - `memory` — HBM/DDR channel model (§4.4 hybrid placement).
//! - `engine` — in-order instruction execution with double-buffer overlap
//!   (§3.2.2) and SLR synchronization.
//! - `power` — xbutil-style power model for the energy-efficiency plots.

pub mod csd_chain;
pub mod engine;
pub mod memory;
pub mod mpe;
pub mod power;
pub mod sfu;

pub use csd_chain::CsdChain;
pub use engine::{Engine, SimReport};
pub use memory::MemorySystem;
pub use mpe::MpeModel;
pub use power::PowerModel;
pub use sfu::SfuModel;
