//! Evaluation metrics (§6.1): end-to-end latency, decode throughput,
//! energy efficiency (Token/J) and cost efficiency (Token/s/$), plus the
//! table/figure-shaped report rows the benches print.

/// One [prefill, decode] evaluation point (the x-axis of Figs. 11-13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvalPoint {
    pub prefill: u64,
    pub decode: u64,
}

impl EvalPoint {
    pub fn label(&self) -> String {
        format!("[{}, {}]", self.prefill, self.decode)
    }
}

/// The paper's evaluation grid (Fig. 11/12/13 x-axes).
pub fn paper_grid() -> Vec<EvalPoint> {
    let mut v = Vec::new();
    for &(p, d) in &[
        (32u64, 32u64),
        (64, 64),
        (128, 128),
        (128, 512),
        (512, 128),
        (512, 512),
        (1024, 512),
    ] {
        v.push(EvalPoint { prefill: p, decode: d });
    }
    v
}

/// An end-to-end measurement of one system on one point.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub system: String,
    pub point: EvalPoint,
    /// End-to-end latency for prefill + all decode steps, seconds.
    pub latency_s: f64,
    /// Decode throughput, tokens/s.
    pub decode_tps: f64,
    /// Average power, W.
    pub power_w: f64,
    /// Achieved HBM/DRAM bandwidth utilization (0..1).
    pub bw_util: f64,
    /// Hardware price, USD.
    pub price_usd: f64,
}

impl Measurement {
    /// Tokens per joule over the decode phase (Fig. 13 metric).
    pub fn tokens_per_joule(&self) -> f64 {
        if self.power_w <= 0.0 {
            return 0.0;
        }
        self.decode_tps / self.power_w
    }

    /// Tokens/s per dollar (the Fig. 1 cost-efficiency axis).
    pub fn tokens_per_s_per_dollar(&self) -> f64 {
        if self.price_usd <= 0.0 {
            return 0.0;
        }
        self.decode_tps / self.price_usd
    }
}

/// Geometric mean — the aggregation the paper uses for speedups.
pub fn geomean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = values.iter().map(|v| v.max(1e-300).ln()).sum();
    (log_sum / values.len() as f64).exp()
}

/// Format a paper-style table: header + aligned rows.  An empty header
/// degenerates to the title alone — `widths.len() - 1` below would
/// otherwise wrap around and try to allocate a usize::MAX-char rule.
pub fn format_table(title: &str, header: &[&str], rows: &[Vec<String>]) -> String {
    if header.is_empty() {
        return format!("== {title} ==\n");
    }
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:>w$}", w = w))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let hdr: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_of_equal_values() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_is_between_min_and_max() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    fn energy_and_cost_efficiency() {
        let m = Measurement {
            system: "test".into(),
            point: EvalPoint { prefill: 128, decode: 512 },
            latency_s: 10.0,
            decode_tps: 50.0,
            power_w: 25.0,
            bw_util: 0.6,
            price_usd: 8000.0,
        };
        assert!((m.tokens_per_joule() - 2.0).abs() < 1e-12);
        assert!((m.tokens_per_s_per_dollar() - 50.0 / 8000.0).abs() < 1e-15);
    }

    #[test]
    fn table_formatting_aligns() {
        let t = format_table(
            "Demo",
            &["a", "long_header"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
        assert!(t.contains("Demo"));
        assert!(t.lines().count() >= 4);
    }

    /// Regression: an empty header used to underflow the separator
    /// width (`widths.len() - 1` on a usize) and abort; it now prints
    /// the degenerate title-only table.
    #[test]
    fn empty_header_degenerates_instead_of_underflowing() {
        assert_eq!(format_table("Empty", &[], &[]), "== Empty ==\n");
        // Rows without a header degrade the same way (nothing to align).
        assert_eq!(format_table("Empty", &[], &[vec!["1".into()]]), "== Empty ==\n");
    }

    #[test]
    fn paper_grid_has_seven_points() {
        assert_eq!(paper_grid().len(), 7);
    }
}
