//! IR operation set. Deliberately close to the source model's layer
//! vocabulary — fusion happens in passes, tiling happens in the compiler.


use crate::isa::{MiscOp, Sparsity};

/// Attention flavor after IR export.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AttentionKind {
    /// Prefill: QK^T (SDDMM under the block mask), softmax, S·V.
    Prefill { block_density: f64 },
    /// Decode: MV against the KV cache at context length `ctx`.
    Decode,
}

/// One IR operation.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Token embedding gather.
    Embed,
    /// Linear layer y = x·W^T (+SiLU/eltwise once fused).
    Linear {
        name: String,
        out_dim: u64,
        in_dim: u64,
        sparsity: Sparsity,
        weight_bits: f64,
        /// MISC ops fused onto the MPE output stream (filled by passes).
        fused: Vec<MiscOp>,
    },
    /// Attention over `heads` heads at head_dim `hd`.
    Attention { kind: AttentionKind, heads: u64, hd: u64, fused_softmax: bool },
    /// Standalone MISC op over a `len`-element vector (SFU).
    Misc { op: MiscOp, len: u64 },
    /// Data-layout view (reshape/transpose-free): removed by passes
    /// because it does not change the physical arrangement (§5.4:
    /// "removing the view() layers that do not impact data arrangement").
    View { name: String },
    /// Residual add (eltwise; fusable).
    Residual { len: u64 },
    /// LM head projection to vocab.
    Head { vocab: u64, dim: u64 },
    /// KV-cache append (decode) or bulk write (prefill).
    KvWrite { bytes: u64 },
}

impl Op {
    pub fn is_view(&self) -> bool {
        matches!(self, Op::View { .. })
    }

    /// Is this op eligible to fuse *into* a preceding Linear?
    pub fn fusable_misc(&self) -> Option<MiscOp> {
        match self {
            Op::Misc { op, .. }
                if matches!(
                    op,
                    MiscOp::Silu | MiscOp::Gelu | MiscOp::EltwiseAdd | MiscOp::EltwiseMul
                ) =>
            {
                Some(*op)
            }
            Op::Residual { .. } => Some(MiscOp::EltwiseAdd),
            _ => None,
        }
    }
}
