//! IR optimization passes (§5.4, Fig. 9 "IR optimization"):
//!
//! 1. `remove_views` — view() layers don't change physical layout, drop
//!    them.
//! 2. `fuse` — attention+softmax fusion and linear+{SiLU, Gelu, Eltwise,
//!    Residual} fusion, so the fused MISC rides the MPE output stream
//!    instead of round-tripping through off-chip memory (§4.1).

use crate::isa::MiscOp;

use super::graph::Graph;
use super::ops::Op;

/// Drop all `View` nodes. Returns how many were removed.
pub fn remove_views(g: &mut Graph) -> usize {
    let before = g.nodes.len();
    g.nodes.retain(|n| !n.op.is_view());
    for (i, n) in g.nodes.iter_mut().enumerate() {
        n.id = i;
    }
    before - g.nodes.len()
}

/// Fuse softmax into the preceding attention node and fusable MISC ops
/// (SiLU/Gelu/Eltwise/Residual) into the preceding linear.  Returns the
/// number of fused (removed) nodes.
pub fn fuse(g: &mut Graph) -> usize {
    let mut out: Vec<super::graph::Node> = Vec::with_capacity(g.nodes.len());
    let mut fused = 0usize;
    for node in g.nodes.drain(..) {
        // softmax directly after attention → fold in.
        if let Op::Misc { op: MiscOp::Softmax, .. } = node.op {
            if let Some(prev) = out.last_mut() {
                if let Op::Attention { fused_softmax, .. } = &mut prev.op {
                    if !*fused_softmax {
                        *fused_softmax = true;
                        fused += 1;
                        continue;
                    }
                }
            }
        }
        // SiLU / Gelu / Eltwise / Residual after a linear → fold in.
        if let Some(misc) = node.op.fusable_misc() {
            if let Some(prev) = out.last_mut() {
                if let Op::Linear { fused: fl, .. } = &mut prev.op {
                    fl.push(misc);
                    fused += 1;
                    continue;
                }
            }
        }
        out.push(node);
    }
    for (i, n) in out.iter_mut().enumerate() {
        n.id = i;
    }
    g.nodes = out;
    fused
}

/// The standard pass pipeline.
pub fn optimize(g: &mut Graph) -> OptStats {
    let views = remove_views(g);
    let fused = fuse(g);
    OptStats { views_removed: views, ops_fused: fused }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OptStats {
    pub views_removed: usize,
    pub ops_fused: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CompressionConfig, ModelConfig};
    use crate::ir::graph::Stage;

    fn llama_graph() -> Graph {
        Graph::from_model(
            &ModelConfig::llama2_7b(),
            &CompressionConfig::paper_default(),
            Stage::Decode { ctx: 256 },
        )
    }

    #[test]
    fn remove_views_drops_all_views() {
        let mut g = llama_graph();
        let removed = remove_views(&mut g);
        assert!(removed >= 32, "expected at least one view per layer");
        assert_eq!(g.count_op(Op::is_view), 0);
        // ids renumbered consecutively
        for (i, n) in g.nodes.iter().enumerate() {
            assert_eq!(n.id, i);
        }
    }

    #[test]
    fn fuse_attaches_softmax_to_attention() {
        let mut g = llama_graph();
        remove_views(&mut g);
        fuse(&mut g);
        for n in &g.nodes {
            if let Op::Attention { fused_softmax, .. } = &n.op {
                assert!(*fused_softmax, "softmax must be fused into attention");
            }
            assert!(
                !matches!(n.op, Op::Misc { op: MiscOp::Softmax, .. }),
                "standalone softmax must be gone"
            );
        }
    }

    #[test]
    fn fuse_attaches_silu_and_eltwise_to_linears() {
        let mut g = llama_graph();
        remove_views(&mut g);
        fuse(&mut g);
        // w1 should carry SiLU, w3 should carry EltwiseMul (SwiGLU).
        let w1 = g
            .nodes
            .iter()
            .find_map(|n| match &n.op {
                Op::Linear { name, fused, .. } if name == "l0.w1" => Some(fused.clone()),
                _ => None,
            })
            .unwrap();
        assert!(w1.contains(&MiscOp::Silu), "w1 fused = {w1:?}");
        let w3 = g
            .nodes
            .iter()
            .find_map(|n| match &n.op {
                Op::Linear { name, fused, .. } if name == "l0.w3" => Some(fused.clone()),
                _ => None,
            })
            .unwrap();
        assert!(w3.contains(&MiscOp::EltwiseMul), "w3 fused = {w3:?}");
    }

    #[test]
    fn optimize_reduces_node_count_but_keeps_linears() {
        let mut g = llama_graph();
        let lin_before = g.count_op(|o| matches!(o, Op::Linear { .. }));
        let before = g.nodes.len();
        let stats = optimize(&mut g);
        assert!(stats.views_removed > 0 && stats.ops_fused > 0);
        assert!(g.nodes.len() < before);
        assert_eq!(g.count_op(|o| matches!(o, Op::Linear { .. })), lin_before);
    }

    #[test]
    fn two_phase_norms_stay_standalone() {
        // RMSNorm/LayerNorm/Softmax need the full vector before they can
        // run (§3.3 two-phase) — they must NOT fuse into linears.
        let mut g = llama_graph();
        optimize(&mut g);
        assert!(
            g.count_op(|o| matches!(o, Op::Misc { op: MiscOp::RmsNorm, .. })) > 0,
            "norms must survive fusion"
        );
    }
}
