//! IR graph: a topologically-ordered op list with tensor metadata —
//! sufficient for a feed-forward transformer (no general dataflow needed)
//! while keeping explicit producer/consumer edges for the passes.


use crate::config::{CompressionConfig, ModelConfig};
use crate::isa::{MiscOp, Sparsity};

use super::ops::{AttentionKind, Op};

pub type NodeId = usize;
pub type TensorId = usize;

/// Which stage this graph executes (decides MM vs MV lowering).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Prefill over `n` prompt tokens.
    Prefill { n: u64 },
    /// One decode step at context length `ctx`.
    Decode { ctx: u64 },
}

impl Stage {
    /// Rows of activation matrices in this stage (the M of MM/MV).
    pub fn m(&self) -> u64 {
        match self {
            Stage::Prefill { n } => *n,
            Stage::Decode { .. } => 1,
        }
    }
}

/// Tensor metadata: logical bytes + where layout pass placed it.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub bytes: u64,
    /// Whether this is weight-like (streamed, HBM) or a small table /
    /// instruction-like blob (DDR candidate) — §4.4 placement policy.
    pub small_access: bool,
}

#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub op: Op,
    /// Tensors this op streams from off-chip (weights, indexes, KV).
    pub reads: Vec<TensorId>,
    /// Tensors written back off-chip (KV updates, final logits).
    pub writes: Vec<TensorId>,
}

#[derive(Debug, Clone)]
pub struct Graph {
    pub stage: Stage,
    pub nodes: Vec<Node>,
    pub tensors: Vec<Tensor>,
}

impl Graph {
    pub fn new(stage: Stage) -> Self {
        Self { stage, nodes: Vec::new(), tensors: Vec::new() }
    }

    pub fn add_tensor(&mut self, name: impl Into<String>, bytes: u64, small: bool) -> TensorId {
        let id = self.tensors.len();
        self.tensors.push(Tensor { name: name.into(), bytes, small_access: small });
        id
    }

    pub fn add_node(&mut self, op: Op, reads: Vec<TensorId>, writes: Vec<TensorId>) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node { id, op, reads, writes });
        id
    }

    /// Build the IR for one transformer forward at `stage` — the export
    /// step of Fig. 9 (model structure + weights + sparse indexes +
    /// attention masks), synthesized from the architecture description.
    pub fn from_model(m: &ModelConfig, c: &CompressionConfig, stage: Stage) -> Self {
        let mut g = Graph::new(stage);
        let wbytes = |o: u64, i: u64| -> u64 {
            (c.model_weight_bytes(o * i)).ceil() as u64
        };
        let seq = match stage {
            Stage::Prefill { n } => n,
            Stage::Decode { ctx } => ctx,
        };
        // OPT uses LayerNorm, LLaMA-family uses RMSNorm.
        let norm_misc = if m.name.starts_with("OPT") {
            MiscOp::LayerNorm
        } else {
            MiscOp::RmsNorm
        };
        let sparsity = if c.weight_pruning {
            // Average N over blocks, rounded to the nearest valid level.
            let n = ((c.weight_density * c.nm_m as f64).round() as u8).max(1);
            Sparsity::nm(n, c.nm_m as u8)
                .expect("compression recipe yields a degenerate N:M descriptor")
        } else {
            Sparsity::Dense
        };
        let wbits = if c.quantization { c.weight_bits } else { 16.0 };

        let emb = g.add_tensor("embed", m.vocab * m.dim * 2, false);
        g.add_node(Op::Embed, vec![emb], vec![]);

        for l in 0..m.n_layers {
            g.add_node(Op::Misc { op: norm_misc, len: m.dim }, vec![], vec![]);
            // QKV + O projections (N:M sparse path).
            for pname in ["wq", "wk", "wv", "wo"] {
                let t = g.add_tensor(
                    format!("l{l}.{pname}"),
                    wbytes(m.dim, m.dim),
                    false,
                );
                // The O projection is preceded by attention.
                if pname == "wo" {
                    let kv = g.add_tensor(
                        format!("l{l}.kv"),
                        m.kv_bytes(seq, (c.act_bits / 8).max(1) as u64) / m.n_layers,
                        false,
                    );
                    let kind = match stage {
                        Stage::Prefill { .. } => AttentionKind::Prefill {
                            block_density: c.effective_attn_density(),
                        },
                        Stage::Decode { .. } => AttentionKind::Decode,
                    };
                    g.add_node(
                        Op::Attention {
                            kind,
                            heads: m.n_heads,
                            hd: m.head_dim(),
                            fused_softmax: false,
                        },
                        vec![kv],
                        vec![],
                    );
                    g.add_node(
                        Op::Misc { op: MiscOp::Softmax, len: seq },
                        vec![],
                        vec![],
                    );
                    let kvw = g.add_tensor(
                        format!("l{l}.kv_new"),
                        2 * m.dim * stage.m() * (c.act_bits / 8).max(1) as u64,
                        false,
                    );
                    g.add_node(Op::KvWrite { bytes: g.tensors[kvw].bytes }, vec![], vec![kvw]);
                }
                g.add_node(
                    Op::Linear {
                        name: format!("l{l}.{pname}"),
                        out_dim: m.dim,
                        in_dim: m.dim,
                        sparsity,
                        weight_bits: wbits,
                        fused: vec![],
                    },
                    vec![t],
                    vec![],
                );
                if pname == "wv" {
                    // The export contains view() reshapes between the
                    // projections and attention (head split) — removed
                    // later by the optimizer.
                    g.add_node(Op::View { name: format!("l{l}.split_heads") }, vec![], vec![]);
                    g.add_node(Op::Misc { op: MiscOp::Rope, len: m.dim }, vec![], vec![]);
                }
            }
            g.add_node(Op::Residual { len: m.dim }, vec![], vec![]);
            g.add_node(Op::Misc { op: norm_misc, len: m.dim }, vec![], vec![]);
            // FFN (mixed-precision dequant path).
            for (pname, o, i) in m
                .layer_linears()
                .into_iter()
                .filter(|(p, _, _)| p.starts_with('w') && p.len() == 2 && !"qkvo".contains(&p[1..2]))
            {
                let t = g.add_tensor(format!("l{l}.{pname}"), wbytes(o, i), false);
                g.add_node(
                    Op::Linear {
                        name: format!("l{l}.{pname}"),
                        out_dim: o,
                        in_dim: i,
                        sparsity,
                        weight_bits: wbits,
                        fused: vec![],
                    },
                    vec![t],
                    vec![],
                );
                if pname == "w1" {
                    let act = match m.ffn {
                        crate::config::FfnKind::Relu2 => MiscOp::Gelu, // OPT uses ReLU; Gelu slot models the LUT op
                        crate::config::FfnKind::SwiGlu3 => MiscOp::Silu,
                    };
                    g.add_node(Op::Misc { op: act, len: o }, vec![], vec![]);
                }
                if pname == "w3" {
                    g.add_node(Op::Misc { op: MiscOp::EltwiseMul, len: o }, vec![], vec![]);
                }
            }
            g.add_node(Op::Residual { len: m.dim }, vec![], vec![]);
            g.add_node(Op::View { name: format!("l{l}.merge") }, vec![], vec![]);
        }
        g.add_node(Op::Misc { op: norm_misc, len: m.dim }, vec![], vec![]);
        let head = g.add_tensor("head", m.vocab * m.dim * 2, false);
        // Small-access DDR candidates: SFU lookup tables (§4.4).
        let lut = g.add_tensor("sfu_luts", 64 * 1024, true);
        g.add_node(Op::Misc { op: MiscOp::Silu, len: 0 }, vec![lut], vec![]);
        g.add_node(Op::Head { vocab: m.vocab, dim: m.dim }, vec![head], vec![]);
        g
    }

    /// Total off-chip weight bytes read once per forward.
    pub fn weight_bytes(&self) -> u64 {
        self.tensors.iter().filter(|t| !t.small_access).map(|t| t.bytes).sum()
    }

    pub fn count_op(&self, pred: impl Fn(&Op) -> bool) -> usize {
        self.nodes.iter().filter(|n| pred(&n.op)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CompressionConfig, ModelConfig};

    #[test]
    fn llama_graph_has_expected_shape() {
        let m = ModelConfig::llama2_7b();
        let c = CompressionConfig::paper_default();
        let g = Graph::from_model(&m, &c, Stage::Decode { ctx: 512 });
        // 7 linears per layer × 32 layers.
        assert_eq!(
            g.count_op(|o| matches!(o, Op::Linear { .. })),
            (7 * 32) as usize
        );
        // One attention per layer.
        assert_eq!(
            g.count_op(|o| matches!(o, Op::Attention { .. })),
            32
        );
        // Views exist before optimization.
        assert!(g.count_op(Op::is_view) > 0);
    }

    #[test]
    fn opt_graph_uses_two_ffn_mats() {
        let m = ModelConfig::opt_6_7b();
        let c = CompressionConfig::none();
        let g = Graph::from_model(&m, &c, Stage::Prefill { n: 128 });
        assert_eq!(
            g.count_op(|o| matches!(o, Op::Linear { .. })),
            (6 * 32) as usize
        );
    }

    #[test]
    fn compressed_weights_smaller_than_dense() {
        let m = ModelConfig::llama2_7b();
        let dense = Graph::from_model(&m, &CompressionConfig::none(), Stage::Decode { ctx: 1 });
        let comp = Graph::from_model(
            &m,
            &CompressionConfig::paper_default(),
            Stage::Decode { ctx: 1 },
        );
        assert!(comp.weight_bytes() < dense.weight_bytes() / 3);
    }

    #[test]
    fn prefill_attention_carries_block_density() {
        let m = ModelConfig::llama2_7b();
        let c = CompressionConfig::paper_default();
        let g = Graph::from_model(&m, &c, Stage::Prefill { n: 256 });
        let att = g
            .nodes
            .iter()
            .find_map(|n| match &n.op {
                Op::Attention { kind: AttentionKind::Prefill { block_density }, .. } => {
                    Some(*block_density)
                }
                _ => None,
            })
            .unwrap();
        assert!((att - c.attn_density).abs() < 1e-12);
    }
}
