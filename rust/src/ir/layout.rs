//! Memory layout assignment (Fig. 9 "address assign" + §4.4 policy):
//! large streaming tensors go to HBM, partitioned round-robin across
//! pseudo-channels to keep every channel busy; small-single-access data
//! (lookup tables, misc params) goes to DDR for its lower latency.

use std::collections::HashMap;


use crate::config::Platform;

use super::graph::{Graph, TensorId};

/// Where a tensor landed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// HBM starting at `addr`, striped over `channels` consecutive
    /// channels beginning at `first_channel`.
    Hbm { addr: u64, first_channel: u8, channels: u8 },
    Ddr { addr: u64 },
}

/// Result of address assignment.
#[derive(Debug, Clone)]
pub struct AddressMap {
    pub placements: HashMap<TensorId, Placement>,
    pub hbm_used: u64,
    pub ddr_used: u64,
}

/// Tensors above this single-access size stream from HBM (§4.4: "~M Bytes"
/// vs "~100 Bytes").
const SMALL_ACCESS_BYTES: u64 = 64 * 1024;

/// Channels ganged per large tensor — matches the 8-channel LD/ST merge.
const STRIPE_CHANNELS: u8 = 8;

pub fn assign_addresses(g: &Graph, platform: &Platform) -> Result<AddressMap, LayoutError> {
    let mut placements = HashMap::new();
    let mut hbm_cursor = 0u64;
    let mut ddr_cursor = 0u64;
    let mut next_first_channel: u8 = 0;
    let hbm_cap = (platform.hbm.capacity_gb * 1e9) as u64;
    let ddr_cap = (platform.ddr.capacity_gb * 1e9) as u64;

    for (id, t) in g.tensors.iter().enumerate() {
        if t.small_access || t.bytes <= SMALL_ACCESS_BYTES {
            let addr = ddr_cursor;
            ddr_cursor += align(t.bytes, 64);
            if ddr_cursor > ddr_cap {
                return Err(LayoutError::DdrOverflow { need: ddr_cursor, cap: ddr_cap });
            }
            placements.insert(id, Placement::Ddr { addr });
        } else {
            let addr = hbm_cursor;
            hbm_cursor += align(t.bytes, 4096);
            if hbm_cursor > hbm_cap {
                return Err(LayoutError::HbmOverflow { need: hbm_cursor, cap: hbm_cap });
            }
            let fc = next_first_channel;
            // Round-robin the stripe start so channels load evenly
            // ("partitioned into appropriate channels to prevent
            // inefficient access", §5.4).
            next_first_channel =
                (next_first_channel + STRIPE_CHANNELS) % platform.hbm.channels as u8;
            placements.insert(
                id,
                Placement::Hbm { addr, first_channel: fc, channels: STRIPE_CHANNELS },
            );
        }
    }
    Ok(AddressMap { placements, hbm_used: hbm_cursor, ddr_used: ddr_cursor })
}

fn align(v: u64, a: u64) -> u64 {
    v.div_ceil(a) * a
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LayoutError {
    HbmOverflow { need: u64, cap: u64 },
    DdrOverflow { need: u64, cap: u64 },
}

impl std::fmt::Display for LayoutError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LayoutError::HbmOverflow { need, cap } =>

                write!(f, "HBM overflow: need {need} B > {cap} B — model too large for always-on-chip decode without (more) compression"),
            LayoutError::DdrOverflow { need, cap } => {
                write!(f, "DDR overflow: need {need} B > {cap} B")
            }
        }
    }
}

impl std::error::Error for LayoutError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CompressionConfig, ModelConfig};
    use crate::ir::graph::Stage;
    use crate::ir::passes;

    fn laid_out(c: &CompressionConfig) -> (Graph, Result<AddressMap, LayoutError>) {
        let m = ModelConfig::llama2_7b();
        let mut g = Graph::from_model(&m, c, Stage::Decode { ctx: 2048 });
        passes::optimize(&mut g);
        let map = assign_addresses(&g, &Platform::u280());
        (g, map)
    }

    #[test]
    fn compressed_llama_fits_hbm() {
        let (_, map) = laid_out(&CompressionConfig::paper_default());
        let map = map.unwrap();
        assert!(map.hbm_used < 8_000_000_000, "hbm = {}", map.hbm_used);
        assert!(map.ddr_used > 0, "luts should land on DDR");
    }

    #[test]
    fn uncompressed_llama_overflows_hbm() {
        // fp16 LLaMA2-7B (13.5 GB) cannot live in U280's 8 GB HBM — the
        // motivation for the compression recipe.
        match laid_out(&CompressionConfig::none()).1 {
            Err(LayoutError::HbmOverflow { .. }) => {}
            other => panic!("expected HBM overflow, got {other:?}"),
        }
    }

    #[test]
    fn small_tensors_go_to_ddr() {
        let m = ModelConfig::tiny();
        let mut g = Graph::from_model(
            &m,
            &CompressionConfig::paper_default(),
            Stage::Decode { ctx: 64 },
        );
        passes::optimize(&mut g);
        let map = assign_addresses(&g, &Platform::u280()).unwrap();
        for (id, t) in g.tensors.iter().enumerate() {
            if t.small_access {
                assert!(
                    matches!(map.placements[&id], Placement::Ddr { .. }),
                    "{} should be on DDR",
                    t.name
                );
            }
        }
    }

    #[test]
    fn hbm_placements_do_not_overlap() {
        // Real interval check over [addr, addr + bytes) — the old version
        // compared degenerate (addr, addr) spans, which only caught exact
        // base-address duplicates, not overlapping extents.
        let (g, map) = laid_out(&CompressionConfig::paper_default());
        let map = map.unwrap();
        let mut spans: Vec<(u64, u64, &str)> = map
            .placements
            .iter()
            .filter_map(|(id, p)| match p {
                Placement::Hbm { addr, .. } => {
                    Some((*addr, *addr + g.tensors[*id].bytes, g.tensors[*id].name.as_str()))
                }
                _ => None,
            })
            .collect();
        assert!(spans.len() > 1, "llama2 must place several HBM tensors");
        spans.sort_unstable();
        for w in spans.windows(2) {
            assert!(
                w[0].1 <= w[1].0,
                "{} [{}, {}) overlaps {} [{}, {})",
                w[0].2,
                w[0].0,
                w[0].1,
                w[1].2,
                w[1].0,
                w[1].1
            );
        }
        let end = spans.last().unwrap().1;
        assert!(end <= map.hbm_used, "spans must stay inside hbm_used");
    }

    #[test]
    fn channel_striping_round_robins() {
        let (_, map) = laid_out(&CompressionConfig::paper_default());
        let map = map.unwrap();
        let firsts: std::collections::HashSet<u8> = map
            .placements
            .values()
            .filter_map(|p| match p {
                Placement::Hbm { first_channel, .. } => Some(*first_channel),
                _ => None,
            })
            .collect();
        assert!(firsts.len() > 1, "stripes should rotate across channel groups");
    }
}
