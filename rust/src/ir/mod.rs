//! Compiler IR — the customized intermediate representation of the
//! mapping flow (§5.4, Fig. 9): the model's structure, weights metadata,
//! sparse indexes and attention masks, exported from the source model and
//! optimized before instruction generation.
//!
//! Pipeline: `Graph::from_model` (stands in for the PyTorch parser) →
//! `passes::remove_views` → `passes::fuse` → `layout::assign_addresses` →
//! `compiler::lower` (instruction generation).

mod graph;
mod layout;
mod ops;
pub mod passes;

pub use graph::{Graph, Node, NodeId, Stage, Tensor, TensorId};
pub use layout::{assign_addresses, AddressMap, Placement};
pub use ops::{AttentionKind, Op};
