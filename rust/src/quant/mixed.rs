//! Mixed-precision weight quantization (§4.3 + §6.2.1): gradient-proxy
//! importance assigns 3, 4 or 5 bits per weight group, averaging ~3.5
//! bits; activations stay INT8.


use super::packing::{BitReader, BitWriter};

/// Allowed weight bit-widths (paper: 3/4/5-bit mix → 3.5-bit average).
pub const WIDTHS: [u32; 3] = [3, 4, 5];

/// Per-group bit-width plan for one weight tensor.
#[derive(Debug, Clone)]
pub struct MixedPrecision {
    /// Quantization group size (elements per scale, paper-style 64..128).
    pub group: usize,
    /// Bit-width of each group.
    pub bits: Vec<u32>,
}

impl MixedPrecision {
    pub fn uniform(groups: usize, bits: u32, group: usize) -> Self {
        Self { group, bits: vec![bits; groups] }
    }

    /// Average bits per weight (the paper's headline 3.5).
    pub fn avg_bits(&self) -> f64 {
        if self.bits.is_empty() {
            return 0.0;
        }
        self.bits.iter().map(|&b| b as f64).sum::<f64>() / self.bits.len() as f64
    }
}

/// Assign per-group widths from importance scores to hit `target_avg`
/// bits: important groups get 5 bits, unimportant get 3.
pub fn assign_bitwidths(scores: &[f64], group: usize, target_avg: f64) -> MixedPrecision {
    let g = scores.len();
    let mut bits = vec![3u32; g];
    let mut order: Vec<usize> = (0..g).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    // Budget in excess bits over the 3-bit floor.
    let mut budget = ((target_avg - 3.0) * g as f64).round() as i64;
    // First pass: upgrade the most important to 4; second pass to 5.
    for &i in &order {
        if budget <= 0 {
            break;
        }
        bits[i] = 4;
        budget -= 1;
    }
    for &i in &order {
        if budget <= 0 {
            break;
        }
        if bits[i] == 4 {
            bits[i] = 5;
            budget -= 1;
        }
    }
    MixedPrecision { group, bits }
}

/// A quantized tensor: packed codes + per-group scales + the plan.
/// This is the off-chip layout the MMU streams and the dequant unit
/// expands (see `DequantUnit`).
#[derive(Debug, Clone)]
pub struct QuantizedTensor {
    pub rows: usize,
    pub cols: usize,
    pub plan: MixedPrecision,
    /// Densely bit-packed codes, row-major, group-by-group.
    pub packed: Vec<u8>,
    /// One f32 scale per group.
    pub scales: Vec<f32>,
}

impl QuantizedTensor {
    /// Symmetric per-group quantization of a dense row-major tensor under
    /// the given plan (plan.bits.len() must equal the group count).
    pub fn quantize(w: &[f32], rows: usize, cols: usize, plan: MixedPrecision) -> Self {
        assert_eq!(w.len(), rows * cols);
        assert_eq!(cols % plan.group, 0, "cols must be a multiple of group");
        let groups_per_row = cols / plan.group;
        assert_eq!(plan.bits.len(), rows * groups_per_row, "plan size mismatch");
        let mut writer = BitWriter::new();
        let mut scales = Vec::with_capacity(plan.bits.len());
        for r in 0..rows {
            for g in 0..groups_per_row {
                let gi = r * groups_per_row + g;
                let bits = plan.bits[gi];
                let qmax = (1i32 << (bits - 1)) - 1;
                let base = r * cols + g * plan.group;
                let amax = w[base..base + plan.group]
                    .iter()
                    .fold(0f32, |m, &v| m.max(v.abs()));
                let scale = if amax > 0.0 { amax / qmax as f32 } else { 1.0 };
                scales.push(scale);
                for &v in &w[base..base + plan.group] {
                    let q = (v / scale).round().clamp(-(qmax as f32) - 1.0, qmax as f32)
                        as i32;
                    writer.push(q as u32, bits);
                }
            }
        }
        Self { rows, cols, plan, packed: writer.finish(), scales }
    }

    /// Dequantize back to f32 (row-major) — reference inverse used by
    /// tests and by the golden path; hardware uses `DequantUnit`.
    pub fn dequantize(&self) -> Vec<f32> {
        let groups_per_row = self.cols / self.plan.group;
        let mut out = vec![0f32; self.rows * self.cols];
        let mut r = BitReader::new(&self.packed);
        for row in 0..self.rows {
            for g in 0..groups_per_row {
                let gi = row * groups_per_row + g;
                let bits = self.plan.bits[gi];
                let shift = 32 - bits;
                let scale = self.scales[gi];
                let base = row * self.cols + g * self.plan.group;
                for i in 0..self.plan.group {
                    let code = ((r.read(bits) << shift) as i32) >> shift;
                    out[base + i] = code as f32 * scale;
                }
            }
        }
        out
    }

    /// Stored bytes (codes + scales) — the off-chip footprint.
    pub fn stored_bytes(&self) -> usize {
        self.packed.len() + self.scales.len() * 4
    }

    /// Compression ratio vs fp16.
    pub fn ratio_vs_fp16(&self) -> f64 {
        (self.rows * self.cols * 2) as f64 / self.stored_bytes() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_weights(rows: usize, cols: usize) -> Vec<f32> {
        (0..rows * cols)
            .map(|i| ((i as f32 * 0.7).sin() + 0.1 * (i as f32 * 3.1).cos()) * 0.3)
            .collect()
    }

    #[test]
    fn avg_bits_hits_target() {
        let scores: Vec<f64> = (0..1000).map(|i| (i % 97) as f64).collect();
        let mp = assign_bitwidths(&scores, 64, 3.5);
        assert!((mp.avg_bits() - 3.5).abs() < 0.01, "avg = {}", mp.avg_bits());
        assert!(mp.bits.iter().all(|b| WIDTHS.contains(b)));
    }

    #[test]
    fn important_groups_get_more_bits() {
        let mut scores = vec![0.0f64; 10];
        scores[2] = 5.0;
        scores[9] = 9.0;
        let mp = assign_bitwidths(&scores, 64, 3.2);
        assert!(mp.bits[9] >= mp.bits[2]);
        assert!(mp.bits[2] > mp.bits[0]);
    }

    #[test]
    fn quantize_dequantize_error_bounded() {
        let w = test_weights(8, 128);
        let plan = MixedPrecision::uniform(8 * 2, 4, 64);
        let q = QuantizedTensor::quantize(&w, 8, 128, plan);
        let d = q.dequantize();
        for (gi, chunk) in w.chunks(64).enumerate() {
            let scale = q.scales[gi];
            for (i, &v) in chunk.iter().enumerate() {
                let err = (v - d[gi * 64 + i]).abs();
                assert!(err <= scale / 2.0 + 1e-6, "err {err} > {}", scale / 2.0);
            }
        }
    }

    #[test]
    fn mixed_plan_roundtrips() {
        let w = test_weights(4, 192);
        let scores: Vec<f64> = (0..4 * 3).map(|i| i as f64).collect();
        let plan = assign_bitwidths(&scores, 64, 4.0);
        let q = QuantizedTensor::quantize(&w, 4, 192, plan);
        let d = q.dequantize();
        assert_eq!(d.len(), w.len());
        // Wider groups should have smaller max error than narrow ones at
        // the same data distribution (statistically; check budget holds).
        let err: f32 = w.iter().zip(&d).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
        assert!(err < 0.1);
    }

    #[test]
    fn storage_matches_3_5_bit_claim() {
        // 3.5-bit average + scales ≈ 4.2× smaller than fp16.
        let w = test_weights(64, 1024);
        let scores: Vec<f64> = (0..64 * 16).map(|i| (i % 13) as f64).collect();
        let plan = assign_bitwidths(&scores, 64, 3.5);
        let q = QuantizedTensor::quantize(&w, 64, 1024, plan);
        let r = q.ratio_vs_fp16();
        assert!(r > 3.5 && r < 4.6, "ratio = {r}");
    }

    #[test]
    fn zero_tensor_is_stable() {
        let w = vec![0f32; 2 * 64];
        let plan = MixedPrecision::uniform(2, 3, 64);
        let q = QuantizedTensor::quantize(&w, 2, 64, plan);
        assert!(q.dequantize().iter().all(|&v| v == 0.0));
    }
}
