//! Bit-level packing of sub-byte weight codes — the compact storage
//! format the MMU streams from HBM (§4.3: "compactly stored
//! mixed-precision data in the buffer").
//!
//! Codes of any width 1..=8 bits are written LSB-first into a contiguous
//! byte stream with no per-element padding; that is what makes the
//! 3-bit stream 3/16 the size of fp16, not 8/16.

/// Streaming bit writer (LSB-first within each byte).
#[derive(Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits already used in the final byte (0..8).
    bit_pos: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append the low `bits` bits of `v`.
    pub fn push(&mut self, v: u32, bits: u32) {
        debug_assert!(bits >= 1 && bits <= 32);
        let mut v = v & if bits == 32 { u32::MAX } else { (1 << bits) - 1 };
        let mut remaining = bits;
        while remaining > 0 {
            if self.bit_pos == 0 {
                self.buf.push(0);
            }
            let free = 8 - self.bit_pos;
            let take = free.min(remaining);
            let byte = self.buf.last_mut().unwrap();
            *byte |= ((v & ((1u32 << take) - 1)) as u8) << self.bit_pos;
            v >>= take;
            self.bit_pos = (self.bit_pos + take) % 8;
            remaining -= take;
        }
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    pub fn bit_len(&self) -> usize {
        if self.bit_pos == 0 {
            self.buf.len() * 8
        } else {
            (self.buf.len() - 1) * 8 + self.bit_pos as usize
        }
    }
}

/// Streaming bit reader matching `BitWriter`'s layout.
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // absolute bit position
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Read the next `bits` bits (LSB-first).
    pub fn read(&mut self, bits: u32) -> u32 {
        debug_assert!(bits >= 1 && bits <= 32);
        let mut out = 0u32;
        let mut got = 0u32;
        while got < bits {
            let byte = self.buf[self.pos / 8];
            let off = (self.pos % 8) as u32;
            let avail = 8 - off;
            let take = avail.min(bits - got);
            let v = ((byte >> off) as u32) & ((1u32 << take) - 1);
            out |= v << got;
            got += take;
            self.pos += take as usize;
        }
        out
    }

    pub fn bits_left(&self) -> usize {
        self.buf.len() * 8 - self.pos
    }
}

/// Pack signed codes at uniform `bits` width (two's complement inside the
/// field).
pub fn pack_bits(codes: &[i32], bits: u32) -> Vec<u8> {
    let mut w = BitWriter::new();
    for &c in codes {
        w.push(c as u32, bits);
    }
    w.finish()
}

/// Unpack `count` signed codes of `bits` width (sign-extended).
pub fn unpack_bits(buf: &[u8], bits: u32, count: usize) -> Vec<i32> {
    let mut r = BitReader::new(buf);
    let shift = 32 - bits;
    (0..count)
        .map(|_| ((r.read(bits) << shift) as i32) >> shift)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_3bit() {
        let codes: Vec<i32> = (-4..4).collect();
        let buf = pack_bits(&codes, 3);
        assert_eq!(buf.len(), 3); // 8 codes × 3 bits = 24 bits
        assert_eq!(unpack_bits(&buf, 3, 8), codes);
    }

    #[test]
    fn roundtrip_4bit() {
        let codes: Vec<i32> = (-8..8).collect();
        let buf = pack_bits(&codes, 4);
        assert_eq!(buf.len(), 8);
        assert_eq!(unpack_bits(&buf, 4, 16), codes);
    }

    #[test]
    fn roundtrip_5bit() {
        let codes: Vec<i32> = (-16..16).collect();
        assert_eq!(unpack_bits(&pack_bits(&codes, 5), 5, 32), codes);
    }

    #[test]
    fn mixed_width_stream() {
        // The real stream interleaves widths group by group.
        let mut w = BitWriter::new();
        w.push(0b101, 3);
        w.push(0b1111, 4);
        w.push(0b10001, 5);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.read(3), 0b101);
        assert_eq!(r.read(4), 0b1111);
        assert_eq!(r.read(5), 0b10001);
    }

    #[test]
    fn bit_len_tracks_pushes() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.push(1, 3);
        assert_eq!(w.bit_len(), 3);
        w.push(1, 8);
        assert_eq!(w.bit_len(), 11);
    }

    #[test]
    fn packing_is_dense() {
        // 1000 3-bit codes = 375 bytes exactly (no padding waste).
        let codes = vec![-1i32; 1000];
        assert_eq!(pack_bits(&codes, 3).len(), 375);
    }

    #[test]
    fn sign_extension() {
        let codes = vec![-4i32, 3, -1];
        assert_eq!(unpack_bits(&pack_bits(&codes, 3), 3, 3), codes);
    }
}
