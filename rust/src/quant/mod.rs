//! Mixed-precision quantization substrate (§4.3): per-group bit-width
//! assignment (3/4/5-bit averaging 3.5), compact bit-packing of the
//! off-chip weight stream, and a bit-exact functional model of the
//! dequantization unit (bit-width expansion to INT8).

mod dequant_unit;
mod mixed;
mod packing;

pub use dequant_unit::DequantUnit;
pub use mixed::{assign_bitwidths, MixedPrecision, QuantizedTensor};
pub use packing::{pack_bits, unpack_bits, BitReader, BitWriter};
