//! Bit-exact functional model of the dedicated dequantization unit
//! (§4.3): "a set of parallel bit-width expansion units, which
//! automatically expand the input data to 8 bits according to the control
//! signal, scale factor, and sign bit", feeding the MPE a uniform INT8
//! stream so that 2/3/4-bit multiplications become INT8 multiplications.

use super::mixed::QuantizedTensor;
use super::packing::BitReader;

/// The hardware unit: expands one packed group at a time to INT8 codes,
/// tracking the per-group scale that the MPE applies after accumulation.
#[derive(Debug, Clone, Copy, Default)]
pub struct DequantUnit {
    /// Expansion lanes operating in parallel (hardware: one per MPE input
    /// lane; only affects the cycle estimate, not the values).
    pub lanes: u32,
}

/// One expanded group: INT8 codes + the scale to fold in post-accumulate.
#[derive(Debug, Clone)]
pub struct ExpandedGroup {
    pub codes: Vec<i8>,
    pub scale: f32,
}

impl DequantUnit {
    pub fn new(lanes: u32) -> Self {
        Self { lanes: lanes.max(1) }
    }

    /// Expand all groups of a quantized tensor. Bit-exact: the INT8 code
    /// equals the stored sub-byte code sign-extended (values in
    /// [-16, 15] for 5-bit, [-8, 7] for 4-bit, [-4, 3] for 3-bit all fit
    /// INT8 trivially — the point is the *uniform* lane format).
    pub fn expand(&self, t: &QuantizedTensor) -> Vec<ExpandedGroup> {
        let groups_per_row = t.cols / t.plan.group;
        let mut out = Vec::with_capacity(t.plan.bits.len());
        let mut r = BitReader::new(&t.packed);
        for gi in 0..t.rows * groups_per_row {
            let bits = t.plan.bits[gi];
            let shift = 32 - bits;
            let codes = (0..t.plan.group)
                .map(|_| (((r.read(bits) << shift) as i32) >> shift) as i8)
                .collect();
            out.push(ExpandedGroup { codes, scale: t.scales[gi] });
        }
        out
    }

    /// Cycles to expand `elems` codes: one code per lane per cycle.
    pub fn expand_cycles(&self, elems: u64) -> u64 {
        elems.div_ceil(self.lanes as u64)
    }

    /// INT8 dot-product of an expanded weight group against INT8
    /// activations with the two scales folded afterwards — exactly the
    /// arithmetic the MPE performs after expansion (INT8 MACs into a
    /// 32-bit accumulator, scale applied once per group).
    pub fn group_dot(group: &ExpandedGroup, acts: &[i8], act_scale: f32) -> f32 {
        assert_eq!(acts.len(), group.codes.len());
        let acc: i32 = group
            .codes
            .iter()
            .zip(acts)
            .map(|(&w, &a)| w as i32 * a as i32)
            .sum();
        acc as f32 * group.scale * act_scale
    }
}

#[cfg(test)]
mod tests {
    use super::super::mixed::{MixedPrecision, QuantizedTensor};
    use super::*;

    #[test]
    fn expansion_is_bit_exact_vs_dequantize() {
        let w: Vec<f32> =
            (0..2 * 128).map(|i| ((i * 37 % 101) as f32 - 50.0) / 80.0).collect();
        let plan = MixedPrecision::uniform(2 * 2, 4, 64);
        let q = QuantizedTensor::quantize(&w, 2, 128, plan);
        let unit = DequantUnit::new(32);
        let groups = unit.expand(&q);
        let deq = q.dequantize();
        for (gi, g) in groups.iter().enumerate() {
            for (i, &c) in g.codes.iter().enumerate() {
                let want = deq[gi * 64 + i];
                let got = c as f32 * g.scale;
                assert!((got - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn expanded_codes_fit_their_width() {
        let w: Vec<f32> = (0..128).map(|i| (i as f32).sin()).collect();
        let plan = MixedPrecision::uniform(2, 3, 64);
        let q = QuantizedTensor::quantize(&w, 1, 128, plan);
        for g in DequantUnit::new(8).expand(&q) {
            for &c in &g.codes {
                assert!((-4..=3).contains(&(c as i32)), "3-bit code {c}");
            }
        }
    }

    #[test]
    fn group_dot_matches_float_path() {
        let w: Vec<f32> = (0..64).map(|i| ((i as f32) * 0.11).cos() * 0.4).collect();
        let plan = MixedPrecision::uniform(1, 5, 64);
        let q = QuantizedTensor::quantize(&w, 1, 64, plan);
        let unit = DequantUnit::new(16);
        let g = &unit.expand(&q)[0];
        // INT8 activations with a known scale.
        let acts: Vec<i8> = (0..64).map(|i| ((i * 7 % 17) as i32 - 8) as i8).collect();
        let act_scale = 0.05f32;
        let got = DequantUnit::group_dot(g, &acts, act_scale);
        let deq = q.dequantize();
        let want: f32 = deq
            .iter()
            .zip(&acts)
            .map(|(&wv, &a)| wv * a as f32 * act_scale)
            .sum();
        assert!((got - want).abs() < 1e-3, "{got} vs {want}");
    }

    #[test]
    fn expand_cycles_scale_with_lanes() {
        assert_eq!(DequantUnit::new(8).expand_cycles(64), 8);
        assert_eq!(DequantUnit::new(32).expand_cycles(64), 2);
        assert_eq!(DequantUnit::new(32).expand_cycles(65), 3);
    }
}
