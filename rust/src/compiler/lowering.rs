//! Instruction generation: optimized IR graph → one SLR's instruction
//! stream (the accelerator is SLR-symmetric; base-address registers remap
//! the same file for the other SLRs, §5.2).
//!
//! Tiling: a linear layer's per-SLR weight slice is streamed tile-by-tile
//! into the weight buffer (merged 8-channel LDs), each tile followed by
//! the MM/MV that consumes it — the double-buffered schedule the engine
//! overlaps.  Prefill attention lowers per (head, kept-block) at `Fine`
//! granularity — which is why unbucketed instruction storage explodes and
//! length-adaptive compilation is needed — or as aggregate block-sparse
//! MMs at `Coarse` granularity (identical MACs/bytes, fewer instructions)
//! for fast simulation.

use crate::config::Target;
use crate::ir::{AttentionKind, Graph, Op, Stage};
use crate::isa::{Inst, MemSpace, MiscOp, OnChipBuf, Sparsity, SysOp};

/// Where generated instructions go. Streams for storage accounting are
/// only *counted* (`CountSink`); streams for simulation are materialized
/// (`VecSink`) or consumed on the fly.
pub trait InstSink {
    fn emit(&mut self, inst: Inst);
}

/// Materializes the stream.
#[derive(Debug, Default)]
pub struct VecSink(pub Vec<Inst>);

impl InstSink for VecSink {
    fn emit(&mut self, inst: Inst) {
        self.0.push(inst);
    }
}

/// Counts instructions and stored bytes without materializing.
#[derive(Debug, Default)]
pub struct CountSink {
    pub count: u64,
}

impl CountSink {
    pub fn bytes(&self) -> u64 {
        self.count * crate::isa::INST_BYTES as u64
    }
}

impl InstSink for CountSink {
    fn emit(&mut self, _inst: Inst) {
        self.count += 1;
    }
}

/// Attention lowering granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnGranularity {
    /// One MM per (head, kept score block) — the real instruction stream
    /// (each head/layer has its own sparse pattern, §5.2.1, so none of
    /// these are reusable).
    Fine,
    /// One block-sparse MM per attention step — same MACs and traffic,
    /// collapsed for fast simulation.
    Coarse,
}

/// Fig. 14's ablation knobs + the §5.2 instruction optimizations.
#[derive(Debug, Clone, Copy)]
pub struct CompilerOptions {
    /// Keep decode activations on-chip (§4.1). When false every linear is
    /// bracketed by activation LD/ST — the naive port.
    pub onchip_decode: bool,
    /// Merge per-channel LD/STs into one instruction (§5.2).
    pub merge_channel_io: bool,
    /// Attention lowering granularity (see above).
    pub attn: AttnGranularity,
    /// Decode batch size (Fig. 15): batch > 1 turns decode MVs into
    /// skinny MMs so the streamed weights are amortized across sequences.
    pub batch: u32,
}

impl CompilerOptions {
    /// The shipped configuration.
    pub fn full() -> Self {
        Self {
            onchip_decode: true,
            merge_channel_io: true,
            attn: AttnGranularity::Coarse,
            batch: 1,
        }
    }

    /// The Fig. 14 "naive" rung (CSD-chain off is an Engine flag).
    pub fn naive() -> Self {
        Self { onchip_decode: false, merge_channel_io: false, ..Self::full() }
    }

    /// Real stored-stream shape (for §5.2 storage accounting).
    pub fn storage_fine() -> Self {
        Self { attn: AttnGranularity::Fine, ..Self::full() }
    }

    pub fn with_batch(batch: u32) -> Self {
        Self { batch: batch.max(1), ..Self::full() }
    }
}

/// HBM channels ganged per merged transfer.
const MERGE_CHANNELS: u8 = 8;

struct Lowerer<'a, S: InstSink> {
    t: &'a Target,
    opt: CompilerOptions,
    sink: &'a mut S,
    /// Rotating channel cursor for weight streams.
    next_channel: u8,
    /// Rotating HBM address cursor (addresses come from ir::layout in a
    /// full run; the rotation here only has to keep channels distinct).
    addr: u64,
    /// Activation spill cursor for the naive schedule, allocating slots
    /// in the upper half of HBM, clear of the weight-stream region.
    act_addr: u64,
    /// Last naive input slot as `(addr, bytes)`: siblings that read the
    /// same vector (wq/wk/wv, w1/w3) reload it from the same slot.
    act_in: Option<(u64, u64)>,
}

impl<'a, S: InstSink> Lowerer<'a, S> {
    /// Weight-buffer capacity per MPE in bytes (BRAM36 = 4 KiB usable).
    fn weight_buf_bytes(&self) -> u64 {
        self.t.accel.weight_buf_bram as u64 * 4096
    }

    fn emit_weight_load(&mut self, bytes: u64) {
        let fc = self.next_channel;
        self.next_channel =
            (self.next_channel + MERGE_CHANNELS) % self.t.platform.hbm.channels as u8;
        // Per-channel leg, 64-byte aligned: the 16-byte encoding stores
        // addresses as 64-byte tile indices, so per-leg addresses (and the
        // cursor below) must stay 64-aligned or they truncate.
        let leg = bytes.div_ceil(MERGE_CHANNELS as u64).next_multiple_of(64) as u32;
        if self.opt.merge_channel_io {
            self.sink.emit(Inst::LdMerged {
                first_channel: fc,
                channels: MERGE_CHANNELS,
                dst: OnChipBuf::Weight,
                addr: self.addr,
                bytes: leg,
            });
        } else {
            // Unmerged: one LD per channel leg (the pre-optimization ISA).
            for c in 0..MERGE_CHANNELS {
                self.sink.emit(Inst::Ld {
                    src: MemSpace::Hbm { channel: fc + c },
                    dst: OnChipBuf::Weight,
                    addr: self.addr + c as u64 * leg as u64,
                    bytes: leg,
                });
            }
        }
        self.addr += MERGE_CHANNELS as u64 * leg as u64;
    }

    /// Fresh 64-aligned activation slot in the naive spill region.
    fn alloc_act_slot(&mut self, bytes: u64) -> u64 {
        let at = self.act_addr;
        self.act_addr += bytes.max(1).next_multiple_of(64);
        at
    }

    /// Slot a naive linear loads its input vector from.  A layer that
    /// `shares` its input with the previous linear (wk/wv after wq,
    /// w3 after w1) rereads the same slot — the round-trip the dataflow
    /// analysis flags as a redundant reload and the optimizer deletes.
    fn naive_act_in(&mut self, bytes: u64, shares: bool) -> u64 {
        match self.act_in {
            Some((a, b)) if shares && b == bytes => a,
            _ => {
                let a = self.alloc_act_slot(bytes);
                self.act_in = Some((a, bytes));
                a
            }
        }
    }

    fn lower_linear(
        &mut self,
        stage: Stage,
        out_dim: u64,
        in_dim: u64,
        sparsity: Sparsity,
        weight_bits: f64,
        fused: &[MiscOp],
        shares_input: bool,
    ) {
        let slr = self.t.platform.slr_count as u64;
        let out_slr = out_dim.div_ceil(slr);
        // Stored bytes of this SLR's weight slice (values at weight_bits
        // + log2(M) index bits per kept value).
        let idx_bits = match sparsity {
            Sparsity::Nm { m, .. } => (m as f64).log2(),
            _ => 0.0,
        };
        let bytes = (out_slr as f64
            * in_dim as f64
            * sparsity.density()
            * (weight_bits + idx_bits)
            / 8.0)
            .ceil() as u64;
        let tile_bytes = self.weight_buf_bytes() / 2; // double buffered
        let tiles = bytes.div_ceil(tile_bytes).max(1);
        let out_per_tile = out_slr.div_ceil(tiles);
        let act_bytes = in_dim * (self.t.compression.act_bits as u64 / 8).max(1);

        if !self.opt.onchip_decode {
            let at = self.naive_act_in(act_bytes, shares_input);
            self.sink.emit(Inst::Ld {
                src: MemSpace::Hbm { channel: self.next_channel },
                dst: OnChipBuf::Activation,
                addr: at,
                bytes: act_bytes as u32,
            });
        }
        for i in 0..tiles {
            let this_out = out_per_tile.min(out_slr.saturating_sub(i * out_per_tile));
            if this_out == 0 {
                break;
            }
            self.emit_weight_load(bytes / tiles);
            match stage {
                Stage::Prefill { n } => self.sink.emit(Inst::Mm {
                    m: n as u32,
                    k: in_dim as u32,
                    n: this_out as u32,
                    sparsity,
                }),
                // Batched decode (Fig. 15): B activation rows share the
                // streamed weight tile — a skinny MM instead of B MVs.
                Stage::Decode { .. } if self.opt.batch > 1 => {
                    self.sink.emit(Inst::Mm {
                        m: self.opt.batch,
                        k: in_dim as u32,
                        n: this_out as u32,
                        sparsity,
                    })
                }
                Stage::Decode { .. } => self.sink.emit(Inst::Mv {
                    k: in_dim as u32,
                    n: this_out as u32,
                    sparsity,
                }),
            }
        }
        for op in fused {
            self.sink.emit(Inst::Misc { op: *op, len: out_slr as u32 });
        }
        if !self.opt.onchip_decode {
            let at = self.alloc_act_slot(out_slr);
            self.sink.emit(Inst::St {
                src: OnChipBuf::Global,
                dst: MemSpace::Hbm { channel: self.next_channel },
                addr: at,
                bytes: out_slr as u32,
            });
        }
    }

    fn lower_attention(&mut self, stage: Stage, kind: AttentionKind, heads: u64, hd: u64, fused_softmax: bool) {
        let slr = self.t.platform.slr_count as u64;
        let heads_slr = heads.div_ceil(slr);
        let act_bytes_per_elem = (self.t.compression.act_bits as u64 / 8).max(1);
        match (stage, kind) {
            (Stage::Decode { ctx }, _) => {
                // MV against the KV cache: K then V, per head group; each
                // batched sequence has its OWN cache (no amortization —
                // this is why the multibatch advantage shrinks, Fig. 15).
                // Each head's K (then V) panel is streamed right before
                // the MV that consumes it: one aggregate KV load for all
                // heads would overflow the weight buffer.
                let b = self.opt.batch.max(1) as u64;
                let panel = (ctx * hd * act_bytes_per_elem).max(MERGE_CHANNELS as u64 * 64);
                for _ in 0..heads_slr * b {
                    // q·K^T : (1×hd)·(hd×ctx), then s·V : (1×ctx)·(ctx×hd)
                    self.emit_weight_load(panel);
                    self.sink.emit(Inst::Mv { k: hd as u32, n: ctx as u32, sparsity: Sparsity::Dense });
                    if fused_softmax {
                        self.sink.emit(Inst::Misc { op: MiscOp::Softmax, len: ctx as u32 });
                    }
                    self.emit_weight_load(panel);
                    self.sink.emit(Inst::Mv { k: ctx as u32, n: hd as u32, sparsity: Sparsity::Dense });
                }
            }
            (Stage::Prefill { n }, AttentionKind::Prefill { block_density }) => {
                let block = self.t.compression.attn_block as u64;
                let nb = n.div_ceil(block);
                let causal_blocks = nb * (nb + 1) / 2;
                let kept = ((causal_blocks as f64 * block_density).ceil() as u64).max(nb);
                // Each head streams its K panel before the QK^T blocks and
                // its V panel before the S·V blocks.  (The old lowering
                // emitted one aggregate KV load for all heads *after* the
                // MMs — a read-before-load the stream verifier rejects,
                // and a panel too large for the weight buffer.)
                let panel = (n * hd * act_bytes_per_elem).max(MERGE_CHANNELS as u64 * 64);
                match self.opt.attn {
                    AttnGranularity::Fine => {
                        // One MM per (head, kept block) for QK^T and for
                        // S·V — the true stored stream (§5.2.1: every
                        // layer and head has its own pattern).
                        for _ in 0..heads_slr {
                            self.emit_weight_load(panel);
                            for _ in 0..kept {
                                self.sink.emit(Inst::Mm {
                                    m: block as u32,
                                    k: hd as u32,
                                    n: block as u32,
                                    sparsity: Sparsity::Dense,
                                });
                            }
                            if fused_softmax {
                                self.sink.emit(Inst::Misc { op: MiscOp::Softmax, len: n as u32 });
                            }
                            self.emit_weight_load(panel);
                            for _ in 0..kept {
                                self.sink.emit(Inst::Mm {
                                    m: block as u32,
                                    k: block as u32,
                                    n: hd as u32,
                                    sparsity: Sparsity::Dense,
                                });
                            }
                        }
                    }
                    AttnGranularity::Coarse => {
                        let d256 = ((block_density * 256.0) as u8).max(1);
                        let sp = Sparsity::BlockSparse { density_256: d256 };
                        for _ in 0..heads_slr {
                            self.emit_weight_load(panel);
                            self.sink.emit(Inst::Mm { m: n as u32, k: hd as u32, n: n as u32, sparsity: sp });
                            if fused_softmax {
                                self.sink.emit(Inst::Misc { op: MiscOp::Softmax, len: n as u32 });
                            }
                            self.emit_weight_load(panel);
                            self.sink.emit(Inst::Mm { m: n as u32, k: n as u32, n: hd as u32, sparsity: sp });
                        }
                    }
                }
            }
            (Stage::Prefill { .. }, AttentionKind::Decode) => unreachable!(),
        }
    }

    fn lower_graph(&mut self, g: &Graph) {
        let slr = self.t.platform.slr_count as u64;
        for node in &g.nodes {
            match &node.op {
                Op::Embed => {
                    // Embedding row gather: one small LD per token.
                    let dim_bytes = 2 * g.stage.m().min(64);
                    self.sink.emit(Inst::Ld {
                        src: MemSpace::Hbm { channel: self.next_channel },
                        dst: OnChipBuf::Activation,
                        addr: self.addr,
                        bytes: (dim_bytes * 128) as u32,
                    });
                }
                Op::Linear { name, out_dim, in_dim, sparsity, weight_bits, fused } => {
                    // wk/wv read the same normed vector wq does, and w3
                    // the same FFN input w1 does.
                    let shares = name.ends_with(".wk")
                        || name.ends_with(".wv")
                        || name.ends_with(".w3");
                    self.lower_linear(
                        g.stage,
                        *out_dim,
                        *in_dim,
                        *sparsity,
                        *weight_bits,
                        fused,
                        shares,
                    );
                }
                Op::Attention { kind, heads, hd, fused_softmax } => {
                    self.lower_attention(g.stage, *kind, *heads, *hd, *fused_softmax);
                }
                Op::Misc { op, len } => {
                    if *len > 0 {
                        self.sink.emit(Inst::Misc {
                            op: *op,
                            len: (*len).div_ceil(slr) as u32,
                        });
                    }
                }
                Op::Residual { len } => {
                    self.sink.emit(Inst::Misc {
                        op: MiscOp::EltwiseAdd,
                        len: (*len).div_ceil(slr) as u32,
                    });
                }
                Op::Head { vocab, dim } => {
                    self.lower_linear(g.stage, *vocab, *dim, Sparsity::Dense, 16.0, &[], false);
                    self.sink.emit(Inst::Sys { op: SysOp::SyncHost });
                }
                Op::KvWrite { bytes } => {
                    let b = (*bytes / slr).max(64);
                    if self.opt.merge_channel_io && b >= MERGE_CHANNELS as u64 * 64 {
                        self.sink.emit(Inst::StMerged {
                            first_channel: self.next_channel,
                            channels: MERGE_CHANNELS,
                            src: OnChipBuf::Global,
                            addr: self.addr,
                            bytes: (b / MERGE_CHANNELS as u64) as u32,
                        });
                    } else {
                        self.sink.emit(Inst::St {
                            src: OnChipBuf::Global,
                            dst: MemSpace::Hbm { channel: self.next_channel },
                            addr: self.addr,
                            bytes: b as u32,
                        });
                    }
                }
                Op::View { .. } => { /* removed by passes; tolerated */ }
            }
            // SLR barrier at each layer boundary: after the FFN's down
            // projection (w2), the last linear of a transformer block.
            // (Residual nodes are fused into linears by the optimizer, so
            // they can't carry the barrier.)
            if matches!(&node.op, Op::Linear { name, .. } if name.ends_with(".w2")) {
                self.sink.emit(Inst::Sys { op: SysOp::SyncSlr });
            }
        }
    }
}

/// Lower an optimized IR graph into `sink` for one SLR of `target`.
pub fn lower<S: InstSink>(g: &Graph, target: &Target, opt: CompilerOptions, sink: &mut S) {
    let mut l = Lowerer {
        t: target,
        opt,
        sink,
        next_channel: 0,
        addr: 0,
        act_addr: (target.platform.hbm.capacity_bytes() / 2).next_multiple_of(64),
        act_in: None,
    };
    l.lower_graph(g);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CompressionConfig, Target};
    use crate::ir::{passes, Graph, Stage};

    fn graph(stage: Stage) -> (Graph, Target) {
        let t = Target::u280_llama2();
        let mut g = Graph::from_model(&t.model, &t.compression, stage);
        passes::optimize(&mut g);
        (g, t)
    }

    #[test]
    fn decode_stream_is_mostly_mv_and_ld() {
        let (g, t) = graph(Stage::Decode { ctx: 512 });
        let mut sink = VecSink::default();
        lower(&g, &t, CompilerOptions::full(), &mut sink);
        let insts = sink.0;
        assert!(!insts.is_empty());
        let mv = insts.iter().filter(|i| matches!(i, Inst::Mv { .. })).count();
        let mm = insts.iter().filter(|i| matches!(i, Inst::Mm { .. })).count();
        assert!(mv > 0, "decode must use MV mode");
        // Head projection is the only MM-free... head lowers to MV too in
        // decode; no MMs at all.
        assert_eq!(mm, 0, "decode stage must not emit MM");
    }

    #[test]
    fn prefill_stream_uses_mm() {
        let (g, t) = graph(Stage::Prefill { n: 256 });
        let mut sink = VecSink::default();
        lower(&g, &t, CompilerOptions::full(), &mut sink);
        let mm = sink.0.iter().filter(|i| matches!(i, Inst::Mm { .. })).count();
        assert!(mm > 0);
    }

    #[test]
    fn naive_options_emit_activation_roundtrips() {
        let (g, t) = graph(Stage::Decode { ctx: 512 });
        let mut full = VecSink::default();
        lower(&g, &t, CompilerOptions::full(), &mut full);
        let mut naive = VecSink::default();
        lower(&g, &t, CompilerOptions::naive(), &mut naive);
        let st = |v: &[Inst]| v.iter().filter(|i| matches!(i, Inst::St { .. })).count();
        assert!(
            st(&naive.0) > st(&full.0) + 100,
            "naive schedule must write activations back: {} vs {}",
            st(&naive.0),
            st(&full.0)
        );
    }

    #[test]
    fn naive_activation_slots_reflect_graph_sharing() {
        // wk/wv reload wq's input slot and w3 reloads w1's: the naive
        // stream's activation addresses must make that visible to the
        // dataflow analysis, and the full stream must have no findings.
        let t = Target::u280_tiny();
        let mut g =
            Graph::from_model(&t.model, &t.compression, Stage::Decode { ctx: t.model.max_seq });
        passes::optimize(&mut g);
        let mut naive = VecSink::default();
        lower(&g, &t, CompilerOptions::naive(), &mut naive);
        let report = crate::verify::dataflow::analyze_stream(&naive.0);
        assert_eq!(report.cost.redundant_reloads, 3 * t.model.n_layers, "{:?}", report.diags);
        let mut full = VecSink::default();
        lower(&g, &t, CompilerOptions::full(), &mut full);
        assert_eq!(crate::verify::dataflow::analyze_stream(&full.0).cost.findings(), 0);
    }

    #[test]
    fn merged_io_shrinks_instruction_count() {
        let (g, t) = graph(Stage::Decode { ctx: 512 });
        let mut merged = CountSink::default();
        lower(&g, &t, CompilerOptions::full(), &mut merged);
        let mut unmerged = CountSink::default();
        lower(
            &g,
            &t,
            CompilerOptions { merge_channel_io: false, ..CompilerOptions::full() },
            &mut unmerged,
        );
        let ratio = unmerged.count as f64 / merged.count as f64;
        assert!(ratio > 1.3, "merge should cut stream size, ratio = {ratio}");
    }

    #[test]
    fn fine_attention_dominates_prefill_storage() {
        // §5.2.1: per-head per-block attention instructions are why the
        // prefill stream is ~100× the decode stream.
        let (gp, t) = graph(Stage::Prefill { n: 2048 });
        let mut fine = CountSink::default();
        lower(&gp, &t, CompilerOptions::storage_fine(), &mut fine);
        let (gd, _) = graph(Stage::Decode { ctx: 2048 });
        let mut dec = CountSink::default();
        lower(&gd, &t, CompilerOptions::storage_fine(), &mut dec);
        let ratio = fine.count as f64 / dec.count as f64;
        assert!(ratio > 20.0, "prefill/decode stream ratio = {ratio}");
    }

    #[test]
    fn count_sink_matches_vec_sink() {
        let (g, t) = graph(Stage::Decode { ctx: 256 });
        let mut v = VecSink::default();
        lower(&g, &t, CompilerOptions::full(), &mut v);
        let mut c = CountSink::default();
        lower(&g, &t, CompilerOptions::full(), &mut c);
        assert_eq!(v.0.len() as u64, c.count);
    }

    #[test]
    fn uncompressed_stream_loads_more_bytes() {
        let t = Target::u280_llama2();
        let mk = |c: &CompressionConfig| {
            let mut g = Graph::from_model(&t.model, c, Stage::Decode { ctx: 512 });
            passes::optimize(&mut g);
            let mut sink = VecSink::default();
            lower(&g, &t, CompilerOptions::full(), &mut sink);
            sink.0.iter().map(|i| i.offchip_bytes()).sum::<u64>()
        };
        let comp = mk(&CompressionConfig::paper_default());
        let dense = mk(&CompressionConfig::none());
        assert!(
            dense as f64 / comp as f64 > 3.0,
            "compression must cut traffic: dense {dense} vs comp {comp}"
        );
    }
}
