//! Certified peephole optimizer over compiled instruction streams.
//!
//! Three rewrites, all driven by `verify::dataflow`: dead-load
//! elimination (a definition nobody reads), redundant-reload coalescing
//! (an off-chip span the buffer already mirrors), and removable-sync
//! deletion (an SLR barrier fencing an empty region).  None of them is
//! trusted: every candidate stream must produce a symbolic
//! memory-effect summary *identical* to the original's — the same
//! compute instructions over the same operand spans, the same stores in
//! the same order — or the rewrite is refused.  A failed certification
//! falls back to the original stream with `certified: false`, so a
//! broken rewrite can never ship silently: the `analyze` CI gate fails
//! loudly instead.

use crate::isa::Inst;
use crate::verify::dataflow;

/// What `optimize_stream` did to one stream.
#[derive(Debug, Clone)]
pub struct OptimizeOutcome {
    pub insts: Vec<Inst>,
    pub dead_loads_removed: u64,
    pub redundant_reloads_removed: u64,
    pub syncs_removed: u64,
    /// Off-chip bytes the removed instructions no longer move.
    pub bytes_saved: u64,
    /// Effect-summary equivalence held for every accepted rewrite.
    pub certified: bool,
}

/// Remove certified-useless work from a stream.
pub fn optimize_stream(insts: &[Inst]) -> OptimizeOutcome {
    let report = dataflow::analyze_stream(insts);
    if report.cost.findings() == 0 {
        // Identity is trivially certified — and skipping the effect
        // summaries matters for the million-instruction prefill streams.
        return OptimizeOutcome {
            insts: insts.to_vec(),
            dead_loads_removed: 0,
            redundant_reloads_removed: 0,
            syncs_removed: 0,
            bytes_saved: 0,
            certified: true,
        };
    }
    let reference = dataflow::effect_summary(insts);
    let mut current: Vec<Inst> = insts.to_vec();
    let (mut dead_removed, mut redundant_removed) = (0u64, 0u64);

    // Stage 1: dead loads and redundant reloads in one cut.  Dead
    // definitions appear in no operand set and redundant reloads create
    // no definition, so the cut preserves the summary — checked anyway.
    let cut: std::collections::HashSet<usize> =
        report.dead_loads.iter().chain(&report.redundant_reloads).copied().collect();
    if !cut.is_empty() {
        let cand: Vec<Inst> = insts
            .iter()
            .enumerate()
            .filter(|(i, _)| !cut.contains(i))
            .map(|(_, x)| x.clone())
            .collect();
        if dataflow::effect_summary(&cand) == reference {
            current = cand;
            dead_removed = report.dead_loads.len() as u64;
            redundant_removed = report.redundant_reloads.len() as u64;
        }
    }

    // Stage 2: removable syncs.  Re-analyze (stage 1 moved indices) and
    // try each barrier individually, highest index first so the earlier
    // indices stay valid.  Deleting a barrier merges two regions, which
    // can leak live definitions into later operand sets — so only
    // individually-certified removals are kept.
    let mut syncs_removed = 0u64;
    let mut candidates = dataflow::analyze_stream(&current).removable_syncs;
    candidates.sort_unstable_by_key(|&x| std::cmp::Reverse(x));
    for idx in candidates {
        let mut cand = current.clone();
        cand.remove(idx);
        if dataflow::effect_summary(&cand) == reference {
            current = cand;
            syncs_removed += 1;
        }
    }

    if dead_removed + redundant_removed + syncs_removed == 0 {
        return OptimizeOutcome {
            insts: insts.to_vec(),
            dead_loads_removed: 0,
            redundant_reloads_removed: 0,
            syncs_removed: 0,
            bytes_saved: 0,
            certified: true,
        };
    }

    // Belt and suspenders: the final stream as a whole must still
    // summarize identically; on failure ship the original, loudly.
    let certified = dataflow::effect_summary(&current) == reference;
    if !certified {
        return OptimizeOutcome {
            insts: insts.to_vec(),
            dead_loads_removed: 0,
            redundant_reloads_removed: 0,
            syncs_removed: 0,
            bytes_saved: 0,
            certified: false,
        };
    }
    let bytes_saved = insts.iter().map(Inst::offchip_bytes).sum::<u64>()
        - current.iter().map(Inst::offchip_bytes).sum::<u64>();
    OptimizeOutcome {
        insts: current,
        dead_loads_removed: dead_removed,
        redundant_reloads_removed: redundant_removed,
        syncs_removed,
        bytes_saved,
        certified,
    }
}
