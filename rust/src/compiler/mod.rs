//! The mapping flow's back end (§5, Fig. 9): IR → per-SLR instruction
//! streams, with length-adaptive compilation (§5.2) and the multi-channel
//! LD/ST merge, plus the storage-size model that reproduces the paper's
//! 1.67 TB → 4.77 GB → 3.25 GB progression.
//!
//! Everything this module emits is checkable without execution: the
//! [`crate::verify`] tier replays a compiled stream through an abstract
//! machine (on-chip occupancy, off-chip address bounds, channel runs,
//! encode/decode, sync discipline) and the `flightllm verify` CI gate
//! holds every shipped target × preset to zero diagnostics.
//!
//! The [`optimize_stream`] pass closes the loop in the other direction:
//! `verify::dataflow`'s liveness analysis feeds a certified peephole
//! optimizer (dead-load elimination, redundant-reload coalescing,
//! removable-sync deletion) whose every rewrite must preserve the
//! stream's symbolic memory-effect summary, and whose output the
//! `flightllm analyze` CI gate holds to zero residual inefficiencies.

mod buckets;
mod lowering;
mod optimize;
mod size_model;

pub use buckets::{decode_bucket, prefill_bucket, BucketPlan};
pub use lowering::{lower, AttnGranularity, CompilerOptions, CountSink, InstSink, VecSink};
pub use optimize::{optimize_stream, OptimizeOutcome};
pub use size_model::{storage_report, StorageReport};
