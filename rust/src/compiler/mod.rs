//! The mapping flow's back end (§5, Fig. 9): IR → per-SLR instruction
//! streams, with length-adaptive compilation (§5.2) and the multi-channel
//! LD/ST merge, plus the storage-size model that reproduces the paper's
//! 1.67 TB → 4.77 GB → 3.25 GB progression.
//!
//! Everything this module emits is checkable without execution: the
//! [`crate::verify`] tier replays a compiled stream through an abstract
//! machine (on-chip occupancy, off-chip address bounds, channel runs,
//! encode/decode, sync discipline) and the `flightllm verify` CI gate
//! holds every shipped target × preset to zero diagnostics.

mod buckets;
mod lowering;
mod size_model;

pub use buckets::{decode_bucket, prefill_bucket, BucketPlan};
pub use lowering::{lower, AttnGranularity, CompilerOptions, CountSink, InstSink, VecSink};
pub use size_model::{storage_report, StorageReport};
