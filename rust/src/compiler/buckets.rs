//! Length-adaptive compilation (§5.2.2): token lengths within a threshold
//! range share one instruction file.
//!
//! Decode executes once per generated token, so its buckets are fine
//! (redundant computation there costs a full extra memory sweep per
//! token); prefill executes once per request, so its buckets are coarse.
//! Bucket edges also respect the N:M block (16) and attention block (64)
//! sizes, which is why rounding up inside a bucket costs little.

/// The bucketing plan for a model's max sequence length.
#[derive(Debug, Clone)]
pub struct BucketPlan {
    pub max_seq: u64,
    /// Decode context buckets (upper edges, ascending).
    pub decode: Vec<u64>,
    /// Prefill length buckets (upper edges, ascending).
    pub prefill: Vec<u64>,
}

impl BucketPlan {
    /// The paper-shaped plan: decode every 64 tokens (finer), prefill in
    /// powers of two from 16 (coarser).
    pub fn paper_default(max_seq: u64) -> Self {
        let decode: Vec<u64> = (1..=max_seq.div_ceil(64)).map(|i| i * 64).collect();
        let mut prefill = Vec::new();
        let mut l = 16u64;
        while l < max_seq {
            prefill.push(l);
            l *= 2;
        }
        prefill.push(max_seq);
        Self { max_seq, decode, prefill }
    }

    /// The tiny-model plan matching python/compile/aot.py PREFILL_BUCKETS.
    /// The last prefill edge is pinned to `max_seq`: without it, lengths
    /// past the largest fixed bucket would silently clamp to a stream
    /// compiled for a shorter prompt.
    pub fn tiny(max_seq: u64) -> Self {
        let mut prefill: Vec<u64> =
            [16, 32, 64, 128].into_iter().filter(|&e| e < max_seq).collect();
        prefill.push(max_seq);
        Self { max_seq, decode: vec![max_seq], prefill }
    }

    pub fn decode_bucket(&self, ctx: u64) -> u64 {
        bucket_of(&self.decode, ctx)
    }

    pub fn prefill_bucket(&self, len: u64) -> u64 {
        bucket_of(&self.prefill, len)
    }

    /// Streams stored: (decode buckets + prefill buckets), one file reused
    /// by all SLRs via base-address registers.
    pub fn stored_streams(&self) -> u64 {
        (self.decode.len() + self.prefill.len()) as u64
    }

    /// How many (stage, length) pairs a naive compiler would store for
    /// all lengths 1..=max_seq on `slrs` SLRs.
    pub fn naive_streams(&self, slrs: u64) -> u64 {
        2 * self.max_seq * slrs
    }
}

fn bucket_of(edges: &[u64], v: u64) -> u64 {
    for &e in edges {
        if v <= e {
            return e;
        }
    }
    *edges.last().expect("bucket table must not be empty")
}

/// Convenience free functions over the paper-default plan.
pub fn decode_bucket(max_seq: u64, ctx: u64) -> u64 {
    BucketPlan::paper_default(max_seq).decode_bucket(ctx)
}

pub fn prefill_bucket(max_seq: u64, len: u64) -> u64 {
    BucketPlan::paper_default(max_seq).prefill_bucket(len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn buckets_cover_every_length() {
        let p = BucketPlan::paper_default(2048);
        for len in 1..=2048u64 {
            let d = p.decode_bucket(len);
            let f = p.prefill_bucket(len);
            assert!(d >= len && d <= 2048);
            assert!(f >= len && f <= 2048);
        }
    }

    #[test]
    fn decode_buckets_finer_than_prefill() {
        // §5.2.2: "more refined thresholds in the decode stage".
        let p = BucketPlan::paper_default(2048);
        assert!(p.decode.len() > 2 * p.prefill.len());
    }

    #[test]
    fn bucket_waste_is_bounded() {
        // Rounding a length up to its decode bucket costs < 64 tokens of
        // extra context sweep.
        let p = BucketPlan::paper_default(2048);
        for len in 1..=2048u64 {
            assert!(p.decode_bucket(len) - len < 64);
        }
    }

    #[test]
    fn stream_count_reduction_is_large() {
        let p = BucketPlan::paper_default(2048);
        let naive = p.naive_streams(3);
        let stored = p.stored_streams();
        // 2·2048·3 = 12288 naive vs (32+8+1)-ish stored → > 250×.
        assert!(
            naive / stored > 250,
            "stream reduction = {}",
            naive / stored
        );
    }

    #[test]
    fn bucket_edges_respect_sparse_blocks() {
        let p = BucketPlan::paper_default(2048);
        for &e in &p.decode {
            assert_eq!(e % 16, 0, "decode edge {e} must align to N:M block");
        }
        for &e in &p.prefill {
            assert_eq!(e % 16, 0, "prefill edge {e} must align to block");
        }
    }

    #[test]
    fn tiny_plan_covers_up_to_max_seq() {
        // Regression: the fixed [16..128] prefill table used to clamp a
        // 256-token prompt onto the 128-token stream.
        for max_seq in [96u64, 128, 256, 1024] {
            let p = BucketPlan::tiny(max_seq);
            assert_eq!(*p.prefill.last().unwrap(), max_seq);
            for w in p.prefill.windows(2) {
                assert!(w[0] < w[1], "edges must stay ascending: {:?}", p.prefill);
            }
            for len in 1..=max_seq {
                assert!(p.prefill_bucket(len) >= len);
            }
        }
    }

    #[test]
    fn property_bucket_is_monotone() {
        proptest::check("bucket monotone", |r| {
            let p = BucketPlan::paper_default(2048);
            let a = 1 + r.below(2048);
            let b = 1 + r.below(2048);
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            assert!(p.decode_bucket(lo) <= p.decode_bucket(hi));
            assert!(p.prefill_bucket(lo) <= p.prefill_bucket(hi));
        });
    }
}
