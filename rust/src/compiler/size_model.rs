//! Instruction-storage accounting (§5.2): reproduces the paper's
//! progression from "naive static compilation would need ~TB" to "fits in
//! DDR" via (1) length-adaptive bucketing, (2) one shared file re-based
//! per SLR, and (3) multi-channel LD/ST merging.
//!
//! Absolute bytes here are smaller than the paper's (their coarse-grained
//! instruction words carry more micro-op payload than our 16 B encoding;
//! see EXPERIMENTS.md) — the *ratios* between rungs are the reproduction
//! target: ~500× total, with merging contributing ~1.5× at the end
//! (4.77 GB → 3.25 GB in the paper).

use crate::config::Target;
use crate::ir::{passes, Graph, Stage};

use super::buckets::BucketPlan;
use super::lowering::{lower, CompilerOptions, CountSink};

/// One rung of the storage progression.
#[derive(Debug, Clone)]
pub struct StorageReport {
    /// All lengths 1..=max_seq, per SLR, unmerged IO — the §5.2.1 blowup.
    pub naive_bytes: f64,
    /// Bucketed lengths, still per-SLR copies, unmerged IO.
    pub bucketed_bytes: f64,
    /// Bucketed + single file shared across SLRs (base-address regs).
    pub shared_bytes: f64,
    /// Bucketed + shared + merged multi-channel LD/ST — what ships.
    pub merged_bytes: f64,
    /// Streams stored at the final rung.
    pub stored_streams: u64,
}

impl StorageReport {
    pub fn total_reduction(&self) -> f64 {
        self.naive_bytes / self.merged_bytes
    }

    pub fn merge_reduction(&self) -> f64 {
        self.shared_bytes / self.merged_bytes
    }
}

/// Count one stream's stored bytes.
fn stream_bytes(t: &Target, stage: Stage, opt: CompilerOptions) -> f64 {
    let mut g = Graph::from_model(&t.model, &t.compression, stage);
    passes::optimize(&mut g);
    let mut sink = CountSink::default();
    lower(&g, t, opt, &mut sink);
    sink.bytes() as f64
}

/// Build the §5.2 storage progression for a target.
///
/// The naive sum over every length is integrated by sampling: stream size
/// is piecewise-linear in the token length (tile counts step smoothly),
/// so sampling every `step` lengths and scaling is accurate to <1%.
pub fn storage_report(t: &Target) -> StorageReport {
    let max_seq = t.model.max_seq;
    let slrs = t.platform.slr_count as u64;
    let plan = BucketPlan::paper_default(max_seq);
    let fine = CompilerOptions::storage_fine();
    let unmerged_fine = CompilerOptions { merge_channel_io: false, ..fine };

    // ---- naive: every length, per SLR, unmerged ----
    let step = 64u64.min(max_seq);
    let mut naive = 0.0;
    let mut sampled = 0u64;
    let mut l = step;
    while l <= max_seq {
        naive += stream_bytes(t, Stage::Prefill { n: l }, unmerged_fine);
        naive += stream_bytes(t, Stage::Decode { ctx: l }, unmerged_fine);
        sampled += 1;
        l += step;
    }
    // Scale sample mean to all max_seq lengths, per SLR.
    let naive_bytes = naive / sampled as f64 * max_seq as f64 * slrs as f64;

    // ---- bucketed, still per-SLR, unmerged ----
    let mut bucketed = 0.0;
    for &b in &plan.prefill {
        bucketed += stream_bytes(t, Stage::Prefill { n: b }, unmerged_fine);
    }
    for &b in &plan.decode {
        bucketed += stream_bytes(t, Stage::Decode { ctx: b }, unmerged_fine);
    }
    let bucketed_bytes = bucketed * slrs as f64;

    // ---- shared across SLRs ----
    let shared_bytes = bucketed;

    // ---- + merged channel IO ----
    let mut merged = 0.0;
    for &b in &plan.prefill {
        merged += stream_bytes(t, Stage::Prefill { n: b }, fine);
    }
    for &b in &plan.decode {
        merged += stream_bytes(t, Stage::Decode { ctx: b }, fine);
    }

    StorageReport {
        naive_bytes,
        bucketed_bytes,
        shared_bytes,
        merged_bytes: merged,
        stored_streams: plan.stored_streams(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Target;

    #[test]
    fn progression_is_monotone() {
        let r = storage_report(&Target::u280_llama2());
        assert!(r.naive_bytes > r.bucketed_bytes);
        assert!(r.bucketed_bytes > r.shared_bytes);
        assert!(r.shared_bytes > r.merged_bytes);
    }

    #[test]
    fn total_reduction_matches_paper_order() {
        // Paper: ~1.67 TB → 3.25 GB ≈ 514×. Ours must land in the same
        // order of magnitude (driven by the same three mechanisms).
        let r = storage_report(&Target::u280_llama2());
        let red = r.total_reduction();
        assert!(
            (100.0..2000.0).contains(&red),
            "total reduction = {red:.0}× (naive {:.3e} B, merged {:.3e} B)",
            r.naive_bytes,
            r.merged_bytes
        );
    }

    #[test]
    fn merge_contributes_modest_final_factor() {
        // Paper: 4.77 GB → 3.25 GB = 1.47×. Our LD-heavier decode streams
        // give the merge a bigger bite; same mechanism, same direction.
        let r = storage_report(&Target::u280_llama2());
        let m = r.merge_reduction();
        assert!((1.1..4.0).contains(&m), "merge reduction = {m:.2}×");
    }

    #[test]
    fn final_size_fits_ddr_naive_does_not_scale() {
        // Our 16 B instruction words make absolute sizes ~150× smaller
        // than the paper's payload-heavy words (1.67 TB naive there,
        // ~11 GB here), so the DDR-feasibility claim is checked on the
        // *ratio*: the shipped streams must be a tiny fraction of DDR
        // while the naive volume is a material fraction of it.
        let t = Target::u280_llama2();
        let r = storage_report(&t);
        let ddr = t.platform.ddr.capacity_gb * 1e9;
        assert!(
            r.merged_bytes < 0.01 * ddr,
            "stored instructions must be ≪ DDR: {:.2e} vs {ddr:.2e}",
            r.merged_bytes
        );
        assert!(
            r.naive_bytes > 0.25 * ddr,
            "naive volume must strain DDR: {:.2e}",
            r.naive_bytes
        );
    }
}
