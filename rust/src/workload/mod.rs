//! Workload generation: the [prefill, decode] grids of the paper's
//! figures, Poisson request arrivals for the serving example, and trace
//! replay.

use crate::util::Rng;

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival time, seconds from trace start.
    pub arrival_s: f64,
    /// Prompt tokens (the coordinator tokenizes upstream; the workload
    /// carries raw token ids for the tiny model).
    pub prompt: Vec<u32>,
    /// Decode budget (tokens to generate).
    pub max_new_tokens: u32,
}

/// Log-normally distributed request lengths: `median` tokens at the
/// 50th percentile, `sigma` of the underlying normal controlling the
/// tail, clamped to `[1, cap]` — the shape of real serving traffic
/// (many short requests, a heavy tail of long ones).
#[derive(Debug, Clone, Copy)]
pub struct LogNormalLen {
    pub median: f64,
    pub sigma: f64,
    pub cap: u32,
}

impl LogNormalLen {
    fn sample(&self, rng: &mut Rng) -> u32 {
        let v = rng.lognormal(self.median, self.sigma).round();
        (v as u32).clamp(1, self.cap.max(1))
    }
}

/// Poisson arrivals with geometric-ish length mixtures — the
/// latency-sensitive single-batch serving scenario of §1.  With the
/// log-normal options set, lengths are drawn from heavy-tailed
/// distributions instead of the choice lists — the open-loop live
/// serving workload (deterministic per seed either way).
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub rate_per_s: f64,
    pub n_requests: usize,
    pub prompt_len_choices: Vec<u32>,
    pub decode_len_choices: Vec<u32>,
    /// When set, prompt lengths are log-normal (ignoring the choices).
    pub prompt_lognormal: Option<LogNormalLen>,
    /// When set, decode budgets are log-normal (ignoring the choices).
    pub decode_lognormal: Option<LogNormalLen>,
    pub vocab: u32,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            rate_per_s: 2.0,
            n_requests: 32,
            prompt_len_choices: vec![16, 32, 64, 128],
            decode_len_choices: vec![16, 32, 64],
            prompt_lognormal: None,
            decode_lognormal: None,
            vocab: 512,
            seed: 0,
        }
    }
}

/// Generate a request trace.
pub fn generate_trace(cfg: &TraceConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0.0f64;
    (0..cfg.n_requests)
        .map(|i| {
            t += rng.exp(cfg.rate_per_s);
            let plen = match cfg.prompt_lognormal {
                Some(d) => d.sample(&mut rng),
                None => *rng.choose(&cfg.prompt_len_choices),
            };
            let dlen = match cfg.decode_lognormal {
                Some(d) => d.sample(&mut rng),
                None => *rng.choose(&cfg.decode_len_choices),
            };
            Request {
                id: i as u64,
                arrival_s: t,
                prompt: (0..plen).map(|_| rng.below(cfg.vocab as u64) as u32).collect(),
                max_new_tokens: dlen,
            }
        })
        .collect()
}

/// Shared-prefix workload: `n_groups` fixed system prompts, each
/// request drawing one of them followed by a per-request tail — the
/// traffic shape (system prompts, few-shot templates) that prefix
/// caching converts from repeated prefill into CoW page sharing.
#[derive(Debug, Clone)]
pub struct SharedPrefixConfig {
    /// Distinct system prompts (prefix groups).
    pub n_groups: usize,
    /// Tokens in each shared prefix.
    pub prefix_len: usize,
    /// Per-request tail lengths (user turns).
    pub tail_len_choices: Vec<u32>,
    pub decode_len_choices: Vec<u32>,
    pub n_requests: usize,
    pub rate_per_s: f64,
    pub vocab: u32,
    pub seed: u64,
}

impl Default for SharedPrefixConfig {
    fn default() -> Self {
        Self {
            n_groups: 2,
            prefix_len: 96,
            tail_len_choices: vec![8, 16, 24],
            decode_len_choices: vec![8, 16],
            n_requests: 16,
            rate_per_s: 8.0,
            vocab: 512,
            seed: 0,
        }
    }
}

/// Generate a shared-prefix request trace.  Deterministic per seed, with
/// strictly increasing arrivals (Poisson gaps).
pub fn generate_shared_prefix_trace(cfg: &SharedPrefixConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    let n_groups = cfg.n_groups.max(1);
    let prefixes: Vec<Vec<u32>> = (0..n_groups)
        .map(|_| (0..cfg.prefix_len).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let mut t = 0.0f64;
    (0..cfg.n_requests)
        .map(|i| {
            t += rng.exp(cfg.rate_per_s);
            let group = rng.below(n_groups as u64) as usize;
            let tail_len = *rng.choose(&cfg.tail_len_choices);
            let mut prompt = prefixes[group].clone();
            prompt.extend((0..tail_len).map(|_| rng.below(cfg.vocab as u64) as u32));
            Request {
                id: i as u64,
                arrival_s: t,
                prompt,
                max_new_tokens: *rng.choose(&cfg.decode_len_choices),
            }
        })
        .collect()
}

/// A SKEWED shared-prefix workload: like [`SharedPrefixConfig`], but
/// one hot group (group 0) draws `hot_percent` of the requests while
/// the rest spread uniformly over the remaining groups — the traffic
/// shape that hotspots prefix-affinity routing (every hot request
/// hashes to ONE lane) and that the fleet's global prefix directory +
/// cross-shard migration are built to absorb.
#[derive(Debug, Clone)]
pub struct SkewedPrefixConfig {
    /// Distinct system prompts; group 0 is the hot one.
    pub n_groups: usize,
    /// Tokens in each shared prefix.
    pub prefix_len: usize,
    pub tail_len_choices: Vec<u32>,
    pub decode_len_choices: Vec<u32>,
    pub n_requests: usize,
    /// Percent of requests drawing the hot group (clamped to 100).
    pub hot_percent: u32,
    pub rate_per_s: f64,
    pub vocab: u32,
    pub seed: u64,
}

impl Default for SkewedPrefixConfig {
    fn default() -> Self {
        Self {
            n_groups: 4,
            prefix_len: 64,
            tail_len_choices: vec![8, 16],
            decode_len_choices: vec![8, 16],
            n_requests: 24,
            hot_percent: 75,
            rate_per_s: 1e3,
            vocab: 512,
            seed: 0,
        }
    }
}

/// Generate a skewed shared-prefix trace (deterministic per seed,
/// strictly increasing Poisson arrivals).
pub fn generate_skewed_prefix_trace(cfg: &SkewedPrefixConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    let n_groups = cfg.n_groups.max(1);
    let hot = cfg.hot_percent.min(100) as u64;
    let prefixes: Vec<Vec<u32>> = (0..n_groups)
        .map(|_| (0..cfg.prefix_len).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let mut t = 0.0f64;
    (0..cfg.n_requests)
        .map(|i| {
            t += rng.exp(cfg.rate_per_s);
            let group = if rng.below(100) < hot || n_groups == 1 {
                0
            } else {
                1 + rng.below((n_groups - 1) as u64) as usize
            };
            let tail_len = *rng.choose(&cfg.tail_len_choices);
            let mut prompt = prefixes[group].clone();
            prompt.extend((0..tail_len).map(|_| rng.below(cfg.vocab as u64) as u32));
            Request {
                id: i as u64,
                arrival_s: t,
                prompt,
                max_new_tokens: *rng.choose(&cfg.decode_len_choices),
            }
        })
        .collect()
}

/// A mixed burst: `n_decode_heavy` short-prompt / long-decode requests
/// arrive at t = 0 and settle into steady decode; `n_prefill_heavy`
/// long-prompt requests then land at `prefill_stagger_s` intervals
/// while those decodes are in flight.  This is the workload where an
/// unchunked prefill freezes every in-flight decode for a whole
/// iteration — the chunked-prefill scheduling benchmark.
#[derive(Debug, Clone)]
pub struct MixedBurstConfig {
    pub n_decode_heavy: usize,
    pub decode_heavy_prompt: usize,
    pub decode_heavy_tokens: u32,
    pub n_prefill_heavy: usize,
    pub prefill_heavy_prompt: usize,
    pub prefill_heavy_tokens: u32,
    /// Gap before (and between) the prefill-heavy arrivals.
    pub prefill_stagger_s: f64,
    pub vocab: u32,
    pub seed: u64,
}

impl Default for MixedBurstConfig {
    fn default() -> Self {
        Self {
            n_decode_heavy: 3,
            decode_heavy_prompt: 16,
            decode_heavy_tokens: 48,
            n_prefill_heavy: 2,
            prefill_heavy_prompt: 192,
            prefill_heavy_tokens: 4,
            prefill_stagger_s: 1e-3,
            vocab: 512,
            seed: 0,
        }
    }
}

/// Generate a mixed decode/prefill burst (deterministic per seed).
pub fn generate_mixed_burst_trace(cfg: &MixedBurstConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    let vocab = cfg.vocab.max(2) as u64;
    let mut trace = Vec::with_capacity(cfg.n_decode_heavy + cfg.n_prefill_heavy);
    for i in 0..cfg.n_decode_heavy {
        trace.push(Request {
            id: i as u64,
            arrival_s: 0.0,
            prompt: (0..cfg.decode_heavy_prompt).map(|_| rng.below(vocab) as u32).collect(),
            max_new_tokens: cfg.decode_heavy_tokens,
        });
    }
    for i in 0..cfg.n_prefill_heavy {
        trace.push(Request {
            id: (cfg.n_decode_heavy + i) as u64,
            arrival_s: cfg.prefill_stagger_s * (i + 1) as f64,
            prompt: (0..cfg.prefill_heavy_prompt).map(|_| rng.below(vocab) as u32).collect(),
            max_new_tokens: cfg.prefill_heavy_tokens,
        });
    }
    trace
}

/// An overload workload: decode-heavy requests arriving faster than the
/// KV pool can hold them, so concurrent KV demand exceeds HBM capacity
/// mid-decode — the regime where a swap-less scheduler silently
/// truncates sequences and a swap-enabled one spills to DDR and
/// resumes.  All requests share one prompt length (deterministic page
/// demand); decode budgets cycle through `decode_len_choices` so
/// sequences finish at staggered times and capacity frees gradually.
#[derive(Debug, Clone)]
pub struct OverloadConfig {
    pub n_requests: usize,
    pub prompt_len: usize,
    pub decode_len_choices: Vec<u32>,
    /// Arrival rate (req/s); high rates pile residents up concurrently.
    pub rate_per_s: f64,
    pub vocab: u32,
    pub seed: u64,
}

impl Default for OverloadConfig {
    fn default() -> Self {
        Self {
            n_requests: 8,
            prompt_len: 32,
            decode_len_choices: vec![48, 64, 96],
            // Near-simultaneous arrivals: the whole batch must be
            // resident together even on µs-scale simulated steps.
            rate_per_s: 1e6,
            vocab: 512,
            seed: 0,
        }
    }
}

/// Generate an overload trace (deterministic per seed, strictly
/// increasing Poisson arrivals).
pub fn generate_overload_trace(cfg: &OverloadConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    let vocab = cfg.vocab.max(2) as u64;
    let choices = if cfg.decode_len_choices.is_empty() {
        vec![64]
    } else {
        cfg.decode_len_choices.clone()
    };
    let mut t = 0.0f64;
    (0..cfg.n_requests)
        .map(|i| {
            t += rng.exp(cfg.rate_per_s.max(1e-9));
            Request {
                id: i as u64,
                arrival_s: t,
                prompt: (0..cfg.prompt_len).map(|_| rng.below(vocab) as u32).collect(),
                max_new_tokens: choices[i % choices.len()].max(1),
            }
        })
        .collect()
}

/// A day-scale open-loop workload: long-horizon Poisson arrivals whose
/// rate follows a diurnal curve — `base_rate_per_s` in the overnight
/// trough, `peak_rate_per_s` at midday, one full cosine cycle over the
/// horizon.  This is the fleet-scale trace the serving bench and the
/// SLO/autoscaling work share, so every consumer prices the same
/// arrival process instead of hand-rolling loops.
#[derive(Debug, Clone)]
pub struct DayTraceConfig {
    /// Trace length in seconds (a day by default).
    pub horizon_s: f64,
    /// Arrival rate at the trough (req/s).
    pub base_rate_per_s: f64,
    /// Arrival rate at the peak (req/s); clamped to ≥ base.
    pub peak_rate_per_s: f64,
    pub prompt_len_choices: Vec<u32>,
    pub decode_len_choices: Vec<u32>,
    pub vocab: u32,
    pub seed: u64,
}

impl Default for DayTraceConfig {
    fn default() -> Self {
        Self {
            horizon_s: 86_400.0,
            base_rate_per_s: 0.5,
            peak_rate_per_s: 4.0,
            prompt_len_choices: vec![16, 32, 64],
            decode_len_choices: vec![16, 32],
            vocab: 512,
            seed: 0,
        }
    }
}

/// Generate a day-scale diurnal trace (deterministic per seed, strictly
/// increasing arrivals).  Implemented by Poisson THINNING: candidate
/// arrivals are drawn at the peak rate, then each is kept with
/// probability `rate(t) / peak` — the standard exact sampler for an
/// inhomogeneous Poisson process, and it reuses the seeded `Rng`
/// end to end.
pub fn generate_day_trace(cfg: &DayTraceConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    let base = cfg.base_rate_per_s.max(0.0);
    let peak = cfg.peak_rate_per_s.max(base).max(1e-9);
    let vocab = cfg.vocab.max(2) as u64;
    let prompts =
        if cfg.prompt_len_choices.is_empty() { vec![32] } else { cfg.prompt_len_choices.clone() };
    let decodes =
        if cfg.decode_len_choices.is_empty() { vec![16] } else { cfg.decode_len_choices.clone() };
    let mut out = Vec::new();
    let mut t = 0.0f64;
    loop {
        t += rng.exp(peak);
        if t >= cfg.horizon_s {
            break;
        }
        // Diurnal rate: trough at the horizon's endpoints (cos phase 0
        // and τ), peak at midday (phase π).
        let phase = (std::f64::consts::TAU * t / cfg.horizon_s).cos();
        let rate = base + (peak - base) * 0.5 * (1.0 - phase);
        if rng.f64() * peak > rate {
            continue;
        }
        let plen = *rng.choose(&prompts);
        out.push(Request {
            id: out.len() as u64,
            arrival_s: t,
            prompt: (0..plen).map(|_| rng.below(vocab) as u32).collect(),
            max_new_tokens: (*rng.choose(&decodes)).max(1),
        });
    }
    out
}

/// A burst: `n` identical-shape requests all arriving at t = 0 — the
/// Fig. 15 multibatch scenario pushed through the serving path, and the
/// worst-case admission pressure for the continuous-batching engine.
pub fn generate_burst_trace(
    n: usize,
    prompt_len: usize,
    max_new_tokens: u32,
    vocab: u32,
    seed: u64,
) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| Request {
            id: i as u64,
            arrival_s: 0.0,
            prompt: (0..prompt_len).map(|_| rng.below(vocab as u64) as u32).collect(),
            max_new_tokens,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_trace_arrives_at_once_with_fixed_shape() {
        let t = generate_burst_trace(4, 32, 8, 64, 3);
        assert_eq!(t.len(), 4);
        for r in &t {
            assert_eq!(r.arrival_s, 0.0);
            assert_eq!(r.prompt.len(), 32);
            assert_eq!(r.max_new_tokens, 8);
            assert!(r.prompt.iter().all(|&x| x < 64));
        }
        let again = generate_burst_trace(4, 32, 8, 64, 3);
        assert_eq!(t[2].prompt, again[2].prompt, "seeded: reproducible");
    }

    #[test]
    fn trace_is_deterministic_per_seed() {
        let cfg = TraceConfig::default();
        let a = generate_trace(&cfg);
        let b = generate_trace(&cfg);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert!((x.arrival_s - y.arrival_s).abs() < 1e-12);
        }
    }

    #[test]
    fn arrivals_are_increasing() {
        let trace = generate_trace(&TraceConfig::default());
        for w in trace.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
    }

    #[test]
    fn mean_interarrival_matches_rate() {
        let cfg = TraceConfig { rate_per_s: 10.0, n_requests: 5000, ..Default::default() };
        let trace = generate_trace(&cfg);
        let total = trace.last().unwrap().arrival_s;
        let mean = total / trace.len() as f64;
        assert!((mean - 0.1).abs() < 0.01, "mean gap = {mean}");
    }

    #[test]
    fn prompt_lengths_come_from_choices() {
        let cfg = TraceConfig::default();
        for r in generate_trace(&cfg) {
            assert!(cfg.prompt_len_choices.contains(&(r.prompt.len() as u32)));
            assert!(cfg.decode_len_choices.contains(&r.max_new_tokens));
        }
    }

    #[test]
    fn tokens_within_vocab() {
        for r in generate_trace(&TraceConfig::default()) {
            assert!(r.prompt.iter().all(|&t| t < 512));
        }
    }

    /// Satellite: the log-normal length option is deterministic per
    /// seed, respects the clamp, and lands its sample median near the
    /// configured one — realistic open-loop arrival/length traffic.
    #[test]
    fn lognormal_trace_deterministic_and_clamped() {
        let cfg = TraceConfig {
            n_requests: 400,
            rate_per_s: 50.0,
            prompt_lognormal: Some(LogNormalLen { median: 48.0, sigma: 0.7, cap: 128 }),
            decode_lognormal: Some(LogNormalLen { median: 16.0, sigma: 0.5, cap: 64 }),
            seed: 17,
            ..Default::default()
        };
        let a = generate_trace(&cfg);
        let b = generate_trace(&cfg);
        assert_eq!(a.len(), 400);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt, "deterministic per seed");
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
        }
        for r in &a {
            assert!((1..=128).contains(&r.prompt.len()), "clamped: {}", r.prompt.len());
            assert!((1..=64).contains(&r.max_new_tokens));
        }
        let mut plens: Vec<usize> = a.iter().map(|r| r.prompt.len()).collect();
        plens.sort_unstable();
        let median = plens[plens.len() / 2] as f64;
        assert!(
            (median / 48.0 - 1.0).abs() < 0.25,
            "sample median = {median} (want ~48)"
        );
        // The heavy tail is real: some requests well past the median.
        assert!(plens.iter().any(|&p| p > 96), "no tail in {plens:?}");
        for w in a.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s, "Poisson arrivals increase");
        }
    }

    /// Satellite: the overload trace is deterministic per seed, keeps
    /// strictly increasing arrivals, one prompt length, and cycles its
    /// decode budgets so completions stagger.
    #[test]
    fn overload_trace_deterministic_and_staggered() {
        let cfg = OverloadConfig { n_requests: 6, seed: 3, ..Default::default() };
        let a = generate_overload_trace(&cfg);
        let b = generate_overload_trace(&cfg);
        assert_eq!(a.len(), 6);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt, "deterministic per seed");
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
            assert_eq!(x.prompt.len(), cfg.prompt_len);
        }
        for w in a.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s, "strictly increasing arrivals");
        }
        let budgets: Vec<u32> = a.iter().map(|r| r.max_new_tokens).collect();
        assert_eq!(budgets, vec![48, 64, 96, 48, 64, 96], "cycled decode budgets");
    }

    /// Satellite: the day trace is deterministic per seed, stays inside
    /// its horizon with strictly increasing arrivals, and draws lengths
    /// from the configured choices.
    #[test]
    fn day_trace_deterministic_and_in_horizon() {
        let cfg = DayTraceConfig {
            horizon_s: 500.0,
            base_rate_per_s: 0.5,
            peak_rate_per_s: 4.0,
            seed: 7,
            ..Default::default()
        };
        let a = generate_day_trace(&cfg);
        let b = generate_day_trace(&cfg);
        assert!(!a.is_empty(), "a 500 s horizon at ≥0.5 req/s yields requests");
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.prompt, y.prompt, "deterministic per seed");
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
            assert!(x.arrival_s < cfg.horizon_s);
            assert!(cfg.prompt_len_choices.contains(&(x.prompt.len() as u32)));
            assert!(cfg.decode_len_choices.contains(&x.max_new_tokens));
        }
        for (i, r) in a.iter().enumerate() {
            assert_eq!(r.id, i as u64, "ids are dense in arrival order");
        }
        for w in a.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s, "strictly increasing arrivals");
        }
    }

    /// Satellite: the diurnal curve is real — the midday window carries
    /// several times the traffic of the trough windows at the horizon's
    /// edges.
    #[test]
    fn day_trace_rate_curve_peaks_at_midday() {
        let cfg = DayTraceConfig {
            horizon_s: 2000.0,
            base_rate_per_s: 0.25,
            peak_rate_per_s: 4.0,
            seed: 13,
            ..Default::default()
        };
        let trace = generate_day_trace(&cfg);
        let count_in = |lo: f64, hi: f64| {
            trace.iter().filter(|r| r.arrival_s >= lo && r.arrival_s < hi).count()
        };
        let edge = count_in(0.0, 250.0) + count_in(1750.0, 2000.0);
        let mid = count_in(875.0, 1375.0);
        assert!(
            mid as f64 > 3.0 * edge.max(1) as f64,
            "midday window must dominate the troughs: mid={mid} edge={edge}"
        );
    }

    /// A degenerate horizon yields an empty trace, not a hang.
    #[test]
    fn day_trace_zero_horizon_is_empty() {
        let cfg = DayTraceConfig { horizon_s: 0.0, ..Default::default() };
        assert!(generate_day_trace(&cfg).is_empty());
    }

    #[test]
    fn mixed_burst_shapes_and_arrivals() {
        let cfg = MixedBurstConfig::default();
        let t = generate_mixed_burst_trace(&cfg);
        assert_eq!(t.len(), 5);
        for r in &t[..3] {
            assert_eq!(r.arrival_s, 0.0);
            assert_eq!(r.prompt.len(), 16);
            assert_eq!(r.max_new_tokens, 48);
        }
        for (i, r) in t[3..].iter().enumerate() {
            assert!((r.arrival_s - 1e-3 * (i + 1) as f64).abs() < 1e-12);
            assert_eq!(r.prompt.len(), 192);
            assert_eq!(r.max_new_tokens, 4);
        }
        let again = generate_mixed_burst_trace(&cfg);
        for (x, y) in t.iter().zip(&again) {
            assert_eq!(x.prompt, y.prompt, "seeded: reproducible");
        }
    }

    /// Satellite: trace generation is deterministic — the same seed
    /// yields an IDENTICAL trace (ids, arrivals, prompts, budgets), and
    /// arrivals are strictly increasing.
    #[test]
    fn shared_prefix_trace_deterministic_and_ordered() {
        let cfg = SharedPrefixConfig { seed: 9, ..Default::default() };
        let a = generate_shared_prefix_trace(&cfg);
        let b = generate_shared_prefix_trace(&cfg);
        assert_eq!(a.len(), cfg.n_requests);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits(), "bit-identical");
        }
        for w in a.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s, "strictly increasing arrivals");
        }
        // A different seed must not replay the same trace.
        let c = generate_shared_prefix_trace(&SharedPrefixConfig { seed: 10, ..Default::default() });
        assert!(a.iter().zip(&c).any(|(x, y)| x.prompt != y.prompt));
    }

    /// Satellite: the skewed trace is deterministic per seed, keeps
    /// strictly increasing arrivals, and its hot group actually
    /// dominates (while the cold groups still appear).
    #[test]
    fn skewed_prefix_trace_hot_group_dominates() {
        let cfg = SkewedPrefixConfig { n_requests: 200, seed: 21, ..Default::default() };
        let a = generate_skewed_prefix_trace(&cfg);
        let b = generate_skewed_prefix_trace(&cfg);
        assert_eq!(a.len(), 200);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt, "deterministic per seed");
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
        }
        for w in a.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s, "strictly increasing arrivals");
        }
        // Group by prefix: the hot prefix is the modal one by a wide
        // margin, and at least one cold group still shows up.
        let mut counts: Vec<(Vec<u32>, usize)> = Vec::new();
        for r in &a {
            let p = r.prompt[..cfg.prefix_len].to_vec();
            match counts.iter_mut().find(|(q, _)| *q == p) {
                Some((_, n)) => *n += 1,
                None => counts.push((p, 1)),
            }
        }
        assert!(counts.len() >= 2, "cold groups must appear");
        assert!(counts.len() <= cfg.n_groups);
        let hot = counts.iter().map(|(_, n)| *n).max().unwrap();
        assert!(
            hot >= 200 * 60 / 100,
            "hot group must dominate at 75%: modal count {hot}/200"
        );
    }

    /// A single-group skewed config degenerates gracefully: every
    /// request draws the one prefix.
    #[test]
    fn skewed_prefix_trace_single_group_is_total_skew() {
        let cfg = SkewedPrefixConfig {
            n_groups: 1,
            hot_percent: 0,
            n_requests: 8,
            ..Default::default()
        };
        let trace = generate_skewed_prefix_trace(&cfg);
        let first = trace[0].prompt[..cfg.prefix_len].to_vec();
        for r in &trace {
            assert_eq!(r.prompt[..cfg.prefix_len], first[..], "one group, one prefix");
        }
    }

    #[test]
    fn shared_prefix_trace_groups_share_prefixes() {
        let cfg = SharedPrefixConfig {
            n_groups: 2,
            prefix_len: 32,
            n_requests: 24,
            ..Default::default()
        };
        let trace = generate_shared_prefix_trace(&cfg);
        // Collect the distinct 32-token prefixes: exactly n_groups of them.
        let mut prefixes: Vec<Vec<u32>> = Vec::new();
        for r in &trace {
            assert!(r.prompt.len() >= 32);
            let p = r.prompt[..32].to_vec();
            if !prefixes.contains(&p) {
                prefixes.push(p);
            }
            assert!(cfg.tail_len_choices.contains(&((r.prompt.len() - 32) as u32)));
        }
        assert!(
            prefixes.len() <= cfg.n_groups,
            "at most n_groups distinct prefixes, got {}",
            prefixes.len()
        );
        assert!(prefixes.len() >= 2, "24 draws over 2 groups hit both");
    }
}
