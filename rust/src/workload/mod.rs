//! Workload generation: the [prefill, decode] grids of the paper's
//! figures, Poisson request arrivals for the serving example, and trace
//! replay.

use crate::util::Rng;

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival time, seconds from trace start.
    pub arrival_s: f64,
    /// Prompt tokens (the coordinator tokenizes upstream; the workload
    /// carries raw token ids for the tiny model).
    pub prompt: Vec<u32>,
    /// Decode budget (tokens to generate).
    pub max_new_tokens: u32,
}

/// Poisson arrivals with geometric-ish length mixtures — the
/// latency-sensitive single-batch serving scenario of §1.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub rate_per_s: f64,
    pub n_requests: usize,
    pub prompt_len_choices: Vec<u32>,
    pub decode_len_choices: Vec<u32>,
    pub vocab: u32,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            rate_per_s: 2.0,
            n_requests: 32,
            prompt_len_choices: vec![16, 32, 64, 128],
            decode_len_choices: vec![16, 32, 64],
            vocab: 512,
            seed: 0,
        }
    }
}

/// Generate a request trace.
pub fn generate_trace(cfg: &TraceConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0.0f64;
    (0..cfg.n_requests)
        .map(|i| {
            t += rng.exp(cfg.rate_per_s);
            let plen = *rng.choose(&cfg.prompt_len_choices);
            let dlen = *rng.choose(&cfg.decode_len_choices);
            Request {
                id: i as u64,
                arrival_s: t,
                prompt: (0..plen).map(|_| rng.below(cfg.vocab as u64) as u32).collect(),
                max_new_tokens: dlen,
            }
        })
        .collect()
}

/// A burst: `n` identical-shape requests all arriving at t = 0 — the
/// Fig. 15 multibatch scenario pushed through the serving path, and the
/// worst-case admission pressure for the continuous-batching engine.
pub fn generate_burst_trace(
    n: usize,
    prompt_len: usize,
    max_new_tokens: u32,
    vocab: u32,
    seed: u64,
) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| Request {
            id: i as u64,
            arrival_s: 0.0,
            prompt: (0..prompt_len).map(|_| rng.below(vocab as u64) as u32).collect(),
            max_new_tokens,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_trace_arrives_at_once_with_fixed_shape() {
        let t = generate_burst_trace(4, 32, 8, 64, 3);
        assert_eq!(t.len(), 4);
        for r in &t {
            assert_eq!(r.arrival_s, 0.0);
            assert_eq!(r.prompt.len(), 32);
            assert_eq!(r.max_new_tokens, 8);
            assert!(r.prompt.iter().all(|&x| x < 64));
        }
        let again = generate_burst_trace(4, 32, 8, 64, 3);
        assert_eq!(t[2].prompt, again[2].prompt, "seeded: reproducible");
    }

    #[test]
    fn trace_is_deterministic_per_seed() {
        let cfg = TraceConfig::default();
        let a = generate_trace(&cfg);
        let b = generate_trace(&cfg);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert!((x.arrival_s - y.arrival_s).abs() < 1e-12);
        }
    }

    #[test]
    fn arrivals_are_increasing() {
        let trace = generate_trace(&TraceConfig::default());
        for w in trace.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
    }

    #[test]
    fn mean_interarrival_matches_rate() {
        let cfg = TraceConfig { rate_per_s: 10.0, n_requests: 5000, ..Default::default() };
        let trace = generate_trace(&cfg);
        let total = trace.last().unwrap().arrival_s;
        let mean = total / trace.len() as f64;
        assert!((mean - 0.1).abs() < 0.01, "mean gap = {mean}");
    }

    #[test]
    fn prompt_lengths_come_from_choices() {
        let cfg = TraceConfig::default();
        for r in generate_trace(&cfg) {
            assert!(cfg.prompt_len_choices.contains(&(r.prompt.len() as u32)));
            assert!(cfg.decode_len_choices.contains(&r.max_new_tokens));
        }
    }

    #[test]
    fn tokens_within_vocab() {
        for r in generate_trace(&TraceConfig::default()) {
            assert!(r.prompt.iter().all(|&t| t < 512));
        }
    }
}
