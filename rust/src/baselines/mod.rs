//! Baseline systems (§6.1): GPU stacks (huggingface-naive and
//! vLLM+SmoothQuant) on V100S/A100, and the SOTA accelerators DFX, CTA
//! and FACT.
//!
//! The paper evaluated the accelerators with in-house C++ simulators
//! aligned on clock / peak performance / bandwidth ("achieving less than
//! 5% deviation using their original data"); we do the same with a shared
//! analytical roofline core (`AnalyticalModel`) parameterized per system.
//! GPU bandwidth-efficiency coefficients come straight from Table 5.

mod accel;
mod gpu;

pub use accel::{cta, dfx, fact};
pub use gpu::{GpuStack, GpuSystem};

use crate::config::ModelConfig;
use crate::metrics::{EvalPoint, Measurement};

/// Shared roofline: decode is bandwidth-bound on the weight+KV stream,
/// prefill is compute-bound, each with an achieved-efficiency factor and
/// a per-layer scheduling overhead.
#[derive(Debug, Clone)]
pub struct AnalyticalModel {
    pub name: String,
    /// Stored bits per weight element (incl. metadata).
    pub weight_bits: f64,
    /// Bytes per KV-cache element.
    pub kv_bytes: f64,
    /// Attention-block density in prefill (1.0 = dense).
    pub attn_density: f64,
    /// DRAM/HBM peak bandwidth, GB/s.
    pub bandwidth_gbs: f64,
    /// Achieved fraction of peak bandwidth in decode.
    pub bw_eff: f64,
    /// Peak matmul throughput, TOPS (at the precision the system uses).
    pub peak_tops: f64,
    /// Achieved fraction of peak compute in prefill.
    pub compute_eff: f64,
    /// Per-layer scheduling/launch overhead, microseconds.
    pub layer_overhead_us: f64,
    /// Average board/device power at load, W.
    pub power_w: f64,
    pub price_usd: f64,
}

impl AnalyticalModel {
    /// Bytes streamed per decode step: all weights + KV cache at `ctx`.
    pub fn decode_bytes(&self, m: &ModelConfig, ctx: u64) -> f64 {
        let weights = m.param_count() as f64 * self.weight_bits / 8.0;
        let kv = m.kv_bytes(ctx, 1) as f64 * self.kv_bytes;
        weights + kv
    }

    /// One decode step at context `ctx`, seconds.
    pub fn decode_step_s(&self, m: &ModelConfig, ctx: u64) -> f64 {
        self.decode_step_batch_s(m, ctx, 1)
    }

    /// One batched decode step: weights stream once, KV and compute scale
    /// with the batch (Fig. 15's GPU side).
    pub fn decode_step_batch_s(&self, m: &ModelConfig, ctx: u64, batch: u32) -> f64 {
        let b = batch.max(1) as f64;
        let weights = m.param_count() as f64 * self.weight_bits / 8.0;
        let kv = m.kv_bytes(ctx, 1) as f64 * self.kv_bytes * b;
        let t_mem = (weights + kv) / (self.bandwidth_gbs * self.bw_eff * 1e9);
        let flops = m.decode_flops(ctx) as f64 * b;
        let t_cmp = flops / (self.peak_tops * self.compute_eff * 1e12);
        t_mem.max(t_cmp) + m.n_layers as f64 * self.layer_overhead_us * 1e-6
    }

    /// Aggregate decode throughput at batch `batch` (tokens/s).
    pub fn batch_tps(&self, m: &ModelConfig, ctx: u64, batch: u32) -> f64 {
        batch.max(1) as f64 / self.decode_step_batch_s(m, ctx, batch)
    }

    /// Full prefill of `n` tokens, seconds.
    pub fn prefill_s(&self, m: &ModelConfig, n: u64) -> f64 {
        let lin_flops = m.prefill_flops(n) as f64
            - (m.n_layers * 2 * 2 * n * n * m.dim) as f64;
        let attn_flops = (m.n_layers * 2 * 2 * n * n * m.dim) as f64 * self.attn_density;
        let t_cmp = (lin_flops + attn_flops) / (self.peak_tops * self.compute_eff * 1e12);
        // Weights also stream once during prefill.
        let t_mem = self.decode_bytes(m, 0) / (self.bandwidth_gbs * self.bw_eff * 1e9);
        t_cmp.max(t_mem) + m.n_layers as f64 * self.layer_overhead_us * 1e-6
    }

    /// End-to-end measurement over an evaluation point.
    pub fn measure(&self, m: &ModelConfig, pt: EvalPoint) -> Measurement {
        let prefill = self.prefill_s(m, pt.prefill);
        let mut decode = 0.0;
        for i in 0..pt.decode {
            decode += self.decode_step_s(m, pt.prefill + i);
        }
        Measurement {
            system: self.name.clone(),
            point: pt,
            latency_s: prefill + decode,
            decode_tps: pt.decode as f64 / decode.max(1e-12),
            power_w: self.power_w,
            bw_util: self.bw_eff,
            price_usd: self.price_usd,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn decode_is_memory_bound_for_7b() {
        let g = gpu::GpuSystem::v100s(gpu::GpuStack::Opt).model();
        let m = ModelConfig::llama2_7b();
        let t_mem = g.decode_bytes(&m, 512) / (g.bandwidth_gbs * g.bw_eff * 1e9);
        let t = g.decode_step_s(&m, 512);
        assert!(t >= t_mem && t < 3.0 * t_mem, "decode should be near memory bound");
    }

    #[test]
    fn longer_context_is_slower() {
        let g = gpu::GpuSystem::a100(gpu::GpuStack::Opt).model();
        let m = ModelConfig::llama2_7b();
        assert!(g.decode_step_s(&m, 2000) > g.decode_step_s(&m, 100));
    }

    #[test]
    fn prefill_scales_superlinearly_past_compute_bound() {
        let g = gpu::GpuSystem::v100s(gpu::GpuStack::Opt).model();
        let m = ModelConfig::llama2_7b();
        let t512 = g.prefill_s(&m, 512);
        let t1024 = g.prefill_s(&m, 1024);
        assert!(t1024 > 1.9 * t512, "{t1024} vs {t512}");
    }
}
