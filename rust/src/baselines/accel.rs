//! SOTA accelerator baselines (§6.1): DFX, CTA and FACT, aligned to the
//! same clock / peak performance / bandwidth as FlightLLM-on-U280 (the
//! paper's fairness alignment), differing in what each architecture can
//! exploit:
//!
//! - **DFX** (Hong et al., Hot Chips '22): decode-stage FPGA appliance,
//!   fp16 end to end, no model-compression support — it streams 4.6×
//!   more weight bytes per token than FlightLLM's 3.5-bit stream.
//! - **CTA** (Wang et al., HPCA '23): compressed-token attention — strong
//!   sparse-attention support, but linear layers stay fp16, so decode
//!   (linear-dominated) barely moves.
//! - **FACT** (Qin et al., ISCA '23): FFN+attention co-optimization with
//!   mixed-precision linears (INT8-class) and eager correlation
//!   prediction — better decode than DFX/CTA, still above FlightLLM's
//!   3.5-bit + always-on-chip stream.

use crate::config::Platform;

use super::AnalyticalModel;

/// Shared U280-aligned hardware parameters (the §6.1 alignment).
fn u280_aligned(name: &str) -> AnalyticalModel {
    let p = Platform::u280();
    AnalyticalModel {
        name: name.to_string(),
        weight_bits: 16.0,
        kv_bytes: 2.0,
        attn_density: 1.0,
        bandwidth_gbs: p.hbm.bandwidth_gbs,
        bw_eff: 0.45,
        // 6144 DSPs × 2 INT8 MACs × 2 ops × 225 MHz ≈ 5.5 TOPS; fp16
        // halves it. Aligned "peak performance" per the paper: ~25 TOPS
        // class for the INT8 designs, fp16 designs at half.
        peak_tops: 25.0,
        compute_eff: 0.55,
        layer_overhead_us: 2.0,
        power_w: p.power_w,
        price_usd: p.price_usd,
    }
}

/// DFX: fp16, decode-optimized dataflow, no compression.
pub fn dfx() -> AnalyticalModel {
    AnalyticalModel {
        weight_bits: 16.0,
        kv_bytes: 2.0,
        attn_density: 1.0,
        bw_eff: 0.45,
        peak_tops: 12.5, // fp16 datapath on the aligned fabric
        compute_eff: 0.60,
        ..u280_aligned("DFX")
    }
}

/// CTA: compressed-token sparse attention, fp16 linears.
pub fn cta() -> AnalyticalModel {
    AnalyticalModel {
        weight_bits: 16.0,
        kv_bytes: 1.0,       // compressed token KV representation
        attn_density: 0.35,  // token pruning removes ~65% of attention
        bw_eff: 0.48,
        peak_tops: 12.5,
        compute_eff: 0.60,
        ..u280_aligned("CTA")
    }
}

/// FACT: mixed-precision linears + eager attention prediction.
pub fn fact() -> AnalyticalModel {
    AnalyticalModel {
        weight_bits: 8.0,    // INT8-class mixed precision on linears
        kv_bytes: 1.0,
        attn_density: 0.50,
        bw_eff: 0.50,
        peak_tops: 25.0,
        compute_eff: 0.60,
        ..u280_aligned("FACT")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::metrics::EvalPoint;

    #[test]
    fn fact_beats_cta_beats_nothing_on_decode() {
        // Decode is linear-dominated: FACT (8-bit linears) must beat DFX
        // and CTA (fp16 linears); CTA ≈ DFX there (its win is attention).
        let m = ModelConfig::opt_6_7b();
        let d = dfx().decode_step_s(&m, 512);
        let c = cta().decode_step_s(&m, 512);
        let f = fact().decode_step_s(&m, 512);
        assert!(f < c && f < d, "FACT must lead decode: {f} vs {c} vs {d}");
        assert!((c - d).abs() / d < 0.25, "CTA ≈ DFX on decode");
    }

    #[test]
    fn cta_and_fact_win_prefill_attention() {
        // At large prefill the sparse-attention designs pull ahead of DFX.
        let m = ModelConfig::opt_6_7b();
        let pt = EvalPoint { prefill: 1024, decode: 16 };
        let d = dfx().measure(&m, pt).latency_s;
        let c = cta().measure(&m, pt).latency_s;
        assert!(c < d, "CTA must beat DFX at large prefill: {c} vs {d}");
    }

    #[test]
    fn aligned_hardware_parameters() {
        // §6.1 fairness: same bandwidth and price basis as the U280.
        let p = Platform::u280();
        for b in [dfx(), cta(), fact()] {
            assert_eq!(b.bandwidth_gbs, p.hbm.bandwidth_gbs, "{}", b.name);
            assert_eq!(b.price_usd, p.price_usd);
        }
    }

    #[test]
    fn dfx_decode_streams_4_6x_flightllm_bytes() {
        let m = ModelConfig::llama2_7b();
        let dfx_bytes = dfx().decode_bytes(&m, 512);
        // FlightLLM stream: 3.5-bit + 4-bit index on kept half ≈ 0.94 B/w.
        let fl_bytes = m.param_count() as f64 * 0.5 * 0.9375 + m.kv_bytes(512, 1) as f64;
        let ratio = dfx_bytes / fl_bytes;
        assert!(ratio > 3.5 && ratio < 5.5, "DFX/FlightLLM traffic = {ratio:.2}");
    }
}
