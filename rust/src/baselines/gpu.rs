//! GPU baselines (Table 2 + Table 5): V100S / A100 running either the
//! huggingface-PyTorch "naive" stack or the vLLM + SmoothQuant "opt"
//! stack.
//!
//! Calibration anchors, all from the paper:
//! - Table 5 bandwidth utilization: V100S 42.5% naive / 65.5% opt,
//!   A100 28.6% naive / 57.4% opt.
//! - naive runs fp16 weights; opt runs SmoothQuant W8A8 (weights 8-bit).
//! - naive pays per-op kernel-launch overhead; vLLM's fused/paged kernels
//!   cut it substantially.
//! - gpt-fast discussion (§6.2.6): A100 INT4 reaches 196.8 tok/s at 44.6%
//!   bandwidth utilization — used as a sanity check in tests.

use crate::config::GpuConfig;

use super::AnalyticalModel;

/// Software stack flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GpuStack {
    /// huggingface PyTorch, fp16.
    Naive,
    /// vLLM + SmoothQuant (W8A8 + paged KV).
    Opt,
}

/// A GPU + stack pair.
#[derive(Debug, Clone)]
pub struct GpuSystem {
    pub gpu: GpuConfig,
    pub stack: GpuStack,
    bw_eff: f64,
}

impl GpuSystem {
    pub fn v100s(stack: GpuStack) -> Self {
        let bw_eff = match stack {
            GpuStack::Naive => 0.425,
            GpuStack::Opt => 0.655,
        };
        Self { gpu: GpuConfig::v100s(), stack, bw_eff }
    }

    pub fn a100(stack: GpuStack) -> Self {
        let bw_eff = match stack {
            GpuStack::Naive => 0.286,
            GpuStack::Opt => 0.574,
        };
        Self { gpu: GpuConfig::a100(), stack, bw_eff }
    }

    pub fn name(&self) -> String {
        match self.stack {
            GpuStack::Naive => format!("{}-naive", self.gpu.name),
            GpuStack::Opt => format!("{}-opt", self.gpu.name),
        }
    }

    /// Roofline parameterization of this system.
    pub fn model(&self) -> AnalyticalModel {
        let (weight_bits, peak_tops, layer_overhead_us) = match self.stack {
            // fp16 weights; eager-mode HF launches ~10 kernels per layer
            // at batch 1 (~150 µs/layer of host+launch tax).
            GpuStack::Naive => (16.0, self.gpu.peak_fp16_tflops, 150.0),
            // W8A8 SmoothQuant + vLLM fused kernels still pay dequant +
            // paged-attention overhead at batch 1 (~120 µs/layer).
            GpuStack::Opt => (8.0, self.gpu.peak_int8_tops, 120.0),
        };
        AnalyticalModel {
            name: self.name(),
            weight_bits,
            kv_bytes: match self.stack {
                GpuStack::Naive => 2.0,
                GpuStack::Opt => 2.0, // vLLM pages fp16 KV
            },
            attn_density: 1.0, // dense attention on GPU
            bandwidth_gbs: self.gpu.bandwidth_gbs,
            bw_eff: self.bw_eff,
            peak_tops,
            compute_eff: match self.stack {
                GpuStack::Naive => 0.35,
                GpuStack::Opt => 0.55,
            },
            layer_overhead_us,
            power_w: match self.stack {
                // Measured-at-load (nvprof) powers; naive stacks stall
                // more and draw slightly less than the busy opt stack.
                GpuStack::Naive => 0.72 * self.gpu.tdp_w,
                GpuStack::Opt => 0.82 * self.gpu.tdp_w,
            },
            price_usd: self.gpu.price_usd,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::metrics::EvalPoint;

    #[test]
    fn opt_stack_beats_naive() {
        let m = ModelConfig::llama2_7b();
        let pt = EvalPoint { prefill: 128, decode: 128 };
        let naive = GpuSystem::v100s(GpuStack::Naive).model().measure(&m, pt);
        let opt = GpuSystem::v100s(GpuStack::Opt).model().measure(&m, pt);
        let speedup = naive.latency_s / opt.latency_s;
        assert!(
            speedup > 1.5 && speedup < 6.0,
            "vLLM+SmoothQuant speedup = {speedup:.2}"
        );
    }

    #[test]
    fn a100_beats_v100s_same_stack() {
        let m = ModelConfig::llama2_7b();
        let pt = EvalPoint { prefill: 512, decode: 512 };
        let v = GpuSystem::v100s(GpuStack::Opt).model().measure(&m, pt);
        let a = GpuSystem::a100(GpuStack::Opt).model().measure(&m, pt);
        assert!(a.latency_s < v.latency_s);
    }

    #[test]
    fn v100s_opt_decode_rate_plausible() {
        // W8A8 7B on V100S-opt: ~6.7 GB stream at 743 GB/s effective
        // ≈ 9 ms/token ≈ 60-110 tok/s.
        let m = ModelConfig::llama2_7b();
        let sys = GpuSystem::v100s(GpuStack::Opt).model();
        let tps = 1.0 / sys.decode_step_s(&m, 256);
        assert!(tps > 50.0 && tps < 130.0, "V100S-opt ≈ {tps:.1} tok/s");
    }

    #[test]
    fn naive_a100_underuses_bandwidth_vs_v100s() {
        // Table 5's surprising row: A100-naive has *lower* utilization
        // than V100S-naive (its bandwidth outpaces eager-mode kernels).
        let v = GpuSystem::v100s(GpuStack::Naive);
        let a = GpuSystem::a100(GpuStack::Naive);
        assert!(a.bw_eff < v.bw_eff);
    }

    #[test]
    fn gpt_fast_sanity_band() {
        // §6.2.6: A100 INT4 gpt-fast = 196.8 tok/s @ 44.6% BW util. Our
        // A100 at INT4-equivalent parameters should land in that regime.
        let m = ModelConfig::llama2_7b();
        let mut sys = GpuSystem::a100(GpuStack::Opt).model();
        sys.weight_bits = 4.5; // INT4 + scales
        sys.bw_eff = 0.446;
        sys.layer_overhead_us = 4.0;
        let tps = 1.0 / sys.decode_step_s(&m, 128);
        assert!(
            tps > 140.0 && tps < 260.0,
            "gpt-fast-like config ≈ {tps:.1} tok/s (paper: 196.8)"
        );
    }
}
