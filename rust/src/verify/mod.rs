//! Static instruction-stream verifier — the checker between
//! `compiler::lower` and `sim::Engine::run`.
//!
//! The compiled stream *is* the hardware contract (§5, Fig. 9): a
//! lowering bug silently becomes a wrong latency number that the whole
//! serving stack then prices.  `VerifySink` abstract-interprets a stream
//! instruction-by-instruction (it is an `InstSink`, so `lower` can emit
//! straight into it without materializing a `Vec`), holding every
//! LD/ST/compute against the platform's budgets:
//!
//! 1. **Buffer occupancy** — bytes in flight per `OnChipBuf` against the
//!    `OnChipBudget`, plus RAW hazards (compute consuming a weight
//!    buffer nothing has loaded since the last barrier).
//! 2. **Off-chip address safety** — every LD/ST span inside HBM/DDR
//!    capacity, and (when an `AddressMap` is supplied) inside some
//!    placed tensor's span with a matching `MemSpace`.
//! 3. **Channel bounds** — merged runs satisfy
//!    `first_channel + channels <= platform.hbm.channels`, no u8 wrap.
//! 4. **Encoding bounds** — every instruction round-trips the 16-byte
//!    word unchanged (field-truncation lint: unaligned addresses, N:M
//!    `n` past the 6-bit field, ...).
//! 5. **Sync discipline** — the expected `SyncSlr` per layer slice, no
//!    store left unsynced at stream end, final host sync present.
//! 6. **Bucket coverage** — `BucketPlan` lint: every length 1..=max_seq
//!    maps to exactly one bucket (no gaps, no overlaps).
//!
//! Safety is half the contract.  The [`dataflow`] submodule layers an
//! *efficiency* tier on top: per-buffer def-use/liveness analysis that
//! flags dead loads, redundant reloads and removable barriers, computes
//! the symbolic memory-effect summaries that certify
//! `compiler::optimize_stream`, and drives the `flightllm analyze` CI
//! gate (zero inefficiencies after optimization).
//!
//! Diagnostics are flood-capped per kind ([`DIAG_KIND_CAP`]): a
//! systematically-corrupt stream keeps the first N findings of each kind
//! and counts the rest as `suppressed` instead of allocating millions of
//! `Diagnostic`s.
//!
//! The analyzer itself is proven by fault-injection property tests: each
//! corruption class (byte flip, channel bump, capacity bust, dropped LD,
//! dropped SYS, degenerate sparsity, wild address) must be rejected with
//! the right diagnostic kind at the right instruction index, while every
//! shipped compiler output verifies clean.

use crate::compiler::{lower, BucketPlan, CompilerOptions, InstSink};
use crate::config::Target;
use crate::ir::{passes, AddressMap, Graph, Placement, Stage};
use crate::isa::{self, Inst, MemSpace, OnChipBuf, SysOp, INST_BYTES};

pub mod dataflow;

/// One verifier finding, anchored to an instruction index.  End-of-stream
/// findings (e.g. a missing barrier) use the stream length as index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub index: usize,
    pub kind: DiagnosticKind,
    pub detail: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {:?}: {}", self.index, self.kind, self.detail)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DiagnosticKind {
    /// LD would overflow an on-chip buffer's capacity.
    BufferOverflow,
    /// Compute reads a weight buffer nothing has loaded since the last
    /// barrier (RAW hazard).
    ReadBeforeLoad,
    /// LD/ST span exceeds HBM/DDR capacity.
    AddressOutOfRange,
    /// Access lies outside every placed tensor span (layout-checked runs).
    UnmappedAccess,
    /// Channel index past the platform channel count, or a merged run
    /// that wraps u8 channel space.
    ChannelOutOfRange,
    /// Instruction does not round-trip the 16-byte encoding (field
    /// truncation, or an undecodable word in an encoded stream).
    EncodingMismatch,
    /// Missing/extra SLR barrier, trailing unsynced store, or missing
    /// final host sync.
    SyncViolation,
    /// Degenerate N:M sparsity descriptor (m == 0, n > m, n > 63).
    SparsityInvalid,
    /// Bucket plan leaves lengths uncovered.
    BucketGap,
    /// Bucket plan edges overlap (not strictly ascending).
    BucketOverlap,
    /// Load whose data is never read before the next barrier or stream
    /// end — wasted off-chip traffic (dataflow tier).
    DeadLoad,
    /// Load of an off-chip span whose on-chip copy is still live and
    /// unchanged — the reload moves bytes for nothing (dataflow tier).
    RedundantReload,
    /// `SyncSlr` with no cross-SLR def-use edge crossing it: nothing was
    /// published off-chip since the previous barrier (dataflow tier).
    RemovableSync,
    /// Encoded stream length is not a multiple of the 16-byte word; the
    /// tail bytes cannot form an instruction.
    TruncatedTail,
}

/// A placed tensor span the layout check holds accesses against.
#[derive(Debug, Clone, Copy)]
struct PlacedSpan {
    start: u64,
    end: u64,
    hbm: bool,
}

/// Platform-derived budgets + toggles for one verification run.
#[derive(Debug, Clone)]
pub struct VerifyContext {
    weight_cap: u64,
    activation_cap: u64,
    global_cap: u64,
    index_cap: u64,
    hbm_capacity: u64,
    ddr_capacity: u64,
    hbm_channels: u32,
    /// Exact `SyncSlr` count the stream must carry (one per layer slice).
    expected_slr_syncs: Option<u64>,
    check_occupancy: bool,
    check_sync: bool,
    spans: Option<Vec<PlacedSpan>>,
}

impl VerifyContext {
    /// Full-strength checks for compiler output.
    pub fn for_target(t: &Target) -> Self {
        let b = t.platform.onchip;
        Self {
            weight_cap: b.weight_bytes,
            activation_cap: b.activation_bytes,
            global_cap: b.global_bytes,
            index_cap: b.index_bytes,
            hbm_capacity: t.platform.hbm.capacity_bytes(),
            ddr_capacity: t.platform.ddr.capacity_bytes(),
            hbm_channels: t.platform.hbm.channels,
            expected_slr_syncs: None,
            check_occupancy: true,
            check_sync: true,
            spans: None,
        }
    }

    /// The machine-safety subset (channels, encoding, address capacity)
    /// for ad-hoc streams the engine replays — no occupancy or sync
    /// discipline, which hand-built test streams legitimately ignore.
    pub fn machine_safety(t: &Target) -> Self {
        Self { check_occupancy: false, check_sync: false, ..Self::for_target(t) }
    }

    /// Require exactly `n` SLR barriers (one per layer slice) and a final
    /// host sync.
    pub fn expect_slr_syncs(mut self, n: u64) -> Self {
        self.expected_slr_syncs = Some(n);
        self
    }

    /// Hold every access against the placed tensor spans of `map`.
    pub fn with_layout(mut self, g: &Graph, map: &AddressMap) -> Self {
        let mut spans = Vec::with_capacity(map.placements.len());
        for (&id, p) in &map.placements {
            let bytes = g.tensors[id].bytes.max(1);
            let (start, hbm) = match p {
                Placement::Hbm { addr, .. } => (*addr, true),
                Placement::Ddr { addr } => (*addr, false),
            };
            spans.push(PlacedSpan { start, end: start + bytes, hbm });
        }
        self.spans = Some(spans);
        self
    }

    fn buf_cap(&self, buf: OnChipBuf) -> u64 {
        match buf {
            OnChipBuf::Weight => self.weight_cap,
            OnChipBuf::Activation => self.activation_cap,
            OnChipBuf::Global => self.global_cap,
            OnChipBuf::Index => self.index_cap,
        }
    }
}

fn buf_index(buf: OnChipBuf) -> usize {
    match buf {
        OnChipBuf::Weight => 0,
        OnChipBuf::Activation => 1,
        OnChipBuf::Global => 2,
        OnChipBuf::Index => 3,
    }
}

const BUFS: [OnChipBuf; 4] =
    [OnChipBuf::Weight, OnChipBuf::Activation, OnChipBuf::Global, OnChipBuf::Index];

/// Per-kind diagnostic flood cap: the first N findings of each kind are
/// kept, the rest only counted — so a systematically-corrupt stream
/// (every instruction tripping the same check) can't allocate millions
/// of `Diagnostic`s.
pub const DIAG_KIND_CAP: usize = 64;

/// Routes diagnostics through the per-kind cap, counting the overflow.
#[derive(Debug, Default)]
pub(crate) struct DiagBudget {
    counts: std::collections::HashMap<DiagnosticKind, u64>,
    suppressed: u64,
}

impl DiagBudget {
    pub(crate) fn push(&mut self, diags: &mut Vec<Diagnostic>, d: Diagnostic) {
        let c = self.counts.entry(d.kind).or_insert(0);
        *c += 1;
        if *c as usize <= DIAG_KIND_CAP {
            diags.push(d);
        } else {
            self.suppressed += 1;
        }
    }

    pub(crate) fn suppressed(&self) -> u64 {
        self.suppressed
    }
}

/// Streaming verifier: feed it a stream via `InstSink::emit` (or let
/// `lower` do so), then call `finish` for the end-of-stream checks.
#[derive(Debug)]
pub struct VerifySink {
    ctx: VerifyContext,
    idx: usize,
    /// Bytes loaded per buffer since the last consuming compute/barrier.
    inflight: [u64; 4],
    /// Whether a buffer holds consumed-and-kept data since the last barrier.
    resident: [bool; 4],
    slr_syncs: u64,
    /// Indices of stores not yet covered by a following SYS.
    pending_stores: Vec<usize>,
    last_inst_was_host_sync: bool,
    budget: DiagBudget,
    diags: Vec<Diagnostic>,
}

impl VerifySink {
    pub fn new(ctx: VerifyContext) -> Self {
        Self {
            ctx,
            idx: 0,
            inflight: [0; 4],
            resident: [false; 4],
            slr_syncs: 0,
            pending_stores: Vec::new(),
            last_inst_was_host_sync: false,
            budget: DiagBudget::default(),
            diags: Vec::new(),
        }
    }

    pub fn instructions(&self) -> usize {
        self.idx
    }

    fn diag(&mut self, kind: DiagnosticKind, detail: String) {
        self.budget.push(&mut self.diags, Diagnostic { index: self.idx, kind, detail });
    }

    fn check_encoding(&mut self, inst: &Inst) {
        match isa::decode(&isa::encode(inst)) {
            Ok(back) if back == *inst => {}
            Ok(back) => self.diag(
                DiagnosticKind::EncodingMismatch,
                format!("{inst:?} decodes back as {back:?}"),
            ),
            Err(e) => self.diag(
                DiagnosticKind::EncodingMismatch,
                format!("{inst:?} does not decode: {e}"),
            ),
        }
    }

    fn check_channel(&mut self, space: &MemSpace) {
        if let MemSpace::Hbm { channel } = space {
            if *channel as u32 >= self.ctx.hbm_channels {
                self.diag(
                    DiagnosticKind::ChannelOutOfRange,
                    format!("channel {channel} >= {} HBM channels", self.ctx.hbm_channels),
                );
            }
        }
    }

    fn check_channel_run(&mut self, first: u8, channels: u8) {
        let end = first as u32 + channels as u32;
        if channels == 0 || end > 256 {
            self.diag(
                DiagnosticKind::ChannelOutOfRange,
                format!("merged run {first}+{channels} wraps u8 channel space"),
            );
        } else if end > self.ctx.hbm_channels {
            self.diag(
                DiagnosticKind::ChannelOutOfRange,
                format!("merged run {first}+{channels} > {} HBM channels", self.ctx.hbm_channels),
            );
        }
    }

    fn check_span(&mut self, hbm: bool, addr: u64, bytes: u64) {
        let cap = if hbm { self.ctx.hbm_capacity } else { self.ctx.ddr_capacity };
        let end = addr.saturating_add(bytes);
        if end > cap {
            let mem = if hbm { "HBM" } else { "DDR" };
            self.diag(
                DiagnosticKind::AddressOutOfRange,
                format!("[{addr:#x}, {end:#x}) exceeds {mem} capacity {cap:#x}"),
            );
            return;
        }
        if let Some(spans) = &self.ctx.spans {
            let inside = spans
                .iter()
                .any(|s| s.hbm == hbm && addr >= s.start && end <= s.end);
            if !inside {
                let mem = if hbm { "HBM" } else { "DDR" };
                self.diag(
                    DiagnosticKind::UnmappedAccess,
                    format!("[{addr:#x}, {end:#x}) in {mem} hits no placed tensor"),
                );
            }
        }
    }

    fn occupy_load(&mut self, dst: OnChipBuf, total_bytes: u64) {
        if !self.ctx.check_occupancy {
            return;
        }
        let cap = self.ctx.buf_cap(dst);
        let b = buf_index(dst);
        self.inflight[b] += total_bytes;
        if self.inflight[b] > cap {
            self.diag(
                DiagnosticKind::BufferOverflow,
                format!("{dst:?} buffer holds {} B > {cap} B capacity", self.inflight[b]),
            );
            // Clamp so one oversized load doesn't cascade into a
            // diagnostic on every subsequent instruction.
            self.inflight[b] = cap;
        }
    }

    /// MM/MV consume the weight buffer (tile streaming) and drain any
    /// staged activations.  Activations may legitimately be produced
    /// on-chip, so only the weight path is a RAW hazard.
    fn consume_compute(&mut self) {
        if !self.ctx.check_occupancy {
            return;
        }
        let w = buf_index(OnChipBuf::Weight);
        if self.inflight[w] == 0 && !self.resident[w] {
            self.diag(
                DiagnosticKind::ReadBeforeLoad,
                "compute reads the weight buffer before any load since the last barrier"
                    .into(),
            );
        }
        for buf in [OnChipBuf::Weight, OnChipBuf::Activation] {
            let b = buf_index(buf);
            self.resident[b] = true;
            self.inflight[b] = 0;
        }
    }

    fn check_sparsity(&mut self, s: &crate::isa::Sparsity) {
        if !s.is_valid() {
            self.diag(DiagnosticKind::SparsityInvalid, format!("{s:?}"));
        }
    }

    fn observe(&mut self, inst: &Inst) {
        self.check_encoding(inst);
        match inst {
            Inst::Ld { src, dst, addr, bytes } => {
                self.check_channel(src);
                self.check_span(matches!(src, MemSpace::Hbm { .. }), *addr, *bytes as u64);
                self.occupy_load(*dst, *bytes as u64);
            }
            Inst::LdMerged { first_channel, channels, dst, addr, bytes } => {
                self.check_channel_run(*first_channel, *channels);
                self.check_span(true, *addr, *channels as u64 * *bytes as u64);
                self.occupy_load(*dst, *channels as u64 * *bytes as u64);
            }
            Inst::St { dst, addr, bytes, .. } => {
                self.check_channel(dst);
                self.check_span(matches!(dst, MemSpace::Hbm { .. }), *addr, *bytes as u64);
                if self.ctx.check_sync {
                    self.pending_stores.push(self.idx);
                }
            }
            Inst::StMerged { first_channel, channels, addr, bytes, .. } => {
                self.check_channel_run(*first_channel, *channels);
                self.check_span(true, *addr, *channels as u64 * *bytes as u64);
                if self.ctx.check_sync {
                    self.pending_stores.push(self.idx);
                }
            }
            Inst::Mm { sparsity, .. } | Inst::Mv { sparsity, .. } => {
                self.check_sparsity(sparsity);
                self.consume_compute();
            }
            Inst::Misc { .. } => {}
            Inst::Sys { op } => {
                if *op == SysOp::SyncSlr {
                    self.slr_syncs += 1;
                }
                self.pending_stores.clear();
                // A barrier drains the pipeline: buffers restart empty.
                for buf in BUFS {
                    let b = buf_index(buf);
                    self.inflight[b] = 0;
                    self.resident[b] = false;
                }
            }
        }
        self.last_inst_was_host_sync = matches!(inst, Inst::Sys { op: SysOp::SyncHost });
        self.idx += 1;
    }

    /// End-of-stream checks; returns every kept diagnostic.
    pub fn finish(self) -> Vec<Diagnostic> {
        self.finish_with_suppressed().0
    }

    /// End-of-stream checks; returns the kept diagnostics plus the count
    /// suppressed by the per-kind flood cap ([`DIAG_KIND_CAP`]).
    pub fn finish_with_suppressed(mut self) -> (Vec<Diagnostic>, u64) {
        if self.ctx.check_sync {
            for idx in std::mem::take(&mut self.pending_stores) {
                self.budget.push(
                    &mut self.diags,
                    Diagnostic {
                        index: idx,
                        kind: DiagnosticKind::SyncViolation,
                        detail: "store not followed by any SYS before stream end".into(),
                    },
                );
            }
            if let Some(expected) = self.ctx.expected_slr_syncs {
                if self.slr_syncs != expected {
                    self.budget.push(
                        &mut self.diags,
                        Diagnostic {
                            index: self.idx,
                            kind: DiagnosticKind::SyncViolation,
                            detail: format!(
                                "{} SyncSlr barriers, expected {expected} (one per layer slice)",
                                self.slr_syncs
                            ),
                        },
                    );
                }
                if self.idx > 0 && !self.last_inst_was_host_sync {
                    self.budget.push(
                        &mut self.diags,
                        Diagnostic {
                            index: self.idx,
                            kind: DiagnosticKind::SyncViolation,
                            detail: "stream does not end with a host sync".into(),
                        },
                    );
                }
            }
        }
        (self.diags, self.budget.suppressed())
    }
}

impl InstSink for VerifySink {
    fn emit(&mut self, inst: Inst) {
        self.observe(&inst);
    }
}

/// Verify a materialized stream (replaying a `VecSink`).
pub fn verify_stream(insts: &[Inst], ctx: &VerifyContext) -> Vec<Diagnostic> {
    let mut sink = VerifySink::new(ctx.clone());
    for inst in insts {
        sink.observe(inst);
    }
    sink.finish()
}

/// Verify an encoded stream: undecodable words become `EncodingMismatch`
/// diagnostics at their word index; a fully-decodable prefix is then run
/// through the stream checks.  A length that is not a multiple of the
/// 16-byte word is a typed `TruncatedTail` diagnostic at the tail's word
/// index — the whole words before it are still verified.
pub fn verify_encoded(bytes: &[u8], ctx: &VerifyContext) -> Vec<Diagnostic> {
    let tail = bytes.len() % INST_BYTES;
    let whole = &bytes[..bytes.len() - tail];
    let mut insts = Vec::with_capacity(whole.len() / INST_BYTES);
    let mut diags = Vec::new();
    for (i, w) in whole.chunks_exact(INST_BYTES).enumerate() {
        match isa::decode(w.try_into().expect("chunk is INST_BYTES")) {
            Ok(inst) => insts.push(inst),
            Err(e) => diags.push(Diagnostic {
                index: i,
                kind: DiagnosticKind::EncodingMismatch,
                detail: format!("word does not decode: {e}"),
            }),
        }
    }
    if diags.is_empty() {
        diags = verify_stream(&insts, ctx);
    }
    if tail != 0 {
        diags.push(Diagnostic {
            index: bytes.len() / INST_BYTES,
            kind: DiagnosticKind::TruncatedTail,
            detail: format!("{tail} trailing bytes do not form a whole 16-byte word"),
        });
    }
    diags
}

/// Lint a bucket plan: edges strictly ascending (else overlap), nonzero,
/// and the last edge reaching max_seq (else lengths silently clamp to a
/// too-short stream — a gap).  Diagnostic indices are edge positions.
pub fn verify_bucket_plan(plan: &BucketPlan) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    for (stage, edges) in [("decode", &plan.decode), ("prefill", &plan.prefill)] {
        if edges.is_empty() {
            diags.push(Diagnostic {
                index: 0,
                kind: DiagnosticKind::BucketGap,
                detail: format!("{stage} bucket table is empty"),
            });
            continue;
        }
        for (i, &e) in edges.iter().enumerate() {
            if e == 0 {
                diags.push(Diagnostic {
                    index: i,
                    kind: DiagnosticKind::BucketGap,
                    detail: format!("{stage} edge 0 covers nothing"),
                });
            }
            if i > 0 && e <= edges[i - 1] {
                diags.push(Diagnostic {
                    index: i,
                    kind: DiagnosticKind::BucketOverlap,
                    detail: format!(
                        "{stage} edges not strictly ascending: {} then {e}",
                        edges[i - 1]
                    ),
                });
            }
        }
        let last = *edges.last().expect("nonempty");
        if last < plan.max_seq {
            diags.push(Diagnostic {
                index: edges.len() - 1,
                kind: DiagnosticKind::BucketGap,
                detail: format!(
                    "{stage} last edge {last} < max_seq {} — lengths past it clamp silently",
                    plan.max_seq
                ),
            });
        }
        if last > plan.max_seq {
            diags.push(Diagnostic {
                index: edges.len() - 1,
                kind: DiagnosticKind::BucketGap,
                detail: format!("{stage} last edge {last} > max_seq {}", plan.max_seq),
            });
        }
    }
    diags
}

/// One verified stream of a target's shipped matrix.
#[derive(Debug, Clone)]
pub struct StreamReport {
    pub label: String,
    pub instructions: usize,
    pub diags: Vec<Diagnostic>,
    /// Diagnostics dropped by the per-kind flood cap ([`DIAG_KIND_CAP`]).
    pub suppressed: u64,
}

/// Verification of every shipped stream for one target: every
/// `CompilerOptions` preset × stage × bucket, plus the bucket-plan lint.
#[derive(Debug, Clone)]
pub struct TargetReport {
    pub target: String,
    pub streams: Vec<StreamReport>,
    pub bucket_diags: Vec<Diagnostic>,
}

impl TargetReport {
    pub fn total_diags(&self) -> usize {
        self.bucket_diags.len() + self.streams.iter().map(|s| s.diags.len()).sum::<usize>()
    }

    pub fn is_clean(&self) -> bool {
        self.total_diags() == 0
    }

    pub fn total_instructions(&self) -> u64 {
        self.streams.iter().map(|s| s.instructions as u64).sum()
    }
}

/// The shipped `CompilerOptions` presets the matrix covers.
pub fn shipped_presets() -> Vec<(&'static str, CompilerOptions)> {
    vec![
        ("full", CompilerOptions::full()),
        ("naive", CompilerOptions::naive()),
        ("storage-fine", CompilerOptions::storage_fine()),
        ("batch8", CompilerOptions::with_batch(8)),
    ]
}

/// Verify every shipped stream of `t` by lowering straight into a
/// `VerifySink` (no stream materialization).
pub fn verify_target(t: &Target) -> TargetReport {
    let plan = BucketPlan::paper_default(t.model.max_seq);
    let ctx = VerifyContext::for_target(t).expect_slr_syncs(t.model.n_layers);
    let mut streams = Vec::new();
    let stages = plan
        .decode
        .iter()
        .map(|&b| Stage::Decode { ctx: b })
        .chain(plan.prefill.iter().map(|&b| Stage::Prefill { n: b }));
    for stage in stages {
        let mut g = Graph::from_model(&t.model, &t.compression, stage);
        passes::optimize(&mut g);
        for (name, opt) in shipped_presets() {
            let mut sink = VerifySink::new(ctx.clone());
            lower(&g, t, opt, &mut sink);
            let instructions = sink.instructions();
            let (diags, suppressed) = sink.finish_with_suppressed();
            streams.push(StreamReport {
                label: format!("{} {:?} {}", t.model.name, stage, name),
                instructions,
                diags,
                suppressed,
            });
        }
    }
    TargetReport {
        target: format!("{} on {}", t.model.name, t.platform.name),
        streams,
        bucket_diags: verify_bucket_plan(&plan),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::VecSink;
    use crate::config::{ModelConfig, Target};
    use crate::ir::assign_addresses;
    use crate::isa::{MiscOp, OnChipBuf, Sparsity};
    use crate::util::proptest;
    use crate::util::rng::Rng;

    fn tiny() -> Target {
        Target::u280_tiny()
    }

    fn shipped_stream(t: &Target, stage: Stage, opt: CompilerOptions) -> Vec<Inst> {
        let mut g = Graph::from_model(&t.model, &t.compression, stage);
        passes::optimize(&mut g);
        let mut sink = VecSink::default();
        lower(&g, t, opt, &mut sink);
        sink.0
    }

    fn full_ctx(t: &Target) -> VerifyContext {
        VerifyContext::for_target(t).expect_slr_syncs(t.model.n_layers)
    }

    /// The tiny decode stream every fault test mutates.
    fn base() -> (Vec<Inst>, VerifyContext) {
        let t = tiny();
        let insts =
            shipped_stream(&t, Stage::Decode { ctx: t.model.max_seq }, CompilerOptions::full());
        (insts, full_ctx(&t))
    }

    fn has(diags: &[Diagnostic], kind: DiagnosticKind, index: usize) -> bool {
        diags.iter().any(|d| d.kind == kind && d.index == index)
    }

    #[test]
    fn shipped_tiny_streams_are_clean() {
        let (insts, ctx) = base();
        let diags = verify_stream(&insts, &ctx);
        assert!(diags.is_empty(), "shipped stream must verify clean: {diags:?}");
        // And through the encoded path.
        assert!(verify_encoded(&isa::encode_stream(&insts), &ctx).is_empty());
    }

    #[test]
    fn verify_sink_streams_equal_replay() {
        // Lowering directly into the sink must see exactly what a VecSink
        // replay sees.
        let t = tiny();
        let stage = Stage::Prefill { n: 64 };
        let mut g = Graph::from_model(&t.model, &t.compression, stage);
        passes::optimize(&mut g);
        let mut sink = VerifySink::new(full_ctx(&t));
        lower(&g, &t, CompilerOptions::full(), &mut sink);
        let direct = sink.finish();
        let replay =
            verify_stream(&shipped_stream(&t, stage, CompilerOptions::full()), &full_ctx(&t));
        assert_eq!(direct, replay);
    }

    #[test]
    fn fault_byte_flip_caught_at_word_index() {
        let (insts, ctx) = base();
        proptest::check_with("byte flip rejected", 64, |r: &mut Rng| {
            let mut bytes = isa::encode_stream(&insts);
            let word = r.below(insts.len() as u64) as usize;
            bytes[word * INST_BYTES] = 0xEE; // invalid opcode
            let diags = verify_encoded(&bytes, &ctx);
            assert!(
                has(&diags, DiagnosticKind::EncodingMismatch, word),
                "flip at word {word} not caught: {diags:?}"
            );
        });
    }

    #[test]
    fn fault_channel_bump_caught() {
        let (insts, ctx) = base();
        let merged: Vec<usize> = insts
            .iter()
            .enumerate()
            .filter(|(_, i)| matches!(i, Inst::LdMerged { .. } | Inst::StMerged { .. }))
            .map(|(i, _)| i)
            .collect();
        assert!(!merged.is_empty());
        proptest::check_with("channel bump rejected", 64, |r: &mut Rng| {
            let mut m = insts.clone();
            let at = merged[r.below(merged.len() as u64) as usize];
            match &mut m[at] {
                Inst::LdMerged { first_channel, .. } | Inst::StMerged { first_channel, .. } => {
                    // 30 + 8 channels > the platform's 32.
                    *first_channel = 30 + r.below(128) as u8;
                }
                _ => unreachable!(),
            }
            let diags = verify_stream(&m, &ctx);
            assert!(
                has(&diags, DiagnosticKind::ChannelOutOfRange, at),
                "bump at {at} not caught: {diags:?}"
            );
        });
    }

    #[test]
    fn fault_capacity_bust_caught() {
        let (insts, ctx) = base();
        let loads: Vec<usize> = insts
            .iter()
            .enumerate()
            .filter(|(_, i)| {
                matches!(
                    i,
                    Inst::Ld { dst: OnChipBuf::Weight, .. }
                        | Inst::LdMerged { dst: OnChipBuf::Weight, .. }
                )
            })
            .map(|(i, _)| i)
            .collect();
        assert!(!loads.is_empty());
        let cap = Target::u280_tiny().platform.onchip.weight_bytes;
        proptest::check_with("capacity bust rejected", 64, |r: &mut Rng| {
            let mut m = insts.clone();
            let at = loads[r.below(loads.len() as u64) as usize];
            match &mut m[at] {
                Inst::Ld { bytes, .. } => *bytes = cap as u32 + 64,
                Inst::LdMerged { channels, bytes, .. } => {
                    *bytes = (cap / *channels as u64) as u32 + 64;
                }
                _ => unreachable!(),
            }
            let diags = verify_stream(&m, &ctx);
            assert!(
                has(&diags, DiagnosticKind::BufferOverflow, at),
                "bust at {at} not caught: {diags:?}"
            );
        });
    }

    #[test]
    fn fault_dropped_load_caught_at_consuming_compute() {
        let (insts, ctx) = base();
        // Boundaries after which the weight buffer restarts empty: stream
        // start and every SYS.
        let mut boundaries = vec![0usize];
        boundaries.extend(
            insts
                .iter()
                .enumerate()
                .filter(|(_, i)| matches!(i, Inst::Sys { .. }))
                .map(|(i, _)| i + 1),
        );
        proptest::check_with("dropped load rejected", 64, |r: &mut Rng| {
            let from = boundaries[r.below(boundaries.len() as u64) as usize];
            // First weight load after the boundary: dropping it starves
            // the next MM/MV (mid-tile drops are hidden by residency).
            let Some(ld) = (from..insts.len()).find(|&i| {
                matches!(
                    insts[i],
                    Inst::Ld { dst: OnChipBuf::Weight, .. }
                        | Inst::LdMerged { dst: OnChipBuf::Weight, .. }
                )
            }) else {
                return; // boundary past the last load (e.g. final sync)
            };
            let mut m = insts.clone();
            m.remove(ld);
            // The starving compute is the first MM/MV after the drop with
            // no weight load in between (another load would hide it).
            let mut compute = None;
            for (i, inst) in m.iter().enumerate().skip(ld) {
                match inst {
                    Inst::Ld { dst: OnChipBuf::Weight, .. }
                    | Inst::LdMerged { dst: OnChipBuf::Weight, .. } => break,
                    Inst::Mm { .. } | Inst::Mv { .. } => {
                        compute = Some(i);
                        break;
                    }
                    _ => {}
                }
            }
            let Some(compute) = compute else { return };
            let diags = verify_stream(&m, &ctx);
            assert!(
                has(&diags, DiagnosticKind::ReadBeforeLoad, compute),
                "dropped load at {ld} not caught at compute {compute}: {diags:?}"
            );
        });
    }

    #[test]
    fn fault_dropped_sync_caught() {
        let (insts, ctx) = base();
        let syncs: Vec<usize> = insts
            .iter()
            .enumerate()
            .filter(|(_, i)| matches!(i, Inst::Sys { op: SysOp::SyncSlr }))
            .map(|(i, _)| i)
            .collect();
        assert!(!syncs.is_empty());
        proptest::check_with("dropped sync rejected", 64, |r: &mut Rng| {
            let mut m = insts.clone();
            m.remove(syncs[r.below(syncs.len() as u64) as usize]);
            let diags = verify_stream(&m, &ctx);
            assert!(
                has(&diags, DiagnosticKind::SyncViolation, m.len()),
                "dropped barrier not caught: {diags:?}"
            );
        });
    }

    #[test]
    fn fault_degenerate_sparsity_caught() {
        let (insts, ctx) = base();
        let computes: Vec<usize> = insts
            .iter()
            .enumerate()
            .filter(|(_, i)| matches!(i, Inst::Mm { .. } | Inst::Mv { .. }))
            .map(|(i, _)| i)
            .collect();
        assert!(!computes.is_empty());
        proptest::check_with("degenerate sparsity rejected", 64, |r: &mut Rng| {
            let mut m = insts.clone();
            let at = computes[r.below(computes.len() as u64) as usize];
            let bad = if r.below(2) == 0 {
                Sparsity::Nm { n: 8, m: 0 } // NaN density
            } else {
                Sparsity::Nm { n: 20, m: 16 } // density > 1
            };
            match &mut m[at] {
                Inst::Mm { sparsity, .. } | Inst::Mv { sparsity, .. } => *sparsity = bad,
                _ => unreachable!(),
            }
            let diags = verify_stream(&m, &ctx);
            assert!(
                has(&diags, DiagnosticKind::SparsityInvalid, at),
                "sparsity at {at} not caught: {diags:?}"
            );
        });
    }

    #[test]
    fn fault_wild_address_caught() {
        let (insts, ctx) = base();
        let mems: Vec<usize> = insts
            .iter()
            .enumerate()
            .filter(|(_, i)| i.is_memory())
            .map(|(i, _)| i)
            .collect();
        // Past both HBM (8 GB) and DDR (32 GB), 64-aligned so the word
        // still round-trips and only the span check can fire.
        let wild: u64 = 64_000_000_000;
        proptest::check_with("wild address rejected", 64, |r: &mut Rng| {
            let mut m = insts.clone();
            let at = mems[r.below(mems.len() as u64) as usize];
            match &mut m[at] {
                Inst::Ld { addr, .. }
                | Inst::St { addr, .. }
                | Inst::LdMerged { addr, .. }
                | Inst::StMerged { addr, .. } => *addr = wild,
                _ => unreachable!(),
            }
            let diags = verify_stream(&m, &ctx);
            assert!(
                has(&diags, DiagnosticKind::AddressOutOfRange, at),
                "wild address at {at} not caught: {diags:?}"
            );
        });
    }

    #[test]
    fn trailing_unsynced_store_flagged() {
        let (mut insts, ctx) = base();
        insts.push(Inst::St {
            src: OnChipBuf::Global,
            dst: MemSpace::Hbm { channel: 0 },
            addr: 0,
            bytes: 64,
        });
        let at = insts.len() - 1;
        let diags = verify_stream(&insts, &ctx);
        assert!(has(&diags, DiagnosticKind::SyncViolation, at), "{diags:?}");
    }

    #[test]
    fn bucket_plan_lint_flags_gaps_and_overlaps() {
        for m in [ModelConfig::llama2_7b(), ModelConfig::tiny()] {
            assert!(verify_bucket_plan(&BucketPlan::paper_default(m.max_seq)).is_empty());
            assert!(verify_bucket_plan(&BucketPlan::tiny(m.max_seq)).is_empty());
        }
        let gap = BucketPlan { max_seq: 256, decode: vec![256], prefill: vec![16, 128] };
        let diags = verify_bucket_plan(&gap);
        assert!(diags.iter().any(|d| d.kind == DiagnosticKind::BucketGap), "{diags:?}");
        let overlap =
            BucketPlan { max_seq: 256, decode: vec![64, 64, 256], prefill: vec![256] };
        let diags = verify_bucket_plan(&overlap);
        assert!(
            diags.iter().any(|d| d.kind == DiagnosticKind::BucketOverlap && d.index == 1),
            "{diags:?}"
        );
    }

    #[test]
    fn layout_checked_accesses_must_hit_placed_tensors() {
        let t = tiny();
        let mut g = Graph::from_model(&t.model, &t.compression, Stage::Decode { ctx: 64 });
        passes::optimize(&mut g);
        let map = assign_addresses(&g, &t.platform).expect("tiny fits");
        let ctx = VerifyContext::for_target(&t).with_layout(&g, &map);
        // A load inside a placed HBM tensor verifies; the same span as
        // DDR (wrong MemSpace) or past every placement does not.
        let (addr, bytes) = map
            .placements
            .iter()
            .find_map(|(id, p)| match p {
                Placement::Hbm { addr, .. } => {
                    Some((*addr, g.tensors[*id].bytes.min(4096) as u32))
                }
                _ => None,
            })
            .expect("some tensor lands on HBM");
        let ld = |space| Inst::Ld { src: space, dst: OnChipBuf::Weight, addr, bytes };
        let ok = vec![
            ld(MemSpace::Hbm { channel: 0 }),
            Inst::Mv { k: 16, n: 16, sparsity: Sparsity::Dense },
            Inst::Sys { op: SysOp::SyncHost },
        ];
        assert!(verify_stream(&ok, &ctx).is_empty());
        let wrong_space = vec![ld(MemSpace::Ddr)];
        assert!(has(&verify_stream(&wrong_space, &ctx), DiagnosticKind::UnmappedAccess, 0));
        let unplaced = vec![Inst::Ld {
            src: MemSpace::Hbm { channel: 0 },
            dst: OnChipBuf::Weight,
            addr: map.hbm_used + (64 << 20),
            bytes: 64,
        }];
        assert!(has(&verify_stream(&unplaced, &ctx), DiagnosticKind::UnmappedAccess, 0));
    }

    #[test]
    fn machine_safety_subset_skips_occupancy_and_sync() {
        let t = tiny();
        let ctx = VerifyContext::machine_safety(&t);
        // An ad-hoc engine-test style stream: compute with no prior load,
        // stores never synced — machine-safe, semantically loose.
        let insts = vec![
            Inst::Mv { k: 1024, n: 256, sparsity: Sparsity::Dense },
            Inst::St {
                src: OnChipBuf::Global,
                dst: MemSpace::Hbm { channel: 3 },
                addr: 4096,
                bytes: 4096,
            },
        ];
        assert!(verify_stream(&insts, &ctx).is_empty());
        // But machine-level faults still fire.
        let bad = vec![Inst::LdMerged {
            first_channel: 30,
            channels: 8,
            dst: OnChipBuf::Weight,
            addr: 0,
            bytes: 64,
        }];
        assert!(has(&verify_stream(&bad, &ctx), DiagnosticKind::ChannelOutOfRange, 0));
    }

    #[test]
    fn unaligned_address_is_an_encoding_lint() {
        let (_, ctx) = base();
        let insts = vec![Inst::Ld {
            src: MemSpace::Hbm { channel: 0 },
            dst: OnChipBuf::Weight,
            addr: 100, // not 64-aligned: truncates in the 16-byte word
            bytes: 64,
        }];
        assert!(has(&verify_stream(&insts, &ctx), DiagnosticKind::EncodingMismatch, 0));
    }

    #[test]
    fn misc_is_exempt_from_weight_raw_check() {
        let (_, ctx) = base();
        // SFU-only streams (layernorm etc.) read no weight buffer.
        let insts = vec![Inst::Misc { op: MiscOp::RmsNorm, len: 256 }];
        let diags = verify_stream(&insts, &ctx);
        assert!(!diags.iter().any(|d| d.kind == DiagnosticKind::ReadBeforeLoad), "{diags:?}");
    }

    #[test]
    fn diagnostic_flood_is_capped_per_kind() {
        // A stream tripping one kind thousands of times keeps the first
        // DIAG_KIND_CAP findings and counts the rest as suppressed.
        let t = tiny();
        // No expected sync count: the only diagnostics are the floods'.
        let ctx = VerifyContext::for_target(&t);
        let mut insts = vec![Inst::Ld {
            src: MemSpace::Hbm { channel: 0 },
            dst: OnChipBuf::Weight,
            addr: 0,
            bytes: 64,
        }];
        let flood = 5000usize;
        // Invalid N:M (density > 1) but round-trips the encoding cleanly,
        // so every MV trips exactly one SparsityInvalid.
        insts.extend(
            (0..flood).map(|_| Inst::Mv { k: 16, n: 16, sparsity: Sparsity::Nm { n: 20, m: 16 } }),
        );
        let mut sink = VerifySink::new(ctx);
        for inst in &insts {
            sink.observe(inst);
        }
        let (diags, suppressed) = sink.finish_with_suppressed();
        assert_eq!(diags.len(), DIAG_KIND_CAP);
        assert!(diags.iter().all(|d| d.kind == DiagnosticKind::SparsityInvalid), "{diags:?}");
        assert_eq!(diags[0].index, 1);
        assert_eq!(diags.last().unwrap().index, DIAG_KIND_CAP);
        assert_eq!(suppressed, (flood - DIAG_KIND_CAP) as u64);
    }

    #[test]
    fn truncated_tail_is_a_typed_diagnostic() {
        let (insts, ctx) = base();
        let bytes = isa::encode_stream(&insts);
        assert!(verify_encoded(&bytes, &ctx).is_empty());
        for r in 1..INST_BYTES {
            let mut cut = bytes.clone();
            cut.resize(bytes.len() + r, 0);
            let diags = verify_encoded(&cut, &ctx);
            assert_eq!(diags.len(), 1, "remainder {r}: {diags:?}");
            assert!(has(&diags, DiagnosticKind::TruncatedTail, insts.len()), "{diags:?}");
        }
    }
}
