//! Def-use/liveness dataflow analysis over compiled instruction streams
//! — the *efficiency* tier on top of the parent module's safety checks.
//!
//! A stream can verify perfectly safe and still waste the machine's
//! time: load bytes nobody reads, reload a span whose on-chip copy is
//! still sitting in the buffer, or raise an SLR barrier no data
//! dependency crosses.  The analysis tracks, per on-chip buffer, every
//! live *definition* — which `LD`/`LD_MERGED` wrote it, which off-chip
//! span it mirrors, and whether any `MM`/`MV`/`MISC`/`ST` has read it —
//! and emits three diagnostics the safety tier cannot see:
//!
//! - [`DiagnosticKind::DeadLoad`] — a definition never read before the
//!   next barrier or stream end.  Pure wasted off-chip traffic.
//! - [`DiagnosticKind::RedundantReload`] — a load of an off-chip span
//!   an unread-or-read but still-live on-chip copy already mirrors
//!   (no intervening store touched the span).  The reload moves bytes
//!   for data the chip already holds.
//! - [`DiagnosticKind::RemovableSync`] — a `SyncSlr` with no store
//!   since the previous barrier: nothing was published off-chip, so no
//!   cross-SLR def-use edge crosses it.  (`SyncHost` is exempt — it is
//!   the host-visible completion contract, not a dataflow fence.)
//!
//! Alongside the diagnostics, every stream gets a [`StreamCost`]: total
//! off-chip bytes moved, bytes wasted by dead/redundant loads, and the
//! barrier count against the dataflow minimum.
//!
//! The same machine produces the *symbolic memory-effect summary* that
//! certifies `compiler::optimize_stream`: the ordered sequence of
//! compute instructions (each with the sorted set of off-chip spans its
//! operands mirror) plus every stored span.  Two streams with identical
//! summaries perform the same computation on the same data and publish
//! the same results — dead-load elimination, redundant-reload
//! coalescing and empty-barrier deletion all preserve the summary by
//! construction, and the optimizer refuses any rewrite that does not.
//!
//! Model notes, matching the parent module's abstract machine: on-chip
//! buffers are accumulation areas (several definitions coexist, e.g.
//! the 8 per-channel legs of one weight tile), `MM`/`MV` consume the
//! streamed weight buffer (its definitions end there — the next tile
//! reuses it), `MISC` reads activations, and barriers drain everything.

use super::{
    buf_index, shipped_presets, verify_stream, DiagBudget, Diagnostic, DiagnosticKind,
    VerifyContext, BUFS,
};
use crate::compiler::{lower, optimize_stream, BucketPlan, VecSink};
use crate::config::Target;
use crate::ir::{passes, Graph, Stage};
use crate::isa::{Inst, OnChipBuf, SysOp};

/// A contiguous off-chip byte range (merged runs already expanded).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct OffchipSpan {
    pub hbm: bool,
    pub addr: u64,
    pub bytes: u64,
}

impl OffchipSpan {
    fn of(inst: &Inst) -> Option<OffchipSpan> {
        inst.offchip_span().map(|(hbm, addr, bytes)| OffchipSpan { hbm, addr, bytes })
    }

    fn overlaps(&self, other: &OffchipSpan) -> bool {
        self.hbm == other.hbm
            && self.addr < other.addr.saturating_add(other.bytes)
            && other.addr < self.addr.saturating_add(self.bytes)
    }
}

/// One entry of a stream's symbolic memory-effect summary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Effect {
    /// A compute instruction with the off-chip spans its live operand
    /// definitions mirror (sorted, deduplicated).
    Compute { inst: Inst, operands: Vec<OffchipSpan> },
    /// An off-chip span published by a store.
    Store { span: OffchipSpan },
}

/// A live on-chip definition: which load produced it, what it mirrors.
#[derive(Debug, Clone, Copy)]
struct Def {
    index: usize,
    span: OffchipSpan,
    read: bool,
    /// False once a store overwrote any part of the mirrored span — a
    /// reload after that fetches fresh data and is not redundant.
    mirror: bool,
}

/// Static per-stream cost report.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StreamCost {
    pub loaded_bytes: u64,
    pub stored_bytes: u64,
    pub dead_load_bytes: u64,
    pub redundant_load_bytes: u64,
    pub dead_loads: u64,
    pub redundant_reloads: u64,
    pub slr_syncs: u64,
    pub removable_syncs: u64,
}

impl StreamCost {
    /// Total off-chip bytes the stream moves.
    pub fn offchip_bytes(&self) -> u64 {
        self.loaded_bytes + self.stored_bytes
    }

    /// Bytes moved for nothing (dead + redundant loads).
    pub fn wasted_bytes(&self) -> u64 {
        self.dead_load_bytes + self.redundant_load_bytes
    }

    /// The dataflow-minimal SLR barrier count.
    pub fn min_syncs(&self) -> u64 {
        self.slr_syncs - self.removable_syncs
    }

    /// Total inefficiency findings.
    pub fn findings(&self) -> u64 {
        self.dead_loads + self.redundant_reloads + self.removable_syncs
    }
}

/// Everything one `analyze_stream` pass produces.
#[derive(Debug, Clone)]
pub struct DataflowReport {
    /// Efficiency diagnostics, sorted by instruction index and capped
    /// per kind ([`super::DIAG_KIND_CAP`]).
    pub diags: Vec<Diagnostic>,
    pub suppressed: u64,
    pub cost: StreamCost,
    /// Instruction indices of the offending loads/syncs — uncapped, so
    /// the optimizer sees every site even when diagnostics saturate.
    pub dead_loads: Vec<usize>,
    pub redundant_reloads: Vec<usize>,
    pub removable_syncs: Vec<usize>,
}

/// The abstract dataflow machine: per-buffer definition lists, waste
/// accounting, and (optionally) the memory-effect summary.
struct Machine {
    defs: [Vec<Def>; 4],
    budget: DiagBudget,
    diags: Vec<Diagnostic>,
    cost: StreamCost,
    dead: Vec<usize>,
    redundant: Vec<usize>,
    removable: Vec<usize>,
    stores_since_sync: u64,
    effects: Vec<Effect>,
    collect_effects: bool,
}

impl Machine {
    fn new(collect_effects: bool) -> Self {
        Self {
            defs: [Vec::new(), Vec::new(), Vec::new(), Vec::new()],
            budget: DiagBudget::default(),
            diags: Vec::new(),
            cost: StreamCost::default(),
            dead: Vec::new(),
            redundant: Vec::new(),
            removable: Vec::new(),
            stores_since_sync: 0,
            effects: Vec::new(),
            collect_effects,
        }
    }

    fn diag(&mut self, index: usize, kind: DiagnosticKind, detail: String) {
        self.budget.push(&mut self.diags, Diagnostic { index, kind, detail });
    }

    fn step(&mut self, index: usize, inst: &Inst) {
        match inst {
            Inst::Ld { dst, .. } | Inst::LdMerged { dst, .. } => {
                let span = OffchipSpan::of(inst).expect("loads carry a span");
                self.load(index, *dst, span);
            }
            Inst::St { src, .. } | Inst::StMerged { src, .. } => {
                let span = OffchipSpan::of(inst).expect("stores carry a span");
                self.store(*src, span);
            }
            Inst::Mm { .. } | Inst::Mv { .. } => {
                self.compute(inst, &[OnChipBuf::Weight, OnChipBuf::Activation, OnChipBuf::Index]);
                // Tile streaming: the consumed weight tile's definitions
                // end here — the buffer is reused by the next tile.
                self.defs[buf_index(OnChipBuf::Weight)].clear();
            }
            Inst::Misc { .. } => self.compute(inst, &[OnChipBuf::Activation]),
            Inst::Sys { op } => self.sync(index, *op),
        }
    }

    fn load(&mut self, index: usize, dst: OnChipBuf, span: OffchipSpan) {
        self.cost.loaded_bytes += span.bytes;
        let b = buf_index(dst);
        let live = self.defs[b].iter().find(|d| d.mirror && d.span == span).map(|d| d.index);
        if let Some(since) = live {
            // The buffer already mirrors this exact span: the reload is
            // an alias of the existing definition, not a new one.
            self.cost.redundant_reloads += 1;
            self.cost.redundant_load_bytes += span.bytes;
            self.redundant.push(index);
            self.diag(
                index,
                DiagnosticKind::RedundantReload,
                format!(
                    "{dst:?} already mirrors [{:#x}, {:#x}) loaded at inst {since}",
                    span.addr,
                    span.addr.saturating_add(span.bytes)
                ),
            );
        } else {
            self.defs[b].push(Def { index, span, read: false, mirror: true });
        }
    }

    fn store(&mut self, src: OnChipBuf, span: OffchipSpan) {
        self.cost.stored_bytes += span.bytes;
        self.stores_since_sync += 1;
        if self.collect_effects {
            self.effects.push(Effect::Store { span });
        }
        for d in &mut self.defs[buf_index(src)] {
            d.read = true;
        }
        // Any on-chip copy mirroring an overlapping off-chip range is
        // stale after this store: a later reload fetches fresh data.
        for defs in &mut self.defs {
            for d in defs.iter_mut() {
                if d.span.overlaps(&span) {
                    d.mirror = false;
                }
            }
        }
    }

    fn compute(&mut self, inst: &Inst, bufs: &[OnChipBuf]) {
        if self.collect_effects {
            let mut operands: Vec<OffchipSpan> = bufs
                .iter()
                .flat_map(|&b| self.defs[buf_index(b)].iter().map(|d| d.span))
                .collect();
            operands.sort_unstable();
            operands.dedup();
            self.effects.push(Effect::Compute { inst: inst.clone(), operands });
        }
        for &b in bufs {
            for d in &mut self.defs[buf_index(b)] {
                d.read = true;
            }
        }
    }

    fn sync(&mut self, index: usize, op: SysOp) {
        if op == SysOp::SyncSlr {
            self.cost.slr_syncs += 1;
            if self.stores_since_sync == 0 {
                self.cost.removable_syncs += 1;
                self.removable.push(index);
                self.diag(
                    index,
                    DiagnosticKind::RemovableSync,
                    "SyncSlr publishes nothing: no store since the previous barrier, so no \
                     cross-SLR def-use edge crosses it"
                        .into(),
                );
            }
        }
        self.stores_since_sync = 0;
        self.drain_defs();
    }

    /// Barrier/stream-end drain: unread definitions were dead loads.
    fn drain_defs(&mut self) {
        for b in 0..self.defs.len() {
            for d in std::mem::take(&mut self.defs[b]) {
                if !d.read {
                    self.cost.dead_loads += 1;
                    self.cost.dead_load_bytes += d.span.bytes;
                    self.dead.push(d.index);
                    self.diag(
                        d.index,
                        DiagnosticKind::DeadLoad,
                        format!(
                            "{:?} load of [{:#x}, {:#x}) is never read before the next barrier",
                            BUFS[b],
                            d.span.addr,
                            d.span.addr.saturating_add(d.span.bytes)
                        ),
                    );
                }
            }
        }
    }

    fn finish(mut self) -> (DataflowReport, Vec<Effect>) {
        self.drain_defs();
        self.diags.sort_by_key(|d| d.index);
        self.dead.sort_unstable();
        (
            DataflowReport {
                diags: self.diags,
                suppressed: self.budget.suppressed(),
                cost: self.cost,
                dead_loads: self.dead,
                redundant_reloads: self.redundant,
                removable_syncs: self.removable,
            },
            self.effects,
        )
    }
}

/// Run the dataflow analysis over a stream.
pub fn analyze_stream(insts: &[Inst]) -> DataflowReport {
    let mut m = Machine::new(false);
    for (i, inst) in insts.iter().enumerate() {
        m.step(i, inst);
    }
    m.finish().0
}

/// The stream's symbolic memory-effect summary — the certification
/// currency of `compiler::optimize_stream`.
pub fn effect_summary(insts: &[Inst]) -> Vec<Effect> {
    let mut m = Machine::new(true);
    for (i, inst) in insts.iter().enumerate() {
        m.step(i, inst);
    }
    m.finish().1
}

/// One shipped stream's analysis: the pre-optimization findings, what
/// the certified optimizer removed, and the post-optimization state.
#[derive(Debug, Clone)]
pub struct StreamAnalysis {
    pub label: String,
    pub instructions: usize,
    pub diags: Vec<Diagnostic>,
    pub suppressed: u64,
    pub cost: StreamCost,
    pub optimized_instructions: usize,
    pub optimized_cost: StreamCost,
    pub dead_loads_removed: u64,
    pub redundant_reloads_removed: u64,
    pub syncs_removed: u64,
    pub bytes_saved: u64,
    /// The optimizer's effect-summary equivalence check passed.
    pub certified: bool,
    /// The optimized stream re-verifies clean under the full safety
    /// discipline (occupancy, sync counts, encoding, addresses).
    pub reverify_clean: bool,
}

impl StreamAnalysis {
    /// The CI gate: certified, safety-clean, zero residual inefficiency.
    pub fn gate_passes(&self) -> bool {
        self.certified && self.reverify_clean && self.optimized_cost.findings() == 0
    }
}

/// Dataflow analysis of every shipped stream for one target.
#[derive(Debug, Clone)]
pub struct TargetAnalysis {
    pub target: String,
    pub streams: Vec<StreamAnalysis>,
}

impl TargetAnalysis {
    pub fn gate_passes(&self) -> bool {
        self.streams.iter().all(StreamAnalysis::gate_passes)
    }

    /// Pre-optimization findings over all streams.
    pub fn total_findings(&self) -> u64 {
        self.streams.iter().map(|s| s.cost.findings()).sum()
    }

    /// Pre-optimization off-chip traffic over all streams.
    pub fn total_bytes_moved(&self) -> u64 {
        self.streams.iter().map(|s| s.cost.offchip_bytes()).sum()
    }

    pub fn total_bytes_saved(&self) -> u64 {
        self.streams.iter().map(|s| s.bytes_saved).sum()
    }
}

/// Analyze and optimize every shipped stream of `t` — the same preset ×
/// stage × bucket matrix `super::verify_target` checks for safety.
pub fn analyze_target(t: &Target) -> TargetAnalysis {
    let plan = BucketPlan::paper_default(t.model.max_seq);
    let vctx = VerifyContext::for_target(t).expect_slr_syncs(t.model.n_layers);
    let mut streams = Vec::new();
    let stages = plan
        .decode
        .iter()
        .map(|&b| Stage::Decode { ctx: b })
        .chain(plan.prefill.iter().map(|&b| Stage::Prefill { n: b }));
    for stage in stages {
        let mut g = Graph::from_model(&t.model, &t.compression, stage);
        passes::optimize(&mut g);
        for (name, opt) in shipped_presets() {
            let mut sink = VecSink::default();
            lower(&g, t, opt, &mut sink);
            let insts = sink.0;
            let pre = analyze_stream(&insts);
            let out = optimize_stream(&insts);
            let post = if out.insts.len() == insts.len() {
                pre.clone()
            } else {
                analyze_stream(&out.insts)
            };
            let reverify_clean = verify_stream(&out.insts, &vctx).is_empty();
            streams.push(StreamAnalysis {
                label: format!("{} {:?} {}", t.model.name, stage, name),
                instructions: insts.len(),
                diags: pre.diags,
                suppressed: pre.suppressed,
                cost: pre.cost,
                optimized_instructions: out.insts.len(),
                optimized_cost: post.cost,
                dead_loads_removed: out.dead_loads_removed,
                redundant_reloads_removed: out.redundant_reloads_removed,
                syncs_removed: out.syncs_removed,
                bytes_saved: out.bytes_saved,
                certified: out.certified,
                reverify_clean,
            });
        }
    }
    TargetAnalysis { target: format!("{} on {}", t.model.name, t.platform.name), streams }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::CompilerOptions;
    use crate::isa::{MemSpace, Sparsity};
    use crate::util::proptest;
    use crate::util::rng::Rng;

    fn shipped(t: &Target, opt: CompilerOptions) -> Vec<Inst> {
        let mut g =
            Graph::from_model(&t.model, &t.compression, Stage::Decode { ctx: t.model.max_seq });
        passes::optimize(&mut g);
        let mut sink = VecSink::default();
        lower(&g, t, opt, &mut sink);
        sink.0
    }

    /// The clean tiny decode stream the fault tests corrupt.
    fn base() -> Vec<Inst> {
        shipped(&Target::u280_tiny(), CompilerOptions::full())
    }

    #[test]
    fn shipped_full_stream_is_efficient() {
        let report = analyze_stream(&base());
        assert_eq!(report.cost.findings(), 0, "{:?}", report.diags);
        assert!(report.cost.loaded_bytes > 0);
        assert!(report.cost.stored_bytes > 0);
        assert!(report.cost.slr_syncs > 0);
        assert_eq!(report.cost.wasted_bytes(), 0);
        assert_eq!(report.cost.min_syncs(), report.cost.slr_syncs);
    }

    #[test]
    fn naive_preset_reloads_shared_activations() {
        // wq/wk/wv read the same normed vector and w1/w3 the same FFN
        // input; the naive off-chip schedule reloads the sibling's slot
        // — 3 redundant reloads per layer, none anywhere else.
        let t = Target::u280_tiny();
        let report = analyze_stream(&shipped(&t, CompilerOptions::naive()));
        assert_eq!(report.cost.redundant_reloads, 3 * t.model.n_layers, "{:?}", report.diags);
        assert_eq!(report.cost.dead_loads, 0, "{:?}", report.diags);
        assert_eq!(report.cost.removable_syncs, 0, "{:?}", report.diags);
        assert!(report.cost.redundant_load_bytes > 0);
        // The full preset keeps activations on-chip: nothing to reload.
        assert_eq!(analyze_stream(&base()).cost.findings(), 0);
    }

    #[test]
    fn stream_cost_accounts_waste() {
        let ld = |addr: u64| Inst::Ld {
            src: MemSpace::Hbm { channel: 0 },
            dst: OnChipBuf::Weight,
            addr,
            bytes: 64,
        };
        let mv = Inst::Mv { k: 8, n: 8, sparsity: Sparsity::Dense };
        let st = Inst::St {
            src: OnChipBuf::Global,
            dst: MemSpace::Hbm { channel: 0 },
            addr: 4096,
            bytes: 64,
        };
        let insts = vec![
            ld(0),                             // 0: live def
            ld(0),                             // 1: redundant reload
            mv,                                // 2: consumes the weight tile
            st,                                // 3: publishes 64 B
            Inst::Sys { op: SysOp::SyncSlr },  // 4: real barrier
            Inst::Sys { op: SysOp::SyncSlr },  // 5: removable (empty region)
            ld(64),                            // 6: dead load
            Inst::Sys { op: SysOp::SyncHost }, // 7: drains -> 6 is dead
        ];
        let r = analyze_stream(&insts);
        assert_eq!(r.cost.loaded_bytes, 192);
        assert_eq!(r.cost.stored_bytes, 64);
        assert_eq!(r.cost.redundant_load_bytes, 64);
        assert_eq!(r.cost.dead_load_bytes, 64);
        assert_eq!(r.cost.slr_syncs, 2);
        assert_eq!(r.cost.removable_syncs, 1);
        assert_eq!(r.cost.offchip_bytes(), 256);
        assert_eq!(r.cost.wasted_bytes(), 128);
        assert_eq!(r.cost.min_syncs(), 1);
        assert_eq!(r.cost.findings(), 3);
        assert_eq!(r.dead_loads, vec![6]);
        assert_eq!(r.redundant_reloads, vec![1]);
        assert_eq!(r.removable_syncs, vec![5]);
        assert_eq!(r.diags.len(), 3);
    }

    #[test]
    fn effect_summaries_certify_equivalence() {
        let ld = |addr: u64| Inst::Ld {
            src: MemSpace::Hbm { channel: 0 },
            dst: OnChipBuf::Weight,
            addr,
            bytes: 64,
        };
        let st = |addr: u64| Inst::St {
            src: OnChipBuf::Global,
            dst: MemSpace::Hbm { channel: 0 },
            addr,
            bytes: 64,
        };
        let mv = Inst::Mv { k: 8, n: 8, sparsity: Sparsity::Dense };
        // A redundant reload is an alias: the summary does not change.
        let a = vec![ld(0), ld(0), mv.clone()];
        let b = vec![ld(0), mv.clone()];
        assert_eq!(effect_summary(&a), effect_summary(&b));
        // Dropping a consumed load changes the compute's operand set.
        assert_ne!(effect_summary(&b), effect_summary(&[mv]));
        // Store order is part of the summary.
        assert_ne!(effect_summary(&[st(0), st(64)]), effect_summary(&[st(64), st(0)]));
    }

    #[test]
    fn fault_injected_dead_load_caught_and_eliminated() {
        let insts = base();
        let syncs: Vec<usize> = insts
            .iter()
            .enumerate()
            .filter(|(_, i)| matches!(i, Inst::Sys { .. }))
            .map(|(i, _)| i)
            .collect();
        assert!(!syncs.is_empty());
        proptest::check_with("dead load caught", 32, |r: &mut Rng| {
            // A load right before a barrier: nothing can read it.
            let at = syncs[r.below(syncs.len() as u64) as usize];
            let mut m = insts.clone();
            m.insert(
                at,
                Inst::Ld {
                    src: MemSpace::Hbm { channel: 0 },
                    dst: OnChipBuf::Weight,
                    addr: 3 << 30,
                    bytes: 64,
                },
            );
            let report = analyze_stream(&m);
            assert!(report.dead_loads.contains(&at), "dead load at {at}: {:?}", report.diags);
            assert!(
                report.diags.iter().any(|d| d.kind == DiagnosticKind::DeadLoad && d.index == at),
                "{:?}",
                report.diags
            );
            let out = optimize_stream(&m);
            assert!(out.certified);
            assert_eq!(out.insts.len(), insts.len());
            assert_eq!(out.dead_loads_removed, 1);
            assert_eq!(out.bytes_saved, 64);
            assert_eq!(analyze_stream(&out.insts).cost.findings(), 0);
        });
    }

    #[test]
    fn fault_injected_redundant_reload_caught_and_eliminated() {
        let insts = base();
        let loads: Vec<usize> = insts
            .iter()
            .enumerate()
            .filter(|(_, i)| matches!(i, Inst::LdMerged { dst: OnChipBuf::Weight, .. }))
            .map(|(i, _)| i)
            .collect();
        assert!(!loads.is_empty());
        proptest::check_with("redundant reload caught", 32, |r: &mut Rng| {
            // Duplicate a weight load while its definition is still live.
            let at = loads[r.below(loads.len() as u64) as usize];
            let mut m = insts.clone();
            let dup = m[at].clone();
            let bytes = dup.offchip_span().expect("load").2;
            m.insert(at + 1, dup);
            let report = analyze_stream(&m);
            assert!(
                report.redundant_reloads.contains(&(at + 1)),
                "reload at {}: {:?}",
                at + 1,
                report.diags
            );
            assert!(
                report
                    .diags
                    .iter()
                    .any(|d| d.kind == DiagnosticKind::RedundantReload && d.index == at + 1),
                "{:?}",
                report.diags
            );
            let out = optimize_stream(&m);
            assert!(out.certified);
            assert_eq!(out.insts.len(), insts.len());
            assert_eq!(out.redundant_reloads_removed, 1);
            assert_eq!(out.bytes_saved, bytes);
            assert_eq!(analyze_stream(&out.insts).cost.findings(), 0);
        });
    }

    #[test]
    fn fault_injected_spurious_sync_caught_and_eliminated() {
        let insts = base();
        let syncs: Vec<usize> = insts
            .iter()
            .enumerate()
            .filter(|(_, i)| matches!(i, Inst::Sys { op: SysOp::SyncSlr }))
            .map(|(i, _)| i)
            .collect();
        assert!(!syncs.is_empty());
        proptest::check_with("spurious sync caught", 32, |r: &mut Rng| {
            // A barrier right after a barrier fences an empty region.
            let at = syncs[r.below(syncs.len() as u64) as usize] + 1;
            let mut m = insts.clone();
            m.insert(at, Inst::Sys { op: SysOp::SyncSlr });
            let report = analyze_stream(&m);
            assert!(report.removable_syncs.contains(&at), "sync at {at}: {:?}", report.diags);
            assert!(
                report
                    .diags
                    .iter()
                    .any(|d| d.kind == DiagnosticKind::RemovableSync && d.index == at),
                "{:?}",
                report.diags
            );
            let out = optimize_stream(&m);
            assert!(out.certified);
            assert_eq!(out.insts.len(), insts.len());
            assert_eq!(out.syncs_removed, 1);
            assert_eq!(out.bytes_saved, 0);
            assert_eq!(analyze_stream(&out.insts).cost.findings(), 0);
        });
    }

    #[test]
    fn optimizer_is_identity_on_clean_streams() {
        let insts = base();
        let out = optimize_stream(&insts);
        assert!(out.certified);
        assert_eq!(out.insts, insts);
        assert_eq!(out.bytes_saved, 0);
    }

    #[test]
    fn tiny_target_analysis_gates_clean_after_optimization() {
        let a = analyze_target(&Target::u280_tiny());
        assert!(!a.streams.is_empty());
        for s in &a.streams {
            assert!(s.gate_passes(), "{} fails the gate: {:?}", s.label, s.diags);
        }
        assert!(a.total_findings() > 0, "the naive preset's reloads must be visible pre-opt");
        assert!(a.total_bytes_saved() > 0, "the optimizer must eliminate them");
        assert!(a.gate_passes());
    }
}
