//! Deterministic xoshiro256**-style RNG — the `rand` replacement for
//! workload generation, simulation jitter and property tests.

/// A small, fast, seedable PRNG (xoshiro256**).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed.
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Rejection-free for our purposes (bias ≤ 2^-32 for small n).
        self.next_u64() % n
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [-1, 1).
    pub fn f32_sym(&mut self) -> f32 {
        (self.f64() * 2.0 - 1.0) as f32
    }

    /// Exponentially-distributed inter-arrival time with rate `lambda`.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        let u = self.f64().max(1e-12);
        -u.ln() / lambda
    }

    /// Standard-normal sample (Box-Muller; one of the pair is discarded
    /// to keep the call stateless beyond the RNG stream).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal sample with the given `median` (= exp(mu)) and `sigma`
    /// of the underlying normal — the shape of real request-length
    /// distributions (many short, a long tail).
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        median.max(f64::MIN_POSITIVE) * (sigma * self.normal()).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_mean_near_inverse_rate() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean = {mean}");
    }

    #[test]
    fn normal_has_zero_mean_unit_variance() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean = {mean}");
        assert!((var - 1.0).abs() < 0.05, "var = {var}");
    }

    #[test]
    fn lognormal_median_tracks_parameter() {
        let mut r = Rng::new(13);
        let n = 10_001;
        let mut samples: Vec<f64> = (0..n).map(|_| r.lognormal(64.0, 0.8)).collect();
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[n / 2];
        assert!(
            (median / 64.0 - 1.0).abs() < 0.1,
            "sample median = {median} (want ~64)"
        );
        assert!(samples.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..32).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..32).collect::<Vec<_>>());
    }
}
