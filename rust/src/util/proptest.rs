//! Mini property-testing harness (proptest replacement): run a predicate
//! over many seeded-random cases; on failure report the failing seed so
//! the case can be replayed deterministically.

use super::rng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: u64 = 256;

/// Run `prop` over `cases` seeded RNGs; panics with the failing seed.
pub fn check_with<F: FnMut(&mut Rng)>(name: &str, cases: u64, mut prop: F) {
    for seed in 0..cases {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed ^ 0xF11C_4711);
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

/// Run with the default case count.
pub fn check<F: FnMut(&mut Rng)>(name: &str, prop: F) {
    check_with(name, DEFAULT_CASES, prop);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", |r| {
            let a = r.below(1000) as i64;
            let b = r.below(1000) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "failed at seed")]
    fn failing_property_reports_seed() {
        check("always fails eventually", |r| {
            assert!(r.below(10) != 3, "hit the bad value");
        });
    }
}
