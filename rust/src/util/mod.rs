//! Small self-contained utilities replacing crates that are not vendored
//! in this offline image: a JSON parser/writer (serde_json), a fast
//! deterministic RNG (rand), and a mini property-testing harness
//! (proptest).

pub mod json;
pub mod proptest;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
