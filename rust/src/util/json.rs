//! Minimal JSON parser + writer (serde_json replacement).
//!
//! Parses the artifact `manifest.json` emitted by python/compile/aot.py
//! and serializes experiment reports.  Supports the full JSON value model
//! with the usual restrictions (numbers as f64, no trailing commas).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------- accessors ----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panic-free typed access helpers.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|v| v as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` chained access, None-propagating.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // ---------------- construction ----------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    // ---------------- parsing ----------------

    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(JsonError::at(p.i, "trailing data"));
        }
        Ok(v)
    }

    // ---------------- serialization ----------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity tokens; `write!("{n}")`
                    // would emit `inf`/`NaN` and the output would no
                    // longer parse.  Serialize as null (what
                    // serde_json does for non-finite f64 too).
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    e.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl JsonError {
    fn at(pos: usize, msg: &str) -> Self {
        Self { pos, msg: msg.to_string() }
    }
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(JsonError::at(self.i, &format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(JsonError::at(self.i, &format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(JsonError::at(self.i, "unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(JsonError::at(self.i, "unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self.peek().ok_or(JsonError::at(self.i, "bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(JsonError::at(self.i, "bad \\u"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| JsonError::at(self.i, "bad \\u"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::at(self.i, "bad \\u"))?;
                            self.i += 4;
                            // Surrogate pairs unsupported (not emitted by
                            // aot.py); map lone surrogates to U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(JsonError::at(self.i, "bad escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| JsonError::at(start, "bad utf8"))?;
                    s.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::at(start, "bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(JsonError::at(self.i, "expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(JsonError::at(self.i, "expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.path(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"params":[{"name":"l0.wq.vals","shape":[256,16,8],"offset":0}],"n":3.5}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn pretty_roundtrip() {
        let j = Json::obj(vec![
            ("x", Json::Num(1.0)),
            ("y", Json::Arr(vec![Json::Bool(false), Json::Null])),
        ]);
        let j2 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
 "config": {"vocab": 512, "dim": 256},
 "params": [
  {"name": "embed", "dtype": "f32", "shape": [512, 256], "offset": 0, "nbytes": 524288}
 ],
 "artifacts": {"decode": {"file": "decode.hlo.txt"}}
}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.path(&["config", "dim"]).unwrap().as_u64(), Some(256));
        let p = &j.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("nbytes").unwrap().as_u64(), Some(524288));
        assert_eq!(
            j.path(&["artifacts", "decode", "file"]).unwrap().as_str(),
            Some("decode.hlo.txt")
        );
    }

    #[test]
    fn escapes_in_output() {
        let j = Json::Str("a\"b\\c\n".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    /// Every escape class round-trips: quotes, backslashes, the named
    /// control escapes, raw control bytes (\u-escaped on the way out)
    /// and multi-byte UTF-8 — both compact and pretty writers.
    #[test]
    fn string_escaping_round_trips_exhaustively() {
        let nasty = "quote\" back\\slash nl\n cr\r tab\t nul\u{0} bell\u{7} é⌘ 猫";
        let j = Json::obj(vec![(nasty, Json::str(nasty))]);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j, "compact");
        assert_eq!(Json::parse(&j.to_string_pretty()).unwrap(), j, "pretty");
        let written = Json::str(nasty).to_string();
        assert!(written.contains("\\u0000"), "raw NUL is \\u-escaped: {written}");
        assert!(!written.contains('\u{0}'), "no raw control bytes in output");
    }

    /// Non-finite floats serialize as `null` (JSON has no Inf/NaN
    /// tokens), and the parser rejects the bare tokens other writers
    /// might emit for them.
    #[test]
    fn non_finite_floats_serialize_as_null_and_never_parse() {
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Json::num(v).to_string(), "null");
            assert_eq!(
                Json::Arr(vec![Json::num(v)]).to_string(),
                "[null]",
                "non-finite inside a container"
            );
        }
        for bad in ["inf", "-inf", "Infinity", "-Infinity", "NaN", "nan", "[1, inf]"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} must not parse");
        }
        // Finite numbers still round-trip through the writer.
        let j = Json::parse(&Json::num(2.5).to_string()).unwrap();
        assert_eq!(j, Json::Num(2.5));
    }

    /// Schema check: the committed bench trajectory at the repo root
    /// parses with this parser, carries the keys CI asserts on, and
    /// round-trips value-identically through both writers.
    #[test]
    fn bench_trajectory_json_round_trips() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sim_hotpath.json");
        let text = std::fs::read_to_string(path).expect("committed bench trajectory");
        let j = Json::parse(&text).expect("bench JSON parses");
        let f = |keys: &[&str]| j.path(keys).and_then(Json::as_f64).expect("numeric key");
        assert!(f(&["serving_step", "dense_steps_per_s"]) > 0.0);
        assert!(f(&["fleet_day_trace", "parallel_wall_s"]) > 0.0);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        assert_eq!(Json::parse(&j.to_string_pretty()).unwrap(), j);
    }
}
