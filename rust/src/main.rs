//! FlightLLM CLI — the leader entrypoint. Subcommands are wired up in
//! `flightllm::cli` (hand-rolled parser; clap is not vendored).

fn main() {
    let args: Vec<String> = std::env::args().collect();
    std::process::exit(flightllm::cli::run(&args));
}
