//! Fixed-width binary encoding of the ISA.
//!
//! Every instruction occupies 16 bytes (`INST_BYTES`), the coarse-grained
//! word size the §5.2 storage accounting uses.  Layout (little-endian):
//!
//! ```text
//! byte 0      opcode
//! byte 1      sub-op / flags   (memspace, buffer, misc-op, sys-op, ...)
//! byte 2..3   aux              (channel info, sparsity descriptor)
//! byte 4..7   field0 (u32)     (bytes / m / k / len)
//! byte 8..11  field1 (u32)     (k / n)
//! byte 12..15 field2 (u32)     (n / addr-low; addr stored as 32-bit tile
//!                               index — tiles are >= 64 B aligned)
//! ```

use super::{Inst, MemSpace, MiscOp, OnChipBuf, Sparsity, SysOp};

/// Bytes per encoded instruction word.
pub const INST_BYTES: usize = 16;

const OP_LD: u8 = 0x01;
const OP_ST: u8 = 0x02;
const OP_MM: u8 = 0x03;
const OP_MV: u8 = 0x04;
const OP_MISC: u8 = 0x05;
const OP_SYS: u8 = 0x06;
const OP_LD_MERGED: u8 = 0x07;
const OP_ST_MERGED: u8 = 0x08;

/// Address granularity: addresses are stored as 64-byte tile indices so a
/// 32-bit field covers 256 GB.
const ADDR_ALIGN: u64 = 64;

#[derive(Debug, PartialEq, Eq)]
pub enum DecodeError {
    BadOpcode(u8),
    BadSubOp(u8, u8),
    Truncated { have: usize },
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::BadOpcode(op) => write!(f, "bad opcode {op:#04x}"),
            DecodeError::BadSubOp(op, sub) => {
                write!(f, "bad sub-op {sub:#04x} for opcode {op:#04x}")
            }
            DecodeError::Truncated { have } => {
                write!(f, "truncated instruction stream ({have} trailing bytes)")
            }
        }
    }
}

impl std::error::Error for DecodeError {}

fn memspace_byte(m: MemSpace) -> (u8, u8) {
    match m {
        MemSpace::Hbm { channel } => (0, channel),
        MemSpace::Ddr => (1, 0),
    }
}

fn memspace_from(b: u8, ch: u8) -> Result<MemSpace, DecodeError> {
    match b {
        0 => Ok(MemSpace::Hbm { channel: ch }),
        1 => Ok(MemSpace::Ddr),
        other => Err(DecodeError::BadSubOp(OP_LD, other)),
    }
}

fn buf_byte(b: OnChipBuf) -> u8 {
    match b {
        OnChipBuf::Weight => 0,
        OnChipBuf::Activation => 1,
        OnChipBuf::Global => 2,
        OnChipBuf::Index => 3,
    }
}

fn buf_from(b: u8) -> Result<OnChipBuf, DecodeError> {
    match b {
        0 => Ok(OnChipBuf::Weight),
        1 => Ok(OnChipBuf::Activation),
        2 => Ok(OnChipBuf::Global),
        3 => Ok(OnChipBuf::Index),
        other => Err(DecodeError::BadSubOp(OP_LD, other)),
    }
}

/// Sparsity packs into the 2-byte aux field: tag in the high 2 bits of
/// byte0, payload split across the rest.
fn sparsity_bytes(s: Sparsity) -> [u8; 2] {
    match s {
        Sparsity::Dense => [0x00, 0],
        Sparsity::Nm { n, m } => [0x40 | (n & 0x3F), m],
        Sparsity::BlockSparse { density_256 } => [0x80, density_256],
    }
}

fn sparsity_from(b: [u8; 2]) -> Result<Sparsity, DecodeError> {
    match b[0] & 0xC0 {
        0x00 => Ok(Sparsity::Dense),
        0x40 => Ok(Sparsity::Nm { n: b[0] & 0x3F, m: b[1] }),
        0x80 => Ok(Sparsity::BlockSparse { density_256: b[1] }),
        other => Err(DecodeError::BadSubOp(OP_MM, other)),
    }
}

fn misc_byte(op: MiscOp) -> u8 {
    match op {
        MiscOp::LayerNorm => 0,
        MiscOp::Softmax => 1,
        MiscOp::Silu => 2,
        MiscOp::Gelu => 3,
        MiscOp::EltwiseAdd => 4,
        MiscOp::EltwiseMul => 5,
        MiscOp::RmsNorm => 6,
        MiscOp::Rope => 7,
    }
}

fn misc_from(b: u8) -> Result<MiscOp, DecodeError> {
    Ok(match b {
        0 => MiscOp::LayerNorm,
        1 => MiscOp::Softmax,
        2 => MiscOp::Silu,
        3 => MiscOp::Gelu,
        4 => MiscOp::EltwiseAdd,
        5 => MiscOp::EltwiseMul,
        6 => MiscOp::RmsNorm,
        7 => MiscOp::Rope,
        other => return Err(DecodeError::BadSubOp(OP_MISC, other)),
    })
}

/// Encode one instruction into its 16-byte word.
pub fn encode(inst: &Inst) -> [u8; INST_BYTES] {
    let mut w = [0u8; INST_BYTES];
    let put32 = |w: &mut [u8; INST_BYTES], at: usize, v: u32| {
        w[at..at + 4].copy_from_slice(&v.to_le_bytes());
    };
    match inst {
        Inst::Ld { src, dst, addr, bytes } => {
            w[0] = OP_LD;
            let (ms, ch) = memspace_byte(*src);
            w[1] = (ms << 4) | buf_byte(*dst);
            w[2] = ch;
            put32(&mut w, 4, *bytes);
            put32(&mut w, 12, (addr / ADDR_ALIGN) as u32);
        }
        Inst::St { src, dst, addr, bytes } => {
            w[0] = OP_ST;
            let (ms, ch) = memspace_byte(*dst);
            w[1] = (ms << 4) | buf_byte(*src);
            w[2] = ch;
            put32(&mut w, 4, *bytes);
            put32(&mut w, 12, (addr / ADDR_ALIGN) as u32);
        }
        Inst::LdMerged { first_channel, channels, dst, addr, bytes } => {
            w[0] = OP_LD_MERGED;
            w[1] = buf_byte(*dst);
            w[2] = *first_channel;
            w[3] = *channels;
            put32(&mut w, 4, *bytes);
            put32(&mut w, 12, (addr / ADDR_ALIGN) as u32);
        }
        Inst::StMerged { first_channel, channels, src, addr, bytes } => {
            w[0] = OP_ST_MERGED;
            w[1] = buf_byte(*src);
            w[2] = *first_channel;
            w[3] = *channels;
            put32(&mut w, 4, *bytes);
            put32(&mut w, 12, (addr / ADDR_ALIGN) as u32);
        }
        Inst::Mm { m, k, n, sparsity } => {
            w[0] = OP_MM;
            let sb = sparsity_bytes(*sparsity);
            w[2] = sb[0];
            w[3] = sb[1];
            put32(&mut w, 4, *m);
            put32(&mut w, 8, *k);
            put32(&mut w, 12, *n);
        }
        Inst::Mv { k, n, sparsity } => {
            w[0] = OP_MV;
            let sb = sparsity_bytes(*sparsity);
            w[2] = sb[0];
            w[3] = sb[1];
            put32(&mut w, 8, *k);
            put32(&mut w, 12, *n);
        }
        Inst::Misc { op, len } => {
            w[0] = OP_MISC;
            w[1] = misc_byte(*op);
            put32(&mut w, 4, *len);
        }
        Inst::Sys { op } => {
            w[0] = OP_SYS;
            w[1] = match op {
                SysOp::SyncSlr => 0,
                SysOp::SyncHost => 1,
            };
        }
    }
    w
}

/// Decode one 16-byte word.
pub fn decode(w: &[u8; INST_BYTES]) -> Result<Inst, DecodeError> {
    let get32 = |at: usize| u32::from_le_bytes(w[at..at + 4].try_into().unwrap());
    let addr = || get32(12) as u64 * ADDR_ALIGN;
    Ok(match w[0] {
        OP_LD => Inst::Ld {
            src: memspace_from(w[1] >> 4, w[2])?,
            dst: buf_from(w[1] & 0x0F)?,
            addr: addr(),
            bytes: get32(4),
        },
        OP_ST => Inst::St {
            src: buf_from(w[1] & 0x0F)?,
            dst: memspace_from(w[1] >> 4, w[2])?,
            addr: addr(),
            bytes: get32(4),
        },
        OP_LD_MERGED => Inst::LdMerged {
            first_channel: w[2],
            channels: w[3],
            dst: buf_from(w[1])?,
            addr: addr(),
            bytes: get32(4),
        },
        OP_ST_MERGED => Inst::StMerged {
            first_channel: w[2],
            channels: w[3],
            src: buf_from(w[1])?,
            addr: addr(),
            bytes: get32(4),
        },
        OP_MM => Inst::Mm {
            m: get32(4),
            k: get32(8),
            n: get32(12),
            sparsity: sparsity_from([w[2], w[3]])?,
        },
        OP_MV => Inst::Mv {
            k: get32(8),
            n: get32(12),
            sparsity: sparsity_from([w[2], w[3]])?,
        },
        OP_MISC => Inst::Misc { op: misc_from(w[1])?, len: get32(4) },
        OP_SYS => Inst::Sys {
            op: match w[1] {
                0 => SysOp::SyncSlr,
                1 => SysOp::SyncHost,
                other => return Err(DecodeError::BadSubOp(OP_SYS, other)),
            },
        },
        other => return Err(DecodeError::BadOpcode(other)),
    })
}

/// Encode a whole instruction stream.
pub fn encode_stream(insts: &[Inst]) -> Vec<u8> {
    let mut out = Vec::with_capacity(insts.len() * INST_BYTES);
    for i in insts {
        out.extend_from_slice(&encode(i));
    }
    out
}

/// Decode a whole stream; errors on trailing partial words.
pub fn decode_stream(bytes: &[u8]) -> Result<Vec<Inst>, DecodeError> {
    if bytes.len() % INST_BYTES != 0 {
        return Err(DecodeError::Truncated { have: bytes.len() % INST_BYTES });
    }
    bytes
        .chunks_exact(INST_BYTES)
        .map(|c| decode(c.try_into().unwrap()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::*;
    use super::*;

    fn samples() -> Vec<Inst> {
        vec![
            Inst::Ld {
                src: MemSpace::Hbm { channel: 7 },
                dst: OnChipBuf::Weight,
                addr: 0x40_0000,
                bytes: 65536,
            },
            Inst::Ld { src: MemSpace::Ddr, dst: OnChipBuf::Global, addr: 64, bytes: 128 },
            Inst::St {
                src: OnChipBuf::Global,
                dst: MemSpace::Hbm { channel: 31 },
                addr: 0xFFFF_C0,
                bytes: 4096,
            },
            Inst::LdMerged {
                first_channel: 0,
                channels: 8,
                dst: OnChipBuf::Activation,
                addr: 1 << 20,
                bytes: 16384,
            },
            Inst::StMerged {
                first_channel: 16,
                channels: 8,
                src: OnChipBuf::Global,
                addr: 128,
                bytes: 2048,
            },
            Inst::Mm { m: 128, k: 4096, n: 4096, sparsity: Sparsity::Dense },
            Inst::Mm {
                m: 64,
                k: 64,
                n: 64,
                sparsity: Sparsity::BlockSparse { density_256: 115 },
            },
            Inst::Mv { k: 4096, n: 11008, sparsity: Sparsity::Nm { n: 8, m: 16 } },
            Inst::Misc { op: MiscOp::Softmax, len: 2048 },
            Inst::Misc { op: MiscOp::Rope, len: 128 },
            Inst::Sys { op: SysOp::SyncSlr },
            Inst::Sys { op: SysOp::SyncHost },
        ]
    }

    #[test]
    fn roundtrip_each_variant() {
        for inst in samples() {
            let enc = encode(&inst);
            let dec = decode(&enc).unwrap();
            assert_eq!(dec, inst, "roundtrip failed for {inst:?}");
        }
    }

    #[test]
    fn stream_roundtrip() {
        let insts = samples();
        let bytes = encode_stream(&insts);
        assert_eq!(bytes.len(), insts.len() * INST_BYTES);
        assert_eq!(decode_stream(&bytes).unwrap(), insts);
    }

    #[test]
    fn truncated_stream_rejected() {
        let mut bytes = encode_stream(&samples());
        bytes.pop();
        assert!(matches!(
            decode_stream(&bytes),
            Err(DecodeError::Truncated { .. })
        ));
    }

    #[test]
    fn bad_opcode_rejected() {
        let mut w = [0u8; INST_BYTES];
        w[0] = 0xEE;
        assert_eq!(decode(&w), Err(DecodeError::BadOpcode(0xEE)));
    }

    #[test]
    fn property_random_instructions_roundtrip() {
        use crate::util::proptest;
        proptest::check("isa roundtrip", |r| {
            let inst = match r.below(8) {
                0 => Inst::Ld {
                    src: if r.below(2) == 0 {
                        MemSpace::Hbm { channel: r.below(32) as u8 }
                    } else {
                        MemSpace::Ddr
                    },
                    dst: OnChipBuf::Weight,
                    addr: r.below(1 << 26) * 64,
                    bytes: r.below(1 << 30) as u32,
                },
                1 => Inst::St {
                    src: OnChipBuf::Global,
                    dst: MemSpace::Hbm { channel: r.below(32) as u8 },
                    addr: r.below(1 << 26) * 64,
                    bytes: r.below(1 << 20) as u32,
                },
                2 => Inst::LdMerged {
                    first_channel: r.below(24) as u8,
                    channels: 1 + r.below(8) as u8,
                    dst: OnChipBuf::Activation,
                    addr: r.below(1 << 26) * 64,
                    bytes: r.below(1 << 24) as u32,
                },
                3 => Inst::StMerged {
                    first_channel: r.below(24) as u8,
                    channels: 1 + r.below(8) as u8,
                    src: OnChipBuf::Index,
                    addr: r.below(1 << 26) * 64,
                    bytes: r.below(1 << 24) as u32,
                },
                4 => Inst::Mm {
                    m: r.below(1 << 16) as u32,
                    k: r.below(1 << 16) as u32,
                    n: r.below(1 << 16) as u32,
                    sparsity: Sparsity::Nm {
                        n: (r.below(63) + 1) as u8,
                        m: r.below(256) as u8,
                    },
                },
                5 => Inst::Mv {
                    k: r.below(1 << 20) as u32,
                    n: r.below(1 << 20) as u32,
                    sparsity: Sparsity::BlockSparse {
                        density_256: r.below(256) as u8,
                    },
                },
                6 => Inst::Misc { op: MiscOp::Rope, len: r.below(1 << 24) as u32 },
                _ => Inst::Sys {
                    op: if r.below(2) == 0 { SysOp::SyncSlr } else { SysOp::SyncHost },
                },
            };
            assert_eq!(decode(&encode(&inst)).unwrap(), inst);
        });
    }

    #[test]
    fn addresses_align_to_64() {
        // Addresses are stored as 64-byte tile indices; aligned addresses
        // must round-trip exactly.
        let inst = Inst::Ld {
            src: MemSpace::Hbm { channel: 0 },
            dst: OnChipBuf::Weight,
            addr: 64 * 12345,
            bytes: 64,
        };
        assert_eq!(decode(&encode(&inst)).unwrap(), inst);
    }
}
