//! The FlightLLM ISA (Table 1): six coarse-grained instructions that
//! connect the compiled LLM to the accelerator.
//!
//! `LD`/`ST` move tiles between off-chip memory (HBM or DDR) and on-chip
//! buffers; `MM`/`MV` run the MPE in matrix-matrix or matrix-vector mode;
//! `MISC` drives the SFU (LayerNorm / Softmax / SiLU / Eltwise); `SYS`
//! synchronizes SLRs with each other or the host.
//!
//! The module also implements the §5.2 *merged multi-channel* LD/ST: one
//! stored instruction that the hardware decoder expands into eight
//! per-channel micro-instructions launched simultaneously — one of the
//! two optimizations that shrink the instruction stream from 4.77 GB to
//! 3.25 GB.

mod encode;

pub use encode::{decode, decode_stream, encode, encode_stream, DecodeError, INST_BYTES};


/// Off-chip source/destination of an LD/ST (§4.4 hybrid memory).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemSpace {
    /// HBM pseudo-channel `channel`. Large streaming data: weights, KV.
    Hbm { channel: u8 },
    /// DDR. Small latency-sensitive data: lookup tables, instructions.
    Ddr,
}

/// On-chip buffer targeted by an LD/ST or used by compute (§3.1 core).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OnChipBuf {
    Weight,
    Activation,
    Global,
    Index,
}

/// Matrix sparsity descriptor carried by MM/MV (§3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Sparsity {
    Dense,
    /// N:M weight sparsity: `n` nonzeros kept per `m`-wide group.
    Nm { n: u8, m: u8 },
    /// Block-sparse (SDDMM/attention): fraction of blocks kept, in 1/256
    /// steps so the descriptor stays one byte.
    BlockSparse { density_256: u8 },
}

impl Sparsity {
    /// Checked N:M constructor: `m == 0` would make `density()` NaN (and
    /// `macs()` a garbage cast), `n > m` a density > 1, and `n > 63` does
    /// not fit the 6-bit encoding field.
    pub fn nm(n: u8, m: u8) -> Result<Sparsity, IsaError> {
        if n == 0 || m == 0 || n > m || n > 63 {
            return Err(IsaError::BadNm { n, m });
        }
        Ok(Sparsity::Nm { n, m })
    }

    /// Whether the descriptor is internally consistent (see `nm`).
    pub fn is_valid(&self) -> bool {
        match self {
            Sparsity::Nm { n, m } => *n >= 1 && *m >= 1 && n <= m && *n <= 63,
            _ => true,
        }
    }

    /// Fraction of MACs actually executed relative to dense.
    pub fn density(&self) -> f64 {
        match self {
            Sparsity::Dense => 1.0,
            Sparsity::Nm { n, m } => *n as f64 / *m as f64,
            Sparsity::BlockSparse { density_256 } => *density_256 as f64 / 256.0,
        }
    }
}

/// Construction-time validation failures for instruction fields.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IsaError {
    /// N:M descriptor out of range (n in 1..=min(m, 63), m >= 1).
    BadNm { n: u8, m: u8 },
    /// Merged LD/ST channel run leaves u8 channel space (or is empty):
    /// `first_channel + channels` must stay <= 256 with channels >= 1.
    BadChannelRun { first_channel: u8, channels: u8 },
}

impl std::fmt::Display for IsaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IsaError::BadNm { n, m } => write!(f, "invalid {n}:{m} sparsity descriptor"),
            IsaError::BadChannelRun { first_channel, channels } => write!(
                f,
                "merged channel run {first_channel}+{channels} wraps u8 channel space"
            ),
        }
    }
}

impl std::error::Error for IsaError {}

/// MISC (SFU) operation kinds (§3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MiscOp {
    /// Two-phase: reduce for mean/var, then normalize.
    LayerNorm,
    /// Two-phase: reduce for max/sum, then scale.
    Softmax,
    /// Element-wise activation (lookup-table backed on the SFU).
    Silu,
    Gelu,
    /// Element-wise add / mul (residuals, SwiGLU gating).
    EltwiseAdd,
    EltwiseMul,
    /// RMSNorm (LLaMA) — two-phase like LayerNorm.
    RmsNorm,
    /// Rotary position embedding applied in-place.
    Rope,
}

impl MiscOp {
    /// Two-phase ops read the whole vector twice (§3.3).
    pub fn is_two_phase(&self) -> bool {
        matches!(self, MiscOp::LayerNorm | MiscOp::Softmax | MiscOp::RmsNorm)
    }
}

/// SYS scopes (§5.1): between SLRs after each layer, or with the host
/// after each inference.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SysOp {
    SyncSlr,
    SyncHost,
}

/// One coarse-grained FlightLLM instruction.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Inst {
    /// Load `bytes` from off-chip `src` at `addr` into `dst`.
    Ld { src: MemSpace, dst: OnChipBuf, addr: u64, bytes: u32 },
    /// Merged multi-channel load (§5.2): the decoder expands this into
    /// `channels` per-channel LDs of `bytes` each, launched concurrently
    /// from consecutive HBM channels starting at `first_channel`.
    LdMerged { first_channel: u8, channels: u8, dst: OnChipBuf, addr: u64, bytes: u32 },
    /// Store from on-chip buffer back to off-chip memory.
    St { src: OnChipBuf, dst: MemSpace, addr: u64, bytes: u32 },
    /// Merged multi-channel store (§5.2).
    StMerged { first_channel: u8, channels: u8, src: OnChipBuf, addr: u64, bytes: u32 },
    /// Matrix-matrix multiply C = X·W^T + b on the MPE (MM mode).
    Mm { m: u32, k: u32, n: u32, sparsity: Sparsity },
    /// Matrix-vector multiply c = x·W^T + b (MV mode, decode stage).
    Mv { k: u32, n: u32, sparsity: Sparsity },
    /// SFU operation over a `len`-element vector.
    Misc { op: MiscOp, len: u32 },
    /// Synchronization barrier.
    Sys { op: SysOp },
}

impl Inst {
    /// Checked merged-load constructor: rejects channel runs that would
    /// wrap u8 channel space when expanded (`first_channel + channels`
    /// must stay <= 256, channels >= 1).  Platform channel-count bounds
    /// are the verifier's job; this guards the arithmetic itself.
    pub fn ld_merged(
        first_channel: u8,
        channels: u8,
        dst: OnChipBuf,
        addr: u64,
        bytes: u32,
    ) -> Result<Inst, IsaError> {
        check_channel_run(first_channel, channels)?;
        Ok(Inst::LdMerged { first_channel, channels, dst, addr, bytes })
    }

    /// Checked merged-store constructor (see `ld_merged`).
    pub fn st_merged(
        first_channel: u8,
        channels: u8,
        src: OnChipBuf,
        addr: u64,
        bytes: u32,
    ) -> Result<Inst, IsaError> {
        check_channel_run(first_channel, channels)?;
        Ok(Inst::StMerged { first_channel, channels, src, addr, bytes })
    }

    /// MAC count of a compute instruction (0 for data movement / sync).
    pub fn macs(&self) -> u64 {
        match self {
            Inst::Mm { m, k, n, sparsity } => {
                ((*m as u64 * *k as u64 * *n as u64) as f64 * sparsity.density())
                    as u64
            }
            Inst::Mv { k, n, sparsity } => {
                ((*k as u64 * *n as u64) as f64 * sparsity.density()) as u64
            }
            _ => 0,
        }
    }

    /// Off-chip bytes moved by this instruction (after decoder expansion).
    pub fn offchip_bytes(&self) -> u64 {
        match self {
            Inst::Ld { bytes, .. } | Inst::St { bytes, .. } => *bytes as u64,
            Inst::LdMerged { channels, bytes, .. }
            | Inst::StMerged { channels, bytes, .. } => {
                *channels as u64 * *bytes as u64
            }
            _ => 0,
        }
    }

    /// The contiguous off-chip span this instruction touches after
    /// decoder expansion, as `(is_hbm, addr, total_bytes)` — a merged
    /// run's per-channel legs are laid out back-to-back from `addr`, so
    /// its span is `channels * bytes` wide.  `None` for compute/sync.
    pub fn offchip_span(&self) -> Option<(bool, u64, u64)> {
        match self {
            Inst::Ld { src, addr, bytes, .. } => {
                Some((matches!(src, MemSpace::Hbm { .. }), *addr, *bytes as u64))
            }
            Inst::St { dst, addr, bytes, .. } => {
                Some((matches!(dst, MemSpace::Hbm { .. }), *addr, *bytes as u64))
            }
            Inst::LdMerged { channels, addr, bytes, .. }
            | Inst::StMerged { channels, addr, bytes, .. } => {
                Some((true, *addr, *channels as u64 * *bytes as u64))
            }
            _ => None,
        }
    }

    /// Expand merged LD/ST into per-channel micro-instructions — the
    /// hardware decoder of §5.2. Non-merged instructions pass through.
    ///
    /// Channel indices are computed in u32 so a run built outside the
    /// checked constructors cannot overflow-panic here; an invalid run
    /// (`first_channel + channels > 256`) wraps mod 256 deterministically
    /// and is flagged by the stream verifier instead.
    pub fn expand(&self) -> Vec<Inst> {
        let wrap = |fc: u8, c: u8| ((fc as u32 + c as u32) % 256) as u8;
        match self {
            Inst::LdMerged { first_channel, channels, dst, addr, bytes } => (0
                ..*channels)
                .map(|c| Inst::Ld {
                    src: MemSpace::Hbm { channel: wrap(*first_channel, c) },
                    dst: *dst,
                    addr: addr + c as u64 * *bytes as u64,
                    bytes: *bytes,
                })
                .collect(),
            Inst::StMerged { first_channel, channels, src, addr, bytes } => (0
                ..*channels)
                .map(|c| Inst::St {
                    src: *src,
                    dst: MemSpace::Hbm { channel: wrap(*first_channel, c) },
                    addr: addr + c as u64 * *bytes as u64,
                    bytes: *bytes,
                })
                .collect(),
            other => vec![other.clone()],
        }
    }

    pub fn is_compute(&self) -> bool {
        matches!(self, Inst::Mm { .. } | Inst::Mv { .. } | Inst::Misc { .. })
    }

    pub fn is_memory(&self) -> bool {
        matches!(
            self,
            Inst::Ld { .. } | Inst::St { .. } | Inst::LdMerged { .. } | Inst::StMerged { .. }
        )
    }
}

fn check_channel_run(first_channel: u8, channels: u8) -> Result<(), IsaError> {
    if channels == 0 || first_channel as u32 + channels as u32 > 256 {
        return Err(IsaError::BadChannelRun { first_channel, channels });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merged_ld_expands_to_consecutive_channels() {
        let ld = Inst::LdMerged {
            first_channel: 8,
            channels: 8,
            dst: OnChipBuf::Weight,
            addr: 0x1000,
            bytes: 4096,
        };
        let ex = ld.expand();
        assert_eq!(ex.len(), 8);
        for (i, inst) in ex.iter().enumerate() {
            match inst {
                Inst::Ld { src: MemSpace::Hbm { channel }, addr, bytes, .. } => {
                    assert_eq!(*channel as usize, 8 + i);
                    assert_eq!(*addr, 0x1000 + i as u64 * 4096);
                    assert_eq!(*bytes, 4096);
                }
                other => panic!("expected Ld, got {other:?}"),
            }
        }
        assert_eq!(ld.offchip_bytes(), 8 * 4096);
    }

    #[test]
    fn sparsity_density() {
        assert_eq!(Sparsity::Dense.density(), 1.0);
        assert_eq!(Sparsity::Nm { n: 4, m: 16 }.density(), 0.25);
        assert!((Sparsity::BlockSparse { density_256: 128 }.density() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn mv_macs_scale_with_density() {
        let dense = Inst::Mv { k: 4096, n: 4096, sparsity: Sparsity::Dense };
        let sparse =
            Inst::Mv { k: 4096, n: 4096, sparsity: Sparsity::Nm { n: 8, m: 16 } };
        assert_eq!(dense.macs(), 4096 * 4096);
        assert_eq!(sparse.macs(), 4096 * 4096 / 2);
    }

    #[test]
    fn two_phase_classification() {
        assert!(MiscOp::Softmax.is_two_phase());
        assert!(MiscOp::RmsNorm.is_two_phase());
        assert!(!MiscOp::Silu.is_two_phase());
        assert!(!MiscOp::EltwiseAdd.is_two_phase());
    }

    #[test]
    fn non_merged_expand_is_identity() {
        let mv = Inst::Mv { k: 16, n: 16, sparsity: Sparsity::Dense };
        assert_eq!(mv.expand(), vec![mv.clone()]);
    }

    #[test]
    fn checked_merged_constructors_reject_u8_wrap() {
        // Regression: `first_channel + c` used to be a bare u8 add that
        // overflowed in expand() for runs crossing channel 255.
        assert!(Inst::ld_merged(248, 8, OnChipBuf::Weight, 0, 64).is_ok());
        assert!(matches!(
            Inst::ld_merged(250, 10, OnChipBuf::Weight, 0, 64),
            Err(IsaError::BadChannelRun { first_channel: 250, channels: 10 })
        ));
        assert!(matches!(
            Inst::st_merged(0, 0, OnChipBuf::Global, 0, 64),
            Err(IsaError::BadChannelRun { .. })
        ));
    }

    #[test]
    fn expand_of_wrapping_run_does_not_panic() {
        // An invalid run built around the checked constructors must not
        // overflow-panic; channels wrap mod 256 and the verifier flags it.
        let ld = Inst::LdMerged {
            first_channel: 250,
            channels: 10,
            dst: OnChipBuf::Weight,
            addr: 0,
            bytes: 64,
        };
        let ex = ld.expand();
        assert_eq!(ex.len(), 10);
        match &ex[9] {
            Inst::Ld { src: MemSpace::Hbm { channel }, .. } => assert_eq!(*channel, 3),
            other => panic!("expected Ld, got {other:?}"),
        }
    }

    #[test]
    fn nm_constructor_rejects_degenerate_descriptors() {
        assert_eq!(Sparsity::nm(8, 16), Ok(Sparsity::Nm { n: 8, m: 16 }));
        assert_eq!(Sparsity::nm(8, 0), Err(IsaError::BadNm { n: 8, m: 0 }));
        assert_eq!(Sparsity::nm(0, 16), Err(IsaError::BadNm { n: 0, m: 16 }));
        assert_eq!(Sparsity::nm(17, 16), Err(IsaError::BadNm { n: 17, m: 16 }));
        assert_eq!(Sparsity::nm(64, 128), Err(IsaError::BadNm { n: 64, m: 128 }));
        assert!(!Sparsity::Nm { n: 8, m: 0 }.density().is_finite());
        assert!(!Sparsity::Nm { n: 8, m: 0 }.is_valid());
        assert!(Sparsity::Nm { n: 8, m: 16 }.is_valid());
        assert!(Sparsity::Dense.is_valid());
    }
}
