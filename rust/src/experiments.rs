//! Experiment drivers shared by the benches, examples and CLI: produce
//! paper-figure measurements from the simulator (FlightLLM) and the
//! analytical baselines, over the [prefill, decode] grids of §6.
//!
//! Decode steps are simulated per length-adaptive *bucket* (one stream
//! per bucket × steps in that bucket) — exactly how the deployed system
//! executes (§5.2), and what keeps the grid sweeps fast.

use crate::baselines::AnalyticalModel;
use crate::compiler::{lower, BucketPlan, CompilerOptions, VecSink};
use crate::config::{CompressionConfig, Target};
use crate::ir::{passes, Graph, Stage};
use crate::metrics::{EvalPoint, Measurement};
use crate::sim::{Engine, PowerModel, SimReport};

/// KV page size (tokens) the serving experiments size their pools and
/// price their swap traffic with — one definition, so `cli serve`'s
/// pool sizing and the experiment drivers can never drift apart.
pub const SERVE_PAGE_TOKENS: usize = 16;

/// Lower and simulate one stream for a target — the single source of
/// stage timings for the figure sweeps AND the serving-path
/// `coordinator::SimBackend`.
pub fn sim_stage(t: &Target, stage: Stage, opt: CompilerOptions, csd: bool) -> SimReport {
    let mut g = Graph::from_model(&t.model, &t.compression, stage);
    passes::optimize(&mut g);
    let mut sink = VecSink::default();
    lower(&g, t, opt, &mut sink);
    Engine::for_target(t, csd).run_ref(&sink.0)
}

/// Before/after pricing of one stream through the certified
/// `compiler::optimize_stream` pass — the fig15 analyze-table row.
#[derive(Debug, Clone, Copy)]
pub struct AnalyzePricing {
    pub insts_before: usize,
    pub insts_after: usize,
    pub bytes_before: u64,
    pub bytes_after: u64,
    pub ns_before: f64,
    pub ns_after: f64,
    pub certified: bool,
}

/// Lower one stage, run the certified stream optimizer, and price both
/// streams through the simulator.
pub fn analyze_stage_pricing(
    t: &Target,
    stage: Stage,
    opt: CompilerOptions,
    csd: bool,
) -> AnalyzePricing {
    let mut g = Graph::from_model(&t.model, &t.compression, stage);
    passes::optimize(&mut g);
    let mut sink = VecSink::default();
    lower(&g, t, opt, &mut sink);
    let insts = sink.0;
    let out = crate::compiler::optimize_stream(&insts);
    let engine = Engine::for_target(t, csd);
    let before = engine.run_ref(&insts);
    let after = engine.run_ref(&out.insts);
    AnalyzePricing {
        insts_before: insts.len(),
        insts_after: out.insts.len(),
        bytes_before: before.hbm_bytes + before.ddr_bytes,
        bytes_after: after.hbm_bytes + after.ddr_bytes,
        ns_before: before.total_ns,
        ns_after: after.total_ns,
        certified: out.certified,
    }
}

/// FlightLLM configuration under test (ablation rungs of Fig. 14).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlightConfig {
    /// Dense fp16 port, activations round-trip off-chip, no CSD chain.
    Naive,
    /// + N:M weight pruning, block-sparse attention, CSD chain.
    Sparse,
    /// + always-on-chip decode with mixed-precision (the full system).
    Full,
}

impl FlightConfig {
    pub fn label(&self) -> &'static str {
        match self {
            FlightConfig::Naive => "naive U280 port",
            FlightConfig::Sparse => "+ sparse DSP chain",
            FlightConfig::Full => "+ always-on-chip decode",
        }
    }

    fn compression(&self, full: &CompressionConfig) -> CompressionConfig {
        match self {
            // The naive port still stores weights in INT8 — an fp16 7B
            // model would not fit U280's 8 GB HBM at all (the Fig. 14
            // baseline runs, so it must be at least W8).
            FlightConfig::Naive => CompressionConfig {
                quantization: true,
                weight_bits: 8.0,
                act_bits: 8,
                ..CompressionConfig::none()
            },
            FlightConfig::Sparse => CompressionConfig {
                quantization: true,
                weight_bits: 8.0,
                act_bits: 8,
                ..full.clone()
            },
            FlightConfig::Full => full.clone(),
        }
    }

    fn options(&self) -> CompilerOptions {
        match self {
            FlightConfig::Naive => CompilerOptions::naive(),
            FlightConfig::Sparse => CompilerOptions {
                onchip_decode: false,
                ..CompilerOptions::full()
            },
            FlightConfig::Full => CompilerOptions::full(),
        }
    }

    fn csd(&self) -> bool {
        !matches!(self, FlightConfig::Naive)
    }
}

/// Measure FlightLLM on one evaluation point.
pub fn flightllm_measure(target: &Target, pt: EvalPoint, cfg: FlightConfig) -> Measurement {
    let t = Target { compression: cfg.compression(&target.compression), ..target.clone() };
    let opt = cfg.options();
    let plan = BucketPlan::paper_default(t.model.max_seq);

    // Prefill once at its bucket.
    let pre_bucket = plan.prefill_bucket(pt.prefill.max(1));
    let pre = sim_stage(&t, Stage::Prefill { n: pre_bucket }, opt, cfg.csd());

    // Decode: group steps by their context bucket.
    let mut decode_ns = 0.0;
    let mut macs = 0u64;
    let mut hbm_bytes = 0u64;
    let mut last: Option<SimReport> = None;
    let mut i = 0u64;
    while i < pt.decode {
        let ctx = pt.prefill + i;
        let bucket = plan.decode_bucket(ctx.max(1));
        // All steps whose ctx falls in this bucket share the stream.
        let steps_in_bucket = (bucket.saturating_sub(ctx) + 1).min(pt.decode - i);
        let rep = sim_stage(&t, Stage::Decode { ctx: bucket }, opt, cfg.csd());
        decode_ns += rep.total_ns * steps_in_bucket as f64;
        macs += rep.macs * steps_in_bucket;
        hbm_bytes += rep.hbm_bytes * steps_in_bucket;
        last = Some(rep);
        i += steps_in_bucket;
    }
    let decode_rep = last.unwrap_or_default();

    let power = PowerModel::for_platform(&t.platform, t.accel.macs_per_cycle());
    let combined = SimReport {
        total_ns: pre.total_ns + decode_ns,
        macs: pre.macs + macs,
        hbm_bytes: pre.hbm_bytes + hbm_bytes,
        ..decode_rep.clone()
    };
    Measurement {
        system: format!("FlightLLM-{} ({})", t.platform.name, cfg.label()),
        point: pt,
        latency_s: (pre.total_ns + decode_ns) * 1e-9,
        decode_tps: if decode_ns > 0.0 {
            pt.decode as f64 / (decode_ns * 1e-9)
        } else {
            0.0
        },
        power_w: power.avg_watts(&combined),
        bw_util: decode_rep.hbm_bw_util,
        price_usd: t.platform.price_usd,
    }
}

/// Convenience: the shipping configuration.
pub fn flightllm_full(target: &Target, pt: EvalPoint) -> Measurement {
    flightllm_measure(target, pt, FlightConfig::Full)
}

/// Multi-batch decode throughput (Fig. 15): aggregate tokens/s when
/// `batch` sequences decode together at context `ctx`.
pub fn flightllm_batch_tps(target: &Target, ctx: u64, batch: u32) -> f64 {
    let opt = crate::compiler::CompilerOptions::with_batch(batch);
    let rep = sim_stage(target, Stage::Decode { ctx }, opt, true);
    if rep.total_ns <= 0.0 {
        return 0.0;
    }
    batch as f64 * 1e9 / rep.total_ns
}

/// Fig. 15 through the serving stack: `batch` simultaneous requests at
/// context `ctx` decode `decode` tokens each through the
/// continuous-batching engine over the sim backend.  Aggregate decode
/// tokens/s comes off the virtual clock (`ServeStats::decode_tps`), so
/// the number reflects scheduling + KV admission, not just the stream
/// time the analytic `flightllm_batch_tps` prices.
pub fn flightllm_serve_batch_tps(
    target: &Target,
    ctx: u64,
    decode: u32,
    batch: u32,
) -> crate::coordinator::ServeStats {
    use crate::coordinator::{Sampler, SchedulerConfig, Server, SimBackend};
    use crate::workload::generate_burst_trace;

    let vocab = 512u32.min(target.model.vocab as u32).max(2);
    let page_tokens = SERVE_PAGE_TOKENS;
    let per_seq = (ctx as usize + decode as usize).div_ceil(page_tokens) + 1;
    let cfg = SchedulerConfig {
        max_batch: batch.max(1) as usize,
        kv_pages: per_seq * batch.max(1) as usize,
        page_tokens,
        max_seq: target.model.max_seq as usize,
        ..Default::default()
    };
    let trace = generate_burst_trace(batch.max(1) as usize, ctx as usize, decode, vocab, 15);
    let backend =
        SimBackend::with_vocab(target.clone(), vocab as usize).with_max_batch(batch.max(1));
    Server::new(backend, cfg, Sampler::greedy())
        .run_trace(trace)
        .expect("sim serving is infallible")
}

/// Serve a shared-prefix trace through the continuous-batching engine
/// over the sim backend, with prefix caching on or off — the controlled
/// comparison behind `serve --prefix-cache`, the serve_e2e example, and
/// the Fig. 15 bench's cache columns.  Everything but the scheduler's
/// `prefix_cache` flag is held fixed, so TTFT / peak-KV deltas isolate
/// the cache's effect (generated tokens are identical either way: the
/// simulator prices time, not numerics).
pub fn flightllm_serve_prefix(
    target: &Target,
    trace_cfg: &crate::workload::SharedPrefixConfig,
    max_batch: usize,
    prefix_cache: bool,
) -> crate::coordinator::ServeStats {
    use crate::coordinator::{Sampler, SchedulerConfig, Server, SimBackend};
    use crate::workload::generate_shared_prefix_trace;

    let cfg = SchedulerConfig {
        max_batch: max_batch.max(1),
        kv_pages: 512,
        page_tokens: SERVE_PAGE_TOKENS,
        max_seq: target.model.max_seq as usize,
        prefix_cache,
        ..Default::default()
    };
    let trace = generate_shared_prefix_trace(trace_cfg);
    let backend = SimBackend::with_vocab(target.clone(), trace_cfg.vocab.max(2) as usize)
        .with_max_batch(max_batch.max(1) as u32);
    Server::new(backend, cfg, Sampler::greedy())
        .run_trace(trace)
        .expect("sim serving is infallible")
}

/// Serve an overload trace (concurrent KV demand exceeding the pool)
/// through the continuous-batching engine over the sim backend, with a
/// `kv_pages`-page pool and swap-to-DDR preemption on or off — the
/// controlled comparison behind `serve --swap`, the serve_e2e overload
/// section and the fig15 swap table.  With swap ON the backend prices
/// spill/resume traffic at the KV page size over `ddr_gbps` (platform
/// DDR bandwidth when `None`), so the virtual clock shows the cost of
/// spilling; sampling is greedy so token streams are comparable across
/// pool sizes (the simulator prices time, not numerics).
pub fn flightllm_serve_overload(
    target: &Target,
    trace_cfg: &crate::workload::OverloadConfig,
    max_batch: usize,
    kv_pages: usize,
    swap: bool,
    ddr_gbps: Option<f64>,
) -> crate::coordinator::ServeStats {
    flightllm_serve_overload_recorded(target, trace_cfg, max_batch, kv_pages, swap, ddr_gbps, false)
        .0
}

/// [`flightllm_serve_overload`] with an optional flight recorder
/// (`record`): the run comes back with its drained `EventLog` (`None`
/// when `record` is off).  Recording only READS engine state, so the
/// stats and token streams are bit-identical either way (asserted in
/// the overload acceptance test).
pub fn flightllm_serve_overload_recorded(
    target: &Target,
    trace_cfg: &crate::workload::OverloadConfig,
    max_batch: usize,
    kv_pages: usize,
    swap: bool,
    ddr_gbps: Option<f64>,
    record: bool,
) -> (crate::coordinator::ServeStats, Option<crate::obs::EventLog>) {
    use crate::coordinator::{Sampler, SchedulerConfig, Server, SimBackend};
    use crate::workload::generate_overload_trace;

    let page_tokens = SERVE_PAGE_TOKENS;
    let cfg = SchedulerConfig {
        max_batch: max_batch.max(1),
        kv_pages: kv_pages.max(1),
        page_tokens,
        max_seq: target.model.max_seq as usize,
        swap,
        ..Default::default()
    };
    let trace = generate_overload_trace(trace_cfg);
    let backend = SimBackend::with_vocab(target.clone(), trace_cfg.vocab.max(2) as usize)
        .with_max_batch(max_batch.max(1) as u32)
        .with_swap_model(page_tokens, ddr_gbps);
    let mut server = Server::new(backend, cfg, Sampler::greedy());
    if record {
        server.set_recorder(crate::obs::Recorder::new());
    }
    let stats = server.run_trace(trace).expect("sim serving is infallible");
    if let Some(rec) = server.recorder() {
        server.backend().record_cost_model(rec, 0, stats.served_s);
    }
    (stats, server.take_event_log())
}

/// The controlled three-way overload comparison: the SAME trace served
/// with an over-provisioned pool (no contention), the small pool with
/// swap-to-DDR preemption, and the small pool with legacy truncation.
/// Returns `(big, swapped, lossy)` — one definition of the comparison
/// shared by the acceptance test, the fig15 swap table, the serve_e2e
/// overload section and `cli serve --swap`.
pub fn flightllm_overload_three_way(
    target: &Target,
    trace_cfg: &crate::workload::OverloadConfig,
    max_batch: usize,
    big_pool: usize,
    small_pool: usize,
    ddr_gbps: Option<f64>,
) -> (
    crate::coordinator::ServeStats,
    crate::coordinator::ServeStats,
    crate::coordinator::ServeStats,
) {
    (
        flightllm_serve_overload(target, trace_cfg, max_batch, big_pool, false, ddr_gbps),
        flightllm_serve_overload(target, trace_cfg, max_batch, small_pool, true, ddr_gbps),
        flightllm_serve_overload(target, trace_cfg, max_batch, small_pool, false, ddr_gbps),
    )
}

/// TTFT / P99-decode-ITL vs prefill chunk size: serve the SAME mixed
/// burst trace (decode-heavy requests in steady state, long prompts
/// landing mid-decode) once per chunk setting through the
/// continuous-batching engine over the sim backend.  Chunk 0 is the
/// unchunked baseline.  The scheduler only re-times the work, so served
/// tokens are byte-identical across settings while chunking caps how
/// long one prompt can stall the decode batch — P99 decode inter-token
/// latency falls.  Feeds the fig15 bench table and `cli serve
/// --prefill-chunk`.
pub fn flightllm_serve_chunk_sweep(
    target: &Target,
    trace_cfg: &crate::workload::MixedBurstConfig,
    max_batch: usize,
    chunks: &[usize],
) -> Vec<(usize, crate::coordinator::ServeStats)> {
    use crate::coordinator::{Sampler, SchedulerConfig, Server, SimBackend};
    use crate::workload::generate_mixed_burst_trace;

    chunks
        .iter()
        .map(|&chunk| {
            let cfg = SchedulerConfig {
                max_batch: max_batch.max(1),
                kv_pages: 512,
                page_tokens: SERVE_PAGE_TOKENS,
                max_seq: target.model.max_seq as usize,
                prefill_chunk: chunk,
                ..Default::default()
            };
            let trace = generate_mixed_burst_trace(trace_cfg);
            let backend = SimBackend::with_vocab(target.clone(), trace_cfg.vocab.max(2) as usize)
                .with_max_batch(max_batch.max(1) as u32);
            let stats = Server::new(backend, cfg, Sampler::greedy())
                .run_trace(trace)
                .expect("sim serving is infallible");
            (chunk, stats)
        })
        .collect()
}

/// Geometry + routing of a sim-backed serving fleet: shard count, the
/// request→shard policy, and the PER-BOARD batch and KV budget (adding
/// shards adds capacity the way adding boards does).
#[derive(Debug, Clone, Copy)]
pub struct FleetSpec {
    pub shards: usize,
    pub route: crate::coordinator::RoutePolicy,
    /// Concurrent sequences per board.
    pub max_batch: usize,
    /// KV pool pages per board (at [`SERVE_PAGE_TOKENS`]-token pages).
    pub kv_pages_per_shard: usize,
    /// Per-board CoW prefix caches (what prefix-affinity routing
    /// exploits).
    pub prefix_cache: bool,
    /// Fabricated-logits width for the sim lanes.
    pub vocab: usize,
    /// Worker threads for fleet lane ticks (1 = sequential; streams
    /// are byte-identical either way).
    pub lane_threads: usize,
    /// Fleet-global prefix directory: lanes adopt hot-prefix pages a
    /// sibling materialized, paying inter-board transfer instead of
    /// re-prefilling.
    pub global_prefix: bool,
    /// Cross-shard migration of parked (swapped-out) requests from
    /// overloaded lanes to idle ones; implies per-lane swap-to-DDR.
    pub migrate: bool,
    /// Prefix-affinity spill threshold: above this many in-flight
    /// requests the home lane overflows to the least-loaded lane
    /// (0 = never spill).
    pub affinity_spill: usize,
}

/// Serve a trace across a multi-shard fleet of sim-backed replica
/// lanes (`coordinator::ShardedService`) — the SLR/board-replication
/// serving tier.  Each lane gets its own `SimBackend`, scheduler and
/// KV pool per `spec` (the dense cost table is built ONCE in a
/// prototype and cloned per lane), and the lanes tick on
/// `spec.lane_threads` workers.  Returns (per-shard stats, merged
/// fleet stats, fleet-summed (table entries, fallback pricings)): the
/// merged percentiles are recomputed from the pooled per-request
/// samples, and `served_s` is the fleet clock (max over lane clocks —
/// boards run in parallel).  Sampling is greedy so token streams are
/// comparable across shard counts (the sim backend derives logits from
/// the sequence alone, so a request generates the same tokens
/// whichever lane serves it).  One definition shared by the acceptance
/// tests, the fig15 shard table, serve_e2e and `cli serve --shards`.
pub fn flightllm_serve_sharded(
    target: &Target,
    trace: Vec<crate::workload::Request>,
    spec: &FleetSpec,
) -> (Vec<crate::coordinator::ServeStats>, crate::coordinator::ServeStats, (usize, u64)) {
    let (per_shard, merged, pricing, _) =
        flightllm_serve_sharded_recorded(target, trace, spec, false);
    (per_shard, merged, pricing)
}

/// [`flightllm_serve_sharded`] with an optional per-lane flight
/// recorder (`record`): each lane gets its own bounded event ring, the
/// backend's cost-table stats land on each ring after the drain, and
/// the per-lane `EventLog`s come back ordered by lane index (empty
/// when `record` is off).  Recording only READS engine state, so
/// stats and token streams are bit-identical either way (asserted in
/// the sharded acceptance test).
pub fn flightllm_serve_sharded_recorded(
    target: &Target,
    trace: Vec<crate::workload::Request>,
    spec: &FleetSpec,
    record: bool,
) -> (
    Vec<crate::coordinator::ServeStats>,
    crate::coordinator::ServeStats,
    (usize, u64),
    Vec<crate::obs::EventLog>,
) {
    use crate::coordinator::{Sampler, SchedulerConfig, ShardedService, SimBackend};

    let shards = spec.shards.max(1);
    let cfg = SchedulerConfig {
        max_batch: spec.max_batch.max(1),
        // The fleet config carries the TOTAL budget; ShardedService
        // splits it back to kv_pages_per_shard per board.
        kv_pages: spec.kv_pages_per_shard.max(1) * shards,
        page_tokens: SERVE_PAGE_TOKENS,
        max_seq: target.model.max_seq as usize,
        prefix_cache: spec.prefix_cache,
        // Migration moves PARKED requests, so the lanes must be able
        // to park (swap out) in the first place.
        swap: spec.migrate,
        ..Default::default()
    };
    let mut proto = SimBackend::with_vocab(target.clone(), spec.vocab.max(2))
        .with_max_batch(spec.max_batch.max(1) as u32);
    if spec.migrate || spec.global_prefix {
        // Fleet-memory traffic (spill, resume, adoption, migration) is
        // priced at the KV page size over the platform's DDR bandwidth
        // — the same model `serve --swap` uses for one board.
        proto = proto.with_swap_model(SERVE_PAGE_TOKENS, None);
    }
    let mut fleet =
        ShardedService::new(shards, spec.route, cfg, Sampler::greedy(), |_| proto.clone())
            .with_lane_threads(spec.lane_threads.max(1));
    if spec.global_prefix {
        fleet = fleet.with_global_prefix();
    }
    if spec.migrate {
        fleet = fleet.with_migration();
    }
    if spec.affinity_spill > 0 {
        fleet = fleet.with_affinity_spill(spec.affinity_spill);
    }
    if record {
        fleet = fleet.with_recording(crate::obs::Recorder::DEFAULT_CAPACITY);
    }
    let merged = fleet.run_trace(trace).expect("sim serving is infallible");
    let pricing = (0..fleet.shards())
        .map(|i| fleet.backend(i).cost_table_stats())
        .fold((0usize, 0u64), |(e, f), (le, lf)| (e + le, f + lf));
    let logs = if record {
        for i in 0..fleet.shards() {
            if let Some(rec) = fleet.recorder(i) {
                fleet.backend(i).record_cost_model(rec, i as u32, fleet.clock_s());
            }
        }
        fleet.take_event_logs()
    } else {
        Vec::new()
    };
    (fleet.shard_stats(), merged, pricing, logs)
}

/// The hand-built fleet-memory showcase trace behind `cli serve
/// --migrate` and the deterministic acceptance test: on a round-robin
/// fleet of `shards` (≥2) lanes with a small per-lane pool, swap and
/// the fleet directory on, it provably exercises BOTH PR 9 mechanisms.
///
/// - `2 * shards` requests arrive together; round-robin pins ids `0`
///   and `shards` — the two long decodes — to lane 0, whose pool they
///   outgrow mid-decode, so the newer one parks while every other lane
///   drains its short request and sits idle: exactly one cross-shard
///   migration, onto lane 1.
/// - The final pair shares a one-page prefix and arrives far enough
///   apart that each is served alone: round-robin splits the pair over
///   lanes 0 and 1, so lane 1 ADOPTS the page lane 0 materialized
///   instead of re-prefilling it.
pub fn fleet_memory_demo_trace(shards: usize) -> Vec<crate::workload::Request> {
    use crate::workload::Request;
    let shards = shards.max(2) as u64;
    let n = 2 * shards;
    let mut trace: Vec<Request> = (0..n)
        .map(|id| Request {
            id,
            arrival_s: 0.0,
            // Sub-page prompts (distinct mod the demo vocab): nothing
            // here lands in the prefix cache, so pool pressure comes
            // purely from decode growth.
            prompt: ((id as u32 * 8)..(id as u32 * 8 + 8)).map(|t| t % 64).collect(),
            max_new_tokens: if id % shards == 0 { 48 } else { 2 },
        })
        .collect();
    // The shared-prefix pair: gaps far above any virtual serving time,
    // so the first copy is fully served (and its page indexed) before
    // the second arrives.
    for (id, arrival_s) in [(n, 100.0f64), (n + 1, 200.0)] {
        trace.push(Request { id, arrival_s, prompt: (0..20).collect(), max_new_tokens: 2 });
    }
    trace
}

/// Fig. 14's three rungs, normalized against a V100S-opt baseline the
/// way the paper plots them.
pub fn fig14_rungs(target: &Target, pt: EvalPoint) -> Vec<(String, Measurement)> {
    [FlightConfig::Naive, FlightConfig::Sparse, FlightConfig::Full]
        .into_iter()
        .map(|c| (c.label().to_string(), flightllm_measure(target, pt, c)))
        .collect()
}

/// Baseline measurement helper.
pub fn baseline_measure(b: &AnalyticalModel, target: &Target, pt: EvalPoint) -> Measurement {
    b.measure(&target.model, pt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{cta, dfx, fact, GpuStack, GpuSystem};
    use crate::config::Target;

    fn pt() -> EvalPoint {
        EvalPoint { prefill: 128, decode: 128 }
    }

    #[test]
    fn shipped_streams_verify_clean() {
        // Acceptance gate: every stream the experiments can ship — each
        // compiler preset × stage × length bucket — passes the static
        // verifier with zero diagnostics, for both the headline model and
        // the runnable tiny one.
        for t in [Target::u280_llama2(), Target::u280_tiny()] {
            let report = crate::verify::verify_target(&t);
            assert!(report.bucket_diags.is_empty(), "{:?}", report.bucket_diags);
            for s in &report.streams {
                assert!(
                    s.diags.is_empty(),
                    "{} fails verification: {:?}",
                    s.label,
                    &s.diags[..s.diags.len().min(5)]
                );
            }
        }
    }

    #[test]
    fn shipped_streams_analyze_efficient_after_optimization() {
        // Acceptance gate, efficiency tier: every shipped stream's
        // optimizer output is certified equivalent, re-verifies clean
        // and analyzes to zero residual inefficiencies — and the naive
        // preset's redundant activation reloads make the sweep save a
        // strictly positive byte count.
        for t in [Target::u280_llama2(), Target::u280_tiny()] {
            let a = crate::verify::dataflow::analyze_target(&t);
            for s in &a.streams {
                assert!(
                    s.gate_passes(),
                    "{} fails the analyze gate (certified {}, reverify {}, residual {})",
                    s.label,
                    s.certified,
                    s.reverify_clean,
                    s.optimized_cost.findings()
                );
            }
            assert!(a.total_findings() > 0, "{}: pre-opt inefficiencies visible", a.target);
            assert!(a.total_bytes_saved() > 0, "{}: optimizer saves traffic", a.target);
        }
    }

    #[test]
    fn naive_preset_prices_strictly_lower_after_optimization() {
        // The fig15 analyze-table contract: eliminating the naive
        // preset's redundant reloads strictly cuts modeled bytes moved
        // and never slows the step; the full preset is untouched.
        let t = Target::u280_tiny();
        let stage = Stage::Decode { ctx: t.model.max_seq };
        let naive = analyze_stage_pricing(&t, stage, CompilerOptions::naive(), true);
        assert!(naive.certified);
        assert!(naive.insts_after < naive.insts_before);
        assert!(
            naive.bytes_after < naive.bytes_before,
            "bytes {} -> {}",
            naive.bytes_before,
            naive.bytes_after
        );
        assert!(
            naive.ns_after <= naive.ns_before + 1e-9,
            "step time {} -> {}",
            naive.ns_before,
            naive.ns_after
        );
        let full = analyze_stage_pricing(&t, stage, CompilerOptions::full(), true);
        assert!(full.certified);
        assert_eq!(full.insts_after, full.insts_before);
        assert_eq!(full.bytes_after, full.bytes_before);
    }

    #[test]
    fn fig14_rungs_are_monotone() {
        // Each added technique must improve end-to-end latency.
        let rungs = fig14_rungs(&Target::u280_llama2(), pt());
        assert_eq!(rungs.len(), 3);
        assert!(
            rungs[1].1.latency_s < rungs[0].1.latency_s,
            "sparse DSP chain must help: {} vs {}",
            rungs[1].1.latency_s,
            rungs[0].1.latency_s
        );
        assert!(
            rungs[2].1.latency_s < rungs[1].1.latency_s,
            "always-on-chip decode must help further"
        );
    }

    #[test]
    fn fig14_total_gain_in_paper_band() {
        // Paper: naive → full is 1.6-1.7× on U280.
        let rungs = fig14_rungs(&Target::u280_llama2(), pt());
        let gain = rungs[0].1.latency_s / rungs[2].1.latency_s;
        assert!(
            gain > 1.3 && gain < 4.0,
            "naive→full gain = {gain:.2} (paper: 1.6-1.7×)"
        );
    }

    #[test]
    fn flightllm_u280_beats_v100s_opt_and_dfx() {
        // Fig. 11 + Fig. 12 headline orderings at [128, 128].
        let t = Target::u280_llama2();
        let fl = flightllm_full(&t, pt());
        let v100 = GpuSystem::v100s(GpuStack::Opt).model().measure(&t.model, pt());
        assert!(
            fl.latency_s < v100.latency_s,
            "FlightLLM {:.3}s must beat V100S-opt {:.3}s",
            fl.latency_s,
            v100.latency_s
        );
        let d = dfx().measure(&t.model, pt());
        let speedup = d.latency_s / fl.latency_s;
        // Paper geomean is 2.7×; a pure traffic roofline (4.6× fewer
        // bytes × higher utilization) puts the physics-consistent value
        // higher — see EXPERIMENTS.md fig12 discussion.
        assert!(
            speedup > 2.0 && speedup < 9.0,
            "FlightLLM vs DFX = {speedup:.2}× (paper geomean 2.7×)"
        );
    }

    #[test]
    fn vhk158_beats_u280() {
        let u = flightllm_full(&Target::u280_llama2(), pt());
        let v = flightllm_full(&Target::vhk158_llama2(), pt());
        assert!(v.latency_s < u.latency_s, "VHK158 (819 GB/s) must lead");
    }

    #[test]
    fn energy_efficiency_beats_gpus_by_paper_factor() {
        // Fig. 13: 6.0× over V100S-opt, 4.2× over A100-opt class.
        let t = Target::u280_llama2();
        let fl = flightllm_full(&t, pt());
        let v = GpuSystem::v100s(GpuStack::Opt).model().measure(&t.model, pt());
        let ratio = fl.tokens_per_joule() / v.tokens_per_joule();
        assert!(
            ratio > 3.0 && ratio < 12.0,
            "energy efficiency vs V100S-opt = {ratio:.1}× (paper 5.5-6×)"
        );
    }

    #[test]
    fn bandwidth_utilization_in_paper_band() {
        // Table 5: FlightLLM U280 = 65.9%.
        let t = Target::u280_llama2();
        let m = flightllm_full(&t, EvalPoint { prefill: 128, decode: 512 });
        assert!(
            m.bw_util > 0.5 && m.bw_util < 0.85,
            "U280 decode HBM utilization = {:.1}% (paper 65.9%)",
            m.bw_util * 100.0
        );
    }

    #[test]
    fn fig15_serving_path_tracks_analytic_batching() {
        // The served tokens/s must rise with batch and sit in the same
        // band as the analytic single-stream number (the serving path
        // adds prefill scheduling and bucket drift, nothing more).
        let t = Target::u280_llama2();
        let s1 = flightllm_serve_batch_tps(&t, 256, 8, 1);
        let s8 = flightllm_serve_batch_tps(&t, 256, 8, 8);
        assert_eq!(s1.results.len(), 1);
        assert_eq!(s8.results.len(), 8);
        assert!(s8.decode_tps() > s1.decode_tps(), "batching must amortize");
        let analytic = flightllm_batch_tps(&t, 256, 1);
        let served = s1.decode_tps();
        assert!(
            served > 0.33 * analytic && served < 3.0 * analytic,
            "served {served:.1} tok/s vs analytic {analytic:.1} tok/s"
        );
    }

    /// Acceptance (prefix caching): on a shared-prefix trace the cached
    /// run reports a nonzero hit rate, strictly lower mean TTFT and peak
    /// KV-page usage than the cache-off run of the SAME trace, and
    /// byte-identical generated tokens.
    #[test]
    fn prefix_cache_cuts_ttft_and_kv_pages_token_identically() {
        use crate::workload::SharedPrefixConfig;
        let t = Target::u280_llama2();
        // Near-simultaneous arrivals at batch 4: concurrent sequences
        // overlap, so page sharing shows up in the footprint peak.
        let cfg = SharedPrefixConfig {
            n_groups: 2,
            prefix_len: 96,
            tail_len_choices: vec![8, 16, 24],
            decode_len_choices: vec![8, 16],
            n_requests: 12,
            rate_per_s: 1e3,
            vocab: 512,
            seed: 4,
        };
        let off = flightllm_serve_prefix(&t, &cfg, 4, false);
        let on = flightllm_serve_prefix(&t, &cfg, 4, true);
        assert_eq!(off.results.len(), 12);
        assert_eq!(on.results.len(), 12);
        assert_eq!(off.prefix_hits, 0, "cache off must not hit");
        assert!(on.prefix_hits > 0, "shared prefixes must hit the cache");
        assert!(on.prefix_cached_tokens > 0);
        assert!(
            on.mean_ttft_s() < off.mean_ttft_s(),
            "cached prefill must cut mean TTFT: {} vs {}",
            on.mean_ttft_s(),
            off.mean_ttft_s()
        );
        assert!(
            on.peak_kv_pages < off.peak_kv_pages,
            "page sharing must cut the KV peak: {} vs {}",
            on.peak_kv_pages,
            off.peak_kv_pages
        );
        for a in &off.results {
            let b = on.results.iter().find(|r| r.id == a.id).expect("same ids");
            assert_eq!(a.tokens, b.tokens, "request {} tokens must be identical", a.id);
        }
    }

    /// Acceptance (swap-to-DDR preemption): on an overload trace with a
    /// KV pool sized to force preemption, swap-enabled serving completes
    /// ALL requests with token streams byte-identical to an
    /// over-provisioned-pool run (zero truncations), and pays for it in
    /// served time — strictly above BOTH the big-pool run (spilling is
    /// priced DDR traffic plus serialization) and the swap-disabled
    /// baseline, which "finishes" early only because it truncates
    /// requests outright.
    #[test]
    fn swap_preemption_completes_overload_token_identically() {
        use crate::workload::OverloadConfig;
        let t = Target::u280_tiny();
        let cfg = OverloadConfig {
            n_requests: 6,
            prompt_len: 32,
            decode_len_choices: vec![48, 64, 96],
            // Near-simultaneous arrivals: tiny-model sim steps are
            // µs-scale, so a slow trace would never overlap residents.
            rate_per_s: 1e7,
            vocab: 64,
            seed: 5,
        };
        // 12 pages × 16 tokens: three concurrent residents outgrow the
        // pool mid-decode, but no single request exceeds it alone.
        let (big, swapped, lossy) = flightllm_overload_three_way(&t, &cfg, 3, 64, 12, None);
        assert_eq!(big.results.len(), 6);
        assert_eq!(big.preempted_truncated(), 0, "the big pool never truncates");
        assert_eq!(swapped.results.len(), 6);
        assert_eq!(swapped.preempted_truncated(), 0, "swap must eliminate truncation");
        assert!(swapped.preemptions > 0, "the small pool must have preempted");
        assert!(swapped.swap_time_s > 0.0, "spill traffic is priced on the clock");
        for a in &big.results {
            let b = swapped.results.iter().find(|r| r.id == a.id).expect("same ids");
            assert_eq!(a.tokens, b.tokens, "request {} must resume byte-identically", a.id);
        }
        assert!(
            lossy.preempted_truncated() > 0,
            "the swap-disabled baseline loses requests under the same pool"
        );
        assert!(
            swapped.served_s > big.served_s,
            "spilling must cost time over abundant HBM: {} vs {}",
            swapped.served_s,
            big.served_s
        );
        assert!(
            swapped.served_s > lossy.served_s,
            "completing the truncated work must cost time over dropping it: {} vs {}",
            swapped.served_s,
            lossy.served_s
        );
    }

    /// Regression (truthful overload stats): the overload run's mean
    /// latency must NOT drop below the uncontended run's — KV-truncated
    /// requests used to pollute the aggregates with artificially short
    /// latencies, making the stats look better exactly under overload.
    #[test]
    fn overload_mean_latency_does_not_drop_below_uncontended() {
        use crate::coordinator::{Sampler, SchedulerConfig, Server, SimBackend};
        use crate::workload::generate_burst_trace;
        let t = Target::u280_tiny();
        let run = |kv_pages: usize| {
            let cfg = SchedulerConfig {
                max_batch: 2,
                kv_pages,
                page_tokens: SERVE_PAGE_TOKENS,
                max_seq: 256,
                ..Default::default()
            };
            // Three identical requests: demand 4 pages each (16-token
            // prompt + 48 decode tokens), arriving together at batch 2.
            let trace = generate_burst_trace(3, 16, 48, 64, 7);
            let backend = SimBackend::with_vocab(t.clone(), 64);
            Server::new(backend, cfg, Sampler::greedy())
                .run_trace(trace)
                .expect("sim serving is infallible")
        };
        let uncontended = run(32);
        let overload = run(6); // first resident pair exhausts 6 pages mid-decode
        assert_eq!(uncontended.preempted_truncated(), 0);
        assert_eq!(
            overload.preempted_truncated(),
            2,
            "the concurrent pair truncates; the queued request completes alone"
        );
        let completed: Vec<_> = overload
            .results
            .iter()
            .filter(|r| !r.evicted && !r.cancelled)
            .collect();
        assert_eq!(completed.len(), 1);
        // The OLD aggregate blended the truncated short latencies in —
        // strictly below the truthful number.
        let polluted_mean = overload.results.iter().map(|r| r.latency_s).sum::<f64>()
            / overload.results.len() as f64;
        assert!(
            overload.mean_latency_s() > polluted_mean,
            "excluding truncated runs must raise the mean: {} vs {}",
            overload.mean_latency_s(),
            polluted_mean
        );
        assert!(
            overload.mean_latency_s() >= uncontended.mean_latency_s(),
            "overload must not report better latency than an uncontended run: {} vs {}",
            overload.mean_latency_s(),
            uncontended.mean_latency_s()
        );
    }

    /// Acceptance (chunked prefill): on a mixed burst trace — sim
    /// backend, virtual clock — a budget-sized chunk setting strictly
    /// improves P99 decode inter-token latency over unchunked, while
    /// the served tokens stay byte-identical per request.
    #[test]
    fn chunked_prefill_cuts_p99_itl_token_identically() {
        use crate::workload::MixedBurstConfig;
        let t = Target::u280_tiny();
        let cfg = MixedBurstConfig {
            n_decode_heavy: 3,
            decode_heavy_prompt: 16,
            decode_heavy_tokens: 48,
            n_prefill_heavy: 2,
            prefill_heavy_prompt: 192,
            prefill_heavy_tokens: 4,
            // Land right after the first engine iteration, while every
            // decode-heavy request is still mid-generation.
            prefill_stagger_s: 1e-6,
            vocab: 64,
            seed: 8,
        };
        let sweep = flightllm_serve_chunk_sweep(&t, &cfg, 6, &[0, 32]);
        assert_eq!(sweep.len(), 2);
        let (c0, unchunked) = &sweep[0];
        let (c32, chunked) = &sweep[1];
        assert_eq!((*c0, *c32), (0, 32));
        assert_eq!(unchunked.results.len(), 5);
        assert_eq!(chunked.results.len(), 5);
        for a in &unchunked.results {
            let b = chunked.results.iter().find(|r| r.id == a.id).expect("same ids");
            assert_eq!(a.tokens, b.tokens, "chunking must not change request {}", a.id);
        }
        assert!(!unchunked.itl_s.is_empty() && !chunked.itl_s.is_empty());
        assert!(
            chunked.p99_itl_s() < unchunked.p99_itl_s(),
            "chunked P99 ITL {:.6}s must beat unchunked {:.6}s",
            chunked.p99_itl_s(),
            unchunked.p99_itl_s()
        );
        // Spreading a 192-token prompt over 32-token chunks takes more
        // engine iterations — that is the mechanism, not a side effect.
        assert!(chunked.steps > unchunked.steps);
    }

    /// Acceptance (sharded fleet): on the overload trace, a 2-shard
    /// fleet serves per-request token streams byte-identical to the
    /// single-shard run and strictly improves P99 TTFT — replication
    /// converts queueing delay into parallelism, never into different
    /// output.  The fleet summary comes out of the one shared
    /// `ServeStats` printer, per shard and merged.
    #[test]
    fn sharded_fleet_improves_p99_ttft_token_identically() {
        use crate::coordinator::RoutePolicy;
        use crate::workload::{generate_overload_trace, OverloadConfig};
        let t = Target::u280_tiny();
        let cfg = OverloadConfig {
            n_requests: 12,
            prompt_len: 32,
            decode_len_choices: vec![32, 48],
            rate_per_s: 1e7, // near-simultaneous: the queue is the overload
            vocab: 64,
            seed: 6,
        };
        let run = |shards: usize| {
            let spec = FleetSpec {
                shards,
                route: RoutePolicy::RoundRobin,
                max_batch: 2,
                kv_pages_per_shard: 64,
                prefix_cache: false,
                vocab: 64,
                lane_threads: shards,
                global_prefix: false,
                migrate: false,
                affinity_spill: 0,
            };
            flightllm_serve_sharded(&t, generate_overload_trace(&cfg), &spec)
        };
        let (_, single, _) = run(1);
        let (per_shard, fleet, (entries, fallbacks)) = run(2);
        assert!(entries > 0, "lanes carry dense pricing tables");
        assert_eq!(fallbacks, 0, "a max_batch-sized table never falls back");
        assert_eq!(single.results.len(), 12);
        assert_eq!(fleet.results.len(), 12);
        assert_eq!(per_shard.len(), 2);
        assert!(
            per_shard.iter().all(|s| !s.results.is_empty()),
            "round-robin must use both shards"
        );
        assert_eq!(single.preempted_truncated(), 0);
        assert_eq!(fleet.preempted_truncated(), 0);
        for a in &single.results {
            let b = fleet.results.iter().find(|r| r.id == a.id).expect("same ids");
            assert_eq!(a.tokens, b.tokens, "request {} tokens must not change", a.id);
        }
        assert!(
            fleet.p99_ttft_s() < single.p99_ttft_s(),
            "2 shards must strictly cut P99 TTFT on the overload trace: {} vs {}",
            fleet.p99_ttft_s(),
            single.p99_ttft_s()
        );
        assert!(fleet.served_s < single.served_s, "two boards must drain the queue faster");
        // Per-shard and merged stats speak through the one printer.
        for (i, s) in per_shard.iter().enumerate() {
            assert!(s.summary("virtual").contains("completed"), "shard {i} summary");
        }
        assert!(fleet.summary("virtual").contains("completed 12 requests"));
    }

    /// Acceptance (flight recorder invisibility, overload): the seed-5
    /// swap-preemption trace served with the recorder ON is
    /// bit-identical to the recorder-OFF run — same token streams,
    /// same virtual clock, same swap pricing — and the drained log
    /// carries the overload story: preemptions, swap traffic in both
    /// directions, every request retired, the cost-model stats event.
    #[test]
    fn recorder_is_invisible_on_the_overload_trace() {
        use crate::workload::OverloadConfig;
        let t = Target::u280_tiny();
        let cfg = OverloadConfig {
            n_requests: 6,
            prompt_len: 32,
            decode_len_choices: vec![48, 64, 96],
            rate_per_s: 1e7,
            vocab: 64,
            seed: 5,
        };
        // Same small swap-forcing pool as the swap acceptance test.
        let (off, none) = flightllm_serve_overload_recorded(&t, &cfg, 3, 12, true, None, false);
        let (on, log) = flightllm_serve_overload_recorded(&t, &cfg, 3, 12, true, None, true);
        assert!(none.is_none(), "no recorder, no log");
        let log = log.expect("recording was on");
        for a in &off.results {
            let b = on.results.iter().find(|r| r.id == a.id).expect("same ids");
            assert_eq!(a.tokens, b.tokens, "request {} tokens must not change", a.id);
        }
        assert_eq!(off.served_s.to_bits(), on.served_s.to_bits(), "virtual clock");
        assert_eq!(off.swap_time_s.to_bits(), on.swap_time_s.to_bits(), "swap pricing");
        assert_eq!(off.decode_tps().to_bits(), on.decode_tps().to_bits());
        assert_eq!(off.steps, on.steps);
        assert_eq!(off.preemptions, on.preemptions);
        assert_eq!(log.dropped, 0, "the default ring holds the whole run");
        assert_eq!(log.lane, 0);
        assert_eq!(log.count("submitted"), 6);
        assert_eq!(log.count("retired"), 6, "swap completes everything");
        assert_eq!(log.count("preempted") as u64, on.preemptions, "one event per preemption");
        assert!(log.count("swap_out") > 0, "spill traffic is on the timeline");
        assert!(log.count("swap_in") > 0, "resume traffic is on the timeline");
        assert_eq!(log.count("step") as u64, on.steps, "one event per engine step");
        assert_eq!(log.count("cost_model"), 1);
        assert!(
            log.events.windows(2).all(|w| w[0].t_s <= w[1].t_s),
            "events are stamped in chronological order"
        );
    }

    /// Acceptance (flight recorder invisibility, fleet): the seed-6
    /// 2-shard run with per-lane recorders is bit-identical to the
    /// unrecorded run, and the drained logs come back one per lane
    /// with distinct lane ids, jointly covering all 12 requests.
    #[test]
    fn recorder_is_invisible_on_the_sharded_fleet() {
        use crate::coordinator::RoutePolicy;
        use crate::workload::{generate_overload_trace, OverloadConfig};
        let t = Target::u280_tiny();
        let cfg = OverloadConfig {
            n_requests: 12,
            prompt_len: 32,
            decode_len_choices: vec![32, 48],
            rate_per_s: 1e7,
            vocab: 64,
            seed: 6,
        };
        let spec = FleetSpec {
            shards: 2,
            route: RoutePolicy::RoundRobin,
            max_batch: 2,
            kv_pages_per_shard: 64,
            prefix_cache: false,
            vocab: 64,
            lane_threads: 2,
            global_prefix: false,
            migrate: false,
            affinity_spill: 0,
        };
        let run = |record: bool| {
            flightllm_serve_sharded_recorded(&t, generate_overload_trace(&cfg), &spec, record)
        };
        let (_, off, _, no_logs) = run(false);
        let (_, on, _, logs) = run(true);
        assert!(no_logs.is_empty(), "no recorders, no logs");
        for a in &off.results {
            let b = on.results.iter().find(|r| r.id == a.id).expect("same ids");
            assert_eq!(a.tokens, b.tokens, "request {} tokens must not change", a.id);
        }
        assert_eq!(off.served_s.to_bits(), on.served_s.to_bits(), "fleet clock");
        assert_eq!(off.p99_ttft_s().to_bits(), on.p99_ttft_s().to_bits());
        assert_eq!(off.steps, on.steps);
        assert_eq!(logs.len(), 2, "one event log per lane");
        assert_eq!(logs[0].lane, 0);
        assert_eq!(logs[1].lane, 1);
        let retired: usize = logs.iter().map(|l| l.count("retired")).sum();
        assert_eq!(retired, 12, "the lanes jointly retire every request");
        for log in &logs {
            assert!(log.count("step") > 0, "lane {} recorded steps", log.lane);
            assert_eq!(log.count("cost_model"), 1, "lane {} pricing stats", log.lane);
            assert_eq!(log.dropped, 0);
        }
    }

    /// Acceptance (prefix-affinity routing): on the shared-prefix trace
    /// with per-shard prefix caches, hashing the prompt's first page
    /// keeps each prefix group on one shard — its hit rate is at least
    /// round-robin's, which scatters every group across all the caches.
    #[test]
    fn prefix_affinity_hit_rate_at_least_round_robin() {
        use crate::coordinator::RoutePolicy;
        use crate::workload::SharedPrefixConfig;
        let t = Target::u280_tiny();
        let cfg = SharedPrefixConfig {
            n_groups: 4,
            prefix_len: 64,
            tail_len_choices: vec![8, 16],
            decode_len_choices: vec![4],
            n_requests: 16,
            rate_per_s: 1e3,
            vocab: 64,
            seed: 13,
        };
        let run = |route: RoutePolicy| {
            let spec = FleetSpec {
                shards: 2,
                route,
                max_batch: 2,
                kv_pages_per_shard: 128,
                prefix_cache: true,
                vocab: 64,
                lane_threads: 2,
                global_prefix: false,
                migrate: false,
                affinity_spill: 0,
            };
            flightllm_serve_sharded(&t, crate::workload::generate_shared_prefix_trace(&cfg), &spec)
        };
        let (_, rr, _) = run(RoutePolicy::RoundRobin);
        let (_, affine, _) = run(RoutePolicy::PrefixAffinity);
        assert_eq!(rr.results.len(), 16);
        assert_eq!(affine.results.len(), 16);
        assert!(affine.prefix_hits > 0, "shared prefixes must hit");
        assert!(
            affine.prefix_hit_rate() >= rr.prefix_hit_rate(),
            "affinity {} must be at least round-robin {}",
            affine.prefix_hit_rate(),
            rr.prefix_hit_rate()
        );
        // Consistent group→shard mapping: at most one cold miss per
        // prefix group across the whole fleet.
        assert!(
            affine.prefix_hits >= (cfg.n_requests - cfg.n_groups) as u64,
            "affinity hits {} < {}",
            affine.prefix_hits,
            cfg.n_requests - cfg.n_groups
        );
        // Routing never changes what a request generates.
        for a in &rr.results {
            let b = affine.results.iter().find(|r| r.id == a.id).expect("same ids");
            assert_eq!(a.tokens, b.tokens);
        }
    }

    /// Acceptance (fleet memory, deterministic): the hand-built
    /// showcase trace exercises BOTH PR 9 mechanisms through the real
    /// sharded driver — exactly one parked request is stolen by an
    /// idle lane and completes in full, and exactly one prefix page is
    /// adopted across lanes instead of re-prefilled — with the
    /// inter-board copies priced on the virtual clock and both stories
    /// visible on the per-lane flight-recorder rings.
    #[test]
    fn fleet_memory_demo_migrates_and_adopts_deterministically() {
        use crate::coordinator::RoutePolicy;
        let t = Target::u280_tiny();
        let spec = FleetSpec {
            shards: 4,
            route: RoutePolicy::RoundRobin,
            max_batch: 2,
            // 6 pages per lane: lane 0's two long decodes outgrow it
            // (they need 4 pages each), every other request fits.
            kv_pages_per_shard: 6,
            prefix_cache: true,
            vocab: 64,
            lane_threads: 2,
            global_prefix: true,
            migrate: true,
            affinity_spill: 0,
        };
        let (per_shard, merged, _, logs) =
            flightllm_serve_sharded_recorded(&t, fleet_memory_demo_trace(4), &spec, true);
        assert_eq!(merged.results.len(), 10);
        assert_eq!(merged.preempted_truncated(), 0, "swap + migration complete everything");
        assert!(merged.preemptions > 0, "lane 0 must actually park under its small pool");
        assert_eq!(merged.migrations, 1, "the parked request is stolen exactly once");
        assert!(merged.migrated_pages > 0, "the DDR image has a footprint");
        assert_eq!(merged.prefix_adoptions, 1, "the shared page is adopted, not re-prefilled");
        assert!(merged.transfer_time_s > 0.0, "inter-board copies are priced on the clock");
        // Both transfers land on lane 1: the idle steal target (lowest
        // index among the idle lanes) and round-robin home of id 9.
        assert_eq!(per_shard[1].migrations, 1, "recorded on the RECEIVING lane");
        assert_eq!(per_shard[1].prefix_adoptions, 1, "recorded on the ADOPTING lane");
        assert_eq!(per_shard[0].migrations + per_shard[0].prefix_adoptions, 0);
        // The stolen request resumed on the foreign lane and ran to its
        // full decode budget.
        let stolen = merged.results.iter().find(|r| r.id == 4).expect("id 4 served");
        assert_eq!(stolen.tokens.len(), 48, "the migrated request completes in full");
        assert_eq!(logs.len(), 4, "one event ring per lane");
        let count = |kind: &str| logs.iter().map(|l| l.count(kind)).sum::<usize>();
        assert_eq!(count("migrated"), 1, "the steal is on the timeline");
        assert_eq!(count("prefix_adopted"), 1, "the adoption is on the timeline");
        assert_eq!(count("retired"), 10, "the lanes jointly retire every request");
    }

    /// Acceptance (PR 9 headline): on a skewed shared-prefix overload
    /// trace — one hot system prompt dominating near-simultaneous
    /// arrivals — the fleet-memory stack (affinity spill + global
    /// prefix directory + migration armed) strictly beats
    /// prefix-affinity-alone on P99 TTFT with byte-identical token
    /// streams, and the hot prefix is materialized by prefill on
    /// exactly one lane fleet-wide: the spilled requests' prefixes
    /// travel by adoption, priced as inter-board transfer.
    #[test]
    fn fleet_memory_beats_affinity_alone_on_skewed_prefix_overload() {
        use crate::coordinator::RoutePolicy;
        use crate::workload::{generate_skewed_prefix_trace, SkewedPrefixConfig};
        let t = Target::u280_tiny();
        let cfg = SkewedPrefixConfig {
            n_groups: 2,
            prefix_len: 64, // 4 full pages at SERVE_PAGE_TOKENS
            tail_len_choices: vec![8, 16],
            decode_len_choices: vec![8, 16],
            n_requests: 24,
            hot_percent: 80,
            rate_per_s: 1e7, // near-simultaneous: the hot lane's queue is the overload
            vocab: 64,
            seed: 17,
        };
        // Warm-up shaping: pull ONE hot-group request to t=0 and push
        // the burst a second out, so the hot prefix is materialized
        // (and owned in the directory) before the burst routes —
        // mirroring a deployed fleet, where the system prompt is warm
        // long before any load spike.  The modal first page is found
        // with a first-seen tie-break so the shaping is deterministic.
        let shaped = || {
            let mut trace = generate_skewed_prefix_trace(&cfg);
            let mut pages: Vec<(&[u32], usize)> = Vec::new();
            for r in &trace {
                let page = &r.prompt[..SERVE_PAGE_TOKENS];
                match pages.iter_mut().find(|(p, _)| *p == page) {
                    Some((_, n)) => *n += 1,
                    None => pages.push((page, 1)),
                }
            }
            let hot = pages.iter().max_by_key(|(_, n)| *n).expect("nonempty").0.to_vec();
            let first_hot = trace
                .iter()
                .position(|r| r.prompt[..SERVE_PAGE_TOKENS] == hot[..])
                .expect("the hot group is populated");
            for (i, r) in trace.iter_mut().enumerate() {
                r.arrival_s = if i == first_hot { 0.0 } else { r.arrival_s + 1.0 };
            }
            trace
        };
        let run = |fleet_memory: bool| {
            let spec = FleetSpec {
                shards: 2,
                route: RoutePolicy::PrefixAffinity,
                max_batch: 2,
                kv_pages_per_shard: 128,
                prefix_cache: true,
                vocab: 64,
                lane_threads: 2,
                global_prefix: fleet_memory,
                migrate: fleet_memory,
                affinity_spill: if fleet_memory { 2 } else { 0 },
            };
            flightllm_serve_sharded(&t, shaped(), &spec).1
        };
        let base = run(false);
        let full = run(true);
        assert_eq!(base.results.len(), 24);
        assert_eq!(full.results.len(), 24);
        assert_eq!(base.preempted_truncated(), 0);
        assert_eq!(full.preempted_truncated(), 0);
        // Routing + adoption re-time requests; they never change what a
        // request generates.
        for a in &base.results {
            let b = full.results.iter().find(|r| r.id == a.id).expect("same ids");
            assert_eq!(a.tokens, b.tokens, "request {} tokens must not change", a.id);
        }
        assert!(
            full.p99_ttft_s() < base.p99_ttft_s(),
            "fleet memory must strictly cut P99 TTFT on the hotspot: {} vs {}",
            full.p99_ttft_s(),
            base.p99_ttft_s()
        );
        assert_eq!(base.prefix_adoptions, 0, "affinity alone never adopts");
        assert!(full.prefix_adoptions > 0, "spilled prefixes must travel by adoption");
        assert!(full.transfer_time_s > 0.0, "adoption traffic is priced on the clock");
        // The hot prefix is materialized by prefill on EXACTLY ONE
        // lane fleet-wide: the warm-up is its only cold prefill, and
        // every later hot admission — home or spilled — is a cache
        // hit (spilled ones backed by adopted pages).  The one cold
        // group may at worst prefill once per shard (its burst can
        // split before either copy is indexed), so any hot re-prefill
        // would push the fleet-wide hits below this floor.
        let floor = (cfg.n_requests - 1 - 2 * (cfg.n_groups - 1)) as u64;
        assert!(
            full.prefix_hits >= floor,
            "fleet-wide hits {} < {floor}: the hot prefix was prefilled more than once",
            full.prefix_hits
        );
    }

    #[test]
    fn accelerator_ordering_matches_fig12() {
        let t = Target::u280_opt();
        let p = EvalPoint { prefill: 128, decode: 512 };
        let fl = flightllm_full(&t, p);
        for b in [dfx(), cta(), fact()] {
            let m = b.measure(&t.model, p);
            assert!(
                fl.latency_s < m.latency_s,
                "FlightLLM must lead {}: {:.3} vs {:.3}",
                m.system,
                fl.latency_s,
                m.latency_s
            );
        }
    }
}
