//! # FlightLLM reproduction
//!
//! Efficient LLM inference with a complete mapping flow (FPGA '24),
//! rebuilt as a three-layer rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the mapping flow (IR → optimization →
//!   length-adaptive instruction generation), a static instruction-stream
//!   verifier gating what the simulator runs, a cycle-approximate model
//!   of the FlightLLM accelerator (CSD-chain MPE, SFU, HBM+DDR MMU), GPU
//!   and SOTA-accelerator baselines, and a serving coordinator that
//!   drives real token generation through AOT-compiled XLA executables.
//! - **L2 (python/compile/model.py)** — the compressed transformer in
//!   JAX, lowered once to HLO text artifacts.
//! - **L1 (python/compile/kernels/)** — Pallas kernels for the paper's
//!   compute hot-spots (N:M SpMM, mixed-precision dequant GEMV,
//!   block-sparse attention).
//!
//! See DESIGN.md for the experiment index mapping every paper table and
//! figure to a module + bench target.

pub mod baselines;
pub mod cli;
pub mod compiler;
pub mod experiments;
pub mod config;
pub mod coordinator;
pub mod ir;
pub mod isa;
pub mod metrics;
pub mod obs;
pub mod quant;
/// The PJRT runtime needs the `xla` crate (xla_extension bindings);
/// everything else — simulator, compiler, coordinator with the
/// `SimBackend`, baselines — builds without it.
#[cfg(feature = "xla")]
pub mod runtime;
pub mod sim;
pub mod sparse;
pub mod util;
pub mod verify;
pub mod workload;
