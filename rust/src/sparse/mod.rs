//! Sparsity substrates: the N:M weight format the CSD-chain consumes
//! (§3.2.1) and the 64×64 block-sparse attention masks (§4.2), plus the
//! gradient-proxy importance analysis that assigns per-block N (§6.2.1).

mod block_mask;
mod importance;
mod nm;

pub use block_mask::BlockMask;
pub use importance::{assign_block_n, importance_scores};
pub use nm::{NmMatrix, NmBlockPattern};
