//! Block-sparse attention masks (§4.2): the 64×64-block masks the fused
//! prefill attention consumes.  A `true` block is computed; a `false`
//! block's LD + MM are skipped entirely by the compiler.


#[derive(Debug, Clone)]
pub struct BlockMask {
    /// Blocks per side (sequence_len / block_edge).
    pub nb: usize,
    /// Block edge in tokens (paper: 64).
    pub block: usize,
    /// Row-major keep flags, lower-triangular for causal attention.
    pub keep: Vec<bool>,
}

impl BlockMask {
    /// Dense causal mask: every block at or below the diagonal kept.
    pub fn dense_causal(nb: usize, block: usize) -> Self {
        let mut keep = vec![false; nb * nb];
        for i in 0..nb {
            for j in 0..=i {
                keep[i * nb + j] = true;
            }
        }
        Self { nb, block, keep }
    }

    /// Sliding-window + global-column pattern (BigBird/Longformer style,
    /// the sparse-attention family the paper builds on [4, 9, 53]).
    pub fn sliding_global(nb: usize, block: usize, window: usize, global: usize) -> Self {
        let mut m = Self { nb, block, keep: vec![false; nb * nb] };
        for i in 0..nb {
            let lo = i.saturating_sub(window.saturating_sub(1));
            for j in lo..=i {
                m.set(i, j, true);
            }
            for j in 0..global.min(i + 1) {
                m.set(i, j, true);
            }
        }
        m
    }

    pub fn get(&self, i: usize, j: usize) -> bool {
        self.keep[i * self.nb + j]
    }

    pub fn set(&mut self, i: usize, j: usize, v: bool) {
        self.keep[i * self.nb + j] = v;
    }

    /// Kept blocks.
    pub fn kept_blocks(&self) -> usize {
        self.keep.iter().filter(|&&k| k).count()
    }

    /// Blocks in the full causal lower triangle.
    pub fn causal_blocks(&self) -> usize {
        self.nb * (self.nb + 1) / 2
    }

    /// Density relative to the causal triangle — what scales the SDDMM
    /// compute and score-memory in the simulator.
    pub fn density(&self) -> f64 {
        self.kept_blocks() as f64 / self.causal_blocks() as f64
    }

    /// MACs for the masked QK^T (SDDMM) at head dim `hd`, all `heads`.
    /// Diagonal blocks are half-utilized under the causal constraint.
    pub fn sddmm_macs(&self, hd: u64, heads: u64) -> u64 {
        let b = self.block as u64;
        let mut macs = 0u64;
        for i in 0..self.nb {
            for j in 0..self.nb {
                if self.get(i, j) {
                    let full = b * b * hd;
                    macs += if i == j { full / 2 } else { full };
                }
            }
        }
        macs * heads
    }

    /// Per-row kept-key counts (tokens) — the S·V work distribution.
    pub fn row_kept_tokens(&self) -> Vec<usize> {
        (0..self.nb)
            .map(|i| (0..self.nb).filter(|&j| self.get(i, j)).count() * self.block)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_causal_density_is_one() {
        let m = BlockMask::dense_causal(8, 64);
        assert_eq!(m.kept_blocks(), 36);
        assert!((m.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sliding_window_is_causal() {
        let m = BlockMask::sliding_global(8, 64, 2, 1);
        for i in 0..8 {
            for j in (i + 1)..8 {
                assert!(!m.get(i, j), "upper triangle must be empty");
            }
            assert!(m.get(i, i), "diagonal must be kept");
        }
    }

    #[test]
    fn window_bounds_density() {
        let tight = BlockMask::sliding_global(16, 64, 1, 0);
        let wide = BlockMask::sliding_global(16, 64, 8, 2);
        assert!(tight.density() < wide.density());
        assert!(wide.density() <= 1.0);
    }

    #[test]
    fn sddmm_macs_scale_with_mask() {
        let dense = BlockMask::dense_causal(4, 64);
        let sparse = BlockMask::sliding_global(4, 64, 1, 0);
        assert!(sparse.sddmm_macs(128, 32) < dense.sddmm_macs(128, 32));
        // 1-wide window = diagonal only: 4 half blocks.
        assert_eq!(sparse.sddmm_macs(128, 1), 4 * (64 * 64 * 128 / 2));
    }

    #[test]
    fn row_kept_tokens_monotone_for_dense_causal() {
        let m = BlockMask::dense_causal(4, 64);
        assert_eq!(m.row_kept_tokens(), vec![64, 128, 192, 256]);
    }
}
