//! N:M sparse weight format (§3.2.1).
//!
//! The paper's scheme: the weight matrix is tiled into 16×16 blocks; each
//! block gets an N ∈ {0, 2, 4, 8, 16} (M = 16, N a partial factor of M)
//! assigned by importance analysis, keeping N nonzeros per M-wide group
//! row.  The CSD-chain consumes exactly this: per kept element a value
//! and a log2(M)-bit in-group index (the sparse-MUX select).


/// Per-block N assignment for a matrix tiled into (M×M) blocks.
#[derive(Debug, Clone)]
pub struct NmBlockPattern {
    /// Block rows × block cols.
    pub rows: usize,
    pub cols: usize,
    /// M (group width; paper: 16).
    pub m: u8,
    /// N per block, row-major; each must divide M and be a power of two
    /// or zero.
    pub n: Vec<u8>,
}

impl NmBlockPattern {
    /// Uniform N:M across the whole matrix.
    pub fn uniform(rows: usize, cols: usize, n: u8, m: u8) -> Self {
        assert!(valid_n(n, m), "invalid N={n} for M={m}");
        Self { rows, cols, m, n: vec![n; rows * cols] }
    }

    pub fn n_at(&self, br: usize, bc: usize) -> u8 {
        self.n[br * self.cols + bc]
    }

    /// Mean density N/M over all blocks.
    pub fn density(&self) -> f64 {
        let total: u64 = self.n.iter().map(|&n| n as u64).sum();
        total as f64 / (self.n.len() as f64 * self.m as f64)
    }

    /// Kept nonzeros for an (rows*M) × (cols*M) matrix.
    pub fn nnz(&self) -> u64 {
        // Each block contributes M rows × N kept per row.
        self.n.iter().map(|&n| self.m as u64 * n as u64).sum()
    }
}

/// Valid N for a given M: zero or a power-of-two factor of M (paper §3.2.1:
/// "M is an integer power of 2, and N is the partial factor of M").
pub fn valid_n(n: u8, m: u8) -> bool {
    n == 0 || (n <= m && m % n == 0 && n.is_power_of_two())
}

/// A dense matrix compressed to N:M form — the host-side mirror of what
/// the MMU's index buffer + weight buffer hold.
#[derive(Debug, Clone)]
pub struct NmMatrix {
    /// Logical shape (out, in) of the dense matrix.
    pub out_dim: usize,
    pub in_dim: usize,
    pub m: u8,
    /// Kept values, row-major by (row, group) — variable count per row
    /// when blocks have different N.
    pub vals: Vec<f32>,
    /// In-group index of each kept value (0..M).
    pub idx: Vec<u8>,
    /// Start offset of each row's (vals, idx) run; len = out_dim + 1.
    pub row_ptr: Vec<u32>,
    /// The block pattern that produced this compression.
    pub pattern: NmBlockPattern,
}

impl NmMatrix {
    /// Compress `w` (out × in, row-major) keeping, per M-group, the
    /// largest-|w| N elements where N comes from `pattern`'s block.
    pub fn compress(w: &[f32], out_dim: usize, in_dim: usize, pattern: NmBlockPattern) -> Self {
        let m = pattern.m as usize;
        assert_eq!(w.len(), out_dim * in_dim);
        assert_eq!(out_dim.div_ceil(m), pattern.rows, "block rows mismatch");
        assert_eq!(in_dim.div_ceil(m), pattern.cols, "block cols mismatch");
        let mut vals = Vec::new();
        let mut idx = Vec::new();
        let mut row_ptr = Vec::with_capacity(out_dim + 1);
        row_ptr.push(0u32);
        let groups = in_dim / m;
        let mut order: Vec<usize> = Vec::with_capacity(m);
        for r in 0..out_dim {
            let br = r / m;
            for g in 0..groups {
                let n = pattern.n_at(br, g) as usize;
                let base = r * in_dim + g * m;
                order.clear();
                order.extend(0..m);
                order.sort_by(|&a, &b| {
                    w[base + b].abs().partial_cmp(&w[base + a].abs()).unwrap()
                });
                let mut kept: Vec<usize> = order[..n].to_vec();
                kept.sort_unstable(); // canonical ascending index order
                for &j in &kept {
                    vals.push(w[base + j]);
                    idx.push(j as u8);
                }
            }
            row_ptr.push(vals.len() as u32);
        }
        Self { out_dim, in_dim, m: pattern.m, vals, idx, row_ptr, pattern }
    }

    /// Expand back to dense (out × in, row-major).
    pub fn decompress(&self) -> Vec<f32> {
        let m = self.m as usize;
        let groups = self.in_dim / m;
        let mut w = vec![0f32; self.out_dim * self.in_dim];
        for r in 0..self.out_dim {
            let br = r / m;
            let mut cursor = self.row_ptr[r] as usize;
            for g in 0..groups {
                let n = self.pattern.n_at(br, g) as usize;
                for _ in 0..n {
                    let j = self.idx[cursor] as usize;
                    w[r * self.in_dim + g * m + j] = self.vals[cursor];
                    cursor += 1;
                }
            }
            debug_assert_eq!(cursor, self.row_ptr[r + 1] as usize);
        }
        w
    }

    /// y = W·x (SpMV) — the functional model of the MV-mode MPE.
    pub fn spmv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.in_dim);
        let m = self.m as usize;
        let groups = self.in_dim / m;
        let mut y = vec![0f32; self.out_dim];
        for r in 0..self.out_dim {
            let br = r / m;
            let mut cursor = self.row_ptr[r] as usize;
            let mut acc = 0f32;
            for g in 0..groups {
                let n = self.pattern.n_at(br, g) as usize;
                let base = g * m;
                for _ in 0..n {
                    // The sparse MUX: select x[index] for each kept value.
                    acc += self.vals[cursor] * x[base + self.idx[cursor] as usize];
                    cursor += 1;
                }
            }
            y[r] = acc;
        }
        y
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.out_dim * self.in_dim) as f64
    }

    /// Stored bytes at `value_bits` per value (index costs log2(M) bits).
    pub fn stored_bytes(&self, value_bits: f64) -> f64 {
        let idx_bits = (self.m as f64).log2();
        self.nnz() as f64 * (value_bits + idx_bits) / 8.0
            + self.row_ptr.len() as f64 * 4.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense(out: usize, inp: usize, seed: u64) -> Vec<f32> {
        // Simple deterministic pseudo-random fill.
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..out * inp)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s % 2000) as f32 - 1000.0) / 250.0
            })
            .collect()
    }

    #[test]
    fn valid_n_matches_paper() {
        // M=16 → N ∈ {0, 2, 4, 8, 16} (and 1, a trivial factor).
        for n in [0u8, 1, 2, 4, 8, 16] {
            assert!(valid_n(n, 16), "N={n} should be valid");
        }
        for n in [3u8, 5, 6, 7, 12, 17] {
            assert!(!valid_n(n, 16), "N={n} should be invalid");
        }
    }

    #[test]
    fn compress_decompress_preserves_kept_values() {
        let w = dense(32, 32, 7);
        let p = NmBlockPattern::uniform(2, 2, 4, 16);
        let c = NmMatrix::compress(&w, 32, 32, p);
        assert_eq!(c.nnz(), 32 * 2 * 4); // rows × groups × N
        let d = c.decompress();
        // Every kept value matches the original exactly.
        for (i, (&orig, &dec)) in w.iter().zip(d.iter()).enumerate() {
            if dec != 0.0 {
                assert_eq!(orig, dec, "mismatch at {i}");
            }
        }
    }

    #[test]
    fn compress_keeps_largest_magnitude() {
        let w = dense(16, 16, 3);
        let p = NmBlockPattern::uniform(1, 1, 2, 16);
        let c = NmMatrix::compress(&w, 16, 16, p);
        let d = c.decompress();
        for r in 0..16 {
            let row = &w[r * 16..(r + 1) * 16];
            let kept: Vec<f32> =
                d[r * 16..(r + 1) * 16].iter().copied().filter(|&v| v != 0.0).collect();
            let mut sorted: Vec<f32> = row.iter().map(|v| v.abs()).collect();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let min_kept = kept.iter().map(|v| v.abs()).fold(f32::MAX, f32::min);
            assert!(min_kept >= sorted[1] - 1e-6);
        }
    }

    #[test]
    fn spmv_matches_dense_matvec() {
        let w = dense(32, 48, 11);
        let p = NmBlockPattern::uniform(2, 3, 8, 16);
        let c = NmMatrix::compress(&w, 32, 48, p);
        let wd = c.decompress();
        let x: Vec<f32> = (0..48).map(|i| (i as f32 * 0.3).sin()).collect();
        let y = c.spmv(&x);
        for r in 0..32 {
            let want: f32 =
                (0..48).map(|j| wd[r * 48 + j] * x[j]).sum();
            assert!((y[r] - want).abs() < 1e-4, "row {r}: {} vs {want}", y[r]);
        }
    }

    #[test]
    fn dense_mode_n_equals_m_roundtrips_exactly() {
        let w = dense(16, 16, 5);
        let p = NmBlockPattern::uniform(1, 1, 16, 16);
        let c = NmMatrix::compress(&w, 16, 16, p);
        assert_eq!(c.decompress(), w);
        assert_eq!(c.density(), 1.0);
    }

    #[test]
    fn variable_block_pattern_density() {
        let mut p = NmBlockPattern::uniform(2, 2, 16, 16);
        p.n = vec![16, 8, 4, 0];
        assert!((p.density() - (16.0 + 8.0 + 4.0 + 0.0) / 64.0).abs() < 1e-12);
        let w = dense(32, 32, 9);
        let c = NmMatrix::compress(&w, 32, 32, p);
        // Block (1,1) has N=0: bottom-right 16×16 must be all zero.
        let d = c.decompress();
        for r in 16..32 {
            for j in 16..32 {
                assert_eq!(d[r * 32 + j], 0.0);
            }
        }
    }

    #[test]
    fn stored_bytes_reflect_compression() {
        let w = dense(64, 64, 1);
        let half = NmMatrix::compress(
            &w, 64, 64, NmBlockPattern::uniform(4, 4, 8, 16),
        );
        let full = NmMatrix::compress(
            &w, 64, 64, NmBlockPattern::uniform(4, 4, 16, 16),
        );
        assert!(half.stored_bytes(4.0) < full.stored_bytes(4.0));
    }
}
