//! Gradient-proxy importance analysis (§6.2.1): FlightLLM "uses
//! gradient-based analysis to quantify the importance of each weight and
//! attention value" and assigns per-block N (and per-group bit-width)
//! accordingly.
//!
//! Offline we don't have gradients for the analytical 7B configs, so the
//! importance proxy is |w| · |∇L/∂w|-like saliency supplied by the caller
//! (for the tiny model, python dumps real saliencies; for synthetic
//! studies a magnitude proxy is used).  What matters architecturally is
//! the *budgeted assignment*: given a global density budget, allocate
//! N ∈ {0, 2, 4, 8, 16} per 16×16 block so more important blocks keep
//! more weights.

use super::nm::{valid_n, NmBlockPattern};

/// Per-block importance: mean |saliency| over the block.
pub fn importance_scores(
    saliency: &[f32],
    out_dim: usize,
    in_dim: usize,
    m: usize,
) -> Vec<f64> {
    let rows = out_dim.div_ceil(m);
    let cols = in_dim.div_ceil(m);
    let mut scores = vec![0f64; rows * cols];
    let mut counts = vec![0u32; rows * cols];
    for r in 0..out_dim {
        for c in 0..in_dim {
            let b = (r / m) * cols + (c / m);
            scores[b] += saliency[r * in_dim + c].abs() as f64;
            counts[b] += 1;
        }
    }
    for (s, &n) in scores.iter_mut().zip(&counts) {
        *s /= n.max(1) as f64;
    }
    scores
}

/// Assign per-block N to hit `target_density` on average, greedily giving
/// higher-importance blocks larger N.  Returns a valid `NmBlockPattern`.
pub fn assign_block_n(
    scores: &[f64],
    rows: usize,
    cols: usize,
    m: u8,
    target_density: f64,
) -> NmBlockPattern {
    assert_eq!(scores.len(), rows * cols);
    let levels: Vec<u8> =
        (0..=m).filter(|&n| valid_n(n, m) && n > 0).collect();
    // Start everyone at the lowest level, then spend the remaining budget
    // on the most important blocks, one level-step at a time.
    let total_budget = (target_density * (rows * cols) as f64 * m as f64).round() as i64;
    let mut n_assign = vec![levels[0]; rows * cols];
    let mut spent: i64 = n_assign.iter().map(|&n| n as i64).sum();

    // Blocks sorted by importance, descending.
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());

    // Repeatedly upgrade the most important block that can still step up.
    'outer: loop {
        let mut progressed = false;
        for &b in &order {
            let cur = n_assign[b];
            if let Some(&next) = levels.iter().find(|&&l| l > cur) {
                let cost = next as i64 - cur as i64;
                if spent + cost <= total_budget {
                    n_assign[b] = next;
                    spent += cost;
                    progressed = true;
                    if spent >= total_budget {
                        break 'outer;
                    }
                }
            }
        }
        if !progressed {
            break;
        }
    }
    NmBlockPattern { rows, cols, m, n: n_assign }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scores_reflect_block_magnitude() {
        // 32×32 matrix, m=16 → 2×2 blocks; make block (0,0) loud.
        let mut s = vec![0.1f32; 32 * 32];
        for r in 0..16 {
            for c in 0..16 {
                s[r * 32 + c] = 10.0;
            }
        }
        let sc = importance_scores(&s, 32, 32, 16);
        assert!(sc[0] > sc[1] && sc[0] > sc[2] && sc[0] > sc[3]);
    }

    #[test]
    fn assignment_hits_density_budget() {
        let scores: Vec<f64> = (0..64).map(|i| i as f64).collect();
        let p = assign_block_n(&scores, 8, 8, 16, 0.5);
        let d = p.density();
        assert!((d - 0.5).abs() < 0.1, "density {d}");
        // All assigned N are valid.
        for &n in &p.n {
            assert!(valid_n(n, 16));
        }
    }

    #[test]
    fn important_blocks_get_more() {
        let mut scores = vec![0.0f64; 16];
        scores[3] = 100.0;
        scores[7] = 50.0;
        let p = assign_block_n(&scores, 4, 4, 16, 0.25);
        let max_n = *p.n.iter().max().unwrap();
        assert_eq!(p.n[3], max_n, "most important block must get max N");
        assert!(p.n[7] >= p.n[0]);
    }

    #[test]
    fn full_density_assigns_all_m() {
        let scores = vec![1.0f64; 4];
        let p = assign_block_n(&scores, 2, 2, 16, 1.0);
        assert!(p.n.iter().all(|&n| n == 16));
    }
}
