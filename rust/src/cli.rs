//! Command-line interface (hand-rolled; clap is not vendored).
//!
//! ```text
//! flightllm serve    [--backend runtime|sim] [--artifacts DIR] [--requests N]
//!                    [--batch N] [--temp T] [--model llama2|opt|tiny]
//!                    [--platform u280|vhk158] [--prefix-cache]
//!                    [--prefill-chunk N] [--live] [--rate R]
//!                    [--swap] [--swap-gbps G]
//!                    [--shards N] [--route rr|load|prefix] [--lane-threads N]
//!                    [--migrate] [--trace-out FILE] [--metrics-out FILE]
//! flightllm simulate [--model llama2|opt] [--platform u280|vhk158]
//!                    [--prefill N] [--decode N]
//! flightllm report   [--what storage|resources|efficiency]
//! flightllm verify   [--model llama2|opt|tiny] [--platform u280|vhk158] [--json]
//! flightllm analyze  [--model llama2|opt|tiny] [--platform u280|vhk158] [--json]
//! ```
//!
//! `verify` statically checks every shipped instruction stream (all
//! compiler presets × stage × bucket) against the platform contract —
//! buffer occupancy, address/channel bounds, encoding roundtrip, sync
//! discipline, bucket coverage — and exits nonzero on any diagnostic.
//! With no flags it covers the LLaMA2-on-U280, LLaMA2-on-VHK158 and tiny
//! targets; `--model`/`--platform` narrow it to one.
//!
//! `analyze` runs the `verify::dataflow` efficiency tier over the same
//! stream matrix: per-stream liveness findings (dead loads, redundant
//! reloads, removable SLR barriers) and byte costs, then the certified
//! `compiler::optimize_stream` pass, exiting nonzero unless every
//! optimized stream is certified equivalent, re-verifies clean and
//! analyzes to zero residual inefficiencies.  Both commands take
//! `--json` to emit the report through `util::json` with a stable
//! schema for CI and tooling (see ROADMAP's "Reading an analysis
//! report").
//!
//! `serve --backend sim` needs no artifacts: the trace is served by the
//! continuous-batching engine against the cycle-approximate simulator,
//! reporting the deterministic TTFT/latency/tokens-per-second FlightLLM
//! would deliver on the chosen platform.  `--prefill-chunk N` caps the
//! prompt tokens prefilled per engine iteration (chunked prefill:
//! decodes stop stalling behind long prompts).
//!
//! `serve --backend sim --prefix-cache` switches to a shared-prefix
//! trace (N system prompts × per-request tails) and serves it TWICE —
//! prefix caching off, then on — printing both summaries plus the
//! hit-rate / TTFT / peak-KV deltas, so the CoW paged-KV win is visible
//! from one command.
//!
//! `serve --backend sim --live` replays a Poisson-arrival /
//! log-normal-length trace OPEN-LOOP through the background
//! `LiveService` on the host clock: requests are submitted at their
//! real inter-arrival gaps (`--rate` req/s), stream tokens as the
//! engine produces them, and resolve to per-request results.
//!
//! `serve --backend sim --swap` serves an overload trace THREE ways —
//! over-provisioned pool, small pool with swap-to-DDR preemption, and
//! small pool with legacy truncation — so the §4.4 hybrid-placement
//! trade (priced DDR spill traffic instead of lost requests) is visible
//! from one command.  `--swap-gbps` overrides the DDR bandwidth the
//! spill traffic is priced at.
//!
//! `serve --backend sim --shards N` serves the same trace on ONE board
//! and on an N-shard fleet (each shard its own engine + KV pool —
//! FlightLLM's SLR-symmetric replication), printing each shard's
//! summary, the merged fleet summary (pooled percentiles) and the P99
//! TTFT delta.  `--route` picks the request router: `rr` round-robin,
//! `load` least-loaded (queue depth + live KV pages, the default), or
//! `prefix` prefix-affinity — which switches to a shared-prefix trace
//! with per-shard prefix caches and also prints the round-robin hit
//! rate for comparison.  `--lane-threads N` sets the worker threads the
//! fleet ticks its lanes on (default: one per lane; `1` restores
//! sequential ticking — streams are byte-identical either way).
//!
//! `serve --backend sim --shards N --migrate` arms the PR 9 fleet
//! memory (global prefix directory + cross-shard migration + per-lane
//! swap) and replays the deterministic showcase trace: two long
//! decodes round-robin onto lane 0 and outgrow its small pool, so the
//! parked one is STOLEN by an idle lane and resumes there; a split
//! shared-prefix pair makes lane 1 ADOPT the page lane 0 materialized
//! instead of re-prefilling it.  The merged summary's `fleet memory:`
//! line and the `prefix_adopted`/`migrated` trace markers carry the
//! story.  The showcase pins round-robin routing, batch 2 and a
//! 6-page-per-lane pool; `--requests`/`--batch`/`--route` are ignored.
//!
//! Every sim serve summary ends with the step-pricing line: how many
//! (stage, bucket, batch) cost points the backend's dense table holds
//! and how many pricings missed it (fell back to a lazily-memoised sim
//! run), so out-of-table pricing is visible instead of silently slow.
//!
//! `serve --trace-out FILE` installs the flight recorder and exports
//! the run as a Chrome/Perfetto `trace_events` timeline (open it in
//! ui.perfetto.dev): one track per shard lane with a slice per engine
//! step (prefill/decode/mixed), an async span per request lifetime,
//! and counter tracks for the KV footprint, queue depth and swap
//! traffic.  `--metrics-out FILE` writes the run's
//! `ServeStats::metrics_registry` as Prometheus text exposition.  Both
//! apply to the default and `--shards` sim serve modes.

use crate::baselines::{GpuStack, GpuSystem};
use crate::config::{ModelConfig, Target};
use crate::coordinator::{Sampler, SchedulerConfig, Server, SimBackend};
use crate::experiments::flightllm_full;
use crate::metrics::{format_table, EvalPoint};
use crate::obs::{perfetto_trace, EventLog, Recorder};
use crate::util::Json;
use crate::workload::{generate_trace, TraceConfig};

/// Tiny flag parser: `--key value` pairs after the subcommand.
fn flag<'a>(args: &'a [String], key: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

fn flag_u64(args: &[String], key: &str, default: u64) -> u64 {
    flag(args, key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn flag_f64(args: &[String], key: &str, default: f64) -> f64 {
    flag(args, key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Presence flag (no value): `--prefix-cache`.
fn has_flag(args: &[String], key: &str) -> bool {
    args.iter().any(|a| a == key)
}

/// Write an observability artifact; reports and returns false on IO
/// failure so the caller can exit nonzero.
fn write_text(path: &str, contents: &str) -> bool {
    match std::fs::write(path, contents) {
        Ok(()) => true,
        Err(e) => {
            eprintln!("failed to write {path}: {e}");
            false
        }
    }
}

/// Serialize drained event logs as a pretty-printed Perfetto trace.
fn trace_json(logs: &[EventLog]) -> String {
    perfetto_trace(logs).to_string_pretty() + "\n"
}

const USAGE: &str = "usage: flightllm <serve|simulate|report|verify|analyze> [flags]
  serve    --backend runtime|sim --artifacts DIR --requests N --batch N --temp T
           --model llama2|opt|tiny --platform u280|vhk158 [--prefix-cache]
           [--prefill-chunk N] [--live] [--rate R] [--swap] [--swap-gbps G]
           [--shards N] [--route rr|load|prefix] [--lane-threads N]
           [--migrate] [--trace-out FILE] [--metrics-out FILE]
  simulate --model llama2|opt --platform u280|vhk158 --prefill N --decode N
  report   --what storage|resources|efficiency
  verify   [--model llama2|opt|tiny] [--platform u280|vhk158] [--json]
  analyze  [--model llama2|opt|tiny] [--platform u280|vhk158] [--json]";

pub fn run(args: &[String]) -> i32 {
    match args.get(1).map(|s| s.as_str()) {
        Some("serve") => cmd_serve(&args[2..]),
        Some("simulate") => cmd_simulate(&args[2..]),
        Some("report") => cmd_report(&args[2..]),
        Some("verify") => cmd_verify(&args[2..]),
        Some("analyze") => cmd_analyze(&args[2..]),
        Some("--help") | Some("-h") | None => {
            eprintln!("{USAGE}");
            if args.len() <= 1 {
                2
            } else {
                0
            }
        }
        Some(other) => {
            eprintln!("unknown subcommand {other}\n{USAGE}");
            2
        }
    }
}

fn target_for(args: &[String]) -> Target {
    let model = match flag(args, "--model").unwrap_or("llama2") {
        "opt" => ModelConfig::opt_6_7b(),
        "tiny" => ModelConfig::tiny(),
        _ => ModelConfig::llama2_7b(),
    };
    let base = match flag(args, "--platform").unwrap_or("u280") {
        "vhk158" => Target::vhk158_llama2(),
        _ => Target::u280_llama2(),
    };
    Target { model, ..base }
}

fn sampler_for(args: &[String]) -> Sampler {
    match flag(args, "--temp").and_then(|v| v.parse::<f64>().ok()) {
        Some(t) if t > 0.0 => Sampler::temperature(t, 0),
        _ => Sampler::greedy(),
    }
}

fn cmd_simulate(args: &[String]) -> i32 {
    let t = target_for(args);
    let pt = EvalPoint {
        prefill: flag_u64(args, "--prefill", 128),
        decode: flag_u64(args, "--decode", 128),
    };
    let m = flightllm_full(&t, pt);
    let v100 = GpuSystem::v100s(GpuStack::Opt).model().measure(&t.model, pt);
    let rows = vec![
        vec![m.system.clone(), format!("{:.3}", m.latency_s), format!("{:.1}", m.decode_tps),
             format!("{:.2}", m.tokens_per_joule())],
        vec![v100.system.clone(), format!("{:.3}", v100.latency_s), format!("{:.1}", v100.decode_tps),
             format!("{:.2}", v100.tokens_per_joule())],
    ];
    println!("{}", format_table(
        &format!("{} @ {}", t.model.name, pt.label()),
        &["system", "latency(s)", "tok/s", "tok/J"], &rows));
    0
}

fn cmd_serve(args: &[String]) -> i32 {
    match flag(args, "--backend").unwrap_or("runtime") {
        "sim" => cmd_serve_sim(args),
        "runtime" => cmd_serve_runtime(args),
        other => {
            eprintln!("unknown backend {other} (want runtime|sim)\n{USAGE}");
            2
        }
    }
}

fn cmd_serve_sim(args: &[String]) -> i32 {
    let t = target_for(args);
    let n = flag_u64(args, "--requests", 8) as usize;
    let batch = flag_u64(args, "--batch", 1) as usize;
    let chunk = flag_u64(args, "--prefill-chunk", 0) as usize;
    let max_seq = t.model.max_seq as usize;
    let vocab = (t.model.vocab as u32).min(512);
    let trace_out = flag(args, "--trace-out");
    let metrics_out = flag(args, "--metrics-out");
    let shards = flag_u64(args, "--shards", 1) as usize;
    let migrate = has_flag(args, "--migrate");
    if shards > 1 || migrate || flag(args, "--route").is_some() {
        use crate::coordinator::RoutePolicy;
        let route = match flag(args, "--route") {
            None => RoutePolicy::LeastLoaded,
            Some(s) => match RoutePolicy::parse(s) {
                Some(r) => r,
                None => {
                    eprintln!("unknown route {s} (want rr|load|prefix)\n{USAGE}");
                    return 2;
                }
            },
        };
        if has_flag(args, "--live") || has_flag(args, "--swap") {
            eprintln!("note: --live/--swap are ignored with --shards (fleet demo is offline)");
        }
        if has_flag(args, "--prefix-cache") || flag(args, "--prefill-chunk").is_some() {
            eprintln!(
                "note: --prefix-cache/--prefill-chunk are ignored with --shards \
                 (per-shard caches follow --route prefix; chunking is off)"
            );
        }
        if flag(args, "--temp").is_some() {
            // Greedy sampling is load-bearing: the 1-shard and N-shard
            // runs must generate byte-identical token streams.
            eprintln!("note: --temp is ignored with --shards (comparison is greedy)");
        }
        if migrate && flag(args, "--route").is_some() && route != RoutePolicy::RoundRobin {
            eprintln!("note: --migrate pins round-robin routing (the showcase is built for it)");
        }
        if shards < 2 {
            eprintln!("note: the fleet comparison needs >= 2 shards; using 2");
        }
        // 0 = the default: one worker thread per lane.
        let lane_threads = flag_u64(args, "--lane-threads", 0) as usize;
        let route = if migrate { RoutePolicy::RoundRobin } else { route };
        let fleet = FleetArgs { shards: shards.max(2), route, lane_threads, migrate };
        return cmd_serve_sim_sharded(&t, n, batch, vocab, &fleet, trace_out, metrics_out);
    }
    if trace_out.is_some() || metrics_out.is_some() {
        // The remaining sub-modes run several comparison traces; there
        // is no single run to export, so the flags only apply to the
        // default offline path below and to --shards above.
        if has_flag(args, "--live") || has_flag(args, "--swap") || has_flag(args, "--prefix-cache")
        {
            eprintln!(
                "note: --trace-out/--metrics-out are ignored with --live/--swap/--prefix-cache \
                 (they export the default and --shards serve modes)"
            );
        }
    }
    if has_flag(args, "--live") {
        if has_flag(args, "--swap") {
            eprintln!("note: --swap is ignored with --live (swap demo runs offline)");
        }
        let rate = flag_f64(args, "--rate", 8.0);
        return cmd_serve_sim_live(t, n, batch, vocab, chunk, rate, sampler_for(args));
    }
    if has_flag(args, "--swap") {
        if flag(args, "--temp").is_some() {
            // Greedy sampling is load-bearing: the three runs must
            // consume no shared RNG state for the token-identity check.
            eprintln!("note: --temp is ignored with --swap (comparison is greedy)");
        }
        if has_flag(args, "--prefix-cache") || flag(args, "--prefill-chunk").is_some() {
            eprintln!(
                "note: --prefix-cache/--prefill-chunk are ignored with --swap \
                 (the overload comparison isolates the swap tier)"
            );
        }
        let gbps = flag(args, "--swap-gbps").and_then(|v| v.parse::<f64>().ok());
        return cmd_serve_sim_swap(&t, n, batch, vocab, gbps);
    }
    if has_flag(args, "--prefix-cache") {
        if flag(args, "--temp").is_some() {
            // Greedy sampling is load-bearing here: with a stateful
            // temperature sampler the on/off runs would consume the RNG
            // in different orders and the token-identity check would
            // compare different generations.
            eprintln!("note: --temp is ignored with --prefix-cache (comparison is greedy)");
        }
        return cmd_serve_sim_prefix_cache(&t, n, batch, vocab);
    }
    let trace = generate_trace(&TraceConfig {
        n_requests: n,
        vocab,
        prompt_len_choices: vec![16, 32, 64],
        decode_len_choices: vec![16, 32],
        ..Default::default()
    });
    let name = format!("{} on {}", t.model.name, t.platform.name);
    let sampler = sampler_for(args);
    let mut server = Server::new(
        SimBackend::with_vocab(t, vocab as usize).with_max_batch(batch.max(1) as u32),
        SchedulerConfig {
            max_batch: batch.max(1),
            kv_pages: 512,
            page_tokens: 16,
            max_seq,
            prefill_chunk: chunk,
            ..Default::default()
        },
        sampler,
    );
    if trace_out.is_some() {
        server.set_recorder(Recorder::new());
    }
    match server.run_trace(trace) {
        Ok(stats) => {
            println!("sim-served {name} (virtual accelerator clock):");
            println!("{}", stats.summary("virtual"));
            let (entries, fallbacks) = server.backend().cost_table_stats();
            println!("step pricing: {entries} dense table entries, {fallbacks} fallback pricings");
            let mut code = 0;
            if let Some(path) = trace_out {
                if let Some(rec) = server.recorder() {
                    server.backend().record_cost_model(rec, 0, stats.served_s);
                }
                let logs: Vec<EventLog> = server.take_event_log().into_iter().collect();
                let events: usize = logs.iter().map(|l| l.events.len()).sum();
                if write_text(path, &trace_json(&logs)) {
                    println!("wrote Perfetto trace ({events} events) to {path}");
                } else {
                    code = 1;
                }
            }
            if let Some(path) = metrics_out {
                if write_text(path, &stats.metrics_registry().prometheus_text()) {
                    println!("wrote Prometheus metrics to {path}");
                } else {
                    code = 1;
                }
            }
            code
        }
        Err(e) => {
            eprintln!("serving failed: {e:#}");
            1
        }
    }
}

/// The `--live` mode: spawn the background engine on the HOST clock and
/// replay a Poisson-arrival / log-normal-length trace open-loop —
/// sleeping out the real inter-arrival gaps, streaming each request
/// through its handle — then drain and print the live stats.
fn cmd_serve_sim_live(
    t: Target,
    n: usize,
    batch: usize,
    vocab: u32,
    chunk: usize,
    rate: f64,
    sampler: Sampler,
) -> i32 {
    use crate::coordinator::LiveService;
    use crate::workload::LogNormalLen;

    let max_seq = t.model.max_seq as usize;
    let rate = if rate > 0.0 { rate } else { 8.0 };
    let trace = generate_trace(&TraceConfig {
        n_requests: n.max(1),
        vocab,
        rate_per_s: rate,
        prompt_lognormal: Some(LogNormalLen {
            median: 48.0,
            sigma: 0.6,
            cap: max_seq.min(256) as u32,
        }),
        decode_lognormal: Some(LogNormalLen { median: 24.0, sigma: 0.5, cap: 64 }),
        ..Default::default()
    });
    println!(
        "live-serving {} open-loop requests ({rate} req/s Poisson, log-normal lengths, \
         batch {}, prefill chunk {chunk}) on {} {} (host clock):",
        trace.len(),
        batch.max(1),
        t.model.name,
        t.platform.name
    );
    let svc = LiveService::spawn(
        SimBackend::with_vocab(t, vocab as usize),
        SchedulerConfig {
            max_batch: batch.max(1),
            kv_pages: 512,
            page_tokens: 16,
            max_seq,
            prefill_chunk: chunk,
            ..Default::default()
        },
        sampler,
    );
    let t0 = std::time::Instant::now();
    let mut handles = Vec::with_capacity(trace.len());
    for r in trace {
        let dt = r.arrival_s - t0.elapsed().as_secs_f64();
        if dt > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(dt));
        }
        handles.push(svc.submit(r.prompt, r.max_new_tokens));
    }
    for h in handles {
        let id = h.id();
        match h.wait() {
            Some(r) => println!(
                "  req {:>2}: {:>3}-token prompt -> {:>2} tokens, ttft {:>7.1} ms, \
                 latency {:>7.1} ms",
                id,
                r.prompt_len,
                r.tokens.len(),
                r.ttft_s * 1e3,
                r.latency_s * 1e3
            ),
            None => println!("  req {id:>2}: not served (rejected, or the engine stopped)"),
        }
    }
    let stats = svc.shutdown();
    println!("{}", stats.summary("live"));
    0
}

/// The `--swap` mode: one overload trace served three ways — an
/// over-provisioned pool (no contention), a small pool with
/// swap-to-DDR preemption (everything completes, spill is priced), and
/// the same small pool with legacy truncation (requests lost).
fn cmd_serve_sim_swap(t: &Target, n: usize, batch: usize, vocab: u32, gbps: Option<f64>) -> i32 {
    use crate::experiments::{flightllm_overload_three_way, SERVE_PAGE_TOKENS};
    use crate::workload::OverloadConfig;

    let batch = batch.max(2); // preemption needs concurrent residents
    let cfg = OverloadConfig { n_requests: n.max(4), vocab, ..Default::default() };
    // Per-request worst case: prompt + largest decode budget, in KV
    // pages; 1.5 requests' worth of pool forces preemption.
    let max_decode = cfg.decode_len_choices.iter().copied().max().unwrap_or(64) as usize;
    let per_seq = (cfg.prompt_len + max_decode).div_ceil(SERVE_PAGE_TOKENS);
    let small = (per_seq * 3).div_ceil(2);
    println!(
        "sim-serving an overload trace ({} requests, batch {batch}, {}-token prompts, \
         decode budgets {:?}) on {} {}:",
        cfg.n_requests,
        cfg.prompt_len,
        cfg.decode_len_choices,
        t.model.name,
        t.platform.name
    );
    let (big, swapped, lossy) =
        flightllm_overload_three_way(t, &cfg, batch, per_seq * batch, small, gbps);
    println!("-- over-provisioned pool ({} pages) --", per_seq * batch);
    println!("{}", big.summary("virtual"));
    println!("-- small pool ({small} pages), swap-to-DDR ON --");
    println!("{}", swapped.summary("virtual"));
    println!("-- small pool ({small} pages), swap OFF (legacy truncation) --");
    println!("{}", lossy.summary("virtual"));
    println!(
        "swap trade: truncations {} -> {} with {} preemptions, served {:.3}s -> {:.3}s \
         ({:.1} ms spilling over DDR)",
        lossy.preempted_truncated(),
        swapped.preempted_truncated(),
        swapped.preemptions,
        lossy.served_s,
        swapped.served_s,
        swapped.swap_time_s * 1e3
    );
    0
}

/// Fleet geometry flags for the `--shards` mode, bundled.
#[derive(Clone, Copy)]
struct FleetArgs {
    shards: usize,
    route: crate::coordinator::RoutePolicy,
    lane_threads: usize,
    /// `--migrate`: arm the fleet memory (directory + migration) and
    /// replay the deterministic showcase trace.
    migrate: bool,
}

/// The `--shards` mode: the same trace served on one board and on an
/// N-shard fleet with the chosen routing policy — per-shard and merged
/// summaries through the one `ServeStats` printer, plus the P99 TTFT
/// delta the replication buys.  `--route prefix` switches to a
/// shared-prefix trace with per-shard prefix caches and adds the
/// round-robin hit rate for comparison.  `--trace-out` records the
/// N-shard run (one Perfetto track per lane); `--metrics-out` exports
/// the merged fleet stats.
fn cmd_serve_sim_sharded(
    t: &Target,
    n: usize,
    batch: usize,
    vocab: u32,
    fleet_args: &FleetArgs,
    trace_out: Option<&str>,
    metrics_out: Option<&str>,
) -> i32 {
    use crate::coordinator::RoutePolicy;
    use crate::experiments::{fleet_memory_demo_trace, flightllm_serve_sharded_recorded, FleetSpec};
    use crate::workload::{
        generate_overload_trace, generate_shared_prefix_trace, OverloadConfig, SharedPrefixConfig,
    };

    let FleetArgs { shards, route, lane_threads, migrate } = *fleet_args;
    let prefix_route = route == RoutePolicy::PrefixAffinity;
    let trace = if migrate {
        let trace = fleet_memory_demo_trace(shards);
        println!(
            "sim-serving the fleet-memory showcase ({} requests: co-located long decodes \
             force a steal, a split shared prefix forces an adoption) on 1 board vs \
             {shards} shards, {} {}:",
            trace.len(),
            t.model.name,
            t.platform.name
        );
        trace
    } else if prefix_route {
        let cfg = SharedPrefixConfig {
            n_requests: n.max(8),
            vocab,
            rate_per_s: 1e3,
            ..Default::default()
        };
        println!(
            "sim-serving a shared-prefix trace ({} groups x {}-token prefixes, {} requests) \
             on 1 board vs {shards} shards ({} routing), {} {}:",
            cfg.n_groups,
            cfg.prefix_len,
            cfg.n_requests,
            route.label(),
            t.model.name,
            t.platform.name
        );
        generate_shared_prefix_trace(&cfg)
    } else {
        let cfg = OverloadConfig { n_requests: n.max(8), vocab, ..Default::default() };
        println!(
            "sim-serving an overload burst ({} requests, batch {batch}/board) on 1 board vs \
             {shards} shards ({} routing), {} {}:",
            cfg.n_requests,
            route.label(),
            t.model.name,
            t.platform.name
        );
        generate_overload_trace(&cfg)
    };
    let run = |shards: usize, route: RoutePolicy, record: bool| {
        let spec = FleetSpec {
            shards,
            route,
            // The showcase pins batch 2 and a 6-page pool: lane 0's
            // long decodes must outgrow it so the steal is certain.
            max_batch: if migrate { 2 } else { batch.max(1) },
            kv_pages_per_shard: if migrate { 6 } else { 256 },
            prefix_cache: prefix_route || migrate,
            vocab: vocab as usize,
            // 0 = default: one worker per lane.
            lane_threads: if lane_threads == 0 { shards } else { lane_threads },
            global_prefix: migrate,
            migrate,
            affinity_spill: 0,
        };
        flightllm_serve_sharded_recorded(t, trace.clone(), &spec, record)
    };
    let (_, single, _, _) = run(1, route, false);
    println!("-- 1 board --");
    println!("{}", single.summary("virtual"));
    let (per_shard, fleet, (entries, fallbacks), logs) = run(shards, route, trace_out.is_some());
    for (i, s) in per_shard.iter().enumerate() {
        println!("-- shard {i}/{shards} --");
        println!("{}", s.summary("virtual"));
    }
    println!("-- fleet merged ({shards} shards, {} routing) --", route.label());
    println!("{}", fleet.summary("virtual"));
    println!("step pricing: {entries} dense table entries, {fallbacks} fallback pricings");
    println!(
        "fleet trade: P99 TTFT {:.1} -> {:.1} ms, served {:.3}s -> {:.3}s on {shards} boards",
        single.p99_ttft_s() * 1e3,
        fleet.p99_ttft_s() * 1e3,
        single.served_s,
        fleet.served_s
    );
    if migrate {
        println!(
            "fleet memory: {} prefix adoptions, {} migrations, {} pages over the \
             inter-board link ({:.2} ms of transfer)",
            fleet.prefix_adoptions,
            fleet.migrations,
            fleet.migrated_pages,
            fleet.transfer_time_s * 1e3
        );
    }
    if prefix_route {
        let (_, rr, _, _) = run(shards, RoutePolicy::RoundRobin, false);
        println!(
            "prefix affinity: {:.0}% hit rate vs {:.0}% under round-robin",
            fleet.prefix_hit_rate() * 100.0,
            rr.prefix_hit_rate() * 100.0
        );
    }
    let mut code = 0;
    if let Some(path) = trace_out {
        let events: usize = logs.iter().map(|l| l.events.len()).sum();
        if write_text(path, &trace_json(&logs)) {
            println!("wrote Perfetto trace ({} lanes, {events} events) to {path}", logs.len());
        } else {
            code = 1;
        }
    }
    if let Some(path) = metrics_out {
        if write_text(path, &fleet.metrics_registry().prometheus_text()) {
            println!("wrote Prometheus metrics to {path}");
        } else {
            code = 1;
        }
    }
    code
}

/// The `--prefix-cache` mode: one shared-prefix trace, served twice
/// (cache off, then on) so the deltas are a controlled comparison.
fn cmd_serve_sim_prefix_cache(t: &Target, n: usize, batch: usize, vocab: u32) -> i32 {
    use crate::experiments::flightllm_serve_prefix;
    use crate::workload::SharedPrefixConfig;

    let cfg = SharedPrefixConfig {
        n_requests: n.max(2),
        vocab,
        rate_per_s: 32.0,
        ..Default::default()
    };
    let name = format!("{} on {}", t.model.name, t.platform.name);
    println!(
        "sim-serving a shared-prefix trace ({} groups x {}-token prefixes, \
         {} requests, batch {}) on {name}:",
        cfg.n_groups,
        cfg.prefix_len,
        cfg.n_requests,
        batch.max(1)
    );
    let off = flightllm_serve_prefix(t, &cfg, batch, false);
    let on = flightllm_serve_prefix(t, &cfg, batch, true);
    println!("-- prefix cache OFF --");
    println!("{}", off.summary("virtual"));
    println!("-- prefix cache ON --");
    println!("{}", on.summary("virtual"));
    println!(
        "prefix caching: {:.0}% hit rate, mean TTFT {:.1} -> {:.1} ms, \
         peak KV {} -> {} pages",
        on.prefix_hit_rate() * 100.0,
        off.mean_ttft_s() * 1e3,
        on.mean_ttft_s() * 1e3,
        off.peak_kv_pages,
        on.peak_kv_pages
    );
    0
}

#[cfg(feature = "xla")]
fn cmd_serve_runtime(args: &[String]) -> i32 {
    use crate::runtime::{ModelRuntime, RuntimeBackend};

    let dir = std::path::PathBuf::from(flag(args, "--artifacts").unwrap_or("artifacts"));
    let rt = match ModelRuntime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("failed to load artifacts: {e:#}");
            return 1;
        }
    };
    let max_seq = rt.manifest.config.max_seq as usize;
    let vocab = rt.vocab() as u32;
    let n = flag_u64(args, "--requests", 8) as usize;
    let batch = flag_u64(args, "--batch", 1) as usize;
    let sampler = sampler_for(args);
    let trace = generate_trace(&TraceConfig {
        n_requests: n,
        vocab,
        prompt_len_choices: vec![16, 32, 64],
        decode_len_choices: vec![16, 32],
        ..Default::default()
    });
    let mut server = Server::new(
        RuntimeBackend::new(rt),
        SchedulerConfig {
            max_batch: batch.max(1),
            kv_pages: 128,
            page_tokens: 16,
            max_seq,
            ..Default::default()
        },
        sampler,
    );
    match server.run_trace(trace) {
        Ok(stats) => {
            println!("{}", stats.summary("measured"));
            println!("host wall time {:.2}s", stats.wall_s);
            0
        }
        Err(e) => {
            eprintln!("serving failed: {e:#}");
            1
        }
    }
}

#[cfg(not(feature = "xla"))]
fn cmd_serve_runtime(_args: &[String]) -> i32 {
    eprintln!(
        "this build has no PJRT runtime (compiled without the `xla` feature) — \
         use `serve --backend sim`, or rebuild with `--features xla`"
    );
    1
}

/// The shipped verification targets, or the one `--model`/`--platform`
/// narrow to.
fn selected_targets(args: &[String]) -> Vec<Target> {
    if flag(args, "--model").is_some() || flag(args, "--platform").is_some() {
        vec![target_for(args)]
    } else {
        vec![Target::u280_llama2(), Target::vhk158_llama2(), Target::u280_tiny()]
    }
}

fn diag_json(d: &crate::verify::Diagnostic) -> Json {
    Json::obj(vec![
        ("index", Json::num(d.index as f64)),
        ("kind", Json::str(format!("{:?}", d.kind))),
        ("detail", Json::str(d.detail.clone())),
    ])
}

/// Stable `verify --json` schema: command/clean at the top, one entry
/// per target with its per-stream diagnostics.
fn verify_report_json(reports: &[crate::verify::TargetReport]) -> Json {
    let clean = reports.iter().all(|r| r.is_clean());
    let targets: Vec<Json> = reports
        .iter()
        .map(|r| {
            let streams: Vec<Json> = r
                .streams
                .iter()
                .map(|s| {
                    Json::obj(vec![
                        ("stream", Json::str(s.label.clone())),
                        ("instructions", Json::num(s.instructions as f64)),
                        ("suppressed", Json::num(s.suppressed as f64)),
                        ("diags", Json::Arr(s.diags.iter().map(diag_json).collect())),
                    ])
                })
                .collect();
            Json::obj(vec![
                ("target", Json::str(r.target.clone())),
                ("clean", Json::Bool(r.is_clean())),
                ("instructions", Json::num(r.total_instructions() as f64)),
                ("bucket_diags", Json::Arr(r.bucket_diags.iter().map(diag_json).collect())),
                ("streams", Json::Arr(streams)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("command", Json::str("verify")),
        ("clean", Json::Bool(clean)),
        ("targets", Json::Arr(targets)),
    ])
}

/// Stable `analyze --json` schema: per-stream pre-opt findings/costs,
/// what the optimizer removed, and the certification/gate state.
fn analyze_report_json(reports: &[crate::verify::dataflow::TargetAnalysis]) -> Json {
    let gate = reports.iter().all(|r| r.gate_passes());
    let targets: Vec<Json> = reports
        .iter()
        .map(|r| {
            let streams: Vec<Json> = r
                .streams
                .iter()
                .map(|s| {
                    Json::obj(vec![
                        ("stream", Json::str(s.label.clone())),
                        ("instructions", Json::num(s.instructions as f64)),
                        ("optimized_instructions", Json::num(s.optimized_instructions as f64)),
                        ("bytes_moved", Json::num(s.cost.offchip_bytes() as f64)),
                        (
                            "optimized_bytes_moved",
                            Json::num(s.optimized_cost.offchip_bytes() as f64),
                        ),
                        ("bytes_saved", Json::num(s.bytes_saved as f64)),
                        ("dead_loads", Json::num(s.cost.dead_loads as f64)),
                        ("redundant_reloads", Json::num(s.cost.redundant_reloads as f64)),
                        ("removable_syncs", Json::num(s.cost.removable_syncs as f64)),
                        ("optimized_findings", Json::num(s.optimized_cost.findings() as f64)),
                        ("certified", Json::Bool(s.certified)),
                        ("reverify_clean", Json::Bool(s.reverify_clean)),
                        ("suppressed", Json::num(s.suppressed as f64)),
                        ("diags", Json::Arr(s.diags.iter().map(diag_json).collect())),
                    ])
                })
                .collect();
            Json::obj(vec![
                ("target", Json::str(r.target.clone())),
                ("gate_passed", Json::Bool(r.gate_passes())),
                ("bytes_moved", Json::num(r.total_bytes_moved() as f64)),
                ("bytes_saved", Json::num(r.total_bytes_saved() as f64)),
                ("findings", Json::num(r.total_findings() as f64)),
                ("streams", Json::Arr(streams)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("command", Json::str("analyze")),
        ("gate_passed", Json::Bool(gate)),
        ("targets", Json::Arr(targets)),
    ])
}

/// Statically verify the shipped instruction streams; exit 1 on any
/// diagnostic (the CI gate).
fn cmd_verify(args: &[String]) -> i32 {
    let reports: Vec<crate::verify::TargetReport> =
        selected_targets(args).iter().map(crate::verify::verify_target).collect();
    let diag_total: usize = reports.iter().map(|r| r.total_diags()).sum();
    if has_flag(args, "--json") {
        println!("{}", verify_report_json(&reports).to_string_pretty());
    } else {
        for report in &reports {
            println!(
                "{}: {} streams, {} instructions — {}",
                report.target,
                report.streams.len(),
                report.total_instructions(),
                if report.is_clean() {
                    "clean".to_string()
                } else {
                    format!("{} diagnostics", report.total_diags())
                }
            );
            for d in &report.bucket_diags {
                println!("  bucket plan: {d}");
            }
            for s in report.streams.iter().filter(|s| !s.diags.is_empty()) {
                for d in s.diags.iter().take(5) {
                    println!("  {}: {d}", s.label);
                }
                if s.diags.len() > 5 {
                    println!("  {}: ... and {} more", s.label, s.diags.len() - 5);
                }
                if s.suppressed > 0 {
                    println!(
                        "  {}: {} further diagnostics suppressed past the per-kind cap",
                        s.label, s.suppressed
                    );
                }
            }
        }
    }
    if diag_total > 0 {
        eprintln!("verification failed with {diag_total} diagnostics");
        1
    } else {
        0
    }
}

/// Run the dataflow/optimizer analysis over the shipped streams; exit 1
/// unless every optimized stream passes the zero-inefficiency gate.
fn cmd_analyze(args: &[String]) -> i32 {
    let reports: Vec<crate::verify::dataflow::TargetAnalysis> =
        selected_targets(args).iter().map(crate::verify::dataflow::analyze_target).collect();
    let gate = reports.iter().all(|r| r.gate_passes());
    if has_flag(args, "--json") {
        println!("{}", analyze_report_json(&reports).to_string_pretty());
    } else {
        for r in &reports {
            println!(
                "{}: {} streams, {} findings pre-opt, {:.3} GB moved, {:.3} MB saved — {}",
                r.target,
                r.streams.len(),
                r.total_findings(),
                r.total_bytes_moved() as f64 / 1e9,
                r.total_bytes_saved() as f64 / 1e6,
                if r.gate_passes() { "gate passed" } else { "GATE FAILED" }
            );
            for s in r.streams.iter().filter(|s| s.cost.findings() > 0 || !s.gate_passes()) {
                println!(
                    "  {}: {} dead / {} redundant / {} removable syncs -> \
                     removed {}+{}+{} ({} B saved), certified {}, reverify {}, residual {}",
                    s.label,
                    s.cost.dead_loads,
                    s.cost.redundant_reloads,
                    s.cost.removable_syncs,
                    s.dead_loads_removed,
                    s.redundant_reloads_removed,
                    s.syncs_removed,
                    s.bytes_saved,
                    s.certified,
                    s.reverify_clean,
                    s.optimized_cost.findings()
                );
            }
        }
    }
    if gate {
        0
    } else {
        eprintln!("analyze gate failed");
        1
    }
}

fn cmd_report(args: &[String]) -> i32 {
    match flag(args, "--what").unwrap_or("efficiency") {
        "storage" => {
            let r = crate::compiler::storage_report(&target_for(args));
            println!("naive     {:>10.3} GB", r.naive_bytes / 1e9);
            println!("bucketed  {:>10.3} GB", r.bucketed_bytes / 1e9);
            println!("shared    {:>10.3} GB", r.shared_bytes / 1e9);
            println!("merged    {:>10.3} GB  ({:.0}× total)", r.merged_bytes / 1e9, r.total_reduction());
            0
        }
        "resources" => {
            let t = target_for(args);
            let r = t.accel.resources();
            let u = t.accel.utilization(&t.platform);
            println!("DSP {} ({:.1}%)  BRAM {} ({:.1}%)  URAM {} ({:.1}%)",
                r.dsp, u.dsp * 100.0, r.bram, u.bram * 100.0, r.uram, u.uram * 100.0);
            println!("LUT {}k ({:.1}%)  FF {}k ({:.1}%)",
                r.lut / 1000, u.lut * 100.0, r.ff / 1000, u.ff * 100.0);
            0
        }
        "efficiency" => {
            let t = target_for(args);
            let pt = EvalPoint { prefill: 128, decode: 512 };
            let m = flightllm_full(&t, pt);
            println!("{}: {:.3}s latency, {:.1} tok/s, {:.2} tok/J, bw {:.1}%",
                m.system, m.latency_s, m.decode_tps, m.tokens_per_joule(), m.bw_util * 100.0);
            0
        }
        other => {
            eprintln!("unknown report {other}");
            2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn usage_on_no_args() {
        assert_eq!(run(&s(&["flightllm"])), 2);
    }

    #[test]
    fn unknown_subcommand_fails() {
        assert_eq!(run(&s(&["flightllm", "frobnicate"])), 2);
    }

    #[test]
    fn simulate_runs() {
        assert_eq!(
            run(&s(&["flightllm", "simulate", "--prefill", "32", "--decode", "32"])),
            0
        );
    }

    #[test]
    fn serve_sim_backend_runs_without_artifacts() {
        assert_eq!(
            run(&s(&[
                "flightllm", "serve", "--backend", "sim", "--model", "tiny",
                "--requests", "3", "--batch", "2",
            ])),
            0
        );
    }

    #[test]
    fn serve_unknown_backend_fails() {
        assert_eq!(run(&s(&["flightllm", "serve", "--backend", "gpu"])), 2);
    }

    #[test]
    fn serve_sim_chunked_prefill_runs() {
        assert_eq!(
            run(&s(&[
                "flightllm", "serve", "--backend", "sim", "--model", "tiny",
                "--requests", "3", "--batch", "2", "--prefill-chunk", "16",
            ])),
            0
        );
    }

    #[test]
    fn serve_sim_live_open_loop_runs() {
        // High rate keeps the open-loop replay's real sleeps tiny.
        assert_eq!(
            run(&s(&[
                "flightllm", "serve", "--backend", "sim", "--model", "tiny",
                "--requests", "3", "--batch", "2", "--live", "--rate", "500",
                "--prefill-chunk", "32",
            ])),
            0
        );
    }

    #[test]
    fn serve_sim_swap_comparison_runs() {
        assert_eq!(
            run(&s(&[
                "flightllm", "serve", "--backend", "sim", "--model", "tiny",
                "--requests", "4", "--batch", "2", "--swap",
            ])),
            0
        );
    }

    #[test]
    fn serve_sim_sharded_fleet_runs() {
        assert_eq!(
            run(&s(&[
                "flightllm", "serve", "--backend", "sim", "--model", "tiny",
                "--requests", "8", "--batch", "2", "--shards", "2",
            ])),
            0
        );
    }

    #[test]
    fn serve_sim_sharded_prefix_route_runs() {
        assert_eq!(
            run(&s(&[
                "flightllm", "serve", "--backend", "sim", "--model", "tiny",
                "--requests", "8", "--batch", "2", "--shards", "2", "--route", "prefix",
            ])),
            0
        );
    }

    #[test]
    fn serve_sim_sharded_lane_threads_runs() {
        // Sequential and parallel lane ticking both serve the fleet
        // comparison (streams are byte-identical; only wall time moves).
        for threads in ["1", "4"] {
            assert_eq!(
                run(&s(&[
                    "flightllm", "serve", "--backend", "sim", "--model", "tiny",
                    "--requests", "8", "--batch", "2", "--shards", "2",
                    "--lane-threads", threads,
                ])),
                0
            );
        }
    }

    #[test]
    fn serve_sim_migrate_showcase_runs() {
        assert_eq!(
            run(&s(&[
                "flightllm", "serve", "--backend", "sim", "--model", "tiny",
                "--shards", "4", "--migrate",
            ])),
            0
        );
    }

    /// `--migrate --trace-out/--metrics-out`: the showcase lands both
    /// fleet-memory stories on the exported artifacts — the Perfetto
    /// trace carries the `prefix_adopted` and `migrated` markers, and
    /// the Prometheus text carries the fleet counters.
    #[test]
    fn serve_sim_migrate_writes_fleet_memory_artifacts() {
        let dir = std::env::temp_dir();
        let trace_path =
            dir.join(format!("flightllm_cli_migrate_trace_{}.json", std::process::id()));
        let metrics_path =
            dir.join(format!("flightllm_cli_migrate_metrics_{}.txt", std::process::id()));
        let trace_arg = trace_path.to_str().unwrap().to_string();
        let metrics_arg = metrics_path.to_str().unwrap().to_string();
        assert_eq!(
            run(&s(&[
                "flightllm", "serve", "--backend", "sim", "--model", "tiny",
                "--shards", "4", "--migrate",
                "--trace-out", &trace_arg, "--metrics-out", &metrics_arg,
            ])),
            0
        );
        let trace = std::fs::read_to_string(&trace_path).unwrap();
        assert!(trace.contains("prefix_adopted"), "the adoption marker is on the timeline");
        assert!(trace.contains("\"migrated\""), "the steal marker is on the timeline");
        let metrics = std::fs::read_to_string(&metrics_path).unwrap();
        assert!(metrics.contains("flightllm_prefix_adoptions_total 1\n"));
        assert!(metrics.contains("flightllm_migrations_total 1\n"));
        let _ = std::fs::remove_file(&trace_path);
        let _ = std::fs::remove_file(&metrics_path);
    }

    #[test]
    fn serve_sim_unknown_route_fails() {
        assert_eq!(
            run(&s(&[
                "flightllm", "serve", "--backend", "sim", "--model", "tiny",
                "--shards", "2", "--route", "sideways",
            ])),
            2
        );
    }

    #[test]
    fn serve_sim_prefix_cache_comparison_runs() {
        assert_eq!(
            run(&s(&[
                "flightllm", "serve", "--backend", "sim", "--model", "tiny",
                "--requests", "6", "--batch", "2", "--prefix-cache",
            ])),
            0
        );
    }

    /// `--trace-out`/`--metrics-out` on the default offline path: the
    /// trace parses back through `util::Json` with a non-empty
    /// `traceEvents` array, and the metrics file is Prometheus text.
    #[test]
    fn serve_sim_writes_trace_and_metrics() {
        let dir = std::env::temp_dir();
        let trace_path = dir.join(format!("flightllm_cli_trace_{}.json", std::process::id()));
        let metrics_path = dir.join(format!("flightllm_cli_metrics_{}.txt", std::process::id()));
        let trace_arg = trace_path.to_str().unwrap().to_string();
        let metrics_arg = metrics_path.to_str().unwrap().to_string();
        assert_eq!(
            run(&s(&[
                "flightllm", "serve", "--backend", "sim", "--model", "tiny",
                "--requests", "3", "--batch", "2",
                "--trace-out", &trace_arg, "--metrics-out", &metrics_arg,
            ])),
            0
        );
        let trace = std::fs::read_to_string(&trace_path).unwrap();
        let json = crate::util::Json::parse(&trace).unwrap();
        let events = json.get("traceEvents").and_then(crate::util::Json::as_arr).unwrap();
        assert!(!events.is_empty(), "the trace must carry events");
        assert!(
            events.iter().all(|e| e.get("ph").and_then(crate::util::Json::as_str).is_some()),
            "every trace event carries a phase"
        );
        let metrics = std::fs::read_to_string(&metrics_path).unwrap();
        assert!(metrics.contains("# TYPE flightllm_requests_completed_total counter"));
        assert!(metrics.contains("flightllm_requests_completed_total 3\n"));
        let _ = std::fs::remove_file(&trace_path);
        let _ = std::fs::remove_file(&metrics_path);
    }

    /// The sharded mode exports too: one Perfetto track per lane.
    #[test]
    fn serve_sim_sharded_writes_trace() {
        let dir = std::env::temp_dir();
        let trace_path =
            dir.join(format!("flightllm_cli_fleet_trace_{}.json", std::process::id()));
        let trace_arg = trace_path.to_str().unwrap().to_string();
        assert_eq!(
            run(&s(&[
                "flightllm", "serve", "--backend", "sim", "--model", "tiny",
                "--requests", "8", "--batch", "2", "--shards", "2",
                "--trace-out", &trace_arg,
            ])),
            0
        );
        let trace = std::fs::read_to_string(&trace_path).unwrap();
        let json = crate::util::Json::parse(&trace).unwrap();
        let lanes = json
            .get("otherData")
            .and_then(|o| o.get("lanes"))
            .and_then(crate::util::Json::as_u64)
            .unwrap();
        assert_eq!(lanes, 2, "one recorded track per fleet lane");
        let _ = std::fs::remove_file(&trace_path);
    }

    #[test]
    fn report_resources_runs() {
        assert_eq!(run(&s(&["flightllm", "report", "--what", "resources"])), 0);
    }

    #[test]
    fn verify_tiny_target_is_clean() {
        assert_eq!(
            run(&s(&["flightllm", "verify", "--model", "tiny"])),
            0,
            "shipped tiny streams must verify clean"
        );
    }

    #[test]
    fn analyze_tiny_target_passes_gate() {
        assert_eq!(
            run(&s(&["flightllm", "analyze", "--model", "tiny"])),
            0,
            "shipped tiny streams must pass the zero-inefficiency gate"
        );
    }

    /// The `--json` schemas round-trip through `util::Json` and carry
    /// the fields the CI python checks scrape.
    #[test]
    fn verify_json_schema_is_stable() {
        let report = crate::verify::verify_target(&Target::u280_tiny());
        let j = verify_report_json(&[report]);
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("command").and_then(Json::as_str), Some("verify"));
        assert_eq!(parsed.get("clean"), Some(&Json::Bool(true)));
        let targets = parsed.get("targets").and_then(Json::as_arr).unwrap();
        assert_eq!(targets.len(), 1);
        let streams = targets[0].get("streams").and_then(Json::as_arr).unwrap();
        assert!(!streams.is_empty());
        for s in streams {
            assert!(s.get("stream").and_then(Json::as_str).is_some());
            assert!(s.get("instructions").and_then(Json::as_u64).is_some());
            assert_eq!(s.get("suppressed").and_then(Json::as_u64), Some(0));
            assert!(s.get("diags").and_then(Json::as_arr).unwrap().is_empty());
        }
    }

    #[test]
    fn analyze_json_schema_is_stable() {
        let report = crate::verify::dataflow::analyze_target(&Target::u280_tiny());
        let j = analyze_report_json(&[report]);
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("command").and_then(Json::as_str), Some("analyze"));
        assert_eq!(parsed.get("gate_passed"), Some(&Json::Bool(true)));
        let targets = parsed.get("targets").and_then(Json::as_arr).unwrap();
        assert!(targets[0].get("bytes_saved").and_then(Json::as_u64).unwrap() > 0);
        let streams = targets[0].get("streams").and_then(Json::as_arr).unwrap();
        assert!(!streams.is_empty());
        for s in streams {
            assert_eq!(s.get("certified"), Some(&Json::Bool(true)));
            assert_eq!(s.get("reverify_clean"), Some(&Json::Bool(true)));
            assert_eq!(s.get("optimized_findings").and_then(Json::as_u64), Some(0));
            let moved = s.get("bytes_moved").and_then(Json::as_u64).unwrap();
            let opt = s.get("optimized_bytes_moved").and_then(Json::as_u64).unwrap();
            let label = s.get("stream").and_then(Json::as_str).unwrap();
            assert!(opt <= moved, "{label}: optimization must not add traffic");
        }
    }

    #[test]
    fn flag_parsing() {
        let a = s(&["--prefill", "64", "--decode", "128"]);
        assert_eq!(flag_u64(&a, "--prefill", 0), 64);
        assert_eq!(flag_u64(&a, "--decode", 0), 128);
        assert_eq!(flag_u64(&a, "--missing", 7), 7);
    }
}
