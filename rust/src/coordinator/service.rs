//! The live serving front-end: clients `submit` requests and get a
//! `RequestHandle` that streams tokens as they are generated, supports
//! mid-flight cancellation (KV pages released immediately), and
//! resolves to a final `RequestResult`.
//!
//! Architecture — one engine loop, three drivers:
//!
//! - [`EngineCore`] is the continuous-batching iteration: plan
//!   (admission + chunked prefill + decode priority), one batched
//!   `ModelBackend::step`, sampling, streaming, retirement.  It is
//!   clock-agnostic: `ClockMode::Virtual` advances by each step's
//!   reported model time, `ClockMode::Real` follows the host clock.
//! - [`Service`] drives the core in virtual-clock mode under MANUAL
//!   `tick`/`drain` control — the deterministic harness the tests (and
//!   `Server::run_trace`) use.
//! - [`LiveService`] spawns the core on a background thread fed by an
//!   mpsc command channel — the open-loop, real-time front-end.
//!
//! Commands flow through one channel in both modes, so cancellation and
//! submission take the identical code path whether the clock is virtual
//! or real.  A dropped `RequestHandle` cancels its request implicitly:
//! the first undeliverable token tells the engine the client is gone.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use crate::obs::{Event, EventLog, Phase, Recorder};
use crate::workload::Request;

use super::sampler::Sampler;
use super::scheduler::{DecodeOutcome, PlanWork, Scheduler, SchedulerConfig, SeqState};
use super::server::{ModelBackend, RequestResult, SeqSlot, SeqWork, ServeStats};

/// What a `RequestHandle` receives while its request is served.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// One generated token (the first arrives when prefill completes).
    Token(u32),
    /// Terminal: the request ran to completion, was evicted, or was
    /// cancelled — see the result's `evicted` / `cancelled` flags.
    Done(RequestResult),
    /// Terminal: the prompt can never fit the KV pool.
    Rejected,
}

/// Client → engine commands (one channel for both clock modes, shared
/// with the multi-shard `fleet` front-end).
pub(crate) enum Command {
    Submit(Request, Sender<StreamEvent>),
    Cancel(u64),
    Shutdown,
}

/// A client's view of one in-flight request.
pub struct RequestHandle {
    id: u64,
    events: Receiver<StreamEvent>,
    commands: Sender<Command>,
}

impl RequestHandle {
    pub(crate) fn new(id: u64, events: Receiver<StreamEvent>, commands: Sender<Command>) -> Self {
        Self { id, events, commands }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// Ask the engine to cancel this request.  Its KV pages are released
    /// as soon as the command is processed; the handle still resolves
    /// (with `cancelled = true`) via [`RequestHandle::wait`].
    pub fn cancel(&self) {
        let _ = self.commands.send(Command::Cancel(self.id));
    }

    /// Non-blocking poll for the next event (virtual-clock mode: call
    /// between `tick`s).
    pub fn try_event(&self) -> Option<StreamEvent> {
        self.events.try_recv().ok()
    }

    /// Blocking receive (live mode).  `None` when the service is gone.
    pub fn recv_event(&self) -> Option<StreamEvent> {
        self.events.recv().ok()
    }

    /// Block until the request resolves, discarding interim tokens (the
    /// result carries them all).  `None` if it was rejected or the
    /// service shut down first.
    pub fn wait(self) -> Option<RequestResult> {
        loop {
            match self.events.recv() {
                Ok(StreamEvent::Done(r)) => return Some(r),
                Ok(StreamEvent::Rejected) => return None,
                Ok(StreamEvent::Token(_)) => {}
                Err(_) => return None,
            }
        }
    }
}

/// How the engine's serving clock advances.
#[derive(Debug, Clone, Copy)]
pub(crate) enum ClockMode {
    /// Deterministic: the clock advances by each step's reported model
    /// time and fast-forwards over idle gaps.
    Virtual,
    /// The clock follows host time elapsed since `t0` (live serving).
    /// EVERY stat is on the host clock in this mode — per-step costs
    /// are measured around `ModelBackend::step`, not taken from the
    /// backend's (possibly virtual) reported time.
    Real { t0: Instant },
}

/// What one engine tick did.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Tick {
    /// Executed one batched backend step.
    Stepped,
    /// Bookkeeping only: retired finished sequences, rejected an
    /// unservable request, or (virtual clock) jumped to the next
    /// arrival.
    Swept,
    /// Real clock only: nothing runnable until the given arrival time.
    Idle(f64),
    /// No waiting and no running requests.
    Drained,
}

/// Why a sequence left the running set for good.  Preemption is NOT a
/// finish: a preempted sequence keeps its streaming state and resumes.
#[derive(Clone, Copy, PartialEq, Eq)]
enum FinishKind {
    Done,
    Evicted,
    Cancelled,
}

/// A parked request packaged for cross-shard migration: the scheduler
/// state plus every per-request table entry the engine keeps (arrival,
/// token timestamps, streaming sink).  Moving ALL of it is what makes a
/// migrated request resume byte-identically — latency aggregates keep
/// the original arrival, and the client's handle keeps streaming from
/// the new lane without noticing the move.
pub(crate) struct ParkedRequest {
    state: SeqState,
    arrival_s: Option<f64>,
    first_token_s: Option<f64>,
    last_token_s: Option<f64>,
    sub: Option<Sender<StreamEvent>>,
}

impl ParkedRequest {
    /// Tokens of KV context the DDR image holds (sizes the transfer).
    pub(crate) fn ctx(&self) -> usize {
        self.state.ctx
    }
}

/// The continuous-batching engine iteration, shared by the offline
/// `Server` and the live `Service`/`LiveService` front-ends.
pub(crate) struct EngineCore<B: ModelBackend> {
    backend: B,
    scheduler: Scheduler,
    sampler: Sampler,
    mode: ClockMode,
    /// Serving-clock seconds (monotone; follows `mode`).
    clock: f64,
    stats: ServeStats,
    arrivals: HashMap<u64, f64>,
    first_token_s: HashMap<u64, f64>,
    last_token_s: HashMap<u64, f64>,
    /// Streaming sinks for requests submitted with a subscriber.
    subs: HashMap<u64, Sender<StreamEvent>>,
    /// Cumulative swap pages (out + in) already priced on the clock.
    swap_pages_charged: u64,
    /// Flight recorder (obs layer).  `None` is the default and costs
    /// nothing; when installed, every emission only READS engine
    /// state, so streams and stats are bit-identical either way.
    recorder: Option<Recorder>,
}

impl<B: ModelBackend> EngineCore<B> {
    pub(crate) fn new(backend: B, scheduler: Scheduler, sampler: Sampler, mode: ClockMode) -> Self {
        Self {
            backend,
            scheduler,
            sampler,
            mode,
            clock: 0.0,
            stats: ServeStats::default(),
            arrivals: HashMap::new(),
            first_token_s: HashMap::new(),
            last_token_s: HashMap::new(),
            subs: HashMap::new(),
            swap_pages_charged: 0,
            recorder: None,
        }
    }

    /// Install (or remove) the flight recorder.
    pub(crate) fn set_recorder(&mut self, rec: Option<Recorder>) {
        self.recorder = rec;
    }

    pub(crate) fn recorder(&self) -> Option<&Recorder> {
        self.recorder.as_ref()
    }

    /// Drain the recorder's ring (chronological, recorder stays
    /// installed).  `None` when no recorder is installed.
    pub(crate) fn take_event_log(&mut self) -> Option<EventLog> {
        self.recorder.as_ref().map(Recorder::drain)
    }

    pub(crate) fn scheduler(&self) -> &Scheduler {
        &self.scheduler
    }

    /// Mutable scheduler access for the fleet layer (prefix-page
    /// adoption installs pages directly into the lane's pool).
    pub(crate) fn scheduler_mut(&mut self) -> &mut Scheduler {
        &mut self.scheduler
    }

    /// The model backend, for inspection (e.g. `SimBackend`
    /// step-pricing table stats in serve summaries).
    pub(crate) fn backend(&self) -> &B {
        &self.backend
    }

    /// The engine's serving-clock seconds so far (virtual mode: the
    /// lane clock the fleet's arrival-gated routing reads).
    pub(crate) fn clock_s(&self) -> f64 {
        self.clock
    }

    fn now(&self) -> f64 {
        match self.mode {
            ClockMode::Virtual => self.clock,
            ClockMode::Real { t0 } => t0.elapsed().as_secs_f64(),
        }
    }

    /// Queue a request, optionally with a streaming subscriber.  A
    /// non-finite arrival (NaN/∞) would bypass the arrival gate anyway
    /// (NaN comparisons are false) and poison every latency aggregate:
    /// pin it to 0.0 — arrived at trace start — so stats stay truthful.
    pub(crate) fn submit(&mut self, mut req: Request, sub: Option<Sender<StreamEvent>>) {
        if !req.arrival_s.is_finite() {
            req.arrival_s = 0.0;
        }
        if let Some(rec) = &self.recorder {
            rec.record(
                req.arrival_s,
                Event::Submitted { id: req.id, prompt_len: req.prompt.len() as u32 },
            );
        }
        self.arrivals.insert(req.id, req.arrival_s);
        if let Some(tx) = sub {
            self.subs.insert(req.id, tx);
        }
        self.scheduler.submit(req);
    }

    /// Cancel a request: a queued one vanishes without ever touching the
    /// pool; one parked in the swap tier leaves the swap registry; a
    /// running one is retired NOW, releasing its KV pages, with whatever
    /// tokens it generated.  Unknown ids are ignored.
    pub(crate) fn cancel(&mut self, seq: u64) {
        if let Some(req) = self.scheduler.cancel_waiting(seq) {
            if let Some(rec) = &self.recorder {
                rec.record(self.clock, Event::Cancelled { id: seq });
            }
            self.stats.cancelled += 1;
            let arrival = self.arrivals.remove(&seq).unwrap_or(req.arrival_s);
            let result = RequestResult {
                id: seq,
                prompt_len: req.prompt.len(),
                tokens: Vec::new(),
                latency_s: (self.clock - arrival).max(0.0),
                ttft_s: 0.0,
                queue_s: 0.0,
                evicted: false,
                cancelled: true,
            };
            self.stats.results.push(result.clone());
            if let Some(tx) = self.subs.remove(&seq) {
                let _ = tx.send(StreamEvent::Done(result));
            }
        } else if let Some(s) = self.scheduler.cancel_preempted(seq) {
            self.finish_state(s, FinishKind::Cancelled);
        } else if self.scheduler.seq(seq).is_some() {
            self.finish(seq, FinishKind::Cancelled);
        }
    }

    /// Deliver an event to a request's subscriber.  `false` means the
    /// client dropped its handle — the engine treats that as a cancel.
    fn emit(&self, seq: u64, ev: StreamEvent) -> bool {
        match self.subs.get(&seq) {
            Some(tx) => tx.send(ev).is_ok(),
            None => true,
        }
    }

    /// Retire a sequence and resolve its result (no-op if already gone).
    fn finish(&mut self, seq: u64, kind: FinishKind) {
        let Some(s) = self.scheduler.retire(seq) else { return };
        self.finish_state(s, kind);
    }

    /// Resolve a sequence already removed from the scheduler (retired,
    /// cancelled out of the swap tier, or terminally unresumable).
    fn finish_state(&mut self, s: SeqState, kind: FinishKind) {
        let seq = s.req.id;
        if let Some(rec) = &self.recorder {
            let ev = match kind {
                FinishKind::Done => Event::Retired { id: seq, tokens: s.generated.len() as u32 },
                FinishKind::Evicted => Event::Evicted { id: seq },
                FinishKind::Cancelled => Event::Cancelled { id: seq },
            };
            rec.record(self.clock, ev);
        }
        self.backend.release(seq);
        if kind == FinishKind::Cancelled {
            self.stats.cancelled += 1;
        }
        let arrival = self.arrivals.remove(&seq).unwrap_or(0.0);
        // Only a request that actually produced a token has a TTFT — a
        // cancel before the first token records 0.0 (and cancelled
        // results are excluded from the ServeStats aggregates anyway).
        let first = self.first_token_s.remove(&seq);
        self.last_token_s.remove(&seq);
        let result = RequestResult {
            id: seq,
            prompt_len: s.req.prompt.len(),
            tokens: s.generated,
            latency_s: self.clock - arrival,
            ttft_s: first.map_or(0.0, |f| f - arrival),
            queue_s: s.admitted_s - arrival,
            evicted: kind == FinishKind::Evicted,
            cancelled: kind == FinishKind::Cancelled,
        };
        self.stats.results.push(result.clone());
        if let Some(tx) = self.subs.remove(&seq) {
            let _ = tx.send(StreamEvent::Done(result));
        }
    }

    /// Price the KV pages moved to/from the DDR swap tier since the last
    /// charge.  On the virtual clock the cost advances the clock (a
    /// swap-in must land back in HBM before the step it precedes; a
    /// swap-out delays whatever runs next).  On the real clock the host
    /// already measures whatever the traffic costs, so only the page
    /// counters move.
    fn charge_swap_traffic(&mut self) {
        // Swap events are derived from the pool's cumulative traffic
        // counters (the recorder keeps its own last-sample memory), so
        // the engine holds no recorder-only state.
        if let Some(rec) = &self.recorder {
            self.scheduler.pool.record_swap_traffic(rec, self.clock);
        }
        let ps = self.scheduler.pool.stats();
        let moved = ps.swapped_out_pages + ps.swapped_in_pages;
        let delta = moved.saturating_sub(self.swap_pages_charged);
        if delta == 0 {
            return;
        }
        self.swap_pages_charged = moved;
        if let ClockMode::Virtual = self.mode {
            let cost = self.backend.swap_cost_s(delta as usize).max(0.0);
            self.clock += cost;
            self.stats.swap_time_s += cost;
        }
    }

    /// Advance the virtual clock to at least `t_s` (no-op on the real
    /// clock, and never moves time backwards).  The fleet calls this on
    /// a migration target with the donor lane's clock: the DDR image
    /// cannot arrive before the donor finished writing it, so resuming
    /// earlier would fabricate latency the hardware cannot deliver.
    pub(crate) fn sync_clock_at_least(&mut self, t_s: f64) {
        if let ClockMode::Virtual = self.mode {
            self.clock = self.clock.max(t_s);
        }
    }

    /// Package a parked (swap-tier) request for migration to another
    /// lane: scheduler state out of the preempted set + swap registry,
    /// plus every per-request engine table entry.  `None` if `seq` is
    /// not parked here.  The home lane keeps the swap-out traffic it
    /// already counted (the write side happened on ITS DDR); the read
    /// side is priced where it happens — on the adopting lane.
    pub(crate) fn export_parked(&mut self, seq: u64) -> Option<ParkedRequest> {
        let state = self.scheduler.take_parked(seq)?;
        Some(ParkedRequest {
            state,
            arrival_s: self.arrivals.remove(&seq),
            first_token_s: self.first_token_s.remove(&seq),
            last_token_s: self.last_token_s.remove(&seq),
            sub: self.subs.remove(&seq),
        })
    }

    /// Install a migrated request on this lane: the inter-board copy of
    /// its DDR image is priced NOW (the clock advances by the transfer
    /// before the sequence can even be considered for resume), then the
    /// state re-enters the swap tier, where the ordinary `swap_in` path
    /// later pays the DDR read like any locally parked sequence.
    pub(crate) fn import_parked(&mut self, parked: ParkedRequest, from_lane: u32) {
        let seq = parked.state.req.id;
        let pages = self.scheduler.pool.pages_for(parked.state.ctx) as u64;
        self.stats.migrations += 1;
        self.stats.migrated_pages += pages;
        let cost = self.backend.swap_cost_s(pages as usize).max(0.0);
        if let ClockMode::Virtual = self.mode {
            self.clock += cost;
        }
        self.stats.transfer_time_s += cost;
        if let Some(rec) = &self.recorder {
            rec.record(
                self.clock,
                Event::Migrated { id: seq, from_lane, to_lane: rec.lane(), pages },
            );
        }
        if let Some(arrival) = parked.arrival_s {
            self.arrivals.insert(seq, arrival);
        }
        if let Some(t) = parked.first_token_s {
            self.first_token_s.insert(seq, t);
        }
        if let Some(t) = parked.last_token_s {
            self.last_token_s.insert(seq, t);
        }
        if let Some(tx) = parked.sub {
            self.subs.insert(seq, tx);
        }
        self.scheduler.inject_parked(parked.state);
    }

    /// Account for `pages` prefix pages this lane just adopted from
    /// another lane's cache (fleet directory hit): the inter-board copy
    /// is priced like swap traffic, and the adoption is recorded so the
    /// trace shows WHY this lane served a prefix it never prefilled.
    pub(crate) fn record_prefix_adoption(&mut self, id: u64, from_lane: u32, pages: u64) {
        self.stats.prefix_adoptions += 1;
        let cost = self.backend.swap_cost_s(pages as usize).max(0.0);
        if let ClockMode::Virtual = self.mode {
            self.clock += cost;
        }
        self.stats.transfer_time_s += cost;
        if let Some(rec) = &self.recorder {
            rec.record(self.clock, Event::PrefixAdopted { id, from_lane, pages });
        }
    }

    /// One engine iteration: plan, step, sample, stream, retire.
    pub(crate) fn tick(&mut self) -> Result<Tick> {
        let now = self.now();
        if now > self.clock {
            self.clock = now;
        }
        let plan = self.scheduler.plan_recorded(self.clock, self.recorder.as_ref());
        // A parked sequence whose next decode step exceeds the ENTIRE
        // pool can never resume: terminal eviction, the one eviction
        // mode that survives with swap enabled.
        for s in self.scheduler.take_unresumable() {
            self.finish_state(s, FinishKind::Evicted);
        }
        // Swap-ins performed during planning are priced before the step
        // runs: the resumed KV must be back in HBM before compute.
        self.charge_swap_traffic();
        // Admission just allocated prompt pages: sample the footprint.
        self.stats.peak_kv_pages = self.stats.peak_kv_pages.max(self.scheduler.pool.used_pages());
        if plan.is_empty() {
            if self.scheduler.is_drained() {
                return Ok(Tick::Drained);
            }
            // Residents that are genuinely finished (done or at the
            // context cap) are retired — and ONLY those.
            let max_seq = self.scheduler.cfg.max_seq;
            let stuck: Vec<u64> = self
                .scheduler
                .running()
                .iter()
                .filter(|s| s.done() || s.context_capped(max_seq))
                .map(|s| s.req.id)
                .collect();
            if !stuck.is_empty() {
                for seq in stuck {
                    self.finish(seq, FinishKind::Done);
                }
                return Ok(Tick::Swept);
            }
            if self.scheduler.running().is_empty() {
                if let Some(t) = self.scheduler.next_arrival_s() {
                    if t > self.clock {
                        match self.mode {
                            ClockMode::Virtual => {
                                // Machine idle: fast-forward to the arrival.
                                self.clock = t;
                                return Ok(Tick::Swept);
                            }
                            ClockMode::Real { .. } => return Ok(Tick::Idle(t)),
                        }
                    }
                    // Arrived, machine empty, still unadmittable: the
                    // prompt can never fit the KV pool.  Reject it
                    // explicitly instead of looping forever.
                    if let Some(req) = self.scheduler.reject_front() {
                        if let Some(rec) = &self.recorder {
                            rec.record(self.clock, Event::Rejected { id: req.id });
                        }
                        self.stats.rejected += 1;
                        self.arrivals.remove(&req.id);
                        if let Some(tx) = self.subs.remove(&req.id) {
                            let _ = tx.send(StreamEvent::Rejected);
                        }
                    }
                    return Ok(Tick::Swept);
                }
            }
            bail!("scheduler stalled: nothing runnable but requests not drained");
        }

        // Build the batched step from the plan.
        let slots: Vec<SeqSlot> = plan
            .iter()
            .map(|item| {
                let s = self.scheduler.seq(item.seq).expect("planned sequence exists");
                let work = match item.work {
                    PlanWork::Decode => SeqWork::Decode {
                        last: *s.generated.last().expect("prefilled seq has a token") as i32,
                        pos: s.ctx as i32,
                    },
                    // The full prompt is copied for EVERY chunk: backends
                    // detect the final chunk by `chunk_end == prompt.len()`
                    // and the recompute-everything PJRT backend needs the
                    // whole prompt there anyway.  O(len²/chunk) bytes per
                    // prompt — accepted; revisit (Arc or an explicit
                    // prompt_len field) if prompts grow past a few K.
                    PlanWork::Prefill { start, end } => SeqWork::Prefill {
                        prompt: s.req.prompt.iter().map(|&t| t as i32).collect(),
                        cached_ctx: s.cached_ctx,
                        chunk_start: start,
                        chunk_end: end,
                    },
                };
                SeqSlot { seq: item.seq, work }
            })
            .collect();

        let step_start = self.clock;
        let step_wall = Instant::now();
        let out = self.backend.step(&slots)?;
        ensure!(
            out.logits.len() == slots.len(),
            "backend returned {} logit rows for a batch of {}",
            out.logits.len(),
            slots.len()
        );
        // Every stat stays on ONE clock: the virtual mode charges the
        // backend's reported model time, the real mode charges measured
        // host time (a simulated backend's virtual seconds would
        // otherwise mix units with the wall-clock TTFT/latency).
        let step_cost_s = match self.mode {
            ClockMode::Virtual => out.step_s.max(0.0),
            ClockMode::Real { .. } => step_wall.elapsed().as_secs_f64(),
        };
        match self.mode {
            ClockMode::Virtual => self.clock += step_cost_s,
            ClockMode::Real { t0 } => self.clock = self.clock.max(t0.elapsed().as_secs_f64()),
        }
        self.stats.steps += 1;
        let n_decode = slots
            .iter()
            .filter(|s| matches!(s.work, SeqWork::Decode { .. }))
            .count() as u64;
        // Pure decode steps sample steady-state throughput; decodes
        // sharing a step with prefill chunks are counted separately (a
        // mixed step's cost is dominated by its prefills), so a
        // chunked-prefill-saturated run still reports its decode rate.
        if n_decode == slots.len() as u64 {
            self.stats.decode_steps += n_decode;
            self.stats.decode_time_s += step_cost_s;
        } else if n_decode > 0 {
            self.stats.mixed_decodes += n_decode;
            self.stats.mixed_time_s += step_cost_s;
        }
        if let Some(rec) = &self.recorder {
            let phase = if n_decode == slots.len() as u64 {
                Phase::Decode
            } else if n_decode == 0 {
                Phase::Prefill
            } else {
                Phase::Mixed
            };
            rec.record(
                step_start,
                Event::Step {
                    lane: rec.lane(),
                    phase,
                    batch: slots.len() as u32,
                    step_s: step_cost_s,
                    kv_pages: self.scheduler.pool.used_pages() as u32,
                    queue_depth: self.scheduler.pending() as u32,
                },
            );
        }

        // Decode appends can park sequences (self-preemption OR a
        // newest-first victim that is not this slot): diff the parked
        // set around the loop so every preemption gets an event.
        let parked_before: Option<Vec<u64>> = self
            .recorder
            .as_ref()
            .map(|_| self.scheduler.preempted().iter().map(|s| s.req.id).collect());
        // Sample each token-yielding slot and stream it; non-final
        // prefill chunks only advance the prefill cursor — their logits
        // row (if a backend supplied one anyway) is never sampled.
        let mut finished: Vec<(u64, FinishKind)> = Vec::new();
        let mut dropped: Vec<u64> = Vec::new();
        for (slot, logits) in slots.iter().zip(&out.logits) {
            if self.scheduler.seq(slot.seq).is_none() {
                // Preempted mid-iteration by an earlier slot's victim
                // selection: its KV did not advance, so the whole slot
                // replays (same tokens) after resume.  Nothing streams.
                continue;
            }
            if slot.work.yields_token() {
                ensure!(
                    logits.is_some(),
                    "backend returned no logits for token-yielding slot {}",
                    slot.seq
                );
            }
            match &slot.work {
                SeqWork::Prefill { chunk_start, chunk_end, .. } if !slot.work.yields_token() => {
                    if let Some(rec) = &self.recorder {
                        rec.record(
                            self.clock,
                            Event::PrefillChunk {
                                id: slot.seq,
                                start: *chunk_start as u32,
                                end: *chunk_end as u32,
                            },
                        );
                    }
                    self.scheduler.on_prefill_chunk(slot.seq, *chunk_end);
                }
                SeqWork::Prefill { .. } => {
                    let tok = self.sampler.sample(logits.as_ref().expect("checked above"));
                    self.scheduler.on_prefill_done(slot.seq, tok);
                    if let Some(rec) = &self.recorder {
                        rec.record(self.clock, Event::FirstToken { id: slot.seq });
                    }
                    self.first_token_s.insert(slot.seq, self.clock);
                    self.last_token_s.insert(slot.seq, self.clock);
                    if !self.emit(slot.seq, StreamEvent::Token(tok)) {
                        dropped.push(slot.seq);
                    }
                }
                SeqWork::Decode { .. } => {
                    let tok = self.sampler.sample(logits.as_ref().expect("checked above"));
                    match self.scheduler.on_decode_done(slot.seq, tok) {
                        DecodeOutcome::Preempted => {
                            // The sequence parked itself in the swap
                            // tier and the token was dropped with it —
                            // the resumed decode re-produces it, so
                            // nothing streams and no ITL is sampled.
                        }
                        outcome => {
                            let prev = self.last_token_s.insert(slot.seq, self.clock);
                            if let Some(prev) = prev {
                                self.stats.record_itl(self.clock - prev);
                            }
                            if outcome == DecodeOutcome::EvictedKvFull {
                                finished.push((slot.seq, FinishKind::Evicted));
                            }
                            if !self.emit(slot.seq, StreamEvent::Token(tok)) {
                                dropped.push(slot.seq);
                            }
                        }
                    }
                }
            }
        }
        if let Some(before) = parked_before {
            let rec = self.recorder.as_ref().expect("recorder set when diff captured");
            for s in self.scheduler.preempted() {
                if !before.contains(&s.req.id) {
                    rec.record(self.clock, Event::Preempted { id: s.req.id });
                }
            }
        }
        // Swap-outs discovered during decode processing are priced after
        // the step: they delay whatever runs next.
        self.charge_swap_traffic();
        // Decode appends may have opened (or CoW-copied) pages.
        self.stats.peak_kv_pages = self.stats.peak_kv_pages.max(self.scheduler.pool.used_pages());
        // Sweep completed sequences (token budget reached, or context
        // cap hit — including prompts that fill the context at prefill).
        let max_seq = self.scheduler.cfg.max_seq;
        finished.extend(
            self.scheduler
                .running()
                .iter()
                .filter(|s| s.done() || s.context_capped(max_seq))
                .map(|s| (s.req.id, FinishKind::Done)),
        );
        for (seq, kind) in finished {
            self.finish(seq, kind);
        }
        // A failed send means the client dropped its handle: treat it
        // as an implicit cancel so the pages come back immediately.
        for seq in dropped {
            self.cancel(seq);
        }
        Ok(Tick::Stepped)
    }

    /// A snapshot of the serving stats so far (prefix + swap counters
    /// and the serving-clock total filled in from live state).
    pub(crate) fn stats_snapshot(&self) -> ServeStats {
        let mut stats = self.stats.clone();
        stats.served_s = self.clock;
        let pool = self.scheduler.pool.stats();
        stats.admissions = pool.admits;
        stats.prefix_hits = pool.prefix_hits;
        stats.prefix_cached_tokens = pool.cached_tokens_served;
        stats.preemptions = pool.swap_outs;
        stats.swapped_out_pages = pool.swapped_out_pages;
        stats.swapped_in_pages = pool.swapped_in_pages;
        stats
    }
}

/// The virtual-clock service: the engine core plus a command channel,
/// driven by MANUAL `tick`/`drain` calls — deterministic streaming and
/// cancellation for tests and offline tools.  Commands (including
/// cancels from handles) are applied at the start of each tick.
pub struct Service<B: ModelBackend> {
    core: EngineCore<B>,
    cmd_tx: Sender<Command>,
    cmd_rx: Receiver<Command>,
}

impl<B: ModelBackend> Service<B> {
    pub fn new(backend: B, cfg: SchedulerConfig, sampler: Sampler) -> Self {
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let core = EngineCore::new(backend, Scheduler::new(cfg), sampler, ClockMode::Virtual);
        Self { core, cmd_tx, cmd_rx }
    }

    /// Submit a request (the caller controls ids and arrival times —
    /// that is what makes virtual-clock runs replayable).
    pub fn submit(&self, req: Request) -> RequestHandle {
        let (etx, erx) = mpsc::channel();
        let id = req.id;
        let _ = self.cmd_tx.send(Command::Submit(req, etx));
        RequestHandle { id, events: erx, commands: self.cmd_tx.clone() }
    }

    fn apply_commands(&mut self) {
        // One dispatcher for both clock modes; Shutdown is meaningless
        // under manual ticking, so the flag it sets goes nowhere here.
        let mut shutdown = false;
        while let Ok(cmd) = self.cmd_rx.try_recv() {
            apply(&mut self.core, cmd, &mut shutdown);
        }
    }

    /// Apply pending commands, then run one engine iteration.
    pub fn tick(&mut self) -> Result<Tick> {
        self.apply_commands();
        self.core.tick()
    }

    /// Tick until every submitted request has resolved.
    pub fn drain(&mut self) -> Result<()> {
        while self.tick()? != Tick::Drained {}
        Ok(())
    }

    /// The scheduler (pool/accounting inspection in tests).
    pub fn scheduler(&self) -> &Scheduler {
        self.core.scheduler()
    }

    pub fn stats(&self) -> ServeStats {
        self.core.stats_snapshot()
    }

    /// Install a flight recorder (replacing any existing one).
    pub fn set_recorder(&mut self, rec: Recorder) {
        self.core.set_recorder(Some(rec));
    }

    /// Drain the recorder's event ring; `None` without a recorder.
    pub fn take_event_log(&mut self) -> Option<EventLog> {
        self.core.take_event_log()
    }
}

/// The real-time front-end: the engine core runs on a background thread
/// fed by the command channel; `submit` stamps arrivals with the host
/// clock (open-loop traffic), handles stream tokens as the engine
/// produces them, and `shutdown` drains in-flight work and returns the
/// final stats.
pub struct LiveService {
    cmd_tx: Sender<Command>,
    next_id: AtomicU64,
    t0: Instant,
    join: Option<thread::JoinHandle<(ServeStats, Option<EventLog>)>>,
}

impl LiveService {
    pub fn spawn<B>(backend: B, cfg: SchedulerConfig, sampler: Sampler) -> Self
    where
        B: ModelBackend + Send + 'static,
    {
        Self::spawn_recorded(backend, cfg, sampler, None)
    }

    /// Spawn with a flight recorder installed on the engine thread;
    /// the (bounded-ring) event log comes back from
    /// [`LiveService::shutdown_with_events`].
    pub fn spawn_recorded<B>(
        backend: B,
        cfg: SchedulerConfig,
        sampler: Sampler,
        recorder: Option<Recorder>,
    ) -> Self
    where
        B: ModelBackend + Send + 'static,
    {
        let (cmd_tx, cmd_rx) = mpsc::channel::<Command>();
        let t0 = Instant::now();
        let join = thread::spawn(move || {
            let mode = ClockMode::Real { t0 };
            let mut core = EngineCore::new(backend, Scheduler::new(cfg), sampler, mode);
            core.set_recorder(recorder);
            let mut shutdown = false;
            loop {
                while let Ok(cmd) = cmd_rx.try_recv() {
                    apply(&mut core, cmd, &mut shutdown);
                }
                match core.tick() {
                    Ok(Tick::Stepped | Tick::Swept) => {}
                    Ok(Tick::Drained) => {
                        if shutdown {
                            break;
                        }
                        // Nothing in flight: block until the next command.
                        match cmd_rx.recv_timeout(Duration::from_millis(2)) {
                            Ok(cmd) => apply(&mut core, cmd, &mut shutdown),
                            Err(RecvTimeoutError::Timeout) => {}
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    }
                    Ok(Tick::Idle(_)) => thread::sleep(Duration::from_micros(200)),
                    // A backend failure or stalled scheduler is fatal for
                    // the engine: report it (outstanding handles resolve
                    // to None) and hand back the stats gathered so far.
                    // The structured event keeps the error in headless
                    // runs where stderr is lost.
                    Err(e) => {
                        if let Some(rec) = core.recorder() {
                            rec.record(
                                core.clock_s(),
                                Event::EngineError { detail: format!("{e:#}") },
                            );
                        }
                        eprintln!("live service engine stopped: {e:#}");
                        break;
                    }
                }
            }
            let events = core.take_event_log();
            (core.stats_snapshot(), events)
        });
        Self { cmd_tx, next_id: AtomicU64::new(0), t0, join: Some(join) }
    }

    /// Submit a prompt; the arrival timestamp is the host clock NOW.
    pub fn submit(&self, prompt: Vec<u32>, max_new_tokens: u32) -> RequestHandle {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id,
            arrival_s: self.t0.elapsed().as_secs_f64(),
            prompt,
            max_new_tokens,
        };
        let (etx, erx) = mpsc::channel();
        let _ = self.cmd_tx.send(Command::Submit(req, etx));
        RequestHandle { id, events: erx, commands: self.cmd_tx.clone() }
    }

    /// Drain in-flight requests, stop the engine thread, and return the
    /// final serving stats.
    pub fn shutdown(mut self) -> ServeStats {
        self.shutdown_inner().map(|(stats, _)| stats).unwrap_or_default()
    }

    /// Like [`LiveService::shutdown`], also returning the drained
    /// flight-recorder log (`None` unless spawned with a recorder).
    pub fn shutdown_with_events(mut self) -> (ServeStats, Option<EventLog>) {
        self.shutdown_inner().unwrap_or_default()
    }

    fn shutdown_inner(&mut self) -> Option<(ServeStats, Option<EventLog>)> {
        let _ = self.cmd_tx.send(Command::Shutdown);
        self.join.take().and_then(|j| j.join().ok())
    }
}

impl Drop for LiveService {
    fn drop(&mut self) {
        let _ = self.shutdown_inner();
    }
}

fn apply<B: ModelBackend>(core: &mut EngineCore<B>, cmd: Command, shutdown: &mut bool) {
    match cmd {
        Command::Submit(req, tx) => core.submit(req, Some(tx)),
        Command::Cancel(id) => core.cancel(id),
        Command::Shutdown => *shutdown = true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testing::EchoBackend;
    use crate::coordinator::Server;
    use crate::workload::{generate_trace, TraceConfig};

    fn req(id: u64, plen: usize, dlen: u32) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            prompt: (0..plen as u32).collect(),
            max_new_tokens: dlen,
        }
    }

    #[test]
    fn virtual_service_streams_tokens_then_done() {
        let mut svc = Service::new(
            EchoBackend::new(32),
            SchedulerConfig { max_seq: 64, ..Default::default() },
            Sampler::greedy(),
        );
        let h = svc.submit(req(0, 4, 4));
        svc.drain().unwrap();
        let mut streamed = Vec::new();
        let result = loop {
            match h.try_event() {
                Some(StreamEvent::Token(t)) => streamed.push(t),
                Some(StreamEvent::Done(r)) => break r,
                Some(StreamEvent::Rejected) => panic!("must not be rejected"),
                None => panic!("event stream ended without Done"),
            }
        };
        assert_eq!(streamed.len(), 4, "every token was streamed incrementally");
        assert_eq!(streamed, result.tokens, "stream and result agree");
        assert!(!result.cancelled && !result.evicted);
        assert_eq!(svc.stats().results.len(), 1);
        assert!(svc.scheduler().is_drained());
    }

    /// Regression (fabricated chunk logits): a non-final prefill chunk
    /// never yields a sampled token — even when the backend returns a
    /// garbage logits row for it instead of `None`.  The garbage peak
    /// (vocab - 1 at logit 99) would be unmissable if sampled.
    #[test]
    fn non_final_chunk_never_samples_even_garbage_logits() {
        let mut backend = EchoBackend::new(32);
        backend.garbage_chunk_rows = true;
        let mut svc = Service::new(
            backend,
            SchedulerConfig {
                max_batch: 1,
                max_seq: 64,
                prefill_chunk: 8,
                ..Default::default()
            },
            Sampler::greedy(),
        );
        let h = svc.submit(req(0, 24, 3)); // 3 chunks: [0,8) [8,16) [16,24)
        for tick in 0..2 {
            svc.tick().unwrap();
            assert!(h.try_event().is_none(), "no token may stream after non-final chunk {tick}");
            assert!(svc.scheduler().running()[0].generated.is_empty());
        }
        svc.drain().unwrap();
        let r = h.wait().expect("completes");
        assert_eq!(r.tokens.len(), 3);
        // Real logits, not the garbage peak: (last prompt token + 1).
        assert_eq!(r.tokens[0], 24, "first token comes from the FINAL chunk's logits");
        assert!(r.tokens.iter().all(|&t| t != 31), "garbage peak never sampled");
    }

    /// Cancelling mid-prefill (chunked, so prefill spans several ticks)
    /// releases the KV pages immediately and still resolves the handle.
    #[test]
    fn cancel_mid_prefill_releases_pages_immediately() {
        let mut svc = Service::new(
            EchoBackend::new(32),
            SchedulerConfig {
                max_batch: 2,
                kv_pages: 16,
                page_tokens: 4,
                max_seq: 64,
                prefill_chunk: 8,
                ..Default::default()
            },
            Sampler::greedy(),
        );
        let h = svc.submit(req(0, 32, 4));
        assert_eq!(svc.tick().unwrap(), Tick::Stepped, "first 8-token chunk ran");
        let s = &svc.scheduler().running()[0];
        assert!(!s.prefilled, "still mid-prefill");
        assert_eq!(s.prefill_pos, 8);
        assert!(svc.scheduler().pool.used_pages() > 0, "prompt pages held");
        h.cancel();
        assert_eq!(svc.tick().unwrap(), Tick::Drained, "cancel applied before planning");
        assert_eq!(svc.scheduler().pool.used_pages(), 0, "pages released at cancel");
        let r = h.wait().expect("cancelled requests still resolve");
        assert!(r.cancelled);
        assert!(r.tokens.is_empty(), "cancelled before the first token");
        assert_eq!(r.ttft_s, 0.0, "no token was produced: no fabricated TTFT");
        let stats = svc.stats();
        assert_eq!(stats.cancelled, 1);
        assert_eq!(
            stats.mean_ttft_s(),
            0.0,
            "cancelled results are excluded from the latency aggregates"
        );
    }

    /// Cancelling mid-decode keeps the tokens generated so far and
    /// frees the pages for the next request.
    #[test]
    fn cancel_mid_decode_keeps_partial_tokens() {
        let mut svc = Service::new(
            EchoBackend::new(32),
            SchedulerConfig {
                max_batch: 1,
                kv_pages: 16,
                page_tokens: 4,
                max_seq: 64,
                ..Default::default()
            },
            Sampler::greedy(),
        );
        let h = svc.submit(req(0, 4, 100));
        svc.tick().unwrap(); // prefill → first token
        svc.tick().unwrap(); // decode
        svc.tick().unwrap(); // decode
        h.cancel();
        assert_eq!(svc.tick().unwrap(), Tick::Drained);
        assert_eq!(svc.scheduler().pool.used_pages(), 0);
        let r = h.wait().expect("resolves with partial output");
        assert!(r.cancelled);
        assert_eq!(r.tokens.len(), 3, "prefill token + two decode tokens kept");
        // The machine is free again: a second request runs to completion.
        let h2 = svc.submit(req(1, 4, 2));
        svc.drain().unwrap();
        let r2 = h2.wait().expect("second request completes");
        assert!(!r2.cancelled);
        assert_eq!(r2.tokens.len(), 2);
    }

    /// A dropped handle is an implicit cancel: the first undeliverable
    /// token releases the request's pages.
    #[test]
    fn dropped_handle_auto_cancels() {
        let mut svc = Service::new(
            EchoBackend::new(32),
            SchedulerConfig { max_batch: 1, max_seq: 64, ..Default::default() },
            Sampler::greedy(),
        );
        let h = svc.submit(req(0, 4, 100));
        drop(h);
        assert_eq!(svc.tick().unwrap(), Tick::Stepped, "prefill token undeliverable");
        assert_eq!(svc.scheduler().pool.used_pages(), 0, "implicitly cancelled");
        assert_eq!(svc.tick().unwrap(), Tick::Drained);
        assert_eq!(svc.stats().cancelled, 1);
    }

    /// A prompt that can never fit the pool resolves the handle with
    /// `Rejected` instead of hanging it.
    #[test]
    fn oversized_prompt_resolves_as_rejected() {
        let mut svc = Service::new(
            EchoBackend::new(32),
            SchedulerConfig {
                max_batch: 1,
                kv_pages: 2,
                page_tokens: 4,
                max_seq: 64,
                ..Default::default()
            },
            Sampler::greedy(),
        );
        let h = svc.submit(req(0, 32, 4)); // needs 8 pages, pool has 2
        svc.drain().unwrap();
        assert!(h.wait().is_none(), "rejected handles resolve to None");
        assert_eq!(svc.stats().rejected, 1);
    }

    /// The virtual-clock service and the offline `run_trace` replay are
    /// the SAME engine: identical tokens and bit-identical timings for
    /// the same trace.
    #[test]
    fn service_matches_offline_replay() {
        let trace_cfg = TraceConfig {
            n_requests: 6,
            vocab: 32,
            prompt_len_choices: vec![4, 8],
            decode_len_choices: vec![4, 8],
            seed: 5,
            ..Default::default()
        };
        let sched_cfg = SchedulerConfig { max_batch: 2, max_seq: 64, ..Default::default() };
        let mut server = Server::new(EchoBackend::new(32), sched_cfg.clone(), Sampler::greedy());
        let offline = server.run_trace(generate_trace(&trace_cfg)).unwrap();

        let mut svc = Service::new(EchoBackend::new(32), sched_cfg, Sampler::greedy());
        let handles: Vec<RequestHandle> = generate_trace(&trace_cfg)
            .into_iter()
            .map(|r| svc.submit(r))
            .collect();
        svc.drain().unwrap();
        let live = svc.stats();

        assert_eq!(live.results.len(), offline.results.len());
        for a in &offline.results {
            let b = live.results.iter().find(|r| r.id == a.id).unwrap();
            assert_eq!(a.tokens, b.tokens, "same engine, same tokens");
            assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "bit-identical TTFT");
            assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits());
        }
        assert_eq!(live.served_s.to_bits(), offline.served_s.to_bits());
        for h in handles {
            assert!(h.wait().is_some(), "every handle resolves");
        }
    }

    /// Live mode smoke test: the background engine serves submissions on
    /// the host clock and `shutdown` drains before returning stats.
    #[test]
    fn live_service_serves_and_shuts_down() {
        let svc = LiveService::spawn(
            EchoBackend::new(32),
            SchedulerConfig { max_batch: 2, max_seq: 64, ..Default::default() },
            Sampler::greedy(),
        );
        let h1 = svc.submit((0..4).collect(), 3);
        let h2 = svc.submit((0..8).collect(), 3);
        let r1 = h1.wait().expect("request 1 completes");
        let r2 = h2.wait().expect("request 2 completes");
        assert_eq!(r1.tokens.len(), 3);
        assert_eq!(r2.tokens.len(), 3);
        assert!(r1.latency_s >= 0.0 && r1.ttft_s >= 0.0);
        let stats = svc.shutdown();
        assert_eq!(stats.results.len(), 2);
        assert_eq!(stats.cancelled, 0);
        assert!(stats.steps > 0);
    }

    /// Tentpole: a request preempted to the swap tier keeps streaming
    /// across the preempt/resume cycle — no terminal `Evicted` event,
    /// the handle resolves with the full token budget, and the streamed
    /// tokens equal the final result byte for byte.
    #[test]
    fn streaming_survives_preempt_resume_cycle() {
        let mut svc = Service::new(
            EchoBackend::new(32),
            SchedulerConfig {
                max_batch: 2,
                kv_pages: 4,
                page_tokens: 4,
                max_seq: 64,
                swap: true,
                ..Default::default()
            },
            Sampler::greedy(),
        );
        // Two residents that each outgrow half the pool: one must spill.
        let h0 = svc.submit(req(0, 4, 12));
        let h1 = svc.submit(req(1, 4, 12));
        svc.drain().unwrap();
        let stats = svc.stats();
        assert!(stats.preemptions > 0, "the pool forces at least one preemption");
        assert_eq!(stats.preempted_truncated(), 0, "no truncation with swap on");
        assert_eq!(svc.scheduler().pool.used_pages(), 0);
        assert_eq!(svc.scheduler().pool.swapped_seqs(), 0);
        for h in [h0, h1] {
            let mut streamed = Vec::new();
            let result = loop {
                match h.try_event() {
                    Some(StreamEvent::Token(t)) => streamed.push(t),
                    Some(StreamEvent::Done(r)) => break r,
                    Some(StreamEvent::Rejected) => panic!("must not be rejected"),
                    None => panic!("stream ended without Done"),
                }
            };
            assert!(!result.evicted && !result.cancelled);
            assert_eq!(result.tokens.len(), 12, "full budget across the swap cycle");
            assert_eq!(streamed, result.tokens, "stream and result agree");
        }
    }

    /// Cancelling a request while it is parked in the swap tier resolves
    /// the handle (partial tokens kept) and clears the swap registry.
    #[test]
    fn cancel_while_preempted_resolves_handle() {
        let mut svc = Service::new(
            EchoBackend::new(32),
            SchedulerConfig {
                max_batch: 2,
                kv_pages: 4,
                page_tokens: 4,
                max_seq: 64,
                swap: true,
                ..Default::default()
            },
            Sampler::greedy(),
        );
        let h0 = svc.submit(req(0, 4, 12));
        let h1 = svc.submit(req(1, 4, 12));
        for _ in 0..20 {
            if !svc.scheduler().preempted().is_empty() {
                break;
            }
            svc.tick().unwrap();
        }
        let parked = svc.scheduler().preempted();
        assert_eq!(parked.len(), 1, "pool pressure parked the newest request");
        assert_eq!(parked[0].req.id, 1);
        h1.cancel();
        svc.tick().unwrap();
        assert_eq!(svc.scheduler().preempted().len(), 0);
        assert_eq!(svc.scheduler().pool.swapped_seqs(), 0, "registry cleared");
        svc.drain().unwrap();
        let r1 = h1.wait().expect("cancelled handle resolves");
        assert!(r1.cancelled);
        assert!(!r1.tokens.is_empty(), "tokens streamed before the preemption kept");
        let r0 = h0.wait().expect("survivor completes");
        assert!(!r0.cancelled && !r0.evicted);
        assert_eq!(r0.tokens.len(), 12);
        let stats = svc.stats();
        assert_eq!(stats.cancelled, 1);
        assert_eq!(stats.preempted_truncated(), 0);
    }

    /// Tentpole (obs): the flight recorder captures the golden event
    /// sequence for a deterministic chunked-prefill request, and
    /// recording leaves tokens and stats bit-identical to a bare run.
    #[test]
    fn flight_recorder_golden_sequence_and_invisibility() {
        let cfg = SchedulerConfig {
            max_batch: 1,
            max_seq: 64,
            prefill_chunk: 8,
            ..Default::default()
        };
        let run = |record: bool| {
            let mut svc = Service::new(EchoBackend::new(32), cfg.clone(), Sampler::greedy());
            if record {
                svc.set_recorder(Recorder::new());
            }
            let h = svc.submit(req(0, 16, 2));
            svc.drain().unwrap();
            let log = svc.take_event_log();
            (svc.stats(), h.wait().expect("completes"), log)
        };
        let (s_off, r_off, log_off) = run(false);
        let (s_on, r_on, log_on) = run(true);
        assert!(log_off.is_none(), "no recorder, no log");
        assert_eq!(r_off.tokens, r_on.tokens, "recording never changes the stream");
        assert_eq!(s_off.served_s.to_bits(), s_on.served_s.to_bits());
        assert_eq!(s_off.steps, s_on.steps);

        let log = log_on.expect("recorder installed");
        assert_eq!(log.dropped, 0);
        // prompt 16, chunk 8: chunk [0,8), final chunk (first token),
        // then one decode step reaches the 2-token budget.
        assert_eq!(
            log.kinds(),
            vec![
                "submitted",
                "admitted",
                "step",
                "prefill_chunk",
                "step",
                "first_token",
                "step",
                "retired",
            ],
            "golden event sequence"
        );
        let phases: Vec<Phase> = log
            .events
            .iter()
            .filter_map(|s| match s.event {
                Event::Step { phase, .. } => Some(phase),
                _ => None,
            })
            .collect();
        assert_eq!(phases, vec![Phase::Prefill, Phase::Prefill, Phase::Decode]);
        match &log.events[3].event {
            Event::PrefillChunk { id: 0, start: 0, end: 8 } => {}
            other => panic!("expected chunk [0,8), got {other:?}"),
        }
        match &log.events[7].event {
            Event::Retired { id: 0, tokens: 2 } => {}
            other => panic!("expected retired with 2 tokens, got {other:?}"),
        }
        // Timestamps are monotone on the virtual clock.
        assert!(log.events.windows(2).all(|w| w[0].t_s <= w[1].t_s));
    }

    /// Live-mode cancellation: the handle always resolves — either the
    /// cancel won (partial tokens) or the request had already finished.
    #[test]
    fn live_cancellation_resolves_handle() {
        let svc = LiveService::spawn(
            EchoBackend::new(32),
            SchedulerConfig {
                max_batch: 1,
                kv_pages: 512,
                page_tokens: 16,
                max_seq: 4096,
                ..Default::default()
            },
            Sampler::greedy(),
        );
        let h = svc.submit((0..8).collect(), 100_000);
        // Wait for the first streamed token so the request is running.
        assert!(h.recv_event().is_some(), "first token streams");
        h.cancel();
        let r = h.wait().expect("handle resolves after cancel");
        assert!(!r.tokens.is_empty());
        let stats = svc.shutdown();
        assert_eq!(stats.results.len(), 1);
    }
}
