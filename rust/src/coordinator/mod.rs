//! L3 serving coordinator: request queue, continuous-batching scheduling
//! against a virtual clock, paged KV-cache management, sampling, and the
//! batched serving loop that drives token generation through a
//! `ModelBackend` — the PJRT runtime for real numerics, or the
//! `sim::Engine`-backed `SimBackend` for deterministic FlightLLM
//! latencies.
//!
//! FlightLLM's own runtime is single-batch latency-oriented (§1); the
//! coordinator serves that policy with `max_batch = 1` and the Fig. 15
//! multi-batch mode with larger batches.

mod kv_cache;
mod sampler;
mod scheduler;
mod server;
mod sim_backend;

pub use kv_cache::{KvError, PagePool, SeqPages};
pub use sampler::Sampler;
pub use scheduler::{DecodeOutcome, Scheduler, SchedulerConfig, SeqState};
pub use server::{
    ModelBackend, RequestResult, SeqSlot, SeqWork, ServeStats, Server, StepOutput,
};
pub use sim_backend::SimBackend;
