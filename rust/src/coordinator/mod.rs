//! L3 serving coordinator — the serving stack, bottom to top:
//!
//! 1. **Page pool** (`kv_cache`): vLLM-style paged KV accounting with
//!    ref-counted copy-on-write sharing.  Full-page prompt prefixes are
//!    indexed by chained content hash; a later admit of the same prefix
//!    shares the pages instead of recomputing them, released prefix
//!    pages are retained (LRU-evicted under pressure), and a shared
//!    partial tail is copied the first time a writer appends through it.
//! 2. **Scheduler** (`scheduler`): continuous-batching admission against
//!    a virtual clock.  Admission charges only the uncached prompt
//!    suffix; `SeqState::cached_ctx` tells the engine how much prefill
//!    the backend may skip.  Invariant: scheduler `ctx` == pool tokens
//!    for every running sequence, shared pages included.
//! 3. **Engine loop** (`server`): one batched `ModelBackend::step` per
//!    iteration (mixed prefill/decode), sampling, retirement, and
//!    `ServeStats` (TTFT/latency means + P50/P99, prefix-hit counters,
//!    peak KV-page footprint).
//! 4. **Backends**: the PJRT `runtime::RuntimeBackend` for real numerics
//!    (monolithic KV literals — recomputes cached prefixes but reports
//!    them), and the `sim::Engine`-backed `SimBackend` for deterministic
//!    FlightLLM latencies (prices prefill by the uncached suffix).
//!
//! FlightLLM's own runtime is single-batch latency-oriented (§1); the
//! coordinator serves that policy with `max_batch = 1` and the Fig. 15
//! multi-batch mode with larger batches.

mod kv_cache;
mod sampler;
mod scheduler;
mod server;
mod sim_backend;

pub use kv_cache::{AdmitOutcome, KvError, PagePool, PoolStats, SeqPages};
pub use sampler::Sampler;
pub use scheduler::{DecodeOutcome, Scheduler, SchedulerConfig, SeqState};
pub use server::{
    ModelBackend, RequestResult, SeqSlot, SeqWork, ServeStats, Server, StepOutput,
};
pub use sim_backend::SimBackend;
