//! L3 serving coordinator — the serving stack, bottom to top:
//!
//! 1. **Page pool** (`kv_cache`): vLLM-style paged KV accounting with
//!    ref-counted copy-on-write sharing.  Full-page prompt prefixes are
//!    indexed by chained content hash; a later admit of the same prefix
//!    shares the pages instead of recomputing them, released prefix
//!    pages are retained (LRU-evicted under pressure), and a shared
//!    partial tail is copied the first time a writer appends through it.
//!    A DDR swap tier (the §4.4 hybrid HBM/DDR placement) backs
//!    preemption: `swap_out` frees a victim's HBM pages while
//!    preserving its token accounting in a swap registry (shared prefix
//!    pages just drop a refcount), `swap_in` reallocates the exact
//!    footprint when capacity frees up, and pages moved in each
//!    direction are counted so the serving layer can price the traffic.
//! 2. **Scheduler** (`scheduler`): continuous-batching admission against
//!    a serving clock, planned per iteration with CHUNKED PREFILL and
//!    decode priority.  `plan` always decodes every prefilled sequence;
//!    prefill work is capped at `SchedulerConfig::prefill_chunk` prompt
//!    tokens per iteration (admission order), so one long prompt runs
//!    as several chunks instead of freezing every in-flight decode.
//!    Chunking composes with prefix caching: a sequence's first chunk
//!    starts at `cached_ctx` (shared pages are never re-run), and
//!    `SeqState::prefill_pos` tracks the cursor between iterations.
//!    Preemption & swap: with `SchedulerConfig::swap` on, KV exhaustion
//!    during decode swaps the NEWEST resident out to DDR (oldest
//!    requests keep their latency) instead of truncating anything;
//!    `plan` swaps parked sequences back in — strict oldest-first,
//!    AHEAD of fresh admissions — and they resume byte-identically.
//!    Terminal eviction survives only for a sequence that alone
//!    exceeds the entire pool.
//!    Invariants: scheduler `ctx` == pool tokens for every running
//!    sequence, shared pages included, and == the swap-registry token
//!    count for every preempted one; only the FINAL chunk
//!    (`chunk_end == prompt.len()`) produces a token; cancellation
//!    (queued, parked in the swap tier, mid-prefill or mid-decode)
//!    releases pages immediately.
//! 3. **Engine loop** (`service::EngineCore`): one batched
//!    `ModelBackend::step` per iteration (mixed prefill chunks +
//!    decodes), sampling, per-request token streaming, retirement, and
//!    `ServeStats` (TTFT/latency means + P50/P99, decode inter-token
//!    latency, prefix-hit counters, peak KV-page footprint, preemption
//!    and swap-traffic counters).  Swap pricing: pages moved to/from
//!    DDR are charged on the virtual clock through
//!    `ModelBackend::swap_cost_s` — the `SimBackend` prices them at KV
//!    page bytes over the platform's DDR bandwidth, so overload shows
//!    up as served time, not as data loss.  Requests keep streaming
//!    across a preempt/resume cycle; KV-truncated requests (swap off)
//!    are excluded from the latency aggregates and surfaced as
//!    `preempted_truncated` so overload can never make the stats look
//!    BETTER.
//! 4. **Front-ends**: `Server::run_trace` replays an offline trace
//!    through the engine core on the deterministic virtual clock;
//!    `Service` drives the same core with manual `tick`/`drain` plus a
//!    command channel (streaming + cancellation, still deterministic);
//!    `LiveService` runs the core on a background thread against the
//!    host clock — `submit` returns a `RequestHandle` that streams
//!    `StreamEvent::Token`s and resolves to a `RequestResult`.
//! 5. **Backends**: the PJRT `runtime::RuntimeBackend` for real numerics
//!    (monolithic KV literals — recomputes cached prefixes and chunked
//!    prompts at the final chunk, but reports them), and the
//!    `sim::Engine`-backed `SimBackend` for deterministic FlightLLM
//!    latencies (prices each prefill chunk by its own length bucket).
//!    A backend never fabricates logits for a slot that yields no token
//!    (non-final prefill chunks carry `None` rows), and the engine
//!    never samples from such a row even if one shows up.
//! 6. **Fleet** (`fleet`): the multi-shard tier.  FlightLLM's
//!    accelerator is SLR-symmetric (§3.1), so serving scales by
//!    replicating the whole engine per die/board: `ShardedService`
//!    owns N independent lanes — each its own backend + `PagePool` +
//!    `Scheduler`, with the fleet KV budget split per board — behind
//!    the same submit/stream/cancel front-end, routing requests
//!    round-robin, least-loaded (queue depth + live KV pages), or by
//!    prefix affinity (the prompt's first-page hash pins shared-prefix
//!    traffic to the shard whose CoW cache holds it).  Lanes advance
//!    their virtual clocks independently; fleet time is the max over
//!    lanes, and per-shard `ServeStats` merge with percentiles
//!    recomputed from pooled samples (`ServeStats::merge`).
//!    **Fleet memory** (opt-in): a fleet-level *prefix directory*
//!    (`with_global_prefix`) maps the same chained first-page hash the
//!    router and the pools use to the lane that materialized it, so a
//!    shard routed away from a warm cache *adopts* the prefix pages —
//!    charged only the inter-board transfer via
//!    `ModelBackend::swap_cost_s` instead of re-prefilling — and hot
//!    prefixes are prefilled on exactly one lane fleet-wide.
//!    *Cross-shard migration* (`with_migration`) work-steals behind the
//!    unchanged front-end: when a lane parks a request under overload
//!    while another sits idle, the fleet `swap_out`s it on the home
//!    lane, re-homes the sticky request→lane mapping, `swap_in`s on the
//!    target (same transfer pricing) and the stream resumes
//!    byte-identically.  Both paths run on the caller's thread between
//!    lane ticks, so parallel lane ticking stays deterministic.
//!
//! FlightLLM's own runtime is single-batch latency-oriented (§1); the
//! coordinator serves that policy with `max_batch = 1` and the Fig. 15
//! multi-batch mode with larger batches.  Chunked prefill is what makes
//! the live path latency-sound: P99 decode inter-token latency on a
//! mixed burst improves while served tokens stay byte-identical
//! (asserted in `experiments::flightllm_serve_chunk_sweep` tests); the
//! fleet tier is what turns overload into parallelism — 2 shards
//! strictly improve P99 TTFT on the overload trace with token streams
//! byte-identical to a single shard (asserted in
//! `experiments::flightllm_serve_sharded` tests).
//!
//! 7. **Hot path & cost model**: what the serving loop does per step,
//!    and what it never does.  *Precomputed:* `SimBackend` builds a
//!    dense `CostTable` at construction — every (stage, bucket, batch)
//!    point the `BucketPlan` can emit (§5.2 makes the set finite), so
//!    step pricing is a bucket-ordinal array read with no hashing and
//!    no lazy simulation; out-of-table points (a decode batch beyond
//!    the table's `max_batch`) fall back to the old memoised sim run —
//!    bit-identical cost — and are counted
//!    (`SimBackend::cost_table_stats`), surfaced in `cli serve`
//!    summaries.  *Allocations:* none on the synthetic hot path — a
//!    yielded token's row is a compact [`Logits::Peak`] (index + value
//!    + vocab width) the `Sampler` consumes directly (greedy in O(1),
//!    temperature with dense-bit-identical arithmetic and the same
//!    single RNG draw); only the PJRT backend carries
//!    `Logits::Dense` vectors, because its numerics are real.
//!    *Worker threads:* `ShardedService` ticks its lanes on a scoped
//!    thread pool (`with_lane_threads`; lanes already own independent
//!    backends, schedulers, KV pools and clocks), merging results and
//!    `ServeStats` deterministically by lane index — served streams
//!    are byte-identical to sequential ticking, asserted in the fleet
//!    equivalence test.
//!
//! 8. **Observability** ([`crate::obs`]): the flight recorder.  Every
//!    layer above accepts an optional [`crate::obs::Recorder`] — a
//!    bounded per-lane event ring stamped on the serving virtual
//!    clock.  The scheduler records admissions, the engine core records
//!    request lifecycles (submitted → admitted → prefill chunks → first
//!    token → retired, plus preempt/cancel/reject paths), the page pool
//!    reports cumulative swap traffic, and each step lands a `Step`
//!    event with phase/batch/KV-footprint/queue-depth.  Recording only
//!    READS engine state, so token streams and `ServeStats` are
//!    bit-identical with the recorder on or off (asserted in the golden
//!    sequence test and the overload/sharded acceptance tests).  Drained
//!    `EventLog`s export as a Chrome/Perfetto `trace_events` timeline
//!    (`obs::perfetto_trace`, one track per shard lane), and
//!    `ServeStats::metrics_registry` projects the same run into an
//!    `obs::MetricsRegistry` (Prometheus text exposition) — the summary
//!    printer reads from the registry, so the human and machine views
//!    can never disagree.
//!
//! Below the backend boundary, every instruction stream the `SimBackend`
//! executes has already passed the [`crate::verify`] static gate: the
//! simulator's `Engine` prechecks streams against the machine-safety
//! subset in debug builds, and CI verifies the full discipline
//! (occupancy, addresses, sync) for every shipped target.

mod fleet;
mod kv_cache;
mod sampler;
mod scheduler;
mod server;
mod service;
mod sim_backend;

pub use fleet::{RoutePolicy, ShardedService};
pub use kv_cache::{AdmitOutcome, KvError, PagePool, PoolStats, SeqPages};
pub use sampler::Sampler;
pub use scheduler::{
    DecodeOutcome, PlanItem, PlanWork, Scheduler, SchedulerConfig, SeqState,
};
pub use server::{
    ITL_SAMPLE_CAP, Logits, ModelBackend, RequestResult, SeqSlot, SeqWork, ServeStats, Server,
    StepOutput,
};
pub use service::{LiveService, RequestHandle, Service, StreamEvent, Tick};
pub use sim_backend::SimBackend;

/// Shared test double for the serving stack's unit tests.
#[cfg(test)]
pub(crate) mod testing {
    use anyhow::Result;

    use super::server::{Logits, ModelBackend, SeqSlot, SeqWork, StepOutput};

    /// A deterministic toy backend: logits favor (last_token + 1) % V,
    /// carried as compact `Logits::Peak` rows (no vocab-sized vectors).
    /// Step cost is flat per phase — every prefill CHUNK charges
    /// `prefill_s`, any number of decode slots share one `decode_s` (so
    /// batching visibly improves aggregate throughput).  Non-final
    /// prefill chunks carry no logits (`None`) — unless
    /// `garbage_chunk_rows` is set, which emits a garbage row there so
    /// tests can prove the engine never samples from it.
    pub(crate) struct EchoBackend {
        pub vocab: usize,
        pub prefill_s: f64,
        pub decode_s: f64,
        pub garbage_chunk_rows: bool,
    }

    impl EchoBackend {
        pub(crate) fn new(vocab: usize) -> Self {
            Self { vocab, prefill_s: 2e-3, decode_s: 1e-3, garbage_chunk_rows: false }
        }
    }

    impl ModelBackend for EchoBackend {
        fn step(&mut self, batch: &[SeqSlot]) -> Result<StepOutput> {
            let mut step_s = 0.0;
            let mut any_decode = false;
            let logits = batch
                .iter()
                .map(|slot| {
                    let last = match &slot.work {
                        SeqWork::Prefill { prompt, .. } => {
                            step_s += self.prefill_s;
                            if !slot.work.yields_token() {
                                // No token this iteration: no logits —
                                // or, for the regression test, a row of
                                // garbage the engine must ignore.
                                return self.garbage_chunk_rows.then(|| Logits::Peak {
                                    index: (self.vocab - 1) as u32,
                                    value: 99.0,
                                    vocab: self.vocab as u32,
                                });
                            }
                            *prompt.last().unwrap_or(&0)
                        }
                        SeqWork::Decode { last, .. } => {
                            any_decode = true;
                            *last
                        }
                    } as usize;
                    Some(Logits::Peak {
                        index: ((last + 1) % self.vocab) as u32,
                        value: 10.0,
                        vocab: self.vocab as u32,
                    })
                })
                .collect();
            if any_decode {
                step_s += self.decode_s;
            }
            Ok(StepOutput { logits, step_s })
        }
    }
}
