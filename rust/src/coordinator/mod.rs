//! L3 serving coordinator: request queue, prefill/decode scheduling,
//! paged KV-cache management, sampling, and the serving loop that drives
//! real token generation through the PJRT runtime.
//!
//! FlightLLM's own runtime is single-batch latency-oriented (§1); the
//! coordinator implements that policy by default and a round-robin
//! multi-batch mode for the Fig. 15 study.

mod kv_cache;
mod sampler;
mod scheduler;
mod server;

pub use kv_cache::{KvError, PagePool, SeqPages};
pub use sampler::Sampler;
pub use scheduler::{Scheduler, SchedulerConfig, SeqState};
pub use server::{ModelBackend, RequestResult, ServeStats, Server};
