//! Paged KV-cache manager (vLLM-style [31], which the paper uses as its
//! GPU-opt baseline and whose paging FlightLLM's HBM KV layout mirrors):
//! fixed-size token pages allocated per sequence, with exact accounting
//! so the scheduler can admission-control instead of OOMing mid-decode.

use std::collections::HashMap;

/// Errors the pool can raise.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KvError {
    OutOfPages { need: usize, free: usize },
    UnknownSeq(u64),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfPages { need, free } => {
                write!(f, "KV pool exhausted: need {need} pages, {free} free")
            }
            KvError::UnknownSeq(id) => write!(f, "unknown sequence {id}"),
        }
    }
}

impl std::error::Error for KvError {}

/// Pages owned by one sequence.
#[derive(Debug, Clone, Default)]
pub struct SeqPages {
    pub pages: Vec<u32>,
    pub tokens: usize,
}

/// A pool of KV pages of `page_tokens` tokens each.
#[derive(Debug)]
pub struct PagePool {
    page_tokens: usize,
    free: Vec<u32>,
    seqs: HashMap<u64, SeqPages>,
    total: usize,
}

impl PagePool {
    pub fn new(total_pages: usize, page_tokens: usize) -> Self {
        assert!(page_tokens > 0 && total_pages > 0);
        Self {
            page_tokens,
            free: (0..total_pages as u32).rev().collect(),
            seqs: HashMap::new(),
            total: total_pages,
        }
    }

    /// Pool sized for a model: `hbm_kv_bytes` budget, `bytes_per_token`
    /// of KV per token.
    pub fn for_budget(hbm_kv_bytes: u64, bytes_per_token: u64, page_tokens: usize) -> Self {
        let pages = (hbm_kv_bytes / (bytes_per_token * page_tokens as u64)).max(1);
        Self::new(pages as usize, page_tokens)
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.total - self.free.len()
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    /// Can `tokens` more tokens be appended to `seq` (or a new seq)?
    pub fn can_grow(&self, seq: u64, tokens: usize) -> bool {
        let cur = self.seqs.get(&seq).map(|s| (s.pages.len(), s.tokens)).unwrap_or((0, 0));
        let need = self.pages_for(cur.1 + tokens).saturating_sub(cur.0);
        need <= self.free.len()
    }

    /// Register a sequence and allocate pages for its prompt.
    pub fn admit(&mut self, seq: u64, prompt_tokens: usize) -> Result<(), KvError> {
        let need = self.pages_for(prompt_tokens);
        if need > self.free.len() {
            return Err(KvError::OutOfPages { need, free: self.free.len() });
        }
        let pages = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.seqs.insert(seq, SeqPages { pages, tokens: prompt_tokens });
        Ok(())
    }

    /// Append one generated token, growing by a page at boundaries.
    pub fn append(&mut self, seq: u64) -> Result<(), KvError> {
        let s = self.seqs.get_mut(&seq).ok_or(KvError::UnknownSeq(seq))?;
        let need = (s.tokens + 1).div_ceil(self.page_tokens);
        if need > s.pages.len() {
            match self.free.pop() {
                Some(p) => s.pages.push(p),
                None => return Err(KvError::OutOfPages { need: 1, free: 0 }),
            }
        }
        s.tokens += 1;
        Ok(())
    }

    /// Release a finished sequence's pages.
    pub fn release(&mut self, seq: u64) -> Result<(), KvError> {
        let s = self.seqs.remove(&seq).ok_or(KvError::UnknownSeq(seq))?;
        self.free.extend(s.pages);
        Ok(())
    }

    pub fn seq(&self, seq: u64) -> Option<&SeqPages> {
        self.seqs.get(&seq)
    }

    /// Invariant: every page is either free or owned by exactly one seq.
    pub fn check_invariants(&self) -> bool {
        let mut seen = std::collections::HashSet::new();
        for &p in &self.free {
            if !seen.insert(p) {
                return false;
            }
        }
        for s in self.seqs.values() {
            for &p in &s.pages {
                if !seen.insert(p) {
                    return false;
                }
            }
            // Owned pages must cover the tokens.
            if s.pages.len() < s.tokens.div_ceil(self.page_tokens) {
                return false;
            }
        }
        seen.len() == self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn admit_and_release_roundtrip() {
        let mut p = PagePool::new(16, 16);
        p.admit(1, 40).unwrap(); // 3 pages
        assert_eq!(p.used_pages(), 3);
        p.release(1).unwrap();
        assert_eq!(p.used_pages(), 0);
        assert!(p.check_invariants());
    }

    #[test]
    fn append_grows_at_page_boundary() {
        let mut p = PagePool::new(4, 4);
        p.admit(1, 4).unwrap(); // exactly 1 page
        assert_eq!(p.used_pages(), 1);
        p.append(1).unwrap(); // token 5 → second page
        assert_eq!(p.used_pages(), 2);
        for _ in 0..3 {
            p.append(1).unwrap(); // fills page 2, no growth
        }
        assert_eq!(p.used_pages(), 2);
        assert!(p.check_invariants());
    }

    #[test]
    fn exhaustion_is_reported_not_corrupted() {
        let mut p = PagePool::new(2, 16);
        p.admit(1, 32).unwrap();
        assert_eq!(p.admit(2, 1), Err(KvError::OutOfPages { need: 1, free: 0 }));
        assert!(p.check_invariants());
    }

    #[test]
    fn can_grow_predicts_append() {
        let mut p = PagePool::new(2, 4);
        p.admit(1, 4).unwrap();
        assert!(p.can_grow(1, 1));
        p.admit(2, 4).unwrap();
        assert!(!p.can_grow(1, 1), "no free page left");
    }

    #[test]
    fn property_no_double_allocation() {
        proptest::check("kv pages never double-allocated", |r| {
            let mut p = PagePool::new(8, 8);
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..64 {
                match r.below(3) {
                    0 => {
                        let id = next_id;
                        next_id += 1;
                        if p.admit(id, 1 + r.below(24) as usize).is_ok() {
                            live.push(id);
                        }
                    }
                    1 if !live.is_empty() => {
                        let id = *r.choose(&live);
                        let _ = p.append(id);
                    }
                    2 if !live.is_empty() => {
                        let i = r.range(0, live.len());
                        let id = live.swap_remove(i);
                        p.release(id).unwrap();
                    }
                    _ => {}
                }
                assert!(p.check_invariants(), "invariant broken");
            }
        });
    }
}
