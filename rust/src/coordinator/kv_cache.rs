//! Paged KV-cache manager (vLLM-style [31], which the paper uses as its
//! GPU-opt baseline and whose paging FlightLLM's HBM KV layout mirrors):
//! fixed-size token pages with exact accounting, ref-counted
//! copy-on-write sharing, and a prompt-prefix index.
//!
//! Sharing model: a page holding a FULL page of prompt tokens is entered
//! into the prefix index under the chained content hash of the prompt up
//! to and including that page.  A later `admit` whose prompt starts with
//! the same full-page prefix shares those pages (refcount bump) instead
//! of allocating and recomputing them; `AdmitOutcome::cached_tokens`
//! tells the serving layer how much prefill it may skip.  Sequences can
//! also `fork` (parallel sampling / beam search), sharing every page
//! including a partial tail; the first `append` through a shared tail
//! page copies it first (copy-on-write), so writers never mutate pages
//! other sequences still reference.
//!
//! Released pages that are still indexed are RETAINED (refcount 0, not
//! free, still serving cache hits) and evicted in LRU order only under
//! allocation pressure — the paged-KV analogue of keeping warm prefixes
//! on-chip for as long as capacity allows (§4.4).
//!
//! Swap tier (§4.4 hybrid HBM/DDR placement): `swap_out` moves a
//! victim's whole KV image out of HBM — its pages are released exactly
//! like a `release` (shared prefix pages just drop a refcount, indexed
//! pages are retained for the cache), but the sequence's token count is
//! preserved in a swapped registry so `swap_in` can later reallocate the
//! exact page footprint and the scheduler can resume the sequence where
//! it left off.  The pool tracks pages moved in each direction so the
//! serving layer can price the DDR traffic.
//!
//! Fleet hooks: `prefix_hashes` exposes the chained keys a prompt's
//! full pages index under (the fleet prefix directory's key space),
//! `adopt_prefix_page` installs a page another lane materialized as a
//! retained index entry (priced by the caller as inter-board
//! transfer), and `register_swapped` re-homes a migrated sequence's
//! swap-registry entry without re-counting the write traffic its home
//! lane already paid for.

use std::collections::{HashMap, VecDeque};

/// Errors the pool can raise.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KvError {
    OutOfPages { need: usize, free: usize },
    UnknownSeq(u64),
    /// Pool geometry that cannot hold a single page.
    BadGeometry(String),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfPages { need, free } => {
                write!(f, "KV pool exhausted: need {need} pages, {free} free")
            }
            KvError::UnknownSeq(id) => write!(f, "unknown sequence {id}"),
            KvError::BadGeometry(msg) => write!(f, "bad KV pool geometry: {msg}"),
        }
    }
}

impl std::error::Error for KvError {}

/// Pages referenced by one sequence.  With prefix caching or forking the
/// pages are not necessarily exclusive: consult the pool's refcounts.
#[derive(Debug, Clone, Default)]
pub struct SeqPages {
    pub pages: Vec<u32>,
    pub tokens: usize,
}

/// What `admit` did for a prompt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmitOutcome {
    /// Prompt tokens served from already-materialized shared pages; the
    /// backend only needs to prefill the remaining suffix.  Always less
    /// than the prompt length (the last token is always recomputed so
    /// prefill has something to produce logits from).
    pub cached_tokens: usize,
}

/// Cumulative pool counters (monotone; survive seq churn).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Sequences admitted.
    pub admits: u64,
    /// Admits that reused at least one cached prefix page.
    pub prefix_hits: u64,
    /// Prompt tokens served from cache across all admits.
    pub cached_tokens_served: u64,
    /// Retained (refcount-0) pages evicted under allocation pressure.
    pub retained_evicted: u64,
    /// Sequences swapped out to the DDR tier (preemptions).
    pub swap_outs: u64,
    /// Sequences swapped back into HBM (resumes).
    pub swap_ins: u64,
    /// KV pages written HBM → DDR across all swap-outs.
    pub swapped_out_pages: u64,
    /// KV pages read DDR → HBM across all swap-ins.
    pub swapped_in_pages: u64,
    /// Prefix pages installed from ANOTHER lane's cache (fleet
    /// directory adoption) instead of local prefill.
    pub adopted_pages: u64,
}

/// Seed for the chained prefix hash (any odd constant works).
pub(crate) const PREFIX_HASH_SEED: u64 = 0x5151_7EAD_F11C_4711;

/// Extend the running prefix hash with one full page of tokens.  The
/// chain makes the hash position-dependent: equal hashes mean equal
/// prompt prefixes (up to 64-bit collision odds), not just equal pages.
/// Shared with the fleet's prefix-affinity router, so a routing key is
/// BY CONSTRUCTION the same hash that keys the per-shard prefix index.
pub(crate) fn chain_hash(prev: u64, page: &[u32]) -> u64 {
    let mut h = prev ^ 0x9E37_79B9_7F4A_7C15;
    for &t in page {
        h ^= t as u64;
        h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h ^= h >> 29;
    }
    h.wrapping_mul(0x94D0_49BB_1331_11EB)
}

/// A pool of KV pages of `page_tokens` tokens each.
#[derive(Debug)]
pub struct PagePool {
    page_tokens: usize,
    total: usize,
    /// Never-referenced / fully-recycled pages, ready to hand out.
    free: Vec<u32>,
    /// Per-page reference count (sequences holding the page).
    refcnt: Vec<u32>,
    /// Chained prefix hash for indexed pages (full prompt pages only).
    page_hash: Vec<Option<u64>>,
    /// Prefix index: chained hash of pages[0..=i] → the page holding
    /// page i of that prompt.  Entries point at live OR retained pages.
    index: HashMap<u64, u32>,
    /// Refcount-0 pages kept alive for the index, LRU order (front =
    /// oldest, evicted first).
    retained: VecDeque<u32>,
    seqs: HashMap<u64, SeqPages>,
    /// Sequences swapped out to the DDR tier: token count preserved so
    /// `swap_in` reallocates the exact page footprint.  Disjoint from
    /// `seqs` — a sequence is resident or swapped, never both.
    swapped: HashMap<u64, usize>,
    /// Whether admits consult and feed the prefix index.
    prefix_caching: bool,
    stats: PoolStats,
}

impl PagePool {
    /// A pool with prefix caching OFF: released pages return straight to
    /// the free list and every page is uniquely owned (the PR-1
    /// behavior).
    pub fn new(total_pages: usize, page_tokens: usize) -> Self {
        Self::build(total_pages, page_tokens, false)
    }

    /// A pool with prefix caching ON: full prompt pages are indexed and
    /// shared across sequences, released pages are retained for reuse.
    pub fn with_prefix_cache(total_pages: usize, page_tokens: usize) -> Self {
        Self::build(total_pages, page_tokens, true)
    }

    fn build(total_pages: usize, page_tokens: usize, prefix_caching: bool) -> Self {
        assert!(page_tokens > 0 && total_pages > 0);
        Self {
            page_tokens,
            total: total_pages,
            free: (0..total_pages as u32).rev().collect(),
            refcnt: vec![0; total_pages],
            page_hash: vec![None; total_pages],
            index: HashMap::new(),
            retained: VecDeque::new(),
            seqs: HashMap::new(),
            swapped: HashMap::new(),
            prefix_caching,
            stats: PoolStats::default(),
        }
    }

    /// Pool sized for a model: `hbm_kv_bytes` budget, `bytes_per_token`
    /// of KV per token.  Errors (instead of panicking or silently
    /// rounding) when the geometry cannot hold even one page.
    pub fn for_budget(
        hbm_kv_bytes: u64,
        bytes_per_token: u64,
        page_tokens: usize,
    ) -> Result<Self, KvError> {
        if page_tokens == 0 {
            return Err(KvError::BadGeometry("page_tokens must be > 0".into()));
        }
        if bytes_per_token == 0 {
            return Err(KvError::BadGeometry("bytes_per_token must be > 0".into()));
        }
        let page_bytes = bytes_per_token.saturating_mul(page_tokens as u64);
        let pages = hbm_kv_bytes / page_bytes;
        if pages == 0 {
            return Err(KvError::BadGeometry(format!(
                "budget of {hbm_kv_bytes} B holds no {page_bytes}-B page \
                 ({page_tokens} tokens x {bytes_per_token} B)"
            )));
        }
        Ok(Self::new(pages as usize, page_tokens))
    }

    /// Pages that an allocation could use: truly free plus retained
    /// (cache-warm) pages, which are evicted on demand.
    pub fn free_pages(&self) -> usize {
        self.free.len() + self.retained.len()
    }

    /// Pages holding live sequence data (shared pages count once).
    /// Retained cache pages are excluded: they are reclaimable.
    pub fn used_pages(&self) -> usize {
        self.total - self.free.len() - self.retained.len()
    }

    /// Refcount-0 pages kept only for the prefix index.
    pub fn retained_pages(&self) -> usize {
        self.retained.len()
    }

    /// Total pool capacity in pages.
    pub fn total_pages(&self) -> usize {
        self.total
    }

    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    /// Report cumulative swap-tier traffic to the flight recorder,
    /// which emits `SwapOut`/`SwapIn` deltas against its last sample.
    /// Read-only: recording never changes pool state.
    pub fn record_swap_traffic(&self, rec: &crate::obs::Recorder, now_s: f64) {
        rec.swap_totals(now_s, self.stats.swapped_out_pages, self.stats.swapped_in_pages);
    }

    /// Pages needed to hold `tokens` tokens at this pool's geometry.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    /// Chained hashes of the prompt's full pages (partial tail
    /// excluded).  Empty with prefix caching off: nothing consults the
    /// index, so admission stays O(1) in the prompt length.
    fn full_page_hashes(&self, prompt: &[u32]) -> Vec<u64> {
        if !self.prefix_caching {
            return Vec::new();
        }
        let mut h = PREFIX_HASH_SEED;
        prompt
            .chunks_exact(self.page_tokens)
            .map(|page| {
                h = chain_hash(h, page);
                h
            })
            .collect()
    }

    /// The longest indexed run of full prompt pages, capped so at least
    /// one prompt token is always left for the backend to prefill.
    fn cached_prefix_pages(&self, hashes: &[u64], prompt_len: usize) -> Vec<u32> {
        if !self.prefix_caching {
            return Vec::new();
        }
        let mut pages = Vec::new();
        for h in hashes {
            match self.index.get(h) {
                Some(&p) => pages.push(p),
                None => break,
            }
        }
        if pages.len() * self.page_tokens >= prompt_len {
            pages.pop();
        }
        pages
    }

    /// Prompt tokens an `admit` of this prompt would serve from cache.
    pub fn cached_prefix_tokens(&self, prompt: &[u32]) -> usize {
        let hashes = self.full_page_hashes(prompt);
        self.cached_prefix_pages(&hashes, prompt.len()).len() * self.page_tokens
    }

    /// Chained hashes of the prompt's full pages that a cache could
    /// ever serve — same cap as admission: at least one prompt token is
    /// always left for the backend to prefill, so a fully-paged prompt
    /// drops its last hash.  Empty with prefix caching off.  This is
    /// the key set the fleet's prefix DIRECTORY publishes and adopts
    /// under: one definition with `admit`'s chain, so the directory can
    /// never drift from the lane caches.
    pub fn prefix_hashes(&self, prompt: &[u32]) -> Vec<u64> {
        let mut hashes = self.full_page_hashes(prompt);
        if hashes.len() * self.page_tokens >= prompt.len() {
            hashes.pop();
        }
        hashes
    }

    /// Does the prefix index currently serve this chained hash?
    pub fn has_indexed(&self, hash: u64) -> bool {
        self.index.contains_key(&hash)
    }

    /// Adopt one prefix page another lane materialized (fleet prefix
    /// directory): install a page under `hash` as a retained,
    /// refcount-0 index entry — exactly the state a local prefill +
    /// release would leave — so the next `admit` of the prompt serves
    /// it as a cache hit instead of re-prefilling.  The caller prices
    /// the inter-board transfer (`ModelBackend::swap_cost_s`).  Only
    /// truly FREE pages are used: evicting warm local cache to install
    /// remote cache would thrash.  Returns false (installing nothing)
    /// when prefix caching is off, the hash is already indexed, or no
    /// free page exists.
    pub fn adopt_prefix_page(&mut self, hash: u64) -> bool {
        if !self.prefix_caching || self.index.contains_key(&hash) {
            return false;
        }
        let Some(p) = self.free.pop() else { return false };
        debug_assert_eq!(self.refcnt[p as usize], 0, "free page must be unreferenced");
        self.page_hash[p as usize] = Some(hash);
        self.index.insert(hash, p);
        self.retained.push_back(p);
        self.stats.adopted_pages += 1;
        true
    }

    /// Retained pages that could be evicted without losing pages the
    /// given cached-prefix claim is about to resurrect.
    fn evictable_beside(&self, cached: &[u32]) -> usize {
        let reclaimed = cached.iter().filter(|&&p| self.refcnt[p as usize] == 0).count();
        self.retained.len() - reclaimed
    }

    /// Can this prompt be admitted right now?  Charges only the uncached
    /// suffix against free + evictable pages.
    pub fn can_admit(&self, prompt: &[u32]) -> bool {
        let hashes = self.full_page_hashes(prompt);
        let cached = self.cached_prefix_pages(&hashes, prompt.len());
        let need = self.pages_for(prompt.len()) - cached.len();
        need <= self.free.len() + self.evictable_beside(&cached)
    }

    /// Can `tokens` more tokens be appended to `seq` (or a new seq)?
    /// (Prefix-blind: use `can_admit` for prompt admission.)  A shared
    /// partial tail (forked sequence) is charged one extra page: the
    /// first append through it copies the page before writing.
    pub fn can_grow(&self, seq: u64, tokens: usize) -> bool {
        let Some(s) = self.seqs.get(&seq) else {
            return self.pages_for(tokens) <= self.free.len() + self.retained.len();
        };
        let cow_tail = tokens > 0
            && s.tokens % self.page_tokens != 0
            && s.pages.last().is_some_and(|&p| self.refcnt[p as usize] > 1);
        let need = self.pages_for(s.tokens + tokens).saturating_sub(s.pages.len())
            + usize::from(cow_tail);
        need <= self.free.len() + self.retained.len()
    }

    /// Hand out one page, evicting the LRU retained page if the free
    /// list is empty.
    fn alloc_page(&mut self) -> Option<u32> {
        if let Some(p) = self.free.pop() {
            return Some(p);
        }
        let p = self.retained.pop_front()?;
        debug_assert_eq!(self.refcnt[p as usize], 0, "retained page must be unreferenced");
        if let Some(h) = self.page_hash[p as usize].take() {
            if self.index.get(&h) == Some(&p) {
                self.index.remove(&h);
            }
        }
        self.stats.retained_evicted += 1;
        Some(p)
    }

    /// Register a sequence: share every indexed full-page prefix page,
    /// allocate pages for the uncached suffix, and index the newly
    /// materialized full prompt pages.  Returns how many prompt tokens
    /// were served from cache (0 with prefix caching off).
    pub fn admit(&mut self, seq: u64, prompt: &[u32]) -> Result<AdmitOutcome, KvError> {
        debug_assert!(!self.seqs.contains_key(&seq), "sequence {seq} admitted twice");
        let hashes = self.full_page_hashes(prompt);
        let cached = self.cached_prefix_pages(&hashes, prompt.len());
        let total_pages = self.pages_for(prompt.len());
        let need = total_pages - cached.len();
        let avail = self.free.len() + self.evictable_beside(&cached);
        if need > avail {
            return Err(KvError::OutOfPages { need, free: avail });
        }
        // Claim the shared prefix first so eviction can never reclaim it.
        for &p in &cached {
            if self.refcnt[p as usize] == 0 {
                self.retained.retain(|&q| q != p);
            }
            self.refcnt[p as usize] += 1;
        }
        let mut pages = cached.clone();
        for i in cached.len()..total_pages {
            let p = self.alloc_page().expect("availability checked above");
            self.refcnt[p as usize] = 1;
            // Newly materialized FULL prompt pages join the prefix index
            // (unless the hash is already served by another page, e.g.
            // the always-recomputed last page of a fully-cached prompt).
            if self.prefix_caching && i < hashes.len() && !self.index.contains_key(&hashes[i]) {
                self.index.insert(hashes[i], p);
                self.page_hash[p as usize] = Some(hashes[i]);
            }
            pages.push(p);
        }
        let cached_tokens = cached.len() * self.page_tokens;
        self.stats.admits += 1;
        if !cached.is_empty() {
            self.stats.prefix_hits += 1;
            self.stats.cached_tokens_served += cached_tokens as u64;
        }
        self.seqs.insert(seq, SeqPages { pages, tokens: prompt.len() });
        Ok(AdmitOutcome { cached_tokens })
    }

    /// Append one generated token.  Grows by a page at boundaries; a
    /// shared partial tail page (forked sequence) is copied first
    /// (copy-on-write) so the other referents never see the write.
    pub fn append(&mut self, seq: u64) -> Result<(), KvError> {
        let (tokens, last) = {
            let s = self.seqs.get(&seq).ok_or(KvError::UnknownSeq(seq))?;
            (s.tokens, s.pages.last().copied())
        };
        if tokens % self.page_tokens == 0 {
            // Page boundary: the token opens a fresh page.
            let Some(p) = self.alloc_page() else {
                return Err(KvError::OutOfPages { need: 1, free: 0 });
            };
            self.refcnt[p as usize] = 1;
            let s = self.seqs.get_mut(&seq).expect("checked above");
            s.pages.push(p);
            s.tokens += 1;
            return Ok(());
        }
        let last = last.expect("a seq with a partial tail owns at least one page");
        if self.refcnt[last as usize] > 1 {
            // Copy-on-write: someone else still references the tail page.
            let Some(p) = self.alloc_page() else {
                return Err(KvError::OutOfPages { need: 1, free: 0 });
            };
            self.refcnt[p as usize] = 1;
            self.refcnt[last as usize] -= 1;
            let s = self.seqs.get_mut(&seq).expect("checked above");
            *s.pages.last_mut().expect("tail page exists") = p;
        }
        let s = self.seqs.get_mut(&seq).expect("checked above");
        s.tokens += 1;
        Ok(())
    }

    /// Fork `src` into a new sequence `dst` sharing every page (parallel
    /// sampling / beam search).  Writes through either sequence's shared
    /// tail copy-on-write in `append`.
    pub fn fork(&mut self, src: u64, dst: u64) -> Result<(), KvError> {
        debug_assert!(!self.seqs.contains_key(&dst), "fork onto live sequence {dst}");
        let (pages, tokens) = {
            let s = self.seqs.get(&src).ok_or(KvError::UnknownSeq(src))?;
            (s.pages.clone(), s.tokens)
        };
        for &p in &pages {
            self.refcnt[p as usize] += 1;
        }
        self.seqs.insert(dst, SeqPages { pages, tokens });
        Ok(())
    }

    /// Release a finished sequence.  Unreferenced pages return to the
    /// free list — except indexed prefix pages, which are RETAINED for
    /// future cache hits (and push to the back of the LRU queue).
    pub fn release(&mut self, seq: u64) -> Result<(), KvError> {
        let s = self.seqs.remove(&seq).ok_or(KvError::UnknownSeq(seq))?;
        self.drop_page_refs(&s.pages);
        Ok(())
    }

    /// Drop one reference per page, retaining indexed pages and freeing
    /// the rest (shared by `release` and `swap_out`).
    fn drop_page_refs(&mut self, pages: &[u32]) {
        for &p in pages {
            debug_assert!(self.refcnt[p as usize] > 0, "releasing unreferenced page {p}");
            self.refcnt[p as usize] -= 1;
            if self.refcnt[p as usize] == 0 {
                if self.page_hash[p as usize].is_some() {
                    self.retained.push_back(p);
                } else {
                    self.free.push(p);
                }
            }
        }
    }

    /// Preempt a resident sequence: write its whole KV image to the DDR
    /// swap tier and give its HBM pages back.  Shared prefix pages only
    /// drop a refcount (other residents keep using them); indexed pages
    /// are retained for the cache like a normal release.  Returns the
    /// pages of DDR write traffic (the full image, sharing included —
    /// that is what crosses the memory bus).
    pub fn swap_out(&mut self, seq: u64) -> Result<usize, KvError> {
        let s = self.seqs.remove(&seq).ok_or(KvError::UnknownSeq(seq))?;
        let moved = s.pages.len();
        self.drop_page_refs(&s.pages);
        self.swapped.insert(seq, s.tokens);
        self.stats.swap_outs += 1;
        self.stats.swapped_out_pages += moved as u64;
        Ok(moved)
    }

    /// Resume a swapped-out sequence: reallocate its page footprint in
    /// HBM (fresh exclusive pages — the image is re-read from DDR, so
    /// prior sharing is not reconstructed) and make it resident again.
    /// Returns the pages of DDR read traffic.
    pub fn swap_in(&mut self, seq: u64) -> Result<usize, KvError> {
        let &tokens = self.swapped.get(&seq).ok_or(KvError::UnknownSeq(seq))?;
        let need = self.pages_for(tokens);
        let avail = self.free_pages();
        if need > avail {
            return Err(KvError::OutOfPages { need, free: avail });
        }
        let pages: Vec<u32> = (0..need)
            .map(|_| {
                let p = self.alloc_page().expect("availability checked above");
                self.refcnt[p as usize] = 1;
                p
            })
            .collect();
        self.swapped.remove(&seq);
        self.seqs.insert(seq, SeqPages { pages, tokens });
        self.stats.swap_ins += 1;
        self.stats.swapped_in_pages += need as u64;
        Ok(need)
    }

    /// Token count of a swapped-out sequence (`None` if not swapped).
    pub fn swapped_tokens(&self, seq: u64) -> Option<usize> {
        self.swapped.get(&seq).copied()
    }

    /// Sequences currently parked in the DDR swap tier.
    pub fn swapped_seqs(&self) -> usize {
        self.swapped.len()
    }

    /// Register a sequence as parked in the swap tier WITHOUT counting
    /// traffic: cross-shard migration moves the registry entry to
    /// another lane's pool — the image was already written to DDR by
    /// the home lane's `swap_out`, and the later `swap_in` here counts
    /// (and prices) the read side as usual.
    pub(crate) fn register_swapped(&mut self, seq: u64, tokens: usize) {
        debug_assert!(
            !self.seqs.contains_key(&seq) && !self.swapped.contains_key(&seq),
            "sequence {seq} already known to this pool"
        );
        self.swapped.insert(seq, tokens);
    }

    /// Forget a swapped-out sequence without bringing it back (cancelled
    /// or terminally evicted while parked in DDR — no HBM pages to free).
    pub fn drop_swapped(&mut self, seq: u64) -> Result<(), KvError> {
        match self.swapped.remove(&seq) {
            Some(_) => Ok(()),
            None => Err(KvError::UnknownSeq(seq)),
        }
    }

    pub fn seq(&self, seq: u64) -> Option<&SeqPages> {
        self.seqs.get(&seq)
    }

    /// Invariant: every page is exactly one of (a) free with refcount 0
    /// and no index entry, (b) retained with refcount 0 and a live index
    /// entry, or (c) referenced by >= 1 sequences with a refcount that
    /// EXACTLY matches the number of referencing sequences; and every
    /// sequence's pages cover its tokens exactly.
    pub fn check_invariants(&self) -> bool {
        let mut seen = std::collections::HashSet::new();
        for &p in &self.free {
            if !seen.insert(p)
                || self.refcnt[p as usize] != 0
                || self.page_hash[p as usize].is_some()
            {
                return false;
            }
        }
        for &p in &self.retained {
            if !seen.insert(p) || self.refcnt[p as usize] != 0 {
                return false;
            }
            // Retained pages exist only to serve the prefix index.
            let Some(h) = self.page_hash[p as usize] else { return false };
            if self.index.get(&h) != Some(&p) {
                return false;
            }
        }
        // Count actual references per page across sequences.
        let mut refs: HashMap<u32, u32> = HashMap::new();
        for s in self.seqs.values() {
            if s.pages.len() != self.pages_for(s.tokens) {
                return false;
            }
            let mut in_seq = std::collections::HashSet::new();
            for &p in &s.pages {
                if !in_seq.insert(p) {
                    return false; // a seq must not list a page twice
                }
                *refs.entry(p).or_insert(0) += 1;
            }
        }
        for (&p, &n) in &refs {
            if self.refcnt[p as usize] != n || !seen.insert(p) {
                return false;
            }
        }
        // No phantom refcounts on pages nothing references.
        for (p, &c) in self.refcnt.iter().enumerate() {
            if c > 0 && !refs.contains_key(&(p as u32)) {
                return false;
            }
        }
        // Index entries point at pages that carry that hash and are
        // either live or retained (never free).
        for (&h, &p) in &self.index {
            if self.page_hash[p as usize] != Some(h) {
                return false;
            }
            if self.refcnt[p as usize] == 0 && !self.retained.contains(&p) {
                return false;
            }
        }
        // A sequence is resident or swapped, never both; a swapped
        // sequence holds tokens but zero HBM pages.
        for id in self.swapped.keys() {
            if self.seqs.contains_key(id) {
                return false;
            }
        }
        seen.len() == self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn admit_and_release_roundtrip() {
        let mut p = PagePool::new(16, 16);
        p.admit(1, &[7; 40]).unwrap(); // 3 pages
        assert_eq!(p.used_pages(), 3);
        p.release(1).unwrap();
        assert_eq!(p.used_pages(), 0);
        assert!(p.check_invariants());
    }

    #[test]
    fn append_grows_at_page_boundary() {
        let mut p = PagePool::new(4, 4);
        p.admit(1, &[1; 4]).unwrap(); // exactly 1 page
        assert_eq!(p.used_pages(), 1);
        p.append(1).unwrap(); // token 5 → second page
        assert_eq!(p.used_pages(), 2);
        for _ in 0..3 {
            p.append(1).unwrap(); // fills page 2, no growth
        }
        assert_eq!(p.used_pages(), 2);
        assert!(p.check_invariants());
    }

    #[test]
    fn exhaustion_is_reported_not_corrupted() {
        let mut p = PagePool::new(2, 16);
        p.admit(1, &[3; 32]).unwrap();
        assert_eq!(p.admit(2, &[4]), Err(KvError::OutOfPages { need: 1, free: 0 }));
        assert!(p.check_invariants());
    }

    #[test]
    fn can_grow_predicts_append() {
        let mut p = PagePool::new(2, 4);
        p.admit(1, &[1; 4]).unwrap();
        assert!(p.can_grow(1, 1));
        p.admit(2, &[2; 4]).unwrap();
        assert!(!p.can_grow(1, 1), "no free page left");
    }

    #[test]
    fn for_budget_rejects_degenerate_geometry() {
        assert!(matches!(
            PagePool::for_budget(1 << 20, 0, 16),
            Err(KvError::BadGeometry(_))
        ));
        assert!(matches!(
            PagePool::for_budget(1 << 20, 512, 0),
            Err(KvError::BadGeometry(_))
        ));
        // Budget smaller than one page: descriptive error, no panic.
        assert!(matches!(
            PagePool::for_budget(100, 512, 16),
            Err(KvError::BadGeometry(_))
        ));
        let p = PagePool::for_budget(1 << 20, 512, 16).unwrap();
        assert_eq!(p.free_pages(), (1 << 20) / (512 * 16));
    }

    /// Two sequences with the same prompt share its full prefix pages;
    /// the last page is always recomputed so prefill has a suffix.
    #[test]
    fn admit_shares_cached_prefix_pages() {
        let mut p = PagePool::with_prefix_cache(8, 16);
        let prompt: Vec<u32> = (0..32).collect();
        let a = p.admit(1, &prompt).unwrap();
        assert_eq!(a.cached_tokens, 0, "cold cache");
        assert_eq!(p.used_pages(), 2);
        let b = p.admit(2, &prompt).unwrap();
        assert_eq!(b.cached_tokens, 16, "first page shared, last recomputed");
        assert_eq!(p.used_pages(), 3, "3 distinct pages serve 4 page-refs");
        assert_eq!(p.seq(1).unwrap().pages[0], p.seq(2).unwrap().pages[0]);
        assert_ne!(p.seq(1).unwrap().pages[1], p.seq(2).unwrap().pages[1]);
        assert!(p.check_invariants());
        assert_eq!(p.stats().prefix_hits, 1);
        assert_eq!(p.stats().cached_tokens_served, 16);
    }

    /// A released prompt's indexed pages are retained and serve a later
    /// admit of the same prompt without recomputation.
    #[test]
    fn retained_pages_serve_later_admits() {
        let mut p = PagePool::with_prefix_cache(4, 16);
        let prompt: Vec<u32> = (100..132).collect();
        p.admit(1, &prompt).unwrap();
        p.release(1).unwrap();
        assert_eq!(p.used_pages(), 0);
        assert_eq!(p.retained_pages(), 2, "both full pages stay indexed");
        let out = p.admit(2, &prompt).unwrap();
        assert_eq!(out.cached_tokens, 16);
        assert_eq!(p.retained_pages(), 1, "page 0 resurrected, page 1 still warm");
        assert!(p.check_invariants());
    }

    /// Under allocation pressure the LRU retained page is evicted (and
    /// unindexed) instead of failing the admit.
    #[test]
    fn retained_pages_are_lru_evicted_under_pressure() {
        let mut p = PagePool::with_prefix_cache(2, 4);
        p.admit(1, &[9; 8]).unwrap(); // 2 full pages, both indexed
        p.release(1).unwrap();
        assert_eq!(p.retained_pages(), 2);
        // A different prompt needs both pages: retained cache is evicted.
        let out = p.admit(2, &[5; 8]).unwrap();
        assert_eq!(out.cached_tokens, 0);
        assert_eq!(p.retained_pages(), 0);
        assert_eq!(p.stats().retained_evicted, 2);
        assert!(p.check_invariants());
        // The old prompt is gone from the index: no stale hits.
        p.release(2).unwrap();
        assert_eq!(p.cached_prefix_tokens(&[9; 8]), 0);
    }

    /// A forked sequence shares its parent's partial tail page until one
    /// of them appends — which copies the page (CoW) first.
    #[test]
    fn append_through_shared_tail_copies_on_write() {
        let mut p = PagePool::with_prefix_cache(8, 4);
        p.admit(1, &[2; 6]).unwrap(); // 1 full page + partial tail (2 tokens)
        p.fork(1, 2).unwrap();
        assert_eq!(p.used_pages(), 2, "fork shares, allocates nothing");
        assert!(p.check_invariants());
        let tail_before = *p.seq(2).unwrap().pages.last().unwrap();
        p.append(2).unwrap();
        let tail_after = *p.seq(2).unwrap().pages.last().unwrap();
        assert_ne!(tail_before, tail_after, "shared tail copied on write");
        assert_eq!(*p.seq(1).unwrap().pages.last().unwrap(), tail_before);
        assert_eq!(p.seq(2).unwrap().tokens, 7);
        assert_eq!(p.used_pages(), 3);
        assert!(p.check_invariants());
        // The parent's tail is now exclusive again: appends in place.
        p.append(1).unwrap();
        assert_eq!(p.used_pages(), 3);
        assert!(p.check_invariants());
    }

    /// `can_grow` charges the CoW copy: a forked sequence's shared
    /// partial tail needs one extra page on its first append, so an
    /// exhausted pool must answer false (and append must agree).
    #[test]
    fn can_grow_accounts_for_cow_tail_copy() {
        let mut p = PagePool::with_prefix_cache(2, 4);
        p.admit(1, &[1; 6]).unwrap(); // both pages: 1 full + partial tail
        p.fork(1, 2).unwrap(); // tail shared, pool exhausted
        assert!(!p.can_grow(2, 1), "CoW copy needs a page the pool lacks");
        assert_eq!(p.append(2), Err(KvError::OutOfPages { need: 1, free: 0 }));
        p.release(1).unwrap(); // tail now exclusive to seq 2
        assert!(p.can_grow(2, 1), "exclusive tail appends in place");
        p.append(2).unwrap();
        assert!(p.check_invariants());
    }

    #[test]
    fn fully_cached_prompt_keeps_a_prefill_suffix() {
        let mut p = PagePool::with_prefix_cache(8, 8);
        let prompt: Vec<u32> = (0..16).collect();
        p.admit(1, &prompt).unwrap();
        let out = p.admit(2, &prompt).unwrap();
        assert!(
            out.cached_tokens < prompt.len(),
            "at least one token must remain for prefill"
        );
        assert_eq!(out.cached_tokens, 8);
        assert!(p.check_invariants());
    }

    /// Swap tier roundtrip: swapping out frees every HBM page while
    /// preserving the token count, and swapping back in reallocates the
    /// exact footprint with the traffic accounted in both directions.
    #[test]
    fn swap_out_then_in_roundtrips_footprint() {
        let mut p = PagePool::new(8, 4);
        p.admit(1, &[5; 10]).unwrap(); // 3 pages
        for _ in 0..2 {
            p.append(1).unwrap(); // 12 tokens, still 3 pages
        }
        assert_eq!(p.swap_out(1), Ok(3));
        assert_eq!(p.used_pages(), 0, "HBM fully reclaimed");
        assert_eq!(p.swapped_tokens(1), Some(12));
        assert_eq!(p.swapped_seqs(), 1);
        assert!(p.seq(1).is_none(), "swapped sequence is not resident");
        assert!(p.check_invariants());
        // The freed pages serve another request while 1 is parked.
        p.admit(2, &[6; 20]).unwrap(); // 5 pages
        assert_eq!(p.swap_in(1), Ok(3));
        assert_eq!(p.seq(1).unwrap().tokens, 12, "token count preserved");
        assert_eq!(p.seq(1).unwrap().pages.len(), 3);
        assert_eq!(p.used_pages(), 8);
        assert_eq!(p.swapped_seqs(), 0);
        assert!(p.check_invariants());
        let st = p.stats();
        assert_eq!((st.swap_outs, st.swap_ins), (1, 1));
        assert_eq!((st.swapped_out_pages, st.swapped_in_pages), (3, 3));
        // Resumed pages are exclusive: once room exists again, appends
        // grow the sequence in place.
        p.release(2).unwrap();
        p.append(1).unwrap();
        assert!(p.check_invariants());
    }

    /// Swap-in is refused (not corrupted) when HBM has no room yet.
    #[test]
    fn swap_in_waits_for_capacity() {
        let mut p = PagePool::new(2, 4);
        p.admit(1, &[1; 8]).unwrap();
        p.swap_out(1).unwrap();
        p.admit(2, &[2; 5]).unwrap(); // 2 pages: pool full again
        assert_eq!(p.swap_in(1), Err(KvError::OutOfPages { need: 2, free: 0 }));
        assert!(p.check_invariants());
        p.release(2).unwrap();
        assert_eq!(p.swap_in(1), Ok(2), "resumes once pages free up");
        assert!(p.check_invariants());
    }

    /// Swapping out a sequence that shares CoW prefix pages only drops
    /// refcounts: the other resident keeps the pages, the index keeps
    /// serving hits, and swap-in comes back with exclusive pages.
    #[test]
    fn swap_out_interacts_with_shared_prefix_refcounts() {
        let mut p = PagePool::with_prefix_cache(8, 16);
        let prompt: Vec<u32> = (0..32).collect();
        p.admit(1, &prompt).unwrap();
        p.admit(2, &prompt).unwrap(); // shares page 0 with seq 1
        let shared = p.seq(1).unwrap().pages[0];
        assert_eq!(p.seq(2).unwrap().pages[0], shared);
        assert_eq!(p.swap_out(2), Ok(2), "traffic counts the shared page too");
        assert_eq!(p.refcnt[shared as usize], 1, "seq 1 still holds the prefix page");
        assert!(p.check_invariants());
        // A third admit still hits the index while 2 is swapped out.
        let out = p.admit(3, &prompt).unwrap();
        assert_eq!(out.cached_tokens, 16);
        p.swap_in(2).unwrap();
        assert_ne!(
            p.seq(2).unwrap().pages[0],
            shared,
            "resume reallocates exclusive pages (image re-read from DDR)"
        );
        assert_eq!(p.seq(2).unwrap().tokens, 32);
        assert!(p.check_invariants());
    }

    /// An adopted prefix page is indistinguishable from a locally
    /// prefilled-and-released one: the next admit of the prompt serves
    /// it as a cache hit without any prefill having happened here.
    #[test]
    fn adopted_pages_serve_admits_like_local_prefill() {
        let mut p = PagePool::with_prefix_cache(8, 16);
        let prompt: Vec<u32> = (0..40).collect(); // 2 full pages + tail
        let hashes = p.prefix_hashes(&prompt);
        assert_eq!(hashes.len(), 2);
        for &h in &hashes {
            assert!(!p.has_indexed(h));
            assert!(p.adopt_prefix_page(h), "free pool must install");
            assert!(p.has_indexed(h));
        }
        assert!(!p.adopt_prefix_page(hashes[0]), "already indexed: no-op");
        assert_eq!(p.retained_pages(), 2);
        assert_eq!(p.used_pages(), 0, "adopted pages are reclaimable cache");
        assert!(p.check_invariants());
        let out = p.admit(1, &prompt).unwrap();
        assert_eq!(out.cached_tokens, 32, "both adopted pages hit");
        assert_eq!(p.stats().adopted_pages, 2);
        assert_eq!(p.stats().prefix_hits, 1);
        assert!(p.check_invariants());
    }

    /// A fully-paged prompt's hash set keeps the admission cap (one
    /// token always left to prefill), and adoption never evicts warm
    /// retained cache or fires with caching off.
    #[test]
    fn adoption_respects_cap_capacity_and_cache_flag() {
        let mut p = PagePool::with_prefix_cache(2, 4);
        assert_eq!(p.prefix_hashes(&[1; 8]).len(), 1, "last full page dropped");
        assert_eq!(p.prefix_hashes(&[1; 9]).len(), 2);
        assert_eq!(p.prefix_hashes(&[1; 3]).len(), 0);
        // Fill the pool with warm retained cache: adoption must refuse
        // rather than evict it.
        p.admit(1, &[9; 8]).unwrap();
        p.release(1).unwrap();
        assert_eq!(p.retained_pages(), 2);
        assert!(!p.adopt_prefix_page(777), "no free page: adoption refused");
        assert_eq!(p.stats().adopted_pages, 0);
        assert!(p.check_invariants());
        let mut off = PagePool::new(4, 4);
        assert!(off.prefix_hashes(&[1; 8]).is_empty());
        assert!(!off.adopt_prefix_page(777), "caching off: no index to feed");
        assert!(off.check_invariants());
    }

    /// `register_swapped` re-homes a parked footprint without counting
    /// traffic; the later `swap_in` counts (and the caller prices) the
    /// read side only.
    #[test]
    fn register_swapped_rehomes_without_traffic() {
        let mut home = PagePool::new(4, 4);
        home.admit(1, &[1; 10]).unwrap(); // 3 pages
        assert_eq!(home.swap_out(1), Ok(3));
        let tokens = home.swapped_tokens(1).unwrap();
        home.drop_swapped(1).unwrap();
        let mut target = PagePool::new(4, 4);
        target.register_swapped(1, tokens);
        assert_eq!(target.swapped_tokens(1), Some(10));
        let before = target.stats();
        assert_eq!((before.swap_outs, before.swapped_out_pages), (0, 0));
        assert!(target.check_invariants());
        assert_eq!(target.swap_in(1), Ok(3));
        assert_eq!(target.seq(1).unwrap().tokens, 10);
        let after = target.stats();
        assert_eq!((after.swap_ins, after.swapped_in_pages), (1, 3));
        assert_eq!(after.swapped_out_pages, 0, "write side stays on the home lane");
        assert!(target.check_invariants());
    }

    /// `drop_swapped` forgets a parked sequence without touching HBM.
    #[test]
    fn drop_swapped_forgets_parked_sequence() {
        let mut p = PagePool::new(4, 4);
        p.admit(1, &[1; 4]).unwrap();
        p.swap_out(1).unwrap();
        assert_eq!(p.drop_swapped(1), Ok(()));
        assert_eq!(p.drop_swapped(1), Err(KvError::UnknownSeq(1)));
        assert_eq!(p.swap_in(1), Err(KvError::UnknownSeq(1)));
        assert_eq!(p.swapped_seqs(), 0);
        assert!(p.check_invariants());
    }

    #[test]
    fn property_no_double_allocation() {
        proptest::check("kv pages never double-allocated", |r| {
            let mut p = PagePool::new(8, 8);
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..64 {
                match r.below(3) {
                    0 => {
                        let id = next_id;
                        next_id += 1;
                        let plen = 1 + r.below(24) as usize;
                        let prompt: Vec<u32> = (0..plen as u32).collect();
                        if p.admit(id, &prompt).is_ok() {
                            live.push(id);
                        }
                    }
                    1 if !live.is_empty() => {
                        let id = *r.choose(&live);
                        let _ = p.append(id);
                    }
                    2 if !live.is_empty() => {
                        let i = r.range(0, live.len());
                        let id = live.swap_remove(i);
                        p.release(id).unwrap();
                    }
                    _ => {}
                }
                assert!(p.check_invariants(), "invariant broken");
            }
        });
    }

    /// The extended sharing property: random admit (with shared
    /// prefixes), append, fork, release and swap-out/swap-in cycles keep
    /// every refcount accurate and every page accounted for, on every
    /// step — and a swapped sequence always comes back with its exact
    /// token count.
    #[test]
    fn property_refcounts_accurate_under_sharing() {
        proptest::check("CoW pool refcount invariant", |r| {
            let mut p = PagePool::with_prefix_cache(24, 4);
            // A small family of shared prefixes drives real cache hits.
            let prefixes: Vec<Vec<u32>> = (0..3u32)
                .map(|g| (0..8).map(|i| g * 100 + i).collect())
                .collect();
            let mut live: Vec<u64> = Vec::new();
            let mut parked: Vec<(u64, usize)> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..96 {
                match r.below(6) {
                    0 => {
                        let id = next_id;
                        next_id += 1;
                        let mut prompt = r.choose(&prefixes).clone();
                        let tail = r.below(6);
                        prompt.extend((0..tail).map(|t| 1000 + t as u32));
                        if p.admit(id, &prompt).is_ok() {
                            live.push(id);
                        }
                    }
                    1 if !live.is_empty() => {
                        let id = *r.choose(&live);
                        let _ = p.append(id);
                    }
                    2 if !live.is_empty() => {
                        let src = *r.choose(&live);
                        let id = next_id;
                        next_id += 1;
                        p.fork(src, id).unwrap();
                        live.push(id);
                    }
                    3 if !live.is_empty() => {
                        let i = r.range(0, live.len());
                        let id = live.swap_remove(i);
                        p.release(id).unwrap();
                    }
                    4 if !live.is_empty() => {
                        let i = r.range(0, live.len());
                        let id = live.swap_remove(i);
                        let tokens = p.seq(id).unwrap().tokens;
                        p.swap_out(id).unwrap();
                        parked.push((id, tokens));
                    }
                    5 if !parked.is_empty() => {
                        let i = r.range(0, parked.len());
                        let (id, tokens) = parked[i];
                        if p.swap_in(id).is_ok() {
                            parked.swap_remove(i);
                            assert_eq!(p.seq(id).unwrap().tokens, tokens, "tokens survive swap");
                            live.push(id);
                        }
                    }
                    _ => {}
                }
                assert!(p.check_invariants(), "refcount invariant broken");
            }
            for id in live {
                p.release(id).unwrap();
            }
            for (id, _) in parked {
                p.drop_swapped(id).unwrap();
            }
            assert!(p.check_invariants());
            assert_eq!(p.used_pages(), 0, "all pages free or retained after drain");
        });
    }
}
