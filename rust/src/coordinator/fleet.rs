//! The multi-shard serving fleet: N independent replica lanes behind
//! the one submit/stream/cancel front-end.
//!
//! FlightLLM's accelerator is SLR-symmetric (§3.1): the natural way to
//! scale serving beyond one die/board is to replicate the whole engine
//! and route requests among the replicas.  [`ShardedService`] owns one
//! lane per shard — each lane its own `ModelBackend` + `PagePool` +
//! `Scheduler` + virtual clock, i.e. a whole board — and a router that
//! assigns every submitted request a home lane:
//!
//! - [`RoutePolicy::RoundRobin`]: lane = arrival index mod N.
//! - [`RoutePolicy::LeastLoaded`]: the lane with the fewest requests in
//!   flight (waiting + running + parked in the swap tier), ties broken
//!   by live KV pages, then lane index — both load signals the issue of
//!   a real fleet scheduler would poll from its boards.
//! - [`RoutePolicy::PrefixAffinity`]: hash the prompt's first full KV
//!   page, lane = hash mod N — requests sharing a system prompt land on
//!   the shard whose CoW prefix cache (PR 2) already holds their
//!   prefix, so the per-board caches see hits a load-blind router would
//!   scatter.  Prompts shorter than one page fall back to least-loaded.
//!
//! The fleet-level `SchedulerConfig` carries the TOTAL KV budget; each
//! lane gets `kv_pages / N` (per-board HBM), so adding shards adds
//! capacity the way adding boards does.  Lanes advance their virtual
//! clocks independently (boards run in parallel); the fleet serving
//! time is the max over lanes, which is what `ServeStats::merge`
//! reports as `served_s`.  Merged percentiles are recomputed from the
//! pooled per-request samples — never averaged per-shard percentiles.
//!
//! Determinism: routing is a pure function of the submission order and
//! lane state, and the sim/echo backends derive logits from (sequence
//! id, last token, position) alone — so under greedy sampling a
//! request's token stream is byte-identical whichever lane serves it,
//! and identical to a single-shard run (asserted in
//! `experiments::sharded_fleet_*` tests).  A cloned temperature sampler
//! seeds one RNG per lane, so routing changes WOULD reorder its draws —
//! the fleet comparisons therefore pin greedy sampling.
//!
//! Parallel lanes: each lane is fully self-contained (own backend,
//! scheduler, KV pool, virtual clock — the PR 5 design), so a fleet
//! tick can run the lane iterations on a scoped worker-thread pool
//! ([`ShardedService::with_lane_threads`]; boards do run in parallel).
//! Routing and command application stay on the caller's thread BEFORE
//! the ticks, lane results are collected back IN LANE ORDER (first
//! error in lane order wins, like the sequential loop), and stats
//! merge by lane index — so a parallel fleet's served streams and
//! merged stats are byte-identical to sequential ticking
//! (`lane_threads == 1`), asserted by the equivalence test below.

use std::collections::HashMap;
use std::sync::mpsc::{self, Receiver, Sender};

use anyhow::Result;

use crate::obs::{EventLog, Recorder};
use crate::workload::Request;

use super::kv_cache::{chain_hash, PREFIX_HASH_SEED};
use super::sampler::Sampler;
use super::scheduler::{Scheduler, SchedulerConfig};
use super::server::{ModelBackend, ServeStats};
use super::service::{ClockMode, Command, EngineCore, RequestHandle, StreamEvent, Tick};

/// How the fleet assigns a submitted request to a shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Submission order mod shard count.
    RoundRobin,
    /// Fewest requests in flight, ties by live KV pages, then index.
    LeastLoaded,
    /// Hash of the prompt's first full KV page, so shared-prefix
    /// traffic keeps hitting the same shard's prefix cache.
    PrefixAffinity,
}

impl RoutePolicy {
    /// Parse a CLI spelling (`rr` / `load` / `prefix`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "rr" | "round-robin" => Some(RoutePolicy::RoundRobin),
            "load" | "least-loaded" => Some(RoutePolicy::LeastLoaded),
            "prefix" | "prefix-affinity" => Some(RoutePolicy::PrefixAffinity),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastLoaded => "least-loaded",
            RoutePolicy::PrefixAffinity => "prefix-affinity",
        }
    }
}

/// N replica serving lanes behind one submit/stream/cancel front-end,
/// driven by manual `tick`/`drain` on per-lane virtual clocks (the
/// deterministic harness, like `Service` for a single engine).
pub struct ShardedService<B: ModelBackend> {
    lanes: Vec<EngineCore<B>>,
    route: RoutePolicy,
    rr_next: usize,
    page_tokens: usize,
    /// Request id → home lane (route decisions are sticky: cancellation
    /// must reach the lane that holds the request's state).  Entries of
    /// finished requests are pruned every [`HOME_PRUNE_TICKS`] ticks so
    /// a long-lived fleet front-end does not grow one entry per request
    /// served, forever.
    homes: HashMap<u64, usize>,
    ticks: u64,
    /// Worker threads for lane ticks (1 = sequential); capped at the
    /// lane count.
    lane_threads: usize,
    cmd_tx: Sender<Command>,
    cmd_rx: Receiver<Command>,
}

/// How often (in fleet ticks) the sticky request→lane map drops
/// entries whose lane no longer tracks the request.
const HOME_PRUNE_TICKS: u64 = 256;

impl<B: ModelBackend> ShardedService<B> {
    /// Build a fleet of `shards` lanes.  `cfg` is the FLEET config: its
    /// `kv_pages` is the total budget, split per board with the
    /// remainder spread over the first `kv_pages % shards` lanes so no
    /// page of the budget is silently dropped (each lane keeps the rest
    /// of the config — `max_batch` is per board, like the compute it
    /// models).  `backend_for(i)` builds lane `i`'s backend; the
    /// sampler is cloned per lane.
    pub fn new(
        shards: usize,
        route: RoutePolicy,
        cfg: SchedulerConfig,
        sampler: Sampler,
        mut backend_for: impl FnMut(usize) -> B,
    ) -> Self {
        let shards = shards.max(1);
        let (base, extra) = (cfg.kv_pages / shards, cfg.kv_pages % shards);
        let lanes = (0..shards)
            .map(|i| {
                let lane_cfg = SchedulerConfig {
                    kv_pages: (base + usize::from(i < extra)).max(1),
                    ..cfg.clone()
                };
                EngineCore::new(
                    backend_for(i),
                    Scheduler::new(lane_cfg),
                    sampler.clone(),
                    ClockMode::Virtual,
                )
            })
            .collect();
        let (cmd_tx, cmd_rx) = mpsc::channel();
        Self {
            lanes,
            route,
            rr_next: 0,
            page_tokens: cfg.page_tokens,
            homes: HashMap::new(),
            ticks: 0,
            lane_threads: shards,
            cmd_tx,
            cmd_rx,
        }
    }

    /// Worker threads for lane ticks.  Defaults to one per lane; `1`
    /// restores strictly sequential ticking (same streams either way —
    /// lanes share no state — this only trades wall time).
    pub fn with_lane_threads(mut self, n: usize) -> Self {
        self.lane_threads = n.max(1);
        self
    }

    /// Install one flight recorder PER LANE (ring of `capacity` events
    /// each, tagged with the lane index).  Lanes never share a
    /// recorder, so the scoped lane workers record without
    /// synchronization and parallel ticking stays byte-identical to
    /// sequential.  Drain with [`ShardedService::take_event_logs`].
    pub fn with_recording(mut self, capacity: usize) -> Self {
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            lane.set_recorder(Some(Recorder::with_capacity(capacity).for_lane(i as u32)));
        }
        self
    }

    /// Drain every lane's event ring, ordered by lane index.  Empty
    /// when recording was never enabled.
    pub fn take_event_logs(&mut self) -> Vec<EventLog> {
        self.lanes.iter_mut().filter_map(EngineCore::take_event_log).collect()
    }

    /// One lane's flight recorder, if recording is enabled — lets a
    /// caller land backend-specific events (e.g. the `SimBackend` cost
    /// table stats) on the lane's ring before draining it.
    pub fn recorder(&self, shard: usize) -> Option<&Recorder> {
        self.lanes[shard].recorder()
    }

    pub fn shards(&self) -> usize {
        self.lanes.len()
    }

    /// One lane's scheduler (pool/accounting inspection in tests).
    pub fn scheduler(&self, shard: usize) -> &Scheduler {
        self.lanes[shard].scheduler()
    }

    /// One lane's model backend (inspection — e.g. `SimBackend`
    /// step-pricing table stats for fleet serve summaries).
    pub fn backend(&self, shard: usize) -> &B {
        self.lanes[shard].backend()
    }

    /// The lane a request was routed to (`None` before its submit
    /// command has been applied by a tick).
    pub fn shard_of(&self, req_id: u64) -> Option<usize> {
        self.homes.get(&req_id).copied()
    }

    /// Submit a request; the router picks its lane when the command is
    /// applied (so least-loaded sees up-to-date lane state).  The
    /// handle streams tokens and cancels exactly like a single-engine
    /// `Service` handle.
    pub fn submit(&self, req: Request) -> RequestHandle {
        let (etx, erx) = mpsc::channel();
        let id = req.id;
        let _ = self.cmd_tx.send(Command::Submit(req, etx));
        RequestHandle::new(id, erx, self.cmd_tx.clone())
    }

    /// Lane index with the fewest requests in flight (waiting + running
    /// + parked), ties by live KV pages, then lane index.
    fn least_loaded(&self) -> usize {
        self.lanes
            .iter()
            .enumerate()
            .min_by_key(|(i, lane)| {
                let s = lane.scheduler();
                let in_flight = s.pending() + s.running().len() + s.preempted().len();
                (in_flight, s.pool.used_pages(), *i)
            })
            .map(|(i, _)| i)
            .expect("a fleet has at least one lane")
    }

    /// Prefix-affinity target: the KV pool's own chained hash of the
    /// prompt's first full page (the exact key the per-shard prefix
    /// index uses — one definition, so routing can never drift from
    /// what the caches actually store), mod the shard count.  `None`
    /// for prompts shorter than one page (nothing cacheable to be
    /// affine to).
    fn prefix_shard(&self, prompt: &[u32]) -> Option<usize> {
        if prompt.len() < self.page_tokens {
            return None;
        }
        let h = chain_hash(PREFIX_HASH_SEED, &prompt[..self.page_tokens]);
        Some((h % self.lanes.len() as u64) as usize)
    }

    fn pick_shard(&mut self, req: &Request) -> usize {
        match self.route {
            RoutePolicy::RoundRobin => {
                let shard = self.rr_next % self.lanes.len();
                self.rr_next = self.rr_next.wrapping_add(1);
                shard
            }
            RoutePolicy::LeastLoaded => self.least_loaded(),
            RoutePolicy::PrefixAffinity => {
                self.prefix_shard(&req.prompt).unwrap_or_else(|| self.least_loaded())
            }
        }
    }

    fn submit_routed(&mut self, req: Request, sub: Option<Sender<StreamEvent>>) {
        let shard = self.pick_shard(&req);
        self.homes.insert(req.id, shard);
        self.lanes[shard].submit(req, sub);
    }

    fn apply_commands(&mut self) {
        while let Ok(cmd) = self.cmd_rx.try_recv() {
            match cmd {
                Command::Submit(req, tx) => self.submit_routed(req, Some(tx)),
                Command::Cancel(id) => {
                    if let Some(&shard) = self.homes.get(&id) {
                        self.lanes[shard].cancel(id);
                    }
                }
                // Meaningless under manual ticking (as for `Service`).
                Command::Shutdown => {}
            }
        }
    }

    /// Apply pending commands, then advance every lane one iteration —
    /// on `lane_threads` scoped workers, or in place when sequential.
    /// Lanes tick independently — board clocks are not synchronized —
    /// and a drained lane is a no-op.  Results are consumed in lane
    /// order either way (first error in lane order wins), so parallel
    /// and sequential ticking are byte-identical.  `Stepped` if any
    /// lane stepped, `Swept` if any did bookkeeping, `Drained` when
    /// the whole fleet is idle.
    pub fn tick(&mut self) -> Result<Tick>
    where
        B: Send,
    {
        self.apply_commands();
        self.ticks += 1;
        if self.ticks % HOME_PRUNE_TICKS == 0 {
            // Forget finished requests' routes: a cancel for a request
            // no lane tracks any more is a no-op on any lane.
            let lanes = &self.lanes;
            self.homes.retain(|&id, &mut shard| lanes[shard].scheduler().tracks(id));
        }
        let threads = self.lane_threads.min(self.lanes.len()).max(1);
        let ticks: Vec<Result<Tick>> = if threads == 1 {
            // Sequential: tick in place, stopping at the first error
            // (the pre-parallel fleet's exact behavior).
            let mut out = Vec::with_capacity(self.lanes.len());
            for lane in &mut self.lanes {
                let t = lane.tick();
                let failed = t.is_err();
                out.push(t);
                if failed {
                    break;
                }
            }
            out
        } else {
            // Each worker owns a disjoint chunk of lanes (no shared
            // state — each lane is a whole board); joining in spawn
            // order keeps the results in lane order.
            let chunk = self.lanes.len().div_ceil(threads);
            std::thread::scope(|s| {
                let handles: Vec<_> = self
                    .lanes
                    .chunks_mut(chunk)
                    .map(|lanes| {
                        s.spawn(move || lanes.iter_mut().map(|l| l.tick()).collect::<Vec<_>>())
                    })
                    .collect();
                handles
                    .into_iter()
                    .flat_map(|h| h.join().expect("lane worker panicked"))
                    .collect()
            })
        };
        let mut any_stepped = false;
        let mut any_active = false;
        for t in ticks {
            match t? {
                Tick::Drained => {}
                Tick::Stepped => {
                    any_stepped = true;
                    any_active = true;
                }
                Tick::Swept | Tick::Idle(_) => any_active = true,
            }
        }
        Ok(if any_stepped {
            Tick::Stepped
        } else if any_active {
            Tick::Swept
        } else {
            Tick::Drained
        })
    }

    /// Tick until every submitted request has resolved on every lane.
    pub fn drain(&mut self) -> Result<()>
    where
        B: Send,
    {
        while self.tick()? != Tick::Drained {}
        Ok(())
    }

    /// Per-shard serving stats, lane order.
    pub fn shard_stats(&self) -> Vec<ServeStats> {
        self.lanes.iter().map(|l| l.stats_snapshot()).collect()
    }

    /// The fleet summary: per-shard stats merged — pooled percentile
    /// samples, summed counters, `served_s` = max over lane clocks.
    pub fn stats(&self) -> ServeStats {
        ServeStats::merge(&self.shard_stats())
    }

    /// The fleet serving clock: boards run in parallel, so fleet time
    /// is the furthest-ahead lane (what `stats().served_s` reports).
    pub fn clock_s(&self) -> f64 {
        self.lanes.iter().map(|l| l.clock_s()).fold(0.0, f64::max)
    }

    /// Offline replay across the fleet (the sharded `Server::run_trace`
    /// equivalent).  A request is routed when the fleet clock reaches
    /// its arrival — NOT when the trace is loaded — so least-loaded
    /// sees the backlog that actually exists at arrival time instead of
    /// counting not-yet-arrived requests.  When every lane is idle the
    /// clock jumps to the next arrival (the single-engine fast-forward,
    /// fleet-wide).  Results land in `shard_stats()` / `stats()`;
    /// per-request streaming still goes through `submit` handles.
    pub fn run_trace(&mut self, mut trace: Vec<Request>) -> Result<ServeStats>
    where
        B: Send,
    {
        trace.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        let mut pending: std::collections::VecDeque<Request> = trace.into();
        loop {
            let now = self.clock_s();
            while pending.front().is_some_and(|r| r.arrival_s <= now) {
                let req = pending.pop_front().expect("front checked");
                self.submit_routed(req, None);
            }
            if self.tick()? == Tick::Drained {
                // Idle fleet: jump to the next arrival (a NaN arrival
                // lands here too and is pinned at submit).
                match pending.pop_front() {
                    Some(req) => self.submit_routed(req, None),
                    None => break,
                }
            }
        }
        Ok(self.stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testing::EchoBackend;
    use crate::coordinator::Server;
    use crate::util::proptest;
    use crate::workload::{
        generate_overload_trace, generate_shared_prefix_trace, generate_trace, OverloadConfig,
        SharedPrefixConfig, TraceConfig,
    };

    fn echo_fleet(
        shards: usize,
        route: RoutePolicy,
        cfg: SchedulerConfig,
    ) -> ShardedService<EchoBackend> {
        ShardedService::new(shards, route, cfg, Sampler::greedy(), |_| EchoBackend::new(64))
    }

    fn trace_cfg(seed: u64) -> TraceConfig {
        TraceConfig {
            n_requests: 10,
            vocab: 64,
            prompt_len_choices: vec![4, 8, 16],
            decode_len_choices: vec![4, 8],
            seed,
            ..Default::default()
        }
    }

    /// Tentpole: the fleet serves the same trace with per-request token
    /// streams byte-identical to a single-shard run — sharding re-times
    /// requests, it never changes what they generate.
    #[test]
    fn fleet_token_streams_match_single_shard() {
        let cfg = SchedulerConfig { max_batch: 2, max_seq: 64, kv_pages: 64, ..Default::default() };
        let routes = [
            RoutePolicy::RoundRobin,
            RoutePolicy::LeastLoaded,
            RoutePolicy::PrefixAffinity,
        ];
        for route in routes {
            let single = Server::new(EchoBackend::new(64), cfg.clone(), Sampler::greedy())
                .run_trace(generate_trace(&trace_cfg(3)))
                .unwrap();
            let mut fleet = echo_fleet(2, route, cfg.clone());
            let merged = fleet.run_trace(generate_trace(&trace_cfg(3))).unwrap();
            assert_eq!(merged.results.len(), single.results.len());
            for a in &single.results {
                let b = merged.results.iter().find(|r| r.id == a.id).unwrap();
                assert_eq!(a.tokens, b.tokens, "{}: req {} differs", route.label(), a.id);
            }
            // Two boards drain a queued trace no slower than one.
            assert!(merged.served_s <= single.served_s, "{} slowed the fleet", route.label());
        }
    }

    /// Streaming and cancellation work through the fleet front-end
    /// exactly as through a single-engine `Service`.
    #[test]
    fn fleet_streams_and_cancels_through_handles() {
        let cfg = SchedulerConfig { max_batch: 1, max_seq: 64, kv_pages: 64, ..Default::default() };
        let mut fleet = echo_fleet(2, RoutePolicy::RoundRobin, cfg);
        let keep = fleet.submit(Request {
            id: 0,
            arrival_s: 0.0,
            prompt: (0..4).collect(),
            max_new_tokens: 4,
        });
        let kill = fleet.submit(Request {
            id: 1,
            arrival_s: 0.0,
            prompt: (0..4).collect(),
            max_new_tokens: 100,
        });
        fleet.tick().unwrap();
        assert_eq!(fleet.shard_of(0), Some(0));
        assert_eq!(fleet.shard_of(1), Some(1), "round-robin spreads the pair");
        for _ in 0..2 {
            fleet.tick().unwrap();
        }
        kill.cancel();
        fleet.drain().unwrap();
        let mut streamed = Vec::new();
        let done = loop {
            match keep.try_event() {
                Some(StreamEvent::Token(t)) => streamed.push(t),
                Some(StreamEvent::Done(r)) => break r,
                Some(StreamEvent::Rejected) => panic!("must not reject"),
                None => panic!("stream ended without Done"),
            }
        };
        assert_eq!(streamed, done.tokens, "stream and result agree");
        assert_eq!(done.tokens.len(), 4);
        let killed = kill.wait().expect("cancelled handles resolve");
        assert!(killed.cancelled);
        assert!(!killed.tokens.is_empty(), "partial tokens kept");
        let stats = fleet.stats();
        assert_eq!(stats.cancelled, 1);
        assert_eq!(stats.results.len(), 2);
    }

    /// Least-loaded spreads a burst by queue depth: routing each submit
    /// against live lane state (pending + running + parked), a 6-burst
    /// over 3 lanes lands exactly 2 requests per lane.
    #[test]
    fn least_loaded_spreads_a_burst() {
        let cfg = SchedulerConfig { max_batch: 1, max_seq: 64, kv_pages: 96, ..Default::default() };
        let mut fleet = echo_fleet(3, RoutePolicy::LeastLoaded, cfg);
        let handles: Vec<RequestHandle> = (0..6)
            .map(|id| {
                fleet.submit(Request {
                    id,
                    arrival_s: 0.0,
                    prompt: (0..8).collect(),
                    max_new_tokens: 4,
                })
            })
            .collect();
        // One tick applies all six submits in order; each routing
        // decision sees the queue depth the previous ones created.
        fleet.tick().unwrap();
        let mut per_lane = [0usize; 3];
        for id in 0..6 {
            per_lane[fleet.shard_of(id).expect("routed")] += 1;
        }
        assert_eq!(per_lane, [2, 2, 2], "queue-depth routing balances the burst");
        fleet.drain().unwrap();
        for h in handles {
            assert_eq!(h.wait().expect("completes").tokens.len(), 4);
        }
    }

    /// The fleet KV budget splits without losing pages: the remainder
    /// of an uneven division lands on the first lanes.
    #[test]
    fn kv_budget_split_keeps_every_page() {
        let cfg = SchedulerConfig { kv_pages: 100, ..Default::default() };
        let fleet = echo_fleet(3, RoutePolicy::RoundRobin, cfg);
        let per: Vec<usize> = (0..3).map(|i| fleet.scheduler(i).cfg.kv_pages).collect();
        assert_eq!(per, vec![34, 33, 33], "remainder spread over the first lanes");
        assert_eq!(per.iter().sum::<usize>(), 100, "no page of the budget dropped");
    }

    /// The sticky request→lane map forgets finished requests: a
    /// long-lived fleet front-end must not grow one entry per served
    /// request forever.
    #[test]
    fn homes_map_prunes_finished_requests() {
        let cfg = SchedulerConfig { max_batch: 1, max_seq: 64, kv_pages: 64, ..Default::default() };
        let mut fleet = echo_fleet(2, RoutePolicy::RoundRobin, cfg);
        let h = fleet.submit(Request {
            id: 0,
            arrival_s: 0.0,
            prompt: (0..4).collect(),
            max_new_tokens: 2,
        });
        fleet.drain().unwrap();
        assert_eq!(h.wait().expect("completes").tokens.len(), 2);
        assert_eq!(fleet.shard_of(0), Some(0), "route remembered until the sweep");
        for _ in 0..HOME_PRUNE_TICKS {
            fleet.tick().unwrap();
        }
        assert_eq!(fleet.shard_of(0), None, "finished request's route pruned");
    }

    /// Prefix affinity is consistent: every request sharing a first
    /// page lands on the same lane, so that lane's prefix cache serves
    /// all of the group's admissions after the first.
    #[test]
    fn prefix_affinity_keeps_groups_on_one_lane() {
        let px = SharedPrefixConfig {
            n_groups: 3,
            prefix_len: 32,
            tail_len_choices: vec![4, 8],
            decode_len_choices: vec![2],
            n_requests: 12,
            rate_per_s: 100.0,
            vocab: 64,
            seed: 11,
        };
        let cfg = SchedulerConfig {
            max_batch: 2,
            max_seq: 128,
            kv_pages: 128,
            page_tokens: 16,
            prefix_cache: true,
            ..Default::default()
        };
        let trace = generate_shared_prefix_trace(&px);
        let prompts: Vec<(u64, Vec<u32>)> =
            trace.iter().map(|r| (r.id, r.prompt[..16].to_vec())).collect();
        let mut fleet = echo_fleet(2, RoutePolicy::PrefixAffinity, cfg);
        let merged = fleet.run_trace(trace).unwrap();
        let mut page_to_lane: HashMap<Vec<u32>, usize> = HashMap::new();
        for (id, page) in prompts {
            let lane = fleet.shard_of(id).expect("routed");
            let prev = page_to_lane.entry(page).or_insert(lane);
            assert_eq!(*prev, lane, "request {id} left its prefix group's lane");
        }
        // Every admission after each group's first hits that lane's cache.
        assert!(merged.prefix_hits >= merged.admissions - 3, "{} hits", merged.prefix_hits);
    }

    /// Tentpole equivalence (parallel lanes): a fleet ticked on 4
    /// worker threads serves a mixed OVERLOAD trace — queueing,
    /// preempt/swap cycles, staggered completions — byte-identical to
    /// the same fleet ticked sequentially: per-request tokens,
    /// bit-identical latencies, and every merged counter.
    #[test]
    fn parallel_lanes_match_sequential_byte_for_byte() {
        let cfg = SchedulerConfig {
            max_batch: 2,
            // 20 pages/lane at 4-token pages vs 16-page sequences: two
            // concurrent residents need 32 pages, so preemption and
            // swap cycles are certain on every lane.
            kv_pages: 4 * 20,
            page_tokens: 4,
            max_seq: 96,
            swap: true,
            ..Default::default()
        };
        let trace_cfg = OverloadConfig {
            n_requests: 16,
            prompt_len: 32,
            decode_len_choices: vec![24, 32],
            vocab: 64,
            seed: 5,
            ..Default::default()
        };
        let run = |threads: usize| {
            let mut fleet = echo_fleet(4, RoutePolicy::LeastLoaded, cfg.clone())
                .with_lane_threads(threads);
            let stats = fleet.run_trace(generate_overload_trace(&trace_cfg)).unwrap();
            (stats, fleet.shard_stats())
        };
        let (a, a_shards) = run(1);
        let (b, b_shards) = run(4);
        assert!(a.preemptions > 0, "the trace must actually overload the lanes");
        assert_eq!(a.results.len(), 16);
        assert_eq!(a.results.len(), b.results.len());
        for x in &a.results {
            let y = b.results.iter().find(|r| r.id == x.id).unwrap();
            assert_eq!(x.tokens, y.tokens, "req {} tokens differ across threading", x.id);
            assert_eq!(x.ttft_s.to_bits(), y.ttft_s.to_bits());
            assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits());
            assert_eq!(x.queue_s.to_bits(), y.queue_s.to_bits());
        }
        assert_eq!(a.served_s.to_bits(), b.served_s.to_bits());
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.decode_steps, b.decode_steps);
        assert_eq!(a.preemptions, b.preemptions);
        assert_eq!(a.swapped_out_pages, b.swapped_out_pages);
        assert_eq!(a.swapped_in_pages, b.swapped_in_pages);
        assert_eq!(a.itl_total, b.itl_total);
        for (i, (x, y)) in a_shards.iter().zip(&b_shards).enumerate() {
            assert_eq!(x.results.len(), y.results.len(), "lane {i} served a different set");
        }
    }

    /// Satellite (fleet property test): random routing policies and
    /// preempt/swap-cycle configs across ≥2 shards, with random
    /// mid-flight cancellations — every lane keeps the ctx == pool
    /// tokens (+ swap registry) invariant on every tick, no request is
    /// ever visible on two shards, and every handle resolves.
    #[test]
    fn property_fleet_lanes_keep_accounting_and_isolation() {
        proptest::check_with("fleet lane accounting", 48, |r| {
            let shards = 2 + r.below(2) as usize;
            let route = match r.below(3) {
                0 => RoutePolicy::RoundRobin,
                1 => RoutePolicy::LeastLoaded,
                _ => RoutePolicy::PrefixAffinity,
            };
            let cfg = SchedulerConfig {
                max_batch: 2,
                // Small per-lane pools: decode growth forces real
                // preempt/swap cycles inside the lanes.
                kv_pages: shards * (8 + r.below(8) as usize),
                page_tokens: 4,
                max_seq: 96,
                prefix_cache: r.below(2) == 0,
                prefill_chunk: (r.below(3) * 8) as usize,
                swap: true,
            };
            let mut fleet = ShardedService::new(shards, route, cfg, Sampler::greedy(), |_| {
                EchoBackend::new(32)
            });
            let trace = generate_trace(&TraceConfig {
                n_requests: 8,
                vocab: 32,
                prompt_len_choices: vec![4, 8, 16],
                decode_len_choices: vec![2, 4, 8],
                seed: r.next_u64(),
                ..Default::default()
            });
            let total = trace.len() as u64;
            let handles: Vec<RequestHandle> = trace.into_iter().map(|t| fleet.submit(t)).collect();
            let mut drained = false;
            for _ in 0..10_000 {
                if r.below(8) == 0 {
                    handles[r.below(total) as usize].cancel();
                }
                let t = fleet.tick().unwrap();
                let mut seen: HashMap<u64, usize> = HashMap::new();
                for s in 0..fleet.shards() {
                    let sched = fleet.scheduler(s);
                    assert!(sched.check_accounting(), "lane {s} ctx/pool desync");
                    for st in sched.running().iter().chain(sched.preempted().iter()) {
                        if let Some(other) = seen.insert(st.req.id, s) {
                            panic!("request {} visible on lanes {other} and {s}", st.req.id);
                        }
                    }
                }
                if t == Tick::Drained {
                    drained = true;
                    break;
                }
            }
            assert!(drained, "fleet must drain");
            for h in handles {
                assert!(h.wait().is_some(), "every handle resolves (done or cancelled)");
            }
        });
    }
}
