//! The multi-shard serving fleet: N independent replica lanes behind
//! the one submit/stream/cancel front-end.
//!
//! FlightLLM's accelerator is SLR-symmetric (§3.1): the natural way to
//! scale serving beyond one die/board is to replicate the whole engine
//! and route requests among the replicas.  [`ShardedService`] owns one
//! lane per shard — each lane its own `ModelBackend` + `PagePool` +
//! `Scheduler` + virtual clock, i.e. a whole board — and a router that
//! assigns every submitted request a home lane:
//!
//! - [`RoutePolicy::RoundRobin`]: lane = arrival index mod N.
//! - [`RoutePolicy::LeastLoaded`]: the lane with the fewest requests in
//!   flight (waiting + running + parked in the swap tier), ties broken
//!   by live KV pages, then lane index — both load signals the issue of
//!   a real fleet scheduler would poll from its boards.
//! - [`RoutePolicy::PrefixAffinity`]: hash the prompt's first full KV
//!   page, lane = hash mod N — requests sharing a system prompt land on
//!   the shard whose CoW prefix cache (PR 2) already holds their
//!   prefix, so the per-board caches see hits a load-blind router would
//!   scatter.  Prompts shorter than one page fall back to least-loaded.
//!
//! The fleet-level `SchedulerConfig` carries the TOTAL KV budget; each
//! lane gets `kv_pages / N` (per-board HBM), so adding shards adds
//! capacity the way adding boards does.  Lanes advance their virtual
//! clocks independently (boards run in parallel); the fleet serving
//! time is the max over lanes, which is what `ServeStats::merge`
//! reports as `served_s`.  Merged percentiles are recomputed from the
//! pooled per-request samples — never averaged per-shard percentiles.
//!
//! Determinism: routing is a pure function of the submission order and
//! lane state, and the sim/echo backends derive logits from (sequence
//! id, last token, position) alone — so under greedy sampling a
//! request's token stream is byte-identical whichever lane serves it,
//! and identical to a single-shard run (asserted in
//! `experiments::sharded_fleet_*` tests).  A cloned temperature sampler
//! seeds one RNG per lane, so routing changes WOULD reorder its draws —
//! the fleet comparisons therefore pin greedy sampling.
//!
//! Parallel lanes: each lane is fully self-contained (own backend,
//! scheduler, KV pool, virtual clock — the PR 5 design), so a fleet
//! tick can run the lane iterations on a scoped worker-thread pool
//! ([`ShardedService::with_lane_threads`]; boards do run in parallel).
//! Routing and command application stay on the caller's thread BEFORE
//! the ticks, lane results are collected back IN LANE ORDER (first
//! error in lane order wins, like the sequential loop), and stats
//! merge by lane index — so a parallel fleet's served streams and
//! merged stats are byte-identical to sequential ticking
//! (`lane_threads == 1`), asserted by the equivalence test below.
//!
//! Fleet memory (PR 9, both opt-in so the defaults above are
//! untouched): the boards' DDR tiers are treated as ONE memory system,
//! the multi-board reading of FlightLLM's HBM/DDR hierarchy (§4.4).
//!
//! - **Global prefix directory** ([`ShardedService::with_global_prefix`]):
//!   a fleet-level map from the pool's own chained page hash to the
//!   lane that materialized the page — the SAME `chain_hash` routing
//!   uses, so the directory can never drift from the lane caches.  At
//!   routing time the target lane *adopts* any directory-owned prefix
//!   pages it is missing (`PagePool::adopt_prefix_page`): the pages
//!   are copied over the inter-board link (priced via
//!   `ModelBackend::swap_cost_s`, like swap traffic) instead of being
//!   re-prefilled, so a hot system prompt is prefilled on exactly one
//!   board fleet-wide.  Stale entries self-heal: an owner that evicted
//!   the page loses the claim to the next lane that materializes it.
//! - **Cross-shard migration** ([`ShardedService::with_migration`]):
//!   true work stealing over the PR 4 swap machinery.  When a lane
//!   holds parked (swapped-out) requests and a strictly less loaded
//!   lane has room, the oldest parked request's DDR image moves over
//!   the inter-board link (`EngineCore::export_parked` →
//!   `import_parked`), the sticky request→lane mapping re-homes, and
//!   the target's ordinary `swap_in` path resumes it byte-identically
//!   — the submit/stream/cancel front-end never notices.
//! - **Affinity spill** ([`ShardedService::with_affinity_spill`]):
//!   prefix-affinity routing falls back to least-loaded once the home
//!   lane's in-flight depth exceeds the threshold, fixing the hotspot
//!   a skewed prefix distribution creates.  With the directory on, the
//!   spilled request's prefix follows it via adoption.
//!
//! Migration and adoption decisions run on the CALLER's thread (inside
//! `tick`/`submit_routed`, never on lane workers), so parallel ticking
//! stays byte-identical to sequential.

use std::collections::HashMap;
use std::sync::mpsc::{self, Receiver, Sender};

use anyhow::Result;

use crate::obs::{EventLog, Recorder};
use crate::workload::Request;

use super::kv_cache::{chain_hash, PREFIX_HASH_SEED};
use super::sampler::Sampler;
use super::scheduler::{Scheduler, SchedulerConfig};
use super::server::{ModelBackend, ServeStats};
use super::service::{ClockMode, Command, EngineCore, RequestHandle, StreamEvent, Tick};

/// How the fleet assigns a submitted request to a shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Submission order mod shard count.
    RoundRobin,
    /// Fewest requests in flight, ties by live KV pages, then index.
    LeastLoaded,
    /// Hash of the prompt's first full KV page, so shared-prefix
    /// traffic keeps hitting the same shard's prefix cache.
    PrefixAffinity,
}

impl RoutePolicy {
    /// Parse a CLI spelling (`rr` / `load` / `prefix`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "rr" | "round-robin" => Some(RoutePolicy::RoundRobin),
            "load" | "least-loaded" => Some(RoutePolicy::LeastLoaded),
            "prefix" | "prefix-affinity" => Some(RoutePolicy::PrefixAffinity),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastLoaded => "least-loaded",
            RoutePolicy::PrefixAffinity => "prefix-affinity",
        }
    }
}

/// N replica serving lanes behind one submit/stream/cancel front-end,
/// driven by manual `tick`/`drain` on per-lane virtual clocks (the
/// deterministic harness, like `Service` for a single engine).
pub struct ShardedService<B: ModelBackend> {
    lanes: Vec<EngineCore<B>>,
    route: RoutePolicy,
    rr_next: usize,
    page_tokens: usize,
    /// Request id → home lane (route decisions are sticky: cancellation
    /// must reach the lane that holds the request's state).  Entries of
    /// finished requests are pruned every [`HOME_PRUNE_TICKS`] ticks so
    /// a long-lived fleet front-end does not grow one entry per request
    /// served, forever.
    homes: HashMap<u64, usize>,
    ticks: u64,
    /// Worker threads for lane ticks (1 = sequential); capped at the
    /// lane count.
    lane_threads: usize,
    /// Fleet prefix directory: chained page hash → lane that
    /// materialized the page (same hash chain as the per-lane index
    /// and affinity routing — one definition, no drift).  Only
    /// consulted when `global_prefix` is on; entries whose owner
    /// evicted the page are stale and self-heal at the next lookup.
    directory: HashMap<u64, usize>,
    /// Opt-in: adopt directory-owned prefix pages across lanes.
    global_prefix: bool,
    /// Opt-in: steal parked (swapped-out) requests from overloaded
    /// lanes onto less loaded ones.
    migrate: bool,
    /// Opt-in: prefix-affinity falls back to least-loaded once the
    /// home lane's in-flight depth EXCEEDS this threshold.
    affinity_spill: Option<usize>,
    cmd_tx: Sender<Command>,
    cmd_rx: Receiver<Command>,
}

/// How often (in fleet ticks) the sticky request→lane map drops
/// entries whose lane no longer tracks the request.
const HOME_PRUNE_TICKS: u64 = 256;

impl<B: ModelBackend> ShardedService<B> {
    /// Build a fleet of `shards` lanes.  `cfg` is the FLEET config: its
    /// `kv_pages` is the total budget, split per board with the
    /// remainder spread over the first `kv_pages % shards` lanes so no
    /// page of the budget is silently dropped (each lane keeps the rest
    /// of the config — `max_batch` is per board, like the compute it
    /// models).  `backend_for(i)` builds lane `i`'s backend; the
    /// sampler is cloned per lane.
    pub fn new(
        shards: usize,
        route: RoutePolicy,
        cfg: SchedulerConfig,
        sampler: Sampler,
        mut backend_for: impl FnMut(usize) -> B,
    ) -> Self {
        let shards = shards.max(1);
        let (base, extra) = (cfg.kv_pages / shards, cfg.kv_pages % shards);
        let lanes = (0..shards)
            .map(|i| {
                let lane_cfg = SchedulerConfig {
                    kv_pages: (base + usize::from(i < extra)).max(1),
                    ..cfg.clone()
                };
                EngineCore::new(
                    backend_for(i),
                    Scheduler::new(lane_cfg),
                    sampler.clone(),
                    ClockMode::Virtual,
                )
            })
            .collect();
        let (cmd_tx, cmd_rx) = mpsc::channel();
        Self {
            lanes,
            route,
            rr_next: 0,
            page_tokens: cfg.page_tokens,
            homes: HashMap::new(),
            ticks: 0,
            lane_threads: shards,
            directory: HashMap::new(),
            global_prefix: false,
            migrate: false,
            affinity_spill: None,
            cmd_tx,
            cmd_rx,
        }
    }

    /// Enable the fleet-global prefix directory: a lane missing a
    /// prefix page another lane already materialized ADOPTS it (one
    /// inter-board page copy, priced like swap traffic) instead of
    /// re-prefilling it.  Off by default.
    pub fn with_global_prefix(mut self) -> Self {
        self.global_prefix = true;
        self
    }

    /// Enable cross-shard migration of parked requests (work
    /// stealing): an overloaded lane's oldest swapped-out request
    /// moves to a strictly less loaded lane with room and resumes
    /// there byte-identically.  Off by default.
    pub fn with_migration(mut self) -> Self {
        self.migrate = true;
        self
    }

    /// Make prefix-affinity routing fall back to least-loaded once the
    /// home lane holds MORE than `max_in_flight` requests (waiting +
    /// running + parked) — the hotspot guard for skewed prefix
    /// distributions.  Off (pure affinity) by default.
    pub fn with_affinity_spill(mut self, max_in_flight: usize) -> Self {
        self.affinity_spill = Some(max_in_flight);
        self
    }

    /// Worker threads for lane ticks.  Defaults to one per lane; `1`
    /// restores strictly sequential ticking (same streams either way —
    /// lanes share no state — this only trades wall time).
    pub fn with_lane_threads(mut self, n: usize) -> Self {
        self.lane_threads = n.max(1);
        self
    }

    /// Install one flight recorder PER LANE (ring of `capacity` events
    /// each, tagged with the lane index).  Lanes never share a
    /// recorder, so the scoped lane workers record without
    /// synchronization and parallel ticking stays byte-identical to
    /// sequential.  Drain with [`ShardedService::take_event_logs`].
    pub fn with_recording(mut self, capacity: usize) -> Self {
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            lane.set_recorder(Some(Recorder::with_capacity(capacity).for_lane(i as u32)));
        }
        self
    }

    /// Drain every lane's event ring, ordered by lane index.  Empty
    /// when recording was never enabled.
    pub fn take_event_logs(&mut self) -> Vec<EventLog> {
        self.lanes.iter_mut().filter_map(EngineCore::take_event_log).collect()
    }

    /// One lane's flight recorder, if recording is enabled — lets a
    /// caller land backend-specific events (e.g. the `SimBackend` cost
    /// table stats) on the lane's ring before draining it.
    pub fn recorder(&self, shard: usize) -> Option<&Recorder> {
        self.lanes[shard].recorder()
    }

    pub fn shards(&self) -> usize {
        self.lanes.len()
    }

    /// One lane's scheduler (pool/accounting inspection in tests).
    pub fn scheduler(&self, shard: usize) -> &Scheduler {
        self.lanes[shard].scheduler()
    }

    /// One lane's model backend (inspection — e.g. `SimBackend`
    /// step-pricing table stats for fleet serve summaries).
    pub fn backend(&self, shard: usize) -> &B {
        self.lanes[shard].backend()
    }

    /// The lane a request was routed to (`None` before its submit
    /// command has been applied by a tick).
    pub fn shard_of(&self, req_id: u64) -> Option<usize> {
        self.homes.get(&req_id).copied()
    }

    /// Submit a request; the router picks its lane when the command is
    /// applied (so least-loaded sees up-to-date lane state).  The
    /// handle streams tokens and cancels exactly like a single-engine
    /// `Service` handle.
    pub fn submit(&self, req: Request) -> RequestHandle {
        let (etx, erx) = mpsc::channel();
        let id = req.id;
        let _ = self.cmd_tx.send(Command::Submit(req, etx));
        RequestHandle::new(id, erx, self.cmd_tx.clone())
    }

    /// Lane index with the fewest requests in flight (waiting + running
    /// + parked), ties by live KV pages, then lane index.
    fn least_loaded(&self) -> usize {
        self.lanes
            .iter()
            .enumerate()
            .min_by_key(|(i, lane)| {
                let s = lane.scheduler();
                let in_flight = s.pending() + s.running().len() + s.preempted().len();
                (in_flight, s.pool.used_pages(), *i)
            })
            .map(|(i, _)| i)
            .expect("a fleet has at least one lane")
    }

    /// Prefix-affinity target: the KV pool's own chained hash of the
    /// prompt's first full page (the exact key the per-shard prefix
    /// index uses — one definition, so routing can never drift from
    /// what the caches actually store), mod the shard count.  `None`
    /// for prompts shorter than one page (nothing cacheable to be
    /// affine to).
    fn prefix_shard(&self, prompt: &[u32]) -> Option<usize> {
        if prompt.len() < self.page_tokens {
            return None;
        }
        let h = chain_hash(PREFIX_HASH_SEED, &prompt[..self.page_tokens]);
        Some((h % self.lanes.len() as u64) as usize)
    }

    /// Requests in flight on one lane (waiting + running + parked) —
    /// the load signal both least-loaded routing and the migration /
    /// affinity-spill policies read.
    fn lane_load(&self, lane: usize) -> usize {
        let s = self.lanes[lane].scheduler();
        s.pending() + s.running().len() + s.preempted().len()
    }

    fn pick_shard(&mut self, req: &Request) -> usize {
        match self.route {
            RoutePolicy::RoundRobin => {
                let shard = self.rr_next % self.lanes.len();
                self.rr_next = self.rr_next.wrapping_add(1);
                shard
            }
            RoutePolicy::LeastLoaded => self.least_loaded(),
            RoutePolicy::PrefixAffinity => match self.prefix_shard(&req.prompt) {
                // The hotspot guard: a skewed prefix distribution can
                // pile every request onto one lane while the rest
                // idle.  Past the spill threshold the request goes to
                // the least-loaded lane instead — and with the global
                // directory on, its prefix follows it by adoption.
                Some(home) => {
                    let spill = self
                        .affinity_spill
                        .is_some_and(|limit| self.lane_load(home) > limit);
                    if spill {
                        self.least_loaded()
                    } else {
                        home
                    }
                }
                None => self.least_loaded(),
            },
        }
    }

    /// Walk the prompt's prefix-hash chain against the fleet directory:
    /// pages this lane already holds re-assert its claim; pages another
    /// live owner holds are ADOPTED (installed into this lane's pool
    /// and priced as inter-board transfer); the first page nobody holds
    /// breaks the chain — this lane will materialize it at prefill, so
    /// it claims ownership of that page now and stops (pages past a
    /// gap can never be served from cache, so copying them would be
    /// pure waste).
    fn adopt_and_publish(&mut self, shard: usize, req: &Request) {
        let hashes = self.lanes[shard].scheduler().pool.prefix_hashes(&req.prompt);
        let mut planned: Vec<(u64, usize)> = Vec::new();
        for &h in &hashes {
            if self.lanes[shard].scheduler().pool.has_indexed(h) {
                self.directory.entry(h).or_insert(shard);
                continue;
            }
            let live_owner = self
                .directory
                .get(&h)
                .copied()
                .filter(|&o| o != shard && self.lanes[o].scheduler().pool.has_indexed(h));
            match live_owner {
                Some(owner) => planned.push((h, owner)),
                None => {
                    // Unowned, or a stale claim (owner evicted it, or
                    // a dangling self-claim): this lane's prefill will
                    // materialize the page, so the claim moves here.
                    self.directory.insert(h, shard);
                    break;
                }
            }
        }
        // Install in chain order, stopping at the first page the pool
        // cannot take (no truly-free page — adoption never evicts the
        // lane's own warm cache).  Consecutive pages from one owner are
        // accounted as one transfer.
        let mut groups: Vec<(usize, u64)> = Vec::new();
        for (h, owner) in planned {
            if !self.lanes[shard].scheduler_mut().pool.adopt_prefix_page(h) {
                break;
            }
            match groups.last_mut() {
                Some((o, pages)) if *o == owner => *pages += 1,
                _ => groups.push((owner, 1)),
            }
        }
        for (owner, pages) in groups {
            self.lanes[shard].record_prefix_adoption(req.id, owner as u32, pages);
        }
    }

    fn submit_routed(&mut self, req: Request, sub: Option<Sender<StreamEvent>>) {
        let shard = self.pick_shard(&req);
        self.homes.insert(req.id, shard);
        if self.global_prefix {
            self.adopt_and_publish(shard, &req);
        }
        self.lanes[shard].submit(req, sub);
    }

    /// Work stealing: for each lane holding parked (swapped-out)
    /// requests, move its OLDEST parked request to the best strictly
    /// less loaded lane that has no parked backlog of its own and
    /// enough free pages to resume it.  The DDR image's inter-board
    /// copy is priced on the target (`EngineCore::import_parked`); the
    /// target's clock is first synced to the donor's so the resumed
    /// request cannot observe time running backwards.  Runs on the
    /// caller's thread, in lane order — deterministic.
    fn migrate_parked(&mut self) {
        for donor in 0..self.lanes.len() {
            let oldest = self.lanes[donor]
                .scheduler()
                .preempted()
                .iter()
                .min_by(|a, b| {
                    a.admitted_s.total_cmp(&b.admitted_s).then(a.req.id.cmp(&b.req.id))
                })
                .map(|s| (s.req.id, s.ctx));
            let Some((seq, ctx)) = oldest else { continue };
            let donor_load = self.lane_load(donor);
            let need = self.lanes[donor].scheduler().pool.pages_for(ctx + 1);
            let target = (0..self.lanes.len())
                .filter(|&t| {
                    t != donor
                        && self.lanes[t].scheduler().preempted().is_empty()
                        && self.lanes[t].scheduler().pool.free_pages() >= need
                        && self.lane_load(t) + 1 < donor_load
                })
                .min_by_key(|&t| (self.lane_load(t), t));
            let Some(target) = target else { continue };
            let donor_clock = self.lanes[donor].clock_s();
            let parked = self.lanes[donor].export_parked(seq).expect("picked from parked set");
            self.lanes[target].sync_clock_at_least(donor_clock);
            self.lanes[target].import_parked(parked, donor as u32);
            self.homes.insert(seq, target);
        }
    }

    fn apply_commands(&mut self) {
        while let Ok(cmd) = self.cmd_rx.try_recv() {
            match cmd {
                Command::Submit(req, tx) => self.submit_routed(req, Some(tx)),
                Command::Cancel(id) => {
                    if let Some(&shard) = self.homes.get(&id) {
                        self.lanes[shard].cancel(id);
                    }
                }
                // Meaningless under manual ticking (as for `Service`).
                Command::Shutdown => {}
            }
        }
    }

    /// Apply pending commands, then advance every lane one iteration —
    /// on `lane_threads` scoped workers, or in place when sequential.
    /// Lanes tick independently — board clocks are not synchronized —
    /// and a drained lane is a no-op.  Results are consumed in lane
    /// order either way (first error in lane order wins), so parallel
    /// and sequential ticking are byte-identical.  `Stepped` if any
    /// lane stepped, `Swept` if any did bookkeeping, `Drained` when
    /// the whole fleet is idle.
    pub fn tick(&mut self) -> Result<Tick>
    where
        B: Send,
    {
        self.apply_commands();
        self.ticks += 1;
        if self.ticks % HOME_PRUNE_TICKS == 0 {
            // Forget finished requests' routes: a cancel for a request
            // no lane tracks any more is a no-op on any lane.
            let lanes = &self.lanes;
            self.homes.retain(|&id, &mut shard| lanes[shard].scheduler().tracks(id));
        }
        if self.migrate {
            // On the caller's thread, BEFORE the lane ticks: no lane
            // worker ever sees a request mid-move.
            self.migrate_parked();
        }
        let threads = self.lane_threads.min(self.lanes.len()).max(1);
        let ticks: Vec<Result<Tick>> = if threads == 1 {
            // Sequential: tick in place, stopping at the first error
            // (the pre-parallel fleet's exact behavior).
            let mut out = Vec::with_capacity(self.lanes.len());
            for lane in &mut self.lanes {
                let t = lane.tick();
                let failed = t.is_err();
                out.push(t);
                if failed {
                    break;
                }
            }
            out
        } else {
            // Each worker owns a disjoint chunk of lanes (no shared
            // state — each lane is a whole board); joining in spawn
            // order keeps the results in lane order.
            let chunk = self.lanes.len().div_ceil(threads);
            std::thread::scope(|s| {
                let handles: Vec<_> = self
                    .lanes
                    .chunks_mut(chunk)
                    .map(|lanes| {
                        s.spawn(move || lanes.iter_mut().map(|l| l.tick()).collect::<Vec<_>>())
                    })
                    .collect();
                handles
                    .into_iter()
                    .flat_map(|h| h.join().expect("lane worker panicked"))
                    .collect()
            })
        };
        let mut any_stepped = false;
        let mut any_active = false;
        for t in ticks {
            match t? {
                Tick::Drained => {}
                Tick::Stepped => {
                    any_stepped = true;
                    any_active = true;
                }
                Tick::Swept | Tick::Idle(_) => any_active = true,
            }
        }
        Ok(if any_stepped {
            Tick::Stepped
        } else if any_active {
            Tick::Swept
        } else {
            Tick::Drained
        })
    }

    /// Tick until every submitted request has resolved on every lane.
    pub fn drain(&mut self) -> Result<()>
    where
        B: Send,
    {
        while self.tick()? != Tick::Drained {}
        Ok(())
    }

    /// Per-shard serving stats, lane order.
    pub fn shard_stats(&self) -> Vec<ServeStats> {
        self.lanes.iter().map(|l| l.stats_snapshot()).collect()
    }

    /// The fleet summary: per-shard stats merged — pooled percentile
    /// samples, summed counters, `served_s` = max over lane clocks.
    pub fn stats(&self) -> ServeStats {
        ServeStats::merge(&self.shard_stats())
    }

    /// The fleet serving clock: boards run in parallel, so fleet time
    /// is the furthest-ahead lane (what `stats().served_s` reports).
    pub fn clock_s(&self) -> f64 {
        self.lanes.iter().map(|l| l.clock_s()).fold(0.0, f64::max)
    }

    /// Offline replay across the fleet (the sharded `Server::run_trace`
    /// equivalent).  A request is routed when the fleet clock reaches
    /// its arrival — NOT when the trace is loaded — so least-loaded
    /// sees the backlog that actually exists at arrival time instead of
    /// counting not-yet-arrived requests.  When every lane is idle the
    /// clock jumps to the next arrival (the single-engine fast-forward,
    /// fleet-wide).  Results land in `shard_stats()` / `stats()`;
    /// per-request streaming still goes through `submit` handles.
    pub fn run_trace(&mut self, mut trace: Vec<Request>) -> Result<ServeStats>
    where
        B: Send,
    {
        trace.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        let mut pending: std::collections::VecDeque<Request> = trace.into();
        loop {
            let now = self.clock_s();
            while pending.front().is_some_and(|r| r.arrival_s <= now) {
                let req = pending.pop_front().expect("front checked");
                self.submit_routed(req, None);
            }
            if self.tick()? == Tick::Drained {
                // Idle fleet: jump to the next arrival (a NaN arrival
                // lands here too and is pinned at submit).
                match pending.pop_front() {
                    Some(req) => self.submit_routed(req, None),
                    None => break,
                }
            }
        }
        Ok(self.stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testing::EchoBackend;
    use crate::coordinator::Server;
    use crate::util::proptest;
    use crate::workload::{
        generate_overload_trace, generate_shared_prefix_trace, generate_trace, OverloadConfig,
        SharedPrefixConfig, TraceConfig,
    };

    fn echo_fleet(
        shards: usize,
        route: RoutePolicy,
        cfg: SchedulerConfig,
    ) -> ShardedService<EchoBackend> {
        ShardedService::new(shards, route, cfg, Sampler::greedy(), |_| EchoBackend::new(64))
    }

    fn trace_cfg(seed: u64) -> TraceConfig {
        TraceConfig {
            n_requests: 10,
            vocab: 64,
            prompt_len_choices: vec![4, 8, 16],
            decode_len_choices: vec![4, 8],
            seed,
            ..Default::default()
        }
    }

    /// Tentpole: the fleet serves the same trace with per-request token
    /// streams byte-identical to a single-shard run — sharding re-times
    /// requests, it never changes what they generate.
    #[test]
    fn fleet_token_streams_match_single_shard() {
        let cfg = SchedulerConfig { max_batch: 2, max_seq: 64, kv_pages: 64, ..Default::default() };
        let routes = [
            RoutePolicy::RoundRobin,
            RoutePolicy::LeastLoaded,
            RoutePolicy::PrefixAffinity,
        ];
        for route in routes {
            let single = Server::new(EchoBackend::new(64), cfg.clone(), Sampler::greedy())
                .run_trace(generate_trace(&trace_cfg(3)))
                .unwrap();
            let mut fleet = echo_fleet(2, route, cfg.clone());
            let merged = fleet.run_trace(generate_trace(&trace_cfg(3))).unwrap();
            assert_eq!(merged.results.len(), single.results.len());
            for a in &single.results {
                let b = merged.results.iter().find(|r| r.id == a.id).unwrap();
                assert_eq!(a.tokens, b.tokens, "{}: req {} differs", route.label(), a.id);
            }
            // Two boards drain a queued trace no slower than one.
            assert!(merged.served_s <= single.served_s, "{} slowed the fleet", route.label());
        }
    }

    /// Streaming and cancellation work through the fleet front-end
    /// exactly as through a single-engine `Service`.
    #[test]
    fn fleet_streams_and_cancels_through_handles() {
        let cfg = SchedulerConfig { max_batch: 1, max_seq: 64, kv_pages: 64, ..Default::default() };
        let mut fleet = echo_fleet(2, RoutePolicy::RoundRobin, cfg);
        let keep = fleet.submit(Request {
            id: 0,
            arrival_s: 0.0,
            prompt: (0..4).collect(),
            max_new_tokens: 4,
        });
        let kill = fleet.submit(Request {
            id: 1,
            arrival_s: 0.0,
            prompt: (0..4).collect(),
            max_new_tokens: 100,
        });
        fleet.tick().unwrap();
        assert_eq!(fleet.shard_of(0), Some(0));
        assert_eq!(fleet.shard_of(1), Some(1), "round-robin spreads the pair");
        for _ in 0..2 {
            fleet.tick().unwrap();
        }
        kill.cancel();
        fleet.drain().unwrap();
        let mut streamed = Vec::new();
        let done = loop {
            match keep.try_event() {
                Some(StreamEvent::Token(t)) => streamed.push(t),
                Some(StreamEvent::Done(r)) => break r,
                Some(StreamEvent::Rejected) => panic!("must not reject"),
                None => panic!("stream ended without Done"),
            }
        };
        assert_eq!(streamed, done.tokens, "stream and result agree");
        assert_eq!(done.tokens.len(), 4);
        let killed = kill.wait().expect("cancelled handles resolve");
        assert!(killed.cancelled);
        assert!(!killed.tokens.is_empty(), "partial tokens kept");
        let stats = fleet.stats();
        assert_eq!(stats.cancelled, 1);
        assert_eq!(stats.results.len(), 2);
    }

    /// Least-loaded spreads a burst by queue depth: routing each submit
    /// against live lane state (pending + running + parked), a 6-burst
    /// over 3 lanes lands exactly 2 requests per lane.
    #[test]
    fn least_loaded_spreads_a_burst() {
        let cfg = SchedulerConfig { max_batch: 1, max_seq: 64, kv_pages: 96, ..Default::default() };
        let mut fleet = echo_fleet(3, RoutePolicy::LeastLoaded, cfg);
        let handles: Vec<RequestHandle> = (0..6)
            .map(|id| {
                fleet.submit(Request {
                    id,
                    arrival_s: 0.0,
                    prompt: (0..8).collect(),
                    max_new_tokens: 4,
                })
            })
            .collect();
        // One tick applies all six submits in order; each routing
        // decision sees the queue depth the previous ones created.
        fleet.tick().unwrap();
        let mut per_lane = [0usize; 3];
        for id in 0..6 {
            per_lane[fleet.shard_of(id).expect("routed")] += 1;
        }
        assert_eq!(per_lane, [2, 2, 2], "queue-depth routing balances the burst");
        fleet.drain().unwrap();
        for h in handles {
            assert_eq!(h.wait().expect("completes").tokens.len(), 4);
        }
    }

    /// The fleet KV budget splits without losing pages: the remainder
    /// of an uneven division lands on the first lanes.
    #[test]
    fn kv_budget_split_keeps_every_page() {
        let cfg = SchedulerConfig { kv_pages: 100, ..Default::default() };
        let fleet = echo_fleet(3, RoutePolicy::RoundRobin, cfg);
        let per: Vec<usize> = (0..3).map(|i| fleet.scheduler(i).cfg.kv_pages).collect();
        assert_eq!(per, vec![34, 33, 33], "remainder spread over the first lanes");
        assert_eq!(per.iter().sum::<usize>(), 100, "no page of the budget dropped");
    }

    /// The sticky request→lane map forgets finished requests: a
    /// long-lived fleet front-end must not grow one entry per served
    /// request forever.
    #[test]
    fn homes_map_prunes_finished_requests() {
        let cfg = SchedulerConfig { max_batch: 1, max_seq: 64, kv_pages: 64, ..Default::default() };
        let mut fleet = echo_fleet(2, RoutePolicy::RoundRobin, cfg);
        let h = fleet.submit(Request {
            id: 0,
            arrival_s: 0.0,
            prompt: (0..4).collect(),
            max_new_tokens: 2,
        });
        fleet.drain().unwrap();
        assert_eq!(h.wait().expect("completes").tokens.len(), 2);
        assert_eq!(fleet.shard_of(0), Some(0), "route remembered until the sweep");
        for _ in 0..HOME_PRUNE_TICKS {
            fleet.tick().unwrap();
        }
        assert_eq!(fleet.shard_of(0), None, "finished request's route pruned");
    }

    /// Prefix affinity is consistent: every request sharing a first
    /// page lands on the same lane, so that lane's prefix cache serves
    /// all of the group's admissions after the first.
    #[test]
    fn prefix_affinity_keeps_groups_on_one_lane() {
        let px = SharedPrefixConfig {
            n_groups: 3,
            prefix_len: 32,
            tail_len_choices: vec![4, 8],
            decode_len_choices: vec![2],
            n_requests: 12,
            rate_per_s: 100.0,
            vocab: 64,
            seed: 11,
        };
        let cfg = SchedulerConfig {
            max_batch: 2,
            max_seq: 128,
            kv_pages: 128,
            page_tokens: 16,
            prefix_cache: true,
            ..Default::default()
        };
        let trace = generate_shared_prefix_trace(&px);
        let prompts: Vec<(u64, Vec<u32>)> =
            trace.iter().map(|r| (r.id, r.prompt[..16].to_vec())).collect();
        let mut fleet = echo_fleet(2, RoutePolicy::PrefixAffinity, cfg);
        let merged = fleet.run_trace(trace).unwrap();
        let mut page_to_lane: HashMap<Vec<u32>, usize> = HashMap::new();
        for (id, page) in prompts {
            let lane = fleet.shard_of(id).expect("routed");
            let prev = page_to_lane.entry(page).or_insert(lane);
            assert_eq!(*prev, lane, "request {id} left its prefix group's lane");
        }
        // Every admission after each group's first hits that lane's cache.
        assert!(merged.prefix_hits >= merged.admissions - 3, "{} hits", merged.prefix_hits);
    }

    /// Tentpole (migration): an overloaded lane's parked request is
    /// stolen by an idle lane and resumes there byte-identically — the
    /// handle keeps streaming, the sticky route re-homes, and the
    /// fleet counters see exactly one migration.
    #[test]
    fn migration_steals_parked_request_and_resumes_byte_identically() {
        let cfg = SchedulerConfig {
            max_batch: 2,
            kv_pages: 8, // 4 pages per lane at 4-token pages
            page_tokens: 4,
            max_seq: 64,
            swap: true,
            ..Default::default()
        };
        // Round-robin pins 0 and 2 to lane 0, 1 to lane 1.  Lane 0's
        // pair outgrows its 4-page pool (preemption parks request 2);
        // lane 1's short request finishes early and sits idle.
        let reqs = || {
            vec![
                Request { id: 0, arrival_s: 0.0, prompt: (0..4).collect(), max_new_tokens: 12 },
                Request { id: 1, arrival_s: 0.0, prompt: (0..4).collect(), max_new_tokens: 2 },
                Request { id: 2, arrival_s: 0.0, prompt: (4..8).collect(), max_new_tokens: 12 },
            ]
        };
        let run = |migrate: bool| {
            let mut fleet = echo_fleet(2, RoutePolicy::RoundRobin, cfg.clone());
            if migrate {
                fleet = fleet.with_migration();
            }
            let handles: Vec<RequestHandle> =
                reqs().into_iter().map(|r| fleet.submit(r)).collect();
            fleet.drain().unwrap();
            let results: Vec<_> =
                handles.into_iter().map(|h| h.wait().expect("resolves")).collect();
            (fleet, results)
        };
        let (_, baseline) = run(false);
        let (mut fleet, stolen) = run(true);
        let merged = fleet.stats();
        assert_eq!(merged.migrations, 1, "exactly one steal");
        assert!(merged.migrated_pages > 0, "the DDR image has a footprint");
        let shards = fleet.shard_stats();
        assert_eq!(shards[1].migrations, 1, "recorded on the RECEIVING lane");
        assert_eq!(shards[0].migrations, 0);
        assert_eq!(fleet.shard_of(2), Some(1), "sticky route re-homed");
        for (a, b) in baseline.iter().zip(&stolen) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "request {} resumes byte-identically", a.id);
        }
        assert_eq!(stolen[0].tokens.len(), 12);
        assert_eq!(stolen[2].tokens.len(), 12, "the migrated request completes in full");
        // Both lanes fully unwound: nothing parked, nothing leaked.
        for s in 0..2 {
            assert!(fleet.scheduler(s).is_drained());
            assert_eq!(fleet.scheduler(s).pool.swapped_seqs(), 0);
        }
    }

    /// Tentpole (directory): a prefix materialized on one lane is
    /// ADOPTED by another lane instead of re-prefilled — the adopting
    /// lane's admit is a cache hit backed by pages it never prefilled,
    /// and the copy shows up in the adoption counters on the adopting
    /// lane only.
    #[test]
    fn global_prefix_directory_adopts_across_lanes() {
        let cfg = SchedulerConfig {
            max_batch: 2,
            kv_pages: 32,
            page_tokens: 4,
            max_seq: 64,
            prefix_cache: true,
            ..Default::default()
        };
        let mk = |id| Request {
            id,
            arrival_s: 0.0,
            prompt: (0..8).collect(),
            max_new_tokens: 2,
        };
        // Round-robin deliberately SPLITS the shared prompt across
        // lanes — without the directory it would be prefilled twice.
        let mut fleet = echo_fleet(2, RoutePolicy::RoundRobin, cfg).with_global_prefix();
        let h0 = fleet.submit(mk(0));
        fleet.drain().unwrap();
        let h1 = fleet.submit(mk(1));
        fleet.drain().unwrap();
        let pool1 = fleet.scheduler(1).pool.stats();
        assert_eq!(pool1.adopted_pages, 1, "first page adopted, not prefilled");
        assert_eq!(pool1.prefix_hits, 1, "the adopted page served the admit as a hit");
        let shards = fleet.shard_stats();
        assert_eq!(shards[1].prefix_adoptions, 1);
        assert_eq!(shards[0].prefix_adoptions, 0, "the materializing lane adopts nothing");
        let merged = fleet.stats();
        assert_eq!(merged.prefix_adoptions, 1);
        assert_eq!(merged.prefix_hits, 1);
        let a = h0.wait().expect("completes");
        let b = h1.wait().expect("completes");
        assert_eq!(a.tokens, b.tokens, "identical prompt, identical stream");
    }

    /// Satellite (hotspot fix): a fully skewed prefix trace — every
    /// request shares one first page — pins ALL traffic to one lane
    /// under pure affinity (the ROADMAP caveat); with the spill
    /// threshold the overflow reroutes to the least-loaded lane.
    #[test]
    fn affinity_spill_reroutes_hotspot_overflow() {
        let cfg = SchedulerConfig {
            max_batch: 1,
            kv_pages: 64,
            page_tokens: 4,
            max_seq: 64,
            prefix_cache: true,
            ..Default::default()
        };
        let mk = |id| Request {
            id,
            arrival_s: 0.0,
            prompt: (0..8).collect(),
            max_new_tokens: 2,
        };
        let spread = |fleet: &ShardedService<EchoBackend>| {
            let mut per = vec![0usize; fleet.shards()];
            for id in 0..6 {
                per[fleet.shard_of(id).expect("routed")] += 1;
            }
            per
        };
        let mut pure = echo_fleet(2, RoutePolicy::PrefixAffinity, cfg.clone());
        let handles: Vec<_> = (0..6).map(|id| pure.submit(mk(id))).collect();
        pure.tick().unwrap();
        let per = spread(&pure);
        assert!(per.contains(&6), "pure affinity hotspots one lane: {per:?}");
        pure.drain().unwrap();
        for h in handles {
            assert!(h.wait().is_some());
        }
        // Spill threshold 2: the home lane keeps three in flight, the
        // overflow goes to the idle lane instead of queueing behind.
        let mut guarded =
            echo_fleet(2, RoutePolicy::PrefixAffinity, cfg).with_affinity_spill(2);
        let handles: Vec<_> = (0..6).map(|id| guarded.submit(mk(id))).collect();
        guarded.tick().unwrap();
        let per = spread(&guarded);
        assert_eq!(per, vec![3, 3], "overflow spilled to the idle lane");
        guarded.drain().unwrap();
        for h in handles {
            assert!(h.wait().is_some());
        }
    }

    /// Tentpole equivalence (parallel lanes): a fleet ticked on 4
    /// worker threads serves a mixed OVERLOAD trace — queueing,
    /// preempt/swap cycles, staggered completions — byte-identical to
    /// the same fleet ticked sequentially: per-request tokens,
    /// bit-identical latencies, and every merged counter.
    #[test]
    fn parallel_lanes_match_sequential_byte_for_byte() {
        let cfg = SchedulerConfig {
            max_batch: 2,
            // 20 pages/lane at 4-token pages vs 16-page sequences: two
            // concurrent residents need 32 pages, so preemption and
            // swap cycles are certain on every lane.
            kv_pages: 4 * 20,
            page_tokens: 4,
            max_seq: 96,
            swap: true,
            ..Default::default()
        };
        let trace_cfg = OverloadConfig {
            n_requests: 16,
            prompt_len: 32,
            decode_len_choices: vec![24, 32],
            vocab: 64,
            seed: 5,
            ..Default::default()
        };
        let run = |threads: usize| {
            let mut fleet = echo_fleet(4, RoutePolicy::LeastLoaded, cfg.clone())
                .with_lane_threads(threads);
            let stats = fleet.run_trace(generate_overload_trace(&trace_cfg)).unwrap();
            (stats, fleet.shard_stats())
        };
        let (a, a_shards) = run(1);
        let (b, b_shards) = run(4);
        assert!(a.preemptions > 0, "the trace must actually overload the lanes");
        assert_eq!(a.results.len(), 16);
        assert_eq!(a.results.len(), b.results.len());
        for x in &a.results {
            let y = b.results.iter().find(|r| r.id == x.id).unwrap();
            assert_eq!(x.tokens, y.tokens, "req {} tokens differ across threading", x.id);
            assert_eq!(x.ttft_s.to_bits(), y.ttft_s.to_bits());
            assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits());
            assert_eq!(x.queue_s.to_bits(), y.queue_s.to_bits());
        }
        assert_eq!(a.served_s.to_bits(), b.served_s.to_bits());
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.decode_steps, b.decode_steps);
        assert_eq!(a.preemptions, b.preemptions);
        assert_eq!(a.swapped_out_pages, b.swapped_out_pages);
        assert_eq!(a.swapped_in_pages, b.swapped_in_pages);
        assert_eq!(a.itl_total, b.itl_total);
        for (i, (x, y)) in a_shards.iter().zip(&b_shards).enumerate() {
            assert_eq!(x.results.len(), y.results.len(), "lane {i} served a different set");
        }
    }

    /// Satellite (fleet property test): random routing policies,
    /// preempt/swap-cycle configs, and fleet-memory features (global
    /// prefix directory, cross-shard migration, affinity spill) across
    /// ≥2 shards, with random mid-flight cancellations — every lane
    /// keeps the ctx == pool tokens (+ swap registry) invariant on
    /// every tick, no request is ever visible on two shards (including
    /// mid-migration: moves complete atomically before lane ticks),
    /// and every handle resolves.
    #[test]
    fn property_fleet_lanes_keep_accounting_and_isolation() {
        proptest::check_with("fleet lane accounting", 48, |r| {
            let shards = 2 + r.below(2) as usize;
            let route = match r.below(3) {
                0 => RoutePolicy::RoundRobin,
                1 => RoutePolicy::LeastLoaded,
                _ => RoutePolicy::PrefixAffinity,
            };
            let cfg = SchedulerConfig {
                max_batch: 2,
                // Small per-lane pools: decode growth forces real
                // preempt/swap cycles inside the lanes.
                kv_pages: shards * (8 + r.below(8) as usize),
                page_tokens: 4,
                max_seq: 96,
                prefix_cache: r.below(2) == 0,
                prefill_chunk: (r.below(3) * 8) as usize,
                swap: true,
            };
            let mut fleet = ShardedService::new(shards, route, cfg, Sampler::greedy(), |_| {
                EchoBackend::new(32)
            });
            if r.below(2) == 0 {
                fleet = fleet.with_migration();
            }
            if r.below(2) == 0 {
                fleet = fleet.with_global_prefix();
            }
            if r.below(2) == 0 {
                fleet = fleet.with_affinity_spill(r.below(4) as usize);
            }
            let trace = generate_trace(&TraceConfig {
                n_requests: 8,
                vocab: 32,
                prompt_len_choices: vec![4, 8, 16],
                decode_len_choices: vec![2, 4, 8],
                seed: r.next_u64(),
                ..Default::default()
            });
            let total = trace.len() as u64;
            let handles: Vec<RequestHandle> = trace.into_iter().map(|t| fleet.submit(t)).collect();
            let mut drained = false;
            for _ in 0..10_000 {
                if r.below(8) == 0 {
                    handles[r.below(total) as usize].cancel();
                }
                let t = fleet.tick().unwrap();
                let mut seen: HashMap<u64, usize> = HashMap::new();
                for s in 0..fleet.shards() {
                    let sched = fleet.scheduler(s);
                    assert!(sched.check_accounting(), "lane {s} ctx/pool desync");
                    for st in sched.running().iter().chain(sched.preempted().iter()) {
                        if let Some(other) = seen.insert(st.req.id, s) {
                            panic!("request {} visible on lanes {other} and {s}", st.req.id);
                        }
                    }
                }
                if t == Tick::Drained {
                    drained = true;
                    break;
                }
            }
            assert!(drained, "fleet must drain");
            for h in handles {
                assert!(h.wait().is_some(), "every handle resolves (done or cancelled)");
            }
        });
    }
}
