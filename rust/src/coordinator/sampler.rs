//! Token sampling: greedy (the latency-benchmark default) and
//! temperature sampling for the interactive demo.
//!
//! Both consume the compact [`Logits`] representation: a `Dense` row is
//! scanned the classic way, while a `Peak` row (the synthetic backends'
//! zero-alloc form) is sampled WITHOUT materializing the vocab-sized
//! vector — greedy in O(1), temperature with the same per-position
//! arithmetic (and the same single RNG draw) the dense path would
//! perform on `to_dense()`, so the sampled token is bit-identical
//! either way.

use crate::util::Rng;

use super::server::Logits;

#[derive(Debug, Clone)]
pub enum Sampler {
    Greedy,
    Temperature { t: f64, rng: Rng },
}

impl Sampler {
    pub fn greedy() -> Self {
        Sampler::Greedy
    }

    pub fn temperature(t: f64, seed: u64) -> Self {
        assert!(t > 0.0);
        Sampler::Temperature { t, rng: Rng::new(seed) }
    }

    /// Pick the next token id from a logits row.
    pub fn sample(&mut self, logits: &Logits) -> u32 {
        match self {
            Sampler::Greedy => match logits {
                Logits::Dense(v) => argmax(v) as u32,
                Logits::Peak { index, value, vocab } => {
                    // Mirror `argmax` over the virtual row exactly
                    // (strict `>`, first maximum wins): a positive peak
                    // wins; a zero or out-of-row peak leaves position 0
                    // the first maximum; a NEGATIVE peak at position 0
                    // loses to the first zero after it.
                    if *value > 0.0 && *index < *vocab {
                        *index
                    } else if *value < 0.0 && *index == 0 && *vocab > 1 {
                        1
                    } else {
                        0
                    }
                }
            },
            Sampler::Temperature { t, rng } => match logits {
                Logits::Dense(v) => {
                    let m = v.iter().fold(f32::MIN, |a, &b| a.max(b));
                    let exps: Vec<f64> =
                        v.iter().map(|&l| (((l - m) as f64) / *t).exp()).collect();
                    let total: f64 = exps.iter().sum();
                    let mut u = rng.f64() * total;
                    for (i, e) in exps.iter().enumerate() {
                        u -= e;
                        if u <= 0.0 {
                            return i as u32;
                        }
                    }
                    (v.len() - 1) as u32
                }
                Logits::Peak { index, value, vocab } => {
                    // The dense computation replayed positionally over
                    // the virtual row — same max, same exp per
                    // position, same left-to-right f64 accumulation
                    // order, one RNG draw — without allocating it.
                    let n = *vocab as usize;
                    let idx = *index as usize;
                    let peak_in = idx < n;
                    let mut m = f32::MIN;
                    if n > usize::from(peak_in) {
                        m = m.max(0.0);
                    }
                    if peak_in {
                        m = m.max(*value);
                    }
                    let e_zero = (((0.0f32 - m) as f64) / *t).exp();
                    let e_peak = (((*value - m) as f64) / *t).exp();
                    let mut total = 0.0f64;
                    for i in 0..n {
                        total += if i == idx { e_peak } else { e_zero };
                    }
                    let mut u = rng.f64() * total;
                    for i in 0..n {
                        u -= if i == idx { e_peak } else { e_zero };
                        if u <= 0.0 {
                            return i as u32;
                        }
                    }
                    n.saturating_sub(1) as u32
                }
            },
        }
    }
}

pub fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut s = Sampler::greedy();
        assert_eq!(s.sample(&Logits::Dense(vec![0.1, 2.0, -1.0, 1.9])), 1);
    }

    #[test]
    fn temperature_prefers_high_logits() {
        let mut s = Sampler::temperature(0.5, 42);
        let logits = Logits::Dense(vec![0.0f32, 5.0, 0.0, 0.0]);
        let mut hits = 0;
        for _ in 0..200 {
            if s.sample(&logits) == 1 {
                hits += 1;
            }
        }
        assert!(hits > 180, "high-logit token sampled {hits}/200");
    }

    #[test]
    fn temperature_is_stochastic_but_valid() {
        let mut s = Sampler::temperature(2.0, 7);
        let logits = Logits::Dense(vec![1.0f32, 1.1, 0.9, 1.05]);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..100 {
            let t = s.sample(&logits);
            assert!((t as usize) < 4);
            seen.insert(t);
        }
        assert!(seen.len() >= 3, "high temperature should spread mass");
    }

    /// Tentpole (zero-alloc logits): greedy over a `Peak` row matches
    /// greedy over its dense materialization on every edge the argmax
    /// tie-break can reach — positive peak anywhere, zero peak,
    /// negative peak at and off position 0, out-of-row index.
    #[test]
    fn peak_greedy_matches_dense_materialization() {
        let cases = [
            Logits::Peak { index: 3, value: 10.0, vocab: 8 },
            Logits::Peak { index: 0, value: 10.0, vocab: 8 },
            Logits::Peak { index: 7, value: 10.0, vocab: 8 },
            Logits::Peak { index: 3, value: 0.0, vocab: 8 },
            Logits::Peak { index: 0, value: -1.0, vocab: 8 },
            Logits::Peak { index: 5, value: -1.0, vocab: 8 },
            Logits::Peak { index: 0, value: -1.0, vocab: 1 },
            Logits::Peak { index: 9, value: 10.0, vocab: 8 },
        ];
        for p in cases {
            let mut a = Sampler::greedy();
            let mut b = Sampler::greedy();
            let dense = Logits::Dense(p.to_dense());
            assert_eq!(a.sample(&p), b.sample(&dense), "diverged on {p:?}");
        }
    }

    /// Tentpole (zero-alloc logits): temperature sampling over `Peak`
    /// rows is BIT-identical to sampling their dense materializations —
    /// same arithmetic, same single RNG draw per token — across a run
    /// long enough to exercise the RNG-state equivalence.
    #[test]
    fn peak_temperature_bit_identical_to_dense() {
        let mut peak_s = Sampler::temperature(0.8, 1234);
        let mut dense_s = Sampler::temperature(0.8, 1234);
        for i in 0..300u32 {
            let p = Logits::Peak { index: i % 7, value: 0.5 + (i % 11) as f32, vocab: 7 };
            let d = Logits::Dense(p.to_dense());
            assert_eq!(peak_s.sample(&p), dense_s.sample(&d), "diverged at draw {i}");
        }
    }

    /// The virtual row reports its width like a dense one.
    #[test]
    fn vocab_and_to_dense_agree() {
        let p = Logits::Peak { index: 2, value: 4.0, vocab: 5 };
        assert_eq!(p.vocab(), 5);
        assert_eq!(p.to_dense(), vec![0.0, 0.0, 4.0, 0.0, 0.0]);
        let d = Logits::Dense(vec![1.0, 2.0]);
        assert_eq!(d.vocab(), 2);
        assert_eq!(d.to_dense(), vec![1.0, 2.0]);
    }
}
