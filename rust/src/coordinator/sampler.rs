//! Token sampling: greedy (the latency-benchmark default) and
//! temperature sampling for the interactive demo.

use crate::util::Rng;

#[derive(Debug, Clone)]
pub enum Sampler {
    Greedy,
    Temperature { t: f64, rng: Rng },
}

impl Sampler {
    pub fn greedy() -> Self {
        Sampler::Greedy
    }

    pub fn temperature(t: f64, seed: u64) -> Self {
        assert!(t > 0.0);
        Sampler::Temperature { t, rng: Rng::new(seed) }
    }

    /// Pick the next token id from logits.
    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        match self {
            Sampler::Greedy => argmax(logits) as u32,
            Sampler::Temperature { t, rng } => {
                let m = logits.iter().fold(f32::MIN, |a, &b| a.max(b));
                let exps: Vec<f64> =
                    logits.iter().map(|&l| (((l - m) as f64) / *t).exp()).collect();
                let total: f64 = exps.iter().sum();
                let mut u = rng.f64() * total;
                for (i, e) in exps.iter().enumerate() {
                    u -= e;
                    if u <= 0.0 {
                        return i as u32;
                    }
                }
                (logits.len() - 1) as u32
            }
        }
    }
}

pub fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut s = Sampler::greedy();
        assert_eq!(s.sample(&[0.1, 2.0, -1.0, 1.9]), 1);
    }

    #[test]
    fn temperature_prefers_high_logits() {
        let mut s = Sampler::temperature(0.5, 42);
        let logits = [0.0f32, 5.0, 0.0, 0.0];
        let mut hits = 0;
        for _ in 0..200 {
            if s.sample(&logits) == 1 {
                hits += 1;
            }
        }
        assert!(hits > 180, "high-logit token sampled {hits}/200");
    }

    #[test]
    fn temperature_is_stochastic_but_valid() {
        let mut s = Sampler::temperature(2.0, 7);
        let logits = [1.0f32, 1.1, 0.9, 1.05];
        let mut seen = std::collections::HashSet::new();
        for _ in 0..100 {
            let t = s.sample(&logits);
            assert!((t as usize) < logits.len());
            seen.insert(t);
        }
        assert!(seen.len() >= 3, "high temperature should spread mass");
    }
}
