//! FlightLLM-timed serving backend: drives the coordinator's batched
//! step API with the cycle-approximate `sim::Engine`, so a served trace
//! reports the deterministic latencies the accelerator would deliver
//! (TTFT, per-token, tokens/s) instead of host wall time.
//!
//! Timing model per engine iteration: each prefill slot replays its
//! length-adaptive prefill stream back-to-back (prefill is per-sequence,
//! §5.2) — priced by the CHUNK of prompt tokens it actually runs this
//! iteration, which composes with prefix caching (the first chunk
//! starts after the cached prefix) and with chunked prefill (a long
//! prompt costs several small-bucket streams spread over iterations
//! instead of one big one) — and all decode slots share ONE
//! batched decode stream at the largest context bucket in the batch — the Fig. 15 multibatch lowering
//! (`CompilerOptions::with_batch`).  Streams are lowered and simulated
//! once per (stage, bucket, batch) and memoised, which is what keeps
//! long traces cheap (the same trick as the grid sweeps in
//! `experiments`).
//!
//! The simulator prices time, not numerics, so logits are fabricated
//! deterministically from (sequence, last token, position): served
//! token streams and latencies are bit-identical across runs for a
//! fixed trace and sampler seed.
//!
//! Swap pricing (§4.4 hybrid HBM/DDR placement): with a swap model
//! configured (`with_swap_model`), preemption spill/resume traffic is
//! charged at page-bytes ÷ DDR bandwidth — page bytes come from the
//! model's KV geometry (`ModelConfig::kv_bytes` per token × tokens per
//! page at the serving layer's page size), the bandwidth defaults to
//! the platform's DDR channel.  The virtual clock then shows the real
//! cost of spilling under overload.

use std::collections::HashMap;

use anyhow::Result;

use crate::compiler::{BucketPlan, CompilerOptions};
use crate::config::Target;
use crate::experiments::sim_stage;
use crate::ir::Stage;
use crate::util::Rng;

use super::server::{ModelBackend, SeqSlot, SeqWork, StepOutput};

/// DDR swap-tier cost model: how many bytes one KV page carries and how
/// fast the DDR channel moves them.
#[derive(Debug, Clone, Copy)]
struct SwapModel {
    page_bytes: f64,
    ddr_gbps: f64,
}

/// Serving backend that executes steps on the simulated accelerator.
pub struct SimBackend {
    target: Target,
    plan: BucketPlan,
    vocab: usize,
    /// Memoised stream timings: (is_prefill, bucket, batch) → seconds.
    cache: HashMap<(bool, u64, u32), f64>,
    /// DDR swap pricing; `None` prices swap traffic free.
    swap: Option<SwapModel>,
}

impl SimBackend {
    /// Backend for a target, fabricating logits over the model's vocab.
    pub fn new(target: Target) -> Self {
        let vocab = target.model.vocab as usize;
        Self::with_vocab(target, vocab)
    }

    /// Override the fabricated-logits width: timing comes from the full
    /// model either way, but a small vocab keeps sampling cheap when
    /// serving a synthetic trace against a 7B-scale target.
    pub fn with_vocab(target: Target, vocab: usize) -> Self {
        let plan = BucketPlan::paper_default(target.model.max_seq);
        Self { target, plan, vocab: vocab.max(2), cache: HashMap::new(), swap: None }
    }

    /// Enable DDR swap pricing for a serving layer using
    /// `page_tokens`-token KV pages.  Page bytes follow the model's KV
    /// geometry at the compression recipe's activation width;
    /// `ddr_gbps` overrides the platform's DDR bandwidth (GB/s).
    pub fn with_swap_model(mut self, page_tokens: usize, ddr_gbps: Option<f64>) -> Self {
        let act_bytes = (self.target.compression.act_bits as u64).div_ceil(8).max(1);
        let page_bytes = self.target.model.kv_bytes(page_tokens.max(1) as u64, act_bytes);
        let ddr_gbps = ddr_gbps.unwrap_or(self.target.platform.ddr.bandwidth_gbs).max(1e-3);
        self.swap = Some(SwapModel { page_bytes: page_bytes as f64, ddr_gbps });
        self
    }

    /// Seconds for one (stage, bucket, batch) stream on the accelerator.
    fn stream_s(&mut self, prefill: bool, bucket: u64, batch: u32) -> f64 {
        let target = &self.target;
        *self.cache.entry((prefill, bucket, batch)).or_insert_with(|| {
            let stage = if prefill {
                Stage::Prefill { n: bucket }
            } else {
                Stage::Decode { ctx: bucket }
            };
            let opt = if prefill {
                CompilerOptions::full()
            } else {
                CompilerOptions::with_batch(batch)
            };
            sim_stage(target, stage, opt, true).total_ns * 1e-9
        })
    }

    /// Deterministic pseudo-logits: a single peak derived from the slot's
    /// identity and position (pure function — no mutable RNG state, so
    /// a request generates the same tokens on any shard of a fleet).
    /// `None` for a non-final prefill chunk: it yields no token, so
    /// fabricating a vocab-sized row for the engine to discard was pure
    /// waste.
    fn logits_for(&self, slot: &SeqSlot) -> Option<Vec<f32>> {
        if !slot.work.yields_token() {
            return None;
        }
        let (last, pos) = match &slot.work {
            SeqWork::Prefill { prompt, .. } => {
                (prompt.last().copied().unwrap_or(0) as u64, prompt.len() as u64)
            }
            SeqWork::Decode { last, pos } => (*last as u64, *pos as u64),
        };
        let seed = slot
            .seq
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ last.rotate_left(17)
            ^ pos.rotate_left(41);
        let peak = Rng::new(seed).next_u64() % self.vocab as u64;
        let mut logits = vec![0.0f32; self.vocab];
        logits[peak as usize] = 10.0;
        Some(logits)
    }
}

impl ModelBackend for SimBackend {
    fn step(&mut self, batch: &[SeqSlot]) -> Result<StepOutput> {
        let mut step_s = 0.0f64;
        let mut n_decode = 0u32;
        let mut max_ctx = 0u64;
        for slot in batch {
            match &slot.work {
                SeqWork::Prefill { chunk_start, chunk_end, .. } => {
                    // Only this iteration's chunk runs through the
                    // accelerator, at its own (smaller) length-adaptive
                    // bucket: cached prefix pages hold already-computed
                    // KV (the first chunk starts after them), and under
                    // chunked prefill the rest of the prompt is priced
                    // by later iterations.  A zero-length chunk is a
                    // planner bug — assert in debug builds, and never
                    // invent cost for it (the old `.max(1)` silently
                    // priced phantom work).
                    let chunk = chunk_end.saturating_sub(*chunk_start);
                    debug_assert!(
                        chunk > 0,
                        "degenerate prefill chunk [{chunk_start}, {chunk_end}) for seq {}",
                        slot.seq
                    );
                    if chunk > 0 {
                        let b = self.plan.prefill_bucket(chunk as u64);
                        step_s += self.stream_s(true, b, 1);
                    }
                }
                SeqWork::Decode { pos, .. } => {
                    n_decode += 1;
                    max_ctx = max_ctx.max((*pos).max(1) as u64);
                }
            }
        }
        if n_decode > 0 {
            let b = self.plan.decode_bucket(max_ctx);
            step_s += self.stream_s(false, b, n_decode);
        }
        let logits = batch.iter().map(|s| self.logits_for(s)).collect();
        Ok(StepOutput { logits, step_s })
    }

    /// Price preemption spill/resume traffic over the DDR channel:
    /// pages × page-bytes ÷ bandwidth.  Free when no swap model is
    /// configured (swap disabled at the serving layer).
    fn swap_cost_s(&mut self, pages: usize) -> f64 {
        match self.swap {
            Some(m) => pages as f64 * m.page_bytes / (m.ddr_gbps * 1e9),
            None => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Sampler, SchedulerConfig, Server};
    use crate::workload::{
        generate_burst_trace, generate_shared_prefix_trace, generate_trace,
        SharedPrefixConfig, TraceConfig,
    };

    fn tiny_server(max_batch: usize) -> Server<SimBackend> {
        tiny_server_cfg(max_batch, false)
    }

    fn tiny_server_cfg(max_batch: usize, prefix_cache: bool) -> Server<SimBackend> {
        Server::new(
            SimBackend::with_vocab(Target::u280_tiny(), 64),
            SchedulerConfig {
                max_batch,
                kv_pages: 256,
                page_tokens: 16,
                max_seq: 256,
                prefix_cache,
                ..Default::default()
            },
            Sampler::greedy(),
        )
    }

    /// Acceptance: run_trace against the sim backend is deterministic —
    /// identical per-request TTFT/latency across runs for a fixed seed.
    #[test]
    fn served_trace_is_deterministic() {
        let trace_cfg = TraceConfig {
            n_requests: 8,
            vocab: 64,
            prompt_len_choices: vec![16, 32, 64],
            decode_len_choices: vec![8, 16],
            seed: 11,
            ..Default::default()
        };
        let a = tiny_server(4).run_trace(generate_trace(&trace_cfg)).unwrap();
        let b = tiny_server(4).run_trace(generate_trace(&trace_cfg)).unwrap();
        assert_eq!(a.results.len(), 8);
        assert_eq!(a.served_s.to_bits(), b.served_s.to_bits());
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.ttft_s.to_bits(), y.ttft_s.to_bits(), "TTFT must be exact");
            assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits());
        }
    }

    /// Queued requests see their wait in TTFT on the virtual clock too.
    #[test]
    fn ttft_orders_with_queueing_on_sim_clock() {
        let trace = generate_burst_trace(2, 32, 8, 64, 5);
        let stats = tiny_server(1).run_trace(trace).unwrap();
        let a = stats.results.iter().find(|r| r.id == 0).unwrap();
        let b = stats.results.iter().find(|r| r.id == 1).unwrap();
        assert!(a.queue_s == 0.0 && b.queue_s > 0.0);
        assert!(
            b.ttft_s > a.latency_s,
            "B's first token waits for A to drain: {} vs {}",
            b.ttft_s,
            a.latency_s
        );
    }

    /// Prefix caching prices prefill by the uncached suffix: the same
    /// shared-prefix trace serves cache hits, strictly improves mean
    /// TTFT, and still produces byte-identical tokens (the simulator
    /// prices time, not numerics).
    #[test]
    fn cached_prefill_is_cheaper_and_token_identical() {
        let trace_cfg = SharedPrefixConfig {
            n_groups: 1,
            prefix_len: 96,
            tail_len_choices: vec![8, 16],
            decode_len_choices: vec![4],
            n_requests: 6,
            rate_per_s: 1e3,
            vocab: 64,
            seed: 21,
        };
        let off = tiny_server_cfg(2, false)
            .run_trace(generate_shared_prefix_trace(&trace_cfg))
            .unwrap();
        let on = tiny_server_cfg(2, true)
            .run_trace(generate_shared_prefix_trace(&trace_cfg))
            .unwrap();
        assert_eq!(off.results.len(), 6);
        assert_eq!(on.results.len(), 6);
        assert_eq!(off.prefix_hits, 0);
        assert!(on.prefix_hits > 0, "shared prefixes must hit the cache");
        assert!(
            on.mean_ttft_s() < off.mean_ttft_s(),
            "cached prefill must cut TTFT: {} vs {}",
            on.mean_ttft_s(),
            off.mean_ttft_s()
        );
        for a in &off.results {
            let b = on.results.iter().find(|r| r.id == a.id).unwrap();
            assert_eq!(a.tokens, b.tokens, "tokens must not change with caching");
        }
    }

    /// Satellite: the DDR swap cost model follows the KV geometry —
    /// linear in pages, inversely proportional to the bandwidth, free
    /// when unconfigured (swap disabled at the serving layer).
    #[test]
    fn swap_cost_scales_with_pages_and_bandwidth() {
        let t = Target::u280_tiny();
        let ddr = t.platform.ddr.bandwidth_gbs;
        let act_bytes = (t.compression.act_bits as u64).div_ceil(8).max(1);
        let expect_one = t.model.kv_bytes(16, act_bytes) as f64 / (ddr * 1e9);
        let mut free = SimBackend::with_vocab(t.clone(), 8);
        assert_eq!(free.swap_cost_s(4), 0.0, "no swap model: traffic is free");
        let mut priced = SimBackend::with_vocab(t.clone(), 8).with_swap_model(16, None);
        let one = priced.swap_cost_s(1);
        assert!(one > 0.0);
        assert!((one - expect_one).abs() < 1e-12, "page bytes follow the KV geometry");
        assert!((priced.swap_cost_s(8) - 8.0 * one).abs() < 1e-15, "cost is linear in pages");
        let mut fast = SimBackend::with_vocab(t, 8).with_swap_model(16, Some(2.0 * ddr));
        assert!(
            (fast.swap_cost_s(1) - one / 2.0).abs() < 1e-12,
            "doubling the bandwidth halves the cost"
        );
    }

    /// Satellite: a zero-length prefill chunk is a planner bug — debug
    /// builds assert instead of silently pricing phantom work.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "degenerate prefill chunk")]
    fn degenerate_prefill_chunk_asserts_in_debug() {
        let mut b = SimBackend::with_vocab(Target::u280_tiny(), 8);
        let slot = SeqSlot {
            seq: 0,
            work: SeqWork::Prefill {
                prompt: vec![1, 2, 3, 4],
                cached_ctx: 0,
                chunk_start: 2,
                chunk_end: 2,
            },
        };
        let _ = b.step(&[slot]);
    }

    /// Satellite: release builds skip the degenerate chunk instead of
    /// inventing one token of cost (the old `.max(1)`), and the logits
    /// row count still matches the batch.
    #[cfg(not(debug_assertions))]
    #[test]
    fn degenerate_prefill_chunk_is_not_priced_in_release() {
        let mut b = SimBackend::with_vocab(Target::u280_tiny(), 8);
        let slot = SeqSlot {
            seq: 0,
            work: SeqWork::Prefill {
                prompt: vec![1, 2, 3, 4],
                cached_ctx: 0,
                chunk_start: 2,
                chunk_end: 2,
            },
        };
        let out = b.step(&[slot]).unwrap();
        assert_eq!(out.step_s, 0.0, "no phantom prefill cost");
        assert_eq!(out.logits.len(), 1, "row count still matches the batch");
    }

    /// Satellite: a non-final prefill chunk yields no token, so the
    /// backend returns `None` for its row instead of fabricating a
    /// vocab-sized logits vector the engine would discard; the final
    /// chunk and decode slots carry real rows.
    #[test]
    fn non_final_chunks_carry_no_logits_row() {
        let mut b = SimBackend::with_vocab(Target::u280_tiny(), 8);
        let prefill = |chunk_end: usize| SeqSlot {
            seq: 0,
            work: SeqWork::Prefill {
                prompt: vec![1, 2, 3, 4],
                cached_ctx: 0,
                chunk_start: 0,
                chunk_end,
            },
        };
        let out = b.step(&[prefill(2)]).unwrap();
        assert_eq!(out.logits.len(), 1);
        assert!(out.logits[0].is_none(), "non-final chunk: no logits");
        assert!(out.step_s > 0.0, "the chunk still costs model time");
        let out = b.step(&[prefill(4)]).unwrap();
        assert!(out.logits[0].is_some(), "final chunk: real logits");
        let decode = SeqSlot { seq: 0, work: SeqWork::Decode { last: 3, pos: 4 } };
        let out = b.step(&[decode]).unwrap();
        assert!(out.logits[0].is_some(), "decode: real logits");
    }

    /// Batched decode amortizes weight streaming (Fig. 15): aggregate
    /// tokens/s must rise with the batch size, on the virtual clock.
    #[test]
    fn batched_decode_raises_aggregate_tps() {
        let run = |batch: usize| {
            let trace = generate_burst_trace(batch, 64, 16, 64, 9);
            tiny_server(batch).run_trace(trace).unwrap()
        };
        let s1 = run(1);
        let s4 = run(4);
        assert_eq!(s1.results.len(), 1);
        assert_eq!(s4.results.len(), 4);
        assert!(
            s4.decode_tps() > s1.decode_tps(),
            "batch 4 {} tok/s must beat batch 1 {} tok/s",
            s4.decode_tps(),
            s1.decode_tps()
        );
    }
}
