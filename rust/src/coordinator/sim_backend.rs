//! FlightLLM-timed serving backend: drives the coordinator's batched
//! step API with the cycle-approximate `sim::Engine`, so a served trace
//! reports the deterministic latencies the accelerator would deliver
//! (TTFT, per-token, tokens/s) instead of host wall time.
//!
//! Timing model per engine iteration: each prefill slot replays its
//! length-adaptive prefill stream back-to-back (prefill is per-sequence,
//! §5.2) — priced by the CHUNK of prompt tokens it actually runs this
//! iteration, which composes with prefix caching (the first chunk
//! starts after the cached prefix) and with chunked prefill (a long
//! prompt costs several small-bucket streams spread over iterations
//! instead of one big one) — and all decode slots share ONE
//! batched decode stream at the largest context bucket in the batch — the Fig. 15 multibatch lowering
//! (`CompilerOptions::with_batch`).
//!
//! Stream pricing is a DENSE TABLE, not a lazy memo: length-adaptive
//! compilation (§5.2) makes the set of (stage, bucket, batch) cost
//! points small and finite, so the constructor enumerates the whole
//! `BucketPlan` up front — prefill buckets at batch 1, decode buckets ×
//! batch 1..=`max_batch` — and the serving hot path becomes a pure
//! array read indexed by bucket-ordinal arithmetic (no hashing, no
//! branch-to-simulate).  Points outside the table (a batch beyond
//! `max_batch`, a foreign bucket) fall back to the old lazily-memoised
//! sim run — byte-identical cost, since both paths call the same
//! `sim_stage` — and are counted (`cost_table_stats`) so out-of-table
//! pricing is visible in serve summaries instead of silently slow.
//!
//! The simulator prices time, not numerics, so logits are fabricated
//! deterministically from (sequence, last token, position): served
//! token streams and latencies are bit-identical across runs for a
//! fixed trace and sampler seed.  A yielded token's row is a compact
//! [`Logits::Peak`] — one index + value, no vocab-sized allocation.
//!
//! Swap pricing (§4.4 hybrid HBM/DDR placement): with a swap model
//! configured (`with_swap_model`), preemption spill/resume traffic is
//! charged at page-bytes ÷ DDR bandwidth — page bytes come from the
//! model's KV geometry (`ModelConfig::kv_bytes` per token × tokens per
//! page at the serving layer's page size), the bandwidth defaults to
//! the platform's DDR channel.  The virtual clock then shows the real
//! cost of spilling under overload.  The fleet's memory tier reuses the
//! same price for the inter-board link: adopting a prefix page another
//! shard materialized and migrating a parked request's KV pages are
//! both charged at `swap_cost_s(pages)`, so cross-board transfers cost
//! exactly what local spill/resume traffic does.

use std::collections::HashMap;

use anyhow::Result;

use crate::compiler::{BucketPlan, CompilerOptions};
use crate::config::Target;
use crate::experiments::sim_stage;
use crate::ir::Stage;
use crate::util::Rng;

use super::server::{Logits, ModelBackend, SeqSlot, SeqWork, StepOutput};

/// DDR swap-tier cost model: how many bytes one KV page carries and how
/// fast the DDR channel moves them.
#[derive(Debug, Clone, Copy)]
struct SwapModel {
    page_bytes: f64,
    ddr_gbps: f64,
}

/// Seconds for one (stage, bucket, batch) stream on the accelerator —
/// the shared pricing primitive behind both the dense table and the
/// fallback memo, so the two paths are bit-identical by construction.
fn price_stream(target: &Target, prefill: bool, bucket: u64, batch: u32) -> f64 {
    let stage = if prefill {
        Stage::Prefill { n: bucket }
    } else {
        Stage::Decode { ctx: bucket }
    };
    let opt = if prefill {
        CompilerOptions::full()
    } else {
        CompilerOptions::with_batch(batch)
    };
    sim_stage(target, stage, opt, true).total_ns * 1e-9
}

/// Dense (stage, bucket, batch) → seconds pricing table, precomputed
/// from a [`BucketPlan`] so the serving hot path never hashes or
/// simulates.
///
/// Layout: `prefill_s[ordinal]` for prefill buckets (always batch 1 —
/// prefill streams are per-sequence, §5.2); `decode_s[ordinal *
/// max_batch + (batch - 1)]` for decode buckets × batch
/// 1..=`max_batch`.  Bucket ordinals come from a binary search over the
/// edge list, with an O(1) arithmetic fast path when the edges are
/// uniform-stride (the paper-default decode plan: every 64 tokens).
///
/// **Decode cost-key conflation (modeling choice, pinned by test):**
/// the engine prices a decode batch by its LARGEST member's context
/// bucket — `decode_cost_s(max_ctx, n_decode)` — because the Fig. 15
/// multibatch lowering runs all batch lanes through one stream compiled
/// at a single context bucket.  A mixed-context batch therefore pays
/// the longest member's memory sweep for every lane; shorter members
/// are conservatively over-priced rather than the stream under-priced.
#[derive(Debug, Clone)]
struct CostTable {
    prefill_edges: Vec<u64>,
    decode_edges: Vec<u64>,
    /// `Some(s)` when `decode_edges[i] == (i + 1) * s` for all i — the
    /// ordinal is then pure arithmetic instead of a binary search.
    decode_stride: Option<u64>,
    prefill_s: Vec<f64>,
    decode_s: Vec<f64>,
    max_batch: u32,
}

impl CostTable {
    /// Enumerate every (stage, bucket, batch) point the plan can emit.
    fn build(target: &Target, plan: &BucketPlan, max_batch: u32) -> Self {
        let max_batch = max_batch.max(1);
        let prefill_s = plan.prefill.iter().map(|&b| price_stream(target, true, b, 1)).collect();
        let mut decode_s = Vec::with_capacity(plan.decode.len() * max_batch as usize);
        for &b in &plan.decode {
            for batch in 1..=max_batch {
                decode_s.push(price_stream(target, false, b, batch));
            }
        }
        Self {
            prefill_edges: plan.prefill.clone(),
            decode_edges: plan.decode.clone(),
            decode_stride: uniform_stride(&plan.decode),
            prefill_s,
            decode_s,
            max_batch,
        }
    }

    /// A table that never hits — every pricing falls back to the memo.
    fn empty() -> Self {
        Self {
            prefill_edges: Vec::new(),
            decode_edges: Vec::new(),
            decode_stride: None,
            prefill_s: Vec::new(),
            decode_s: Vec::new(),
            max_batch: 0,
        }
    }

    /// Seconds for a prefill chunk of `len` tokens, if tabled.
    fn prefill_cost_s(&self, len: u64) -> Option<f64> {
        let ord = ordinal(&self.prefill_edges, len)?;
        self.prefill_s.get(ord).copied()
    }

    /// Seconds for a decode step over `batch` lanes at the batch's max
    /// context, if tabled.  See the type doc for the conflation rule:
    /// the whole batch is priced at `max_ctx`'s bucket.
    fn decode_cost_s(&self, max_ctx: u64, batch: u32) -> Option<f64> {
        if batch == 0 || batch > self.max_batch {
            return None;
        }
        let n = self.decode_edges.len();
        let ord = match self.decode_stride {
            Some(s) => {
                if n == 0 {
                    return None;
                }
                (max_ctx.div_ceil(s).saturating_sub(1) as usize).min(n - 1)
            }
            None => ordinal(&self.decode_edges, max_ctx)?,
        };
        self.decode_s.get(ord * self.max_batch as usize + (batch - 1) as usize).copied()
    }

    /// Number of precomputed cost points (prefill + decode×batch).
    fn entries(&self) -> usize {
        self.prefill_s.len() + self.decode_s.len()
    }
}

/// `Some(s)` when `edges[i] == (i + 1) * s` for every i (nonzero `s`).
fn uniform_stride(edges: &[u64]) -> Option<u64> {
    let s = *edges.first()?;
    if s == 0 {
        return None;
    }
    edges.iter().enumerate().all(|(i, &e)| e == (i as u64 + 1) * s).then_some(s)
}

/// Ordinal of the bucket covering `v`: first edge ≥ `v`, clamped to the
/// last (matching `bucket_of` in the compiler's bucket plan).
fn ordinal(edges: &[u64], v: u64) -> Option<usize> {
    if edges.is_empty() {
        return None;
    }
    Some(edges.partition_point(|&e| e < v).min(edges.len() - 1))
}

/// Serving backend that executes steps on the simulated accelerator.
///
/// `Clone` so a fleet can build the (eagerly priced) cost table ONCE in
/// a prototype and stamp out one backend per lane.
#[derive(Clone)]
pub struct SimBackend {
    target: Target,
    plan: BucketPlan,
    vocab: usize,
    /// Dense precomputed pricing — the hot path.
    table: CostTable,
    /// Lazily-memoised pricing for out-of-table points: (is_prefill,
    /// bucket, batch) → seconds.  Same `sim_stage` as the table, so
    /// falling back never changes a price.
    fallback: HashMap<(bool, u64, u32), f64>,
    /// How many pricings missed the table (visible via
    /// `cost_table_stats`).
    fallback_prices: u64,
    /// DDR swap pricing; `None` prices swap traffic free.
    swap: Option<SwapModel>,
}

impl SimBackend {
    /// Backend for a target, fabricating logits over the model's vocab.
    pub fn new(target: Target) -> Self {
        let vocab = target.model.vocab as usize;
        Self::with_vocab(target, vocab)
    }

    /// Override the fabricated-logits width: timing comes from the full
    /// model either way, but a small vocab keeps sampling cheap when
    /// serving a synthetic trace against a 7B-scale target.
    pub fn with_vocab(target: Target, vocab: usize) -> Self {
        let plan = BucketPlan::paper_default(target.model.max_seq);
        let table = CostTable::build(&target, &plan, 1);
        Self {
            target,
            plan,
            vocab: vocab.max(2),
            table,
            fallback: HashMap::new(),
            fallback_prices: 0,
            swap: None,
        }
    }

    /// Rebuild the dense table for decode batches up to `max_batch`
    /// (the serving layer's `SchedulerConfig::max_batch`): steps whose
    /// decode batch exceeds the table fall back to the memo and are
    /// counted, so size this to the scheduler for a fully-dense run.
    pub fn with_max_batch(mut self, max_batch: u32) -> Self {
        self.table = CostTable::build(&self.target, &self.plan, max_batch.max(1));
        self
    }

    /// Disable the dense table entirely — every pricing runs through
    /// the lazily-memoised path.  The pre-table behavior, kept for the
    /// bit-identity equivalence tests and the bench's before/after
    /// comparison.
    pub fn without_cost_table(mut self) -> Self {
        self.table = CostTable::empty();
        self
    }

    /// (dense table entries, pricings that missed the table so far).
    pub fn cost_table_stats(&self) -> (usize, u64) {
        (self.table.entries(), self.fallback_prices)
    }

    /// Emit this backend's cost-model posture (dense-table coverage vs
    /// fallback pricings) as a `CostModel` flight-recorder event — the
    /// trace exporter shows it as a global annotation on `lane`.
    pub fn record_cost_model(&self, rec: &crate::obs::Recorder, lane: u32, now_s: f64) {
        let (entries, fallbacks) = self.cost_table_stats();
        rec.record(
            now_s,
            crate::obs::Event::CostModel {
                lane,
                table_entries: entries as u64,
                fallback_pricings: fallbacks,
            },
        );
    }

    /// Enable DDR swap pricing for a serving layer using
    /// `page_tokens`-token KV pages.  Page bytes follow the model's KV
    /// geometry at the compression recipe's activation width;
    /// `ddr_gbps` overrides the platform's DDR bandwidth (GB/s).
    pub fn with_swap_model(mut self, page_tokens: usize, ddr_gbps: Option<f64>) -> Self {
        let act_bytes = (self.target.compression.act_bits as u64).div_ceil(8).max(1);
        let page_bytes = self.target.model.kv_bytes(page_tokens.max(1) as u64, act_bytes);
        let ddr_gbps = ddr_gbps.unwrap_or(self.target.platform.ddr.bandwidth_gbs).max(1e-3);
        self.swap = Some(SwapModel { page_bytes: page_bytes as f64, ddr_gbps });
        self
    }

    /// Seconds for this iteration's prefill chunk: dense table read,
    /// falling back to the memo for a foreign bucket.
    fn prefill_cost(&mut self, chunk: u64) -> f64 {
        if let Some(s) = self.table.prefill_cost_s(chunk) {
            return s;
        }
        self.fallback_prices += 1;
        let bucket = self.plan.prefill_bucket(chunk);
        self.memo_stream_s(true, bucket, 1)
    }

    /// Seconds for the shared decode stream: the WHOLE batch is priced
    /// at the largest member's context bucket (Fig. 15 multibatch
    /// lowering — one stream, one bucket; see [`CostTable`]).  Dense
    /// table read, falling back to the memo when the batch exceeds the
    /// table's `max_batch`.
    fn decode_cost(&mut self, max_ctx: u64, batch: u32) -> f64 {
        if let Some(s) = self.table.decode_cost_s(max_ctx, batch) {
            return s;
        }
        self.fallback_prices += 1;
        let bucket = self.plan.decode_bucket(max_ctx);
        self.memo_stream_s(false, bucket, batch)
    }

    /// The pre-table pricing path: lower + simulate once per (stage,
    /// bucket, batch) and memoise.
    fn memo_stream_s(&mut self, prefill: bool, bucket: u64, batch: u32) -> f64 {
        let target = &self.target;
        *self
            .fallback
            .entry((prefill, bucket, batch))
            .or_insert_with(|| price_stream(target, prefill, bucket, batch))
    }

    /// Deterministic pseudo-logits: a single peak derived from the slot's
    /// identity and position (pure function — no mutable RNG state, so
    /// a request generates the same tokens on any shard of a fleet).
    /// The row is a compact [`Logits::Peak`] — index + value, not a
    /// vocab-sized vector — and `None` for a non-final prefill chunk:
    /// it yields no token, so fabricating anything for the engine to
    /// discard was pure waste.
    fn logits_for(&self, slot: &SeqSlot) -> Option<Logits> {
        if !slot.work.yields_token() {
            return None;
        }
        let (last, pos) = match &slot.work {
            SeqWork::Prefill { prompt, .. } => {
                (prompt.last().copied().unwrap_or(0) as u64, prompt.len() as u64)
            }
            SeqWork::Decode { last, pos } => (*last as u64, *pos as u64),
        };
        let seed = slot
            .seq
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ last.rotate_left(17)
            ^ pos.rotate_left(41);
        let peak = Rng::new(seed).next_u64() % self.vocab as u64;
        Some(Logits::Peak { index: peak as u32, value: 10.0, vocab: self.vocab as u32 })
    }
}

impl ModelBackend for SimBackend {
    fn step(&mut self, batch: &[SeqSlot]) -> Result<StepOutput> {
        let mut step_s = 0.0f64;
        let mut n_decode = 0u32;
        let mut max_ctx = 0u64;
        for slot in batch {
            match &slot.work {
                SeqWork::Prefill { chunk_start, chunk_end, .. } => {
                    // Only this iteration's chunk runs through the
                    // accelerator, at its own (smaller) length-adaptive
                    // bucket: cached prefix pages hold already-computed
                    // KV (the first chunk starts after them), and under
                    // chunked prefill the rest of the prompt is priced
                    // by later iterations.  A zero-length chunk is a
                    // planner bug — assert in debug builds, and never
                    // invent cost for it (the old `.max(1)` silently
                    // priced phantom work).
                    let chunk = chunk_end.saturating_sub(*chunk_start);
                    debug_assert!(
                        chunk > 0,
                        "degenerate prefill chunk [{chunk_start}, {chunk_end}) for seq {}",
                        slot.seq
                    );
                    if chunk > 0 {
                        step_s += self.prefill_cost(chunk as u64);
                    }
                }
                SeqWork::Decode { pos, .. } => {
                    n_decode += 1;
                    max_ctx = max_ctx.max((*pos).max(1) as u64);
                }
            }
        }
        if n_decode > 0 {
            step_s += self.decode_cost(max_ctx, n_decode);
        }
        let logits = batch.iter().map(|s| self.logits_for(s)).collect();
        Ok(StepOutput { logits, step_s })
    }

    /// Price preemption spill/resume traffic over the DDR channel:
    /// pages × page-bytes ÷ bandwidth.  Free when no swap model is
    /// configured (swap disabled at the serving layer).  The fleet also
    /// charges this price for inter-board transfers — prefix-page
    /// adoption and parked-request migration between shards.
    fn swap_cost_s(&mut self, pages: usize) -> f64 {
        match self.swap {
            Some(m) => pages as f64 * m.page_bytes / (m.ddr_gbps * 1e9),
            None => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Sampler, SchedulerConfig, Server};
    use crate::workload::{
        generate_burst_trace, generate_shared_prefix_trace, generate_trace,
        SharedPrefixConfig, TraceConfig,
    };

    fn tiny_server(max_batch: usize) -> Server<SimBackend> {
        tiny_server_cfg(max_batch, false)
    }

    fn tiny_server_cfg(max_batch: usize, prefix_cache: bool) -> Server<SimBackend> {
        Server::new(
            SimBackend::with_vocab(Target::u280_tiny(), 64),
            SchedulerConfig {
                max_batch,
                kv_pages: 256,
                page_tokens: 16,
                max_seq: 256,
                prefix_cache,
                ..Default::default()
            },
            Sampler::greedy(),
        )
    }

    /// Acceptance: run_trace against the sim backend is deterministic —
    /// identical per-request TTFT/latency across runs for a fixed seed.
    #[test]
    fn served_trace_is_deterministic() {
        let trace_cfg = TraceConfig {
            n_requests: 8,
            vocab: 64,
            prompt_len_choices: vec![16, 32, 64],
            decode_len_choices: vec![8, 16],
            seed: 11,
            ..Default::default()
        };
        let a = tiny_server(4).run_trace(generate_trace(&trace_cfg)).unwrap();
        let b = tiny_server(4).run_trace(generate_trace(&trace_cfg)).unwrap();
        assert_eq!(a.results.len(), 8);
        assert_eq!(a.served_s.to_bits(), b.served_s.to_bits());
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.ttft_s.to_bits(), y.ttft_s.to_bits(), "TTFT must be exact");
            assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits());
        }
    }

    /// Queued requests see their wait in TTFT on the virtual clock too.
    #[test]
    fn ttft_orders_with_queueing_on_sim_clock() {
        let trace = generate_burst_trace(2, 32, 8, 64, 5);
        let stats = tiny_server(1).run_trace(trace).unwrap();
        let a = stats.results.iter().find(|r| r.id == 0).unwrap();
        let b = stats.results.iter().find(|r| r.id == 1).unwrap();
        assert!(a.queue_s == 0.0 && b.queue_s > 0.0);
        assert!(
            b.ttft_s > a.latency_s,
            "B's first token waits for A to drain: {} vs {}",
            b.ttft_s,
            a.latency_s
        );
    }

    /// Prefix caching prices prefill by the uncached suffix: the same
    /// shared-prefix trace serves cache hits, strictly improves mean
    /// TTFT, and still produces byte-identical tokens (the simulator
    /// prices time, not numerics).
    #[test]
    fn cached_prefill_is_cheaper_and_token_identical() {
        let trace_cfg = SharedPrefixConfig {
            n_groups: 1,
            prefix_len: 96,
            tail_len_choices: vec![8, 16],
            decode_len_choices: vec![4],
            n_requests: 6,
            rate_per_s: 1e3,
            vocab: 64,
            seed: 21,
        };
        let off = tiny_server_cfg(2, false)
            .run_trace(generate_shared_prefix_trace(&trace_cfg))
            .unwrap();
        let on = tiny_server_cfg(2, true)
            .run_trace(generate_shared_prefix_trace(&trace_cfg))
            .unwrap();
        assert_eq!(off.results.len(), 6);
        assert_eq!(on.results.len(), 6);
        assert_eq!(off.prefix_hits, 0);
        assert!(on.prefix_hits > 0, "shared prefixes must hit the cache");
        assert!(
            on.mean_ttft_s() < off.mean_ttft_s(),
            "cached prefill must cut TTFT: {} vs {}",
            on.mean_ttft_s(),
            off.mean_ttft_s()
        );
        for a in &off.results {
            let b = on.results.iter().find(|r| r.id == a.id).unwrap();
            assert_eq!(a.tokens, b.tokens, "tokens must not change with caching");
        }
    }

    /// Satellite: the DDR swap cost model follows the KV geometry —
    /// linear in pages, inversely proportional to the bandwidth, free
    /// when unconfigured (swap disabled at the serving layer).
    #[test]
    fn swap_cost_scales_with_pages_and_bandwidth() {
        let t = Target::u280_tiny();
        let ddr = t.platform.ddr.bandwidth_gbs;
        let act_bytes = (t.compression.act_bits as u64).div_ceil(8).max(1);
        let expect_one = t.model.kv_bytes(16, act_bytes) as f64 / (ddr * 1e9);
        let mut free = SimBackend::with_vocab(t.clone(), 8);
        assert_eq!(free.swap_cost_s(4), 0.0, "no swap model: traffic is free");
        let mut priced = SimBackend::with_vocab(t.clone(), 8).with_swap_model(16, None);
        let one = priced.swap_cost_s(1);
        assert!(one > 0.0);
        assert!((one - expect_one).abs() < 1e-12, "page bytes follow the KV geometry");
        assert!((priced.swap_cost_s(8) - 8.0 * one).abs() < 1e-15, "cost is linear in pages");
        let mut fast = SimBackend::with_vocab(t, 8).with_swap_model(16, Some(2.0 * ddr));
        assert!(
            (fast.swap_cost_s(1) - one / 2.0).abs() < 1e-12,
            "doubling the bandwidth halves the cost"
        );
    }

    /// Satellite: a zero-length prefill chunk is a planner bug — debug
    /// builds assert instead of silently pricing phantom work.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "degenerate prefill chunk")]
    fn degenerate_prefill_chunk_asserts_in_debug() {
        let mut b = SimBackend::with_vocab(Target::u280_tiny(), 8);
        let slot = SeqSlot {
            seq: 0,
            work: SeqWork::Prefill {
                prompt: vec![1, 2, 3, 4],
                cached_ctx: 0,
                chunk_start: 2,
                chunk_end: 2,
            },
        };
        let _ = b.step(&[slot]);
    }

    /// Satellite: release builds skip the degenerate chunk instead of
    /// inventing one token of cost (the old `.max(1)`), and the logits
    /// row count still matches the batch.
    #[cfg(not(debug_assertions))]
    #[test]
    fn degenerate_prefill_chunk_is_not_priced_in_release() {
        let mut b = SimBackend::with_vocab(Target::u280_tiny(), 8);
        let slot = SeqSlot {
            seq: 0,
            work: SeqWork::Prefill {
                prompt: vec![1, 2, 3, 4],
                cached_ctx: 0,
                chunk_start: 2,
                chunk_end: 2,
            },
        };
        let out = b.step(&[slot]).unwrap();
        assert_eq!(out.step_s, 0.0, "no phantom prefill cost");
        assert_eq!(out.logits.len(), 1, "row count still matches the batch");
    }

    /// Satellite: a non-final prefill chunk yields no token, so the
    /// backend returns `None` for its row instead of fabricating
    /// logits the engine would discard; the final chunk and decode
    /// slots carry real rows.
    #[test]
    fn non_final_chunks_carry_no_logits_row() {
        let mut b = SimBackend::with_vocab(Target::u280_tiny(), 8);
        let prefill = |chunk_end: usize| SeqSlot {
            seq: 0,
            work: SeqWork::Prefill {
                prompt: vec![1, 2, 3, 4],
                cached_ctx: 0,
                chunk_start: 0,
                chunk_end,
            },
        };
        let out = b.step(&[prefill(2)]).unwrap();
        assert_eq!(out.logits.len(), 1);
        assert!(out.logits[0].is_none(), "non-final chunk: no logits");
        assert!(out.step_s > 0.0, "the chunk still costs model time");
        let out = b.step(&[prefill(4)]).unwrap();
        assert!(out.logits[0].is_some(), "final chunk: real logits");
        let decode = SeqSlot { seq: 0, work: SeqWork::Decode { last: 3, pos: 4 } };
        let out = b.step(&[decode]).unwrap();
        assert!(out.logits[0].is_some(), "decode: real logits");
    }

    /// Batched decode amortizes weight streaming (Fig. 15): aggregate
    /// tokens/s must rise with the batch size, on the virtual clock.
    #[test]
    fn batched_decode_raises_aggregate_tps() {
        let run = |batch: usize| {
            let trace = generate_burst_trace(batch, 64, 16, 64, 9);
            tiny_server(batch).run_trace(trace).unwrap()
        };
        let s1 = run(1);
        let s4 = run(4);
        assert_eq!(s1.results.len(), 1);
        assert_eq!(s4.results.len(), 4);
        assert!(
            s4.decode_tps() > s1.decode_tps(),
            "batch 4 {} tok/s must beat batch 1 {} tok/s",
            s4.decode_tps(),
            s1.decode_tps()
        );
    }

    fn decode_slot(seq: u64, pos: i32) -> SeqSlot {
        SeqSlot { seq, work: SeqWork::Decode { last: 3, pos } }
    }

    fn prefill_slot(seq: u64, len: usize) -> SeqSlot {
        SeqSlot {
            seq,
            work: SeqWork::Prefill {
                prompt: vec![1; len],
                cached_ctx: 0,
                chunk_start: 0,
                chunk_end: len,
            },
        }
    }

    /// Tentpole equivalence: the dense table returns BIT-identical
    /// `step_s` to the memoised path across every (stage, bucket,
    /// batch) the bucket plan can emit — edge lengths, mid-bucket
    /// lengths, and every decode batch the table covers — with zero
    /// fallbacks on the dense side.
    #[test]
    fn dense_table_prices_bit_identical_to_memoised_path() {
        let t = Target::u280_tiny();
        let plan = BucketPlan::paper_default(t.model.max_seq);
        let mut dense = SimBackend::with_vocab(t.clone(), 8).with_max_batch(4);
        let mut memo = SimBackend::with_vocab(t, 8).without_cost_table();
        for &edge in &plan.prefill {
            for len in [edge, edge.saturating_sub(5).max(1)] {
                let a = dense.step(&[prefill_slot(0, len as usize)]).unwrap().step_s;
                let b = memo.step(&[prefill_slot(0, len as usize)]).unwrap().step_s;
                assert_eq!(a.to_bits(), b.to_bits(), "prefill len {len}");
            }
        }
        for &edge in &plan.decode {
            for ctx in [edge, edge.saturating_sub(7).max(1)] {
                for batch in 1..=4u64 {
                    let slots: Vec<SeqSlot> =
                        (0..batch).map(|i| decode_slot(i, ctx as i32)).collect();
                    let a = dense.step(&slots).unwrap().step_s;
                    let b = memo.step(&slots).unwrap().step_s;
                    assert_eq!(a.to_bits(), b.to_bits(), "decode ctx {ctx} batch {batch}");
                }
            }
        }
        assert_eq!(dense.cost_table_stats().1, 0, "dense path must never fall back");
        let (entries, fallbacks) = memo.cost_table_stats();
        assert_eq!(entries, 0, "disabled table holds nothing");
        assert!(fallbacks > 0, "memo path counts every pricing as a fallback");
    }

    /// Tentpole: a pricing point outside the table (decode batch beyond
    /// the table's max_batch) falls back to the memoised path — same
    /// bits — and increments the fallback counter each time.
    #[test]
    fn out_of_table_points_fall_back_and_are_counted() {
        let t = Target::u280_tiny();
        let mut small = SimBackend::with_vocab(t.clone(), 8).with_max_batch(2);
        let mut memo = SimBackend::with_vocab(t, 8).without_cost_table();
        let slots: Vec<SeqSlot> = (0..3).map(|i| decode_slot(i, 100)).collect();
        assert_eq!(small.cost_table_stats().1, 0);
        let a = small.step(&slots).unwrap().step_s;
        assert_eq!(small.cost_table_stats().1, 1, "batch 3 misses a max_batch-2 table");
        let a2 = small.step(&slots).unwrap().step_s;
        assert_eq!(small.cost_table_stats().1, 2, "every miss is counted, even memo hits");
        let b = memo.step(&slots).unwrap().step_s;
        assert_eq!(a.to_bits(), b.to_bits(), "fallback pricing is bit-identical");
        assert_eq!(a.to_bits(), a2.to_bits());
        let in_table: Vec<SeqSlot> = (0..2).map(|i| decode_slot(i, 100)).collect();
        let _ = small.step(&in_table).unwrap();
        assert_eq!(small.cost_table_stats().1, 2, "in-table pricing never falls back");
    }

    /// Satellite: the decode cost-key conflation, pinned — a
    /// mixed-context decode batch is priced at its LARGEST member's
    /// context bucket (one Fig. 15 stream, one bucket), not per-member.
    #[test]
    fn mixed_context_decode_batch_priced_at_largest_bucket() {
        let t = Target::u280_tiny();
        let mut b = SimBackend::with_vocab(t.clone(), 8).with_max_batch(2);
        let mixed = b.step(&[decode_slot(0, 3), decode_slot(1, 200)]).unwrap().step_s;
        let at_max = b.step(&[decode_slot(0, 200), decode_slot(1, 200)]).unwrap().step_s;
        let at_min = b.step(&[decode_slot(0, 3), decode_slot(1, 3)]).unwrap().step_s;
        assert_eq!(
            mixed.to_bits(),
            at_max.to_bits(),
            "mixed batch must be priced at the largest member's bucket"
        );
        assert_ne!(
            mixed.to_bits(),
            at_min.to_bits(),
            "ctx 3 and ctx 200 land in different decode buckets"
        );
    }

    /// Tentpole equivalence, end to end: a served trace is byte- and
    /// bit-identical with and without the dense table (tokens, TTFT,
    /// latency, served_s) — the table changes how fast pricing runs,
    /// never what it returns.
    #[test]
    fn end_to_end_serving_identical_with_and_without_table() {
        let trace_cfg = TraceConfig {
            n_requests: 8,
            vocab: 64,
            prompt_len_choices: vec![16, 32, 64],
            decode_len_choices: vec![8, 16],
            seed: 11,
            ..Default::default()
        };
        let cfg = SchedulerConfig {
            max_batch: 4,
            kv_pages: 256,
            page_tokens: 16,
            max_seq: 256,
            ..Default::default()
        };
        let dense = Server::new(
            SimBackend::with_vocab(Target::u280_tiny(), 64).with_max_batch(4),
            cfg.clone(),
            Sampler::greedy(),
        )
        .run_trace(generate_trace(&trace_cfg))
        .unwrap();
        let memo = Server::new(
            SimBackend::with_vocab(Target::u280_tiny(), 64).without_cost_table(),
            cfg,
            Sampler::greedy(),
        )
        .run_trace(generate_trace(&trace_cfg))
        .unwrap();
        assert_eq!(dense.results.len(), memo.results.len());
        assert_eq!(dense.served_s.to_bits(), memo.served_s.to_bits());
        for (x, y) in dense.results.iter().zip(&memo.results) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.tokens, y.tokens, "tokens must not depend on the pricing path");
            assert_eq!(x.ttft_s.to_bits(), y.ttft_s.to_bits());
            assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits());
        }
    }
}
