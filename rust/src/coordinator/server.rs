//! Serving data model + the offline serving client.
//!
//! The engine loop itself lives in `service::EngineCore` (one batched
//! `ModelBackend::step` per iteration, chunk-aware prefill, sampling,
//! retirement, streaming).  This module defines what flows through it —
//! `SeqWork`/`SeqSlot`/`StepOutput` on the way in, `RequestResult` and
//! the aggregate `ServeStats` on the way out — and `Server`, the
//! offline replay client: `run_trace` submits a whole pre-collected
//! trace and drives the shared engine core to drain on the virtual
//! clock.  The live front-end (`service::Service`/`LiveService`) drives
//! the SAME core from a request channel.
//!
//! The virtual clock advances by each step's reported model time, which
//! makes admission, TTFT and per-request latency deterministic
//! functions of the trace and the backend's timing model: the
//! `sim::Engine`-backed backend reports the FlightLLM accelerator's
//! latencies, while the PJRT runtime backend reports measured host time.
//!
//! Prefix caching + chunked prefill: a `Prefill` slot carries the chunk
//! range `[chunk_start, chunk_end)` of prompt tokens to run this
//! iteration (the first chunk starts at `cached_ctx`, the prompt tokens
//! already materialized in shared KV pages).  Only the final chunk
//! (`chunk_end == prompt.len()`) produces a sampled token.
//!
//! TTFT and latency are measured from request ARRIVAL, so queueing delay
//! is included (the paper's serving scenario, §1).

use std::time::Instant;

use anyhow::Result;

use crate::obs::registry::LATENCY_BUCKETS_S;
use crate::obs::{EventLog, MetricsRegistry, Recorder};
use crate::workload::Request;

use super::sampler::Sampler;
use super::scheduler::{Scheduler, SchedulerConfig};
use super::service::{ClockMode, EngineCore, Tick};

/// One sequence's share of a batched engine iteration.
#[derive(Debug, Clone)]
pub enum SeqWork {
    /// Run prompt tokens `[chunk_start, chunk_end)` through the model.
    /// The first `cached_ctx` tokens were served from shared KV pages
    /// (never re-run); under chunked prefill the remainder arrives over
    /// several iterations.  The full prompt is carried for positioning
    /// and (on recompute-everything backends) parity; the chunk is
    /// final — and must yield real logits — iff `chunk_end` equals the
    /// prompt length.
    Prefill { prompt: Vec<i32>, cached_ctx: usize, chunk_start: usize, chunk_end: usize },
    /// One decode step: feed the last sampled token at position `pos`.
    Decode { last: i32, pos: i32 },
}

impl SeqWork {
    /// Does this slot produce a sampled token this iteration?
    pub fn yields_token(&self) -> bool {
        match self {
            SeqWork::Prefill { prompt, chunk_end, .. } => *chunk_end >= prompt.len(),
            SeqWork::Decode { .. } => true,
        }
    }
}

/// A slot in a batched step.
#[derive(Debug, Clone)]
pub struct SeqSlot {
    pub seq: u64,
    pub work: SeqWork,
}

/// One slot's sampling input.  The synthetic backends (sim, echo)
/// fabricate a distribution with a single peak over an otherwise-zero
/// vocab row; materializing that row as a `Vec<f32>` cost ~vocab floats
/// of allocation per yielded token (~128 KB at LLaMA2 scale) just for
/// the sampler to scan it.  `Peak` carries the three numbers that
/// define the row instead — zero allocation on the serving hot path —
/// while `Dense` keeps the full-row representation for backends with
/// real numerics (the PJRT runtime).  `Sampler` consumes both and
/// produces bit-identical tokens for a `Peak` and its `to_dense`
/// materialization.
#[derive(Debug, Clone)]
pub enum Logits {
    /// `value` at `index`, 0.0 at every other position of a
    /// `vocab`-wide row.
    Peak { index: u32, value: f32, vocab: u32 },
    /// A full per-token logits row.
    Dense(Vec<f32>),
}

impl Logits {
    /// Width of the (possibly virtual) logits row.
    pub fn vocab(&self) -> usize {
        match self {
            Logits::Peak { vocab, .. } => *vocab as usize,
            Logits::Dense(v) => v.len(),
        }
    }

    /// Materialize the full row (tests and the bench's emulation of the
    /// pre-compact allocating path; never used by the serving loop).
    pub fn to_dense(&self) -> Vec<f32> {
        match self {
            Logits::Dense(v) => v.clone(),
            Logits::Peak { index, value, vocab } => {
                let mut v = vec![0.0f32; *vocab as usize];
                if let Some(slot) = v.get_mut(*index as usize) {
                    *slot = *value;
                }
                v
            }
        }
    }
}

/// What one batched step produced.
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// Per-slot logits, same order as the input batch.  A slot that
    /// yields a sampled token this iteration (`SeqWork::yields_token`)
    /// must carry `Some`; a non-final prefill chunk carries `None` —
    /// backends no longer fabricate a row just for the engine to
    /// discard it.  The row count always matches the batch, and the
    /// engine never samples from a non-yielding slot's row even if a
    /// backend returns garbage there.
    pub logits: Vec<Option<Logits>>,
    /// Seconds of model time the step took (virtual for the simulator,
    /// measured wall time for the PJRT runtime).
    pub step_s: f64,
}

/// The execution engine behind the serving loop.  Implementations keep
/// their own per-sequence KV state, keyed by `SeqSlot::seq`.
pub trait ModelBackend {
    /// Run one engine iteration over `batch` (mixed prefill/decode).
    fn step(&mut self, batch: &[SeqSlot]) -> Result<StepOutput>;

    /// Drop any per-sequence state held for a retired sequence.
    fn release(&mut self, _seq: u64) {}

    /// Seconds of model time to move `pages` KV pages between HBM and
    /// the DDR swap tier (preemption spill / resume traffic).  The
    /// default prices it free — backends with a memory model override
    /// this so the serving clock shows the real cost of spilling.
    fn swap_cost_s(&mut self, _pages: usize) -> f64 {
        0.0
    }
}

/// Completed-request record.  All times are on the serving clock
/// (virtual seconds for simulated backends).
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    /// Seconds from request arrival to last token.
    pub latency_s: f64,
    /// Seconds from request arrival to first token (includes queueing).
    pub ttft_s: f64,
    /// Seconds the request waited in the queue before admission.
    pub queue_s: f64,
    /// True if the sequence was cut short by KV-pool exhaustion (swap
    /// disabled, or the sequence alone exceeds the entire pool).  Its
    /// `tokens` are a TRUNCATED stream, so the request is excluded from
    /// the TTFT/latency aggregates and counted as preempted-truncated.
    pub evicted: bool,
    /// True if the client cancelled the request (its KV pages were
    /// released immediately; `tokens` holds whatever was generated).
    pub cancelled: bool,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub results: Vec<RequestResult>,
    /// Serving-clock seconds to drain the trace.
    pub served_s: f64,
    /// Host wall seconds actually spent.
    pub wall_s: f64,
    /// Batched engine iterations executed.
    pub steps: u64,
    /// Decode slot-executions in PURE decode steps (no prefill slot in
    /// the batch).  Mixed steps are counted separately so `decode_tps`
    /// samples steady-state decode throughput instead of absorbing
    /// prefill cost.
    pub decode_steps: u64,
    /// Serving-clock seconds of those pure decode steps.
    pub decode_time_s: f64,
    /// Decode slot-executions in MIXED steps (a prefill slot shared the
    /// batch).  A chunked-prefill-saturated run decodes thousands of
    /// tokens without a single pure decode step — these keep that
    /// throughput visible instead of reporting ~0 tok/s.
    pub mixed_decodes: u64,
    /// Serving-clock seconds of those mixed steps (prefill cost
    /// included, which is why the two rates are reported separately).
    pub mixed_time_s: f64,
    /// Decode inter-token gaps, serving-clock seconds: for every
    /// generated token after a request's first, the time since its
    /// previous token.  A long prefill sharing an iteration with decodes
    /// shows up here as a spike — the latency chunked prefill removes.
    /// Bounded: a long-lived service keeps only the most recent
    /// [`ITL_SAMPLE_CAP`] samples (ring overwrite), so the percentiles
    /// describe recent traffic and memory stays flat.
    pub itl_s: Vec<f64>,
    /// Decode gaps observed over the whole run (`itl_s` holds at most
    /// the last [`ITL_SAMPLE_CAP`] of them).
    pub itl_total: u64,
    /// Requests rejected at admission (prompt cannot fit the KV pool).
    pub rejected: u64,
    /// Requests cancelled by their client (mid-flight or while queued).
    pub cancelled: u64,
    /// Sequences admitted into the KV pool (denominator for the prefix
    /// hit rate: hits are counted at admission, so neither truncation
    /// nor cancellation afterwards can push the rate past 100%).
    pub admissions: u64,
    /// Admissions that reused at least one cached prefix page.
    pub prefix_hits: u64,
    /// Prompt tokens served from the prefix cache (prefill skipped).
    pub prefix_cached_tokens: u64,
    /// Peak pages holding live sequence data (shared pages count once;
    /// retained cache pages excluded) — the KV-capacity figure of merit.
    pub peak_kv_pages: usize,
    /// Sequences preempted to the DDR swap tier (swap-out events).
    pub preemptions: u64,
    /// KV pages written HBM → DDR across all preemptions.
    pub swapped_out_pages: u64,
    /// KV pages read DDR → HBM across all resumes.
    pub swapped_in_pages: u64,
    /// Serving-clock seconds charged for that swap traffic (virtual
    /// clock only; on the host clock swap cost is whatever it measures).
    pub swap_time_s: f64,
    /// Prefix adoptions: admissions on this lane served from prefix
    /// pages ANOTHER lane materialized (fleet directory hit — the pages
    /// were copied over the inter-board link instead of re-prefilled).
    pub prefix_adoptions: u64,
    /// Parked requests this lane RECEIVED from an overloaded lane
    /// (cross-shard migration / work stealing).
    pub migrations: u64,
    /// KV pages copied over the inter-board link for those migrations.
    pub migrated_pages: u64,
    /// Serving-clock seconds charged for inter-board transfer traffic
    /// (prefix adoptions + migrations; virtual clock only).
    pub transfer_time_s: f64,
}

/// Most recent decode inter-token gaps retained for the ITL
/// percentiles; older samples are overwritten ring-style so an
/// always-on `LiveService` does not grow one f64 per served token
/// forever.
pub const ITL_SAMPLE_CAP: usize = 65_536;

/// Nearest-rank (ceil convention) percentile of a sample: the smallest
/// value with at least `q`% of the sample at or below it —
/// `sorted[ceil(q/100 · N) - 1]`.  The old `.round()` on the rank made
/// P50 of a 2-sample set return the MAX, so percentiles drifted with
/// sample count and fleet-merged numbers were not comparable across
/// shard counts.  Returns 0.0 on an empty set — a zero-completion run
/// must yield zeros, never NaN or a panic.  A NaN sample sorts last
/// (`total_cmp`) instead of panicking the serving loop mid-trace.
fn percentile_of(vals: &[f64], q: f64) -> f64 {
    if vals.is_empty() {
        return 0.0;
    }
    let mut vals = vals.to_vec();
    vals.sort_by(f64::total_cmp);
    let rank = ((q / 100.0) * vals.len() as f64).ceil() as usize;
    vals[rank.clamp(1, vals.len()) - 1]
}

/// Mean of a sample; 0.0 when empty (never NaN).
fn mean(it: impl Iterator<Item = f64>) -> f64 {
    let (mut sum, mut n) = (0.0, 0u64);
    for v in it {
        sum += v;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

impl ServeStats {
    /// Aggregate decode throughput, tokens/s on the serving clock:
    /// pure-step rate when any pure decode step ran, otherwise the
    /// mixed-step rate.  A chunked-prefill-saturated run used to report
    /// ~0 tok/s here despite thousands of decoded tokens, because every
    /// decode shared its step with a prefill chunk.
    pub fn decode_tps(&self) -> f64 {
        if self.decode_time_s > 0.0 {
            self.decode_steps as f64 / self.decode_time_s
        } else {
            self.mixed_decode_tps()
        }
    }

    /// Decode throughput over MIXED steps only (decode slot-executions
    /// over mixed-step seconds — prefill cost included, so this is a
    /// lower bound on the decode rate those steps sustained).
    pub fn mixed_decode_tps(&self) -> f64 {
        if self.mixed_time_s <= 0.0 {
            return 0.0;
        }
        self.mixed_decodes as f64 / self.mixed_time_s
    }

    /// Merge per-shard stats into one fleet summary.  Percentiles and
    /// means are recomputed from the POOLED per-request samples (the
    /// merged `results`), never averaged across shards — an average of
    /// per-shard P99s is not a P99.  Counters sum; `served_s` is the
    /// fleet clock (max over lanes: boards run in parallel);
    /// `peak_kv_pages` sums because each board has its own HBM pool.
    /// The throughput ratios (`decode_tps`) pool slot-executions and
    /// step seconds across lanes, so they read as per-board rates —
    /// fleet-level speedup shows up in `served_s`, not here.
    ///
    /// The merged value is a reporting SNAPSHOT, not a live ring: its
    /// `itl_s` concatenates each shard's retained window and may hold
    /// up to shards × [`ITL_SAMPLE_CAP`] samples.  Keep recording into
    /// the per-shard stats and re-merge; do not `record_itl` into a
    /// merged snapshot.
    pub fn merge(shards: &[ServeStats]) -> ServeStats {
        let mut out = ServeStats::default();
        for s in shards {
            out.results.extend(s.results.iter().cloned());
            out.served_s = out.served_s.max(s.served_s);
            out.wall_s = out.wall_s.max(s.wall_s);
            out.steps += s.steps;
            out.decode_steps += s.decode_steps;
            out.decode_time_s += s.decode_time_s;
            out.mixed_decodes += s.mixed_decodes;
            out.mixed_time_s += s.mixed_time_s;
            out.itl_total += s.itl_total;
            out.itl_s.extend_from_slice(&s.itl_s);
            out.rejected += s.rejected;
            out.cancelled += s.cancelled;
            out.admissions += s.admissions;
            out.prefix_hits += s.prefix_hits;
            out.prefix_cached_tokens += s.prefix_cached_tokens;
            out.peak_kv_pages += s.peak_kv_pages;
            out.preemptions += s.preemptions;
            out.swapped_out_pages += s.swapped_out_pages;
            out.swapped_in_pages += s.swapped_in_pages;
            out.swap_time_s += s.swap_time_s;
            out.prefix_adoptions += s.prefix_adoptions;
            out.migrations += s.migrations;
            out.migrated_pages += s.migrated_pages;
            out.transfer_time_s += s.transfer_time_s;
        }
        out
    }

    /// Record one decode inter-token gap, ring-overwriting the oldest
    /// sample once [`ITL_SAMPLE_CAP`] are held.
    pub(crate) fn record_itl(&mut self, gap_s: f64) {
        let i = self.itl_total as usize;
        self.itl_total += 1;
        if self.itl_s.len() < ITL_SAMPLE_CAP {
            self.itl_s.push(gap_s);
        } else {
            self.itl_s[i % ITL_SAMPLE_CAP] = gap_s;
        }
    }

    /// Results that ran to completion.  Cancelled AND KV-truncated
    /// (`evicted`) requests stay in `results` (the client's final
    /// record) but are EXCLUDED from the latency aggregates below — a
    /// request the client killed has no meaningful TTFT or end-to-end
    /// latency, and a truncated one finished artificially EARLY, which
    /// used to make the stats look better exactly when the pool was
    /// overloaded.
    fn completed(&self) -> impl Iterator<Item = &RequestResult> + '_ {
        self.results.iter().filter(|r| !r.cancelled && !r.evicted)
    }

    /// Requests cut short by KV exhaustion (truncated streams): reported
    /// separately, never blended into the latency aggregates.
    pub fn preempted_truncated(&self) -> usize {
        self.results.iter().filter(|r| r.evicted).count()
    }

    pub fn mean_latency_s(&self) -> f64 {
        mean(self.completed().map(|r| r.latency_s))
    }

    pub fn mean_ttft_s(&self) -> f64 {
        mean(self.completed().map(|r| r.ttft_s))
    }

    pub fn mean_queue_s(&self) -> f64 {
        mean(self.completed().map(|r| r.queue_s))
    }

    /// The `q`-th percentile of a per-request metric; 0.0 when no
    /// requests completed.
    fn percentile(&self, q: f64, f: impl Fn(&RequestResult) -> f64) -> f64 {
        let vals: Vec<f64> = self.completed().map(f).collect();
        percentile_of(&vals, q)
    }

    pub fn p50_ttft_s(&self) -> f64 {
        self.percentile(50.0, |r| r.ttft_s)
    }

    pub fn p99_ttft_s(&self) -> f64 {
        self.percentile(99.0, |r| r.ttft_s)
    }

    pub fn p50_latency_s(&self) -> f64 {
        self.percentile(50.0, |r| r.latency_s)
    }

    pub fn p99_latency_s(&self) -> f64 {
        self.percentile(99.0, |r| r.latency_s)
    }

    pub fn mean_itl_s(&self) -> f64 {
        mean(self.itl_s.iter().copied())
    }

    pub fn p50_itl_s(&self) -> f64 {
        percentile_of(&self.itl_s, 50.0)
    }

    /// P99 decode inter-token latency — the figure chunked prefill
    /// improves on mixed prefill/decode traffic.
    pub fn p99_itl_s(&self) -> f64 {
        percentile_of(&self.itl_s, 99.0)
    }

    pub fn max_itl_s(&self) -> f64 {
        self.itl_s.iter().copied().fold(0.0, f64::max)
    }

    /// Fraction of admissions that hit the prefix cache.  Hits are
    /// counted when a prompt is admitted, so the denominator is
    /// admissions too — a request truncated or cancelled AFTER its
    /// admission consulted the cache cannot push the rate past 100%.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.admissions == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / self.admissions as f64
    }

    /// Export this run as a [`MetricsRegistry`]: counters for every
    /// request outcome and traffic total, gauges for the derived
    /// rates/percentiles, and fixed-bucket histograms over the
    /// per-request TTFT/latency and retained ITL samples.  The
    /// registry is the ONE source for serving numbers: `summary()`
    /// formats from it and `prometheus_text()` exposes it, so the two
    /// can never disagree.
    pub fn metrics_registry(&self) -> MetricsRegistry {
        let mut m = MetricsRegistry::new();
        m.help("flightllm_requests_completed_total", "Requests that ran to completion.");
        m.counter_add("flightllm_requests_completed_total", self.completed().count() as u64);
        m.counter_add("flightllm_requests_rejected_total", self.rejected);
        m.counter_add("flightllm_requests_cancelled_total", self.cancelled);
        m.counter_add("flightllm_requests_truncated_total", self.preempted_truncated() as u64);
        m.counter_add("flightllm_engine_steps_total", self.steps);
        m.counter_add("flightllm_mixed_step_decodes_total", self.mixed_decodes);
        m.counter_add("flightllm_itl_samples_total", self.itl_total);
        m.counter_add("flightllm_admissions_total", self.admissions);
        m.counter_add("flightllm_prefix_hits_total", self.prefix_hits);
        m.counter_add("flightllm_prefix_cached_tokens_total", self.prefix_cached_tokens);
        m.counter_add("flightllm_preemptions_total", self.preemptions);
        m.counter_add("flightllm_swapped_out_pages_total", self.swapped_out_pages);
        m.counter_add("flightllm_swapped_in_pages_total", self.swapped_in_pages);
        m.counter_add("flightllm_prefix_adoptions_total", self.prefix_adoptions);
        m.counter_add("flightllm_migrations_total", self.migrations);
        m.counter_add("flightllm_migrated_pages_total", self.migrated_pages);
        m.counter_add("flightllm_peak_kv_pages", self.peak_kv_pages as u64);
        m.gauge_set("flightllm_served_seconds", self.served_s);
        m.gauge_set("flightllm_swap_seconds", self.swap_time_s);
        m.gauge_set("flightllm_transfer_seconds", self.transfer_time_s);
        m.help("flightllm_decode_tokens_per_second", "Steady-state decode throughput.");
        m.gauge_set("flightllm_decode_tokens_per_second", self.decode_tps());
        m.gauge_set("flightllm_mixed_decode_tokens_per_second", self.mixed_decode_tps());
        m.gauge_set("flightllm_ttft_mean_seconds", self.mean_ttft_s());
        m.gauge_set("flightllm_ttft_p50_seconds", self.p50_ttft_s());
        m.gauge_set("flightllm_ttft_p99_seconds", self.p99_ttft_s());
        m.gauge_set("flightllm_queue_mean_seconds", self.mean_queue_s());
        m.gauge_set("flightllm_latency_mean_seconds", self.mean_latency_s());
        m.gauge_set("flightllm_latency_p50_seconds", self.p50_latency_s());
        m.gauge_set("flightllm_latency_p99_seconds", self.p99_latency_s());
        m.gauge_set("flightllm_itl_mean_seconds", self.mean_itl_s());
        m.gauge_set("flightllm_itl_p50_seconds", self.p50_itl_s());
        m.gauge_set("flightllm_itl_p99_seconds", self.p99_itl_s());
        m.gauge_set("flightllm_itl_max_seconds", self.max_itl_s());
        m.gauge_set("flightllm_prefix_hit_ratio", self.prefix_hit_rate());
        for r in self.completed() {
            m.observe("flightllm_ttft_seconds", LATENCY_BUCKETS_S, r.ttft_s);
            m.observe("flightllm_latency_seconds", LATENCY_BUCKETS_S, r.latency_s);
        }
        for &gap in &self.itl_s {
            m.observe("flightllm_itl_seconds", LATENCY_BUCKETS_S, gap);
        }
        m
    }

    /// Human-readable summary (one printer for the CLI and examples).
    /// `clock_label` names the serving clock: "virtual" or "measured".
    /// Every number is read back out of [`ServeStats::metrics_registry`]
    /// so the summary and the Prometheus exposition share one source.
    pub fn summary(&self, clock_label: &str) -> String {
        let m = self.metrics_registry();
        let mut out = format!(
            "completed {} requests in {:.3}s {clock_label} ({} engine steps)\n",
            m.counter("flightllm_requests_completed_total"),
            m.gauge("flightllm_served_seconds"),
            m.counter("flightllm_engine_steps_total")
        );
        let rejected = m.counter("flightllm_requests_rejected_total");
        if rejected > 0 {
            out.push_str(&format!(
                "rejected {rejected} requests (prompt cannot fit the KV pool)\n"
            ));
        }
        let cancelled = m.counter("flightllm_requests_cancelled_total");
        if cancelled > 0 {
            out.push_str(&format!("cancelled {cancelled} requests (client-initiated)\n"));
        }
        let truncated = m.counter("flightllm_requests_truncated_total");
        if truncated > 0 {
            out.push_str(&format!(
                "preempted_truncated {truncated} requests (KV exhausted — excluded from \
                 the latency aggregates)\n"
            ));
        }
        out.push_str(&format!(
            "decode throughput {:.1} tok/s, mean TTFT {:.1} ms (queue {:.1} ms), \
             mean latency {:.1} ms\n",
            m.gauge("flightllm_decode_tokens_per_second"),
            m.gauge("flightllm_ttft_mean_seconds") * 1e3,
            m.gauge("flightllm_queue_mean_seconds") * 1e3,
            m.gauge("flightllm_latency_mean_seconds") * 1e3
        ));
        let mixed = m.counter("flightllm_mixed_step_decodes_total");
        if mixed > 0 {
            out.push_str(&format!(
                "mixed-step decodes {mixed} ({:.1} tok/s alongside prefill chunks)\n",
                m.gauge("flightllm_mixed_decode_tokens_per_second")
            ));
        }
        out.push_str(&format!(
            "TTFT P50/P99 {:.1}/{:.1} ms, latency P50/P99 {:.1}/{:.1} ms, \
             peak KV {} pages",
            m.gauge("flightllm_ttft_p50_seconds") * 1e3,
            m.gauge("flightllm_ttft_p99_seconds") * 1e3,
            m.gauge("flightllm_latency_p50_seconds") * 1e3,
            m.gauge("flightllm_latency_p99_seconds") * 1e3,
            m.counter("flightllm_peak_kv_pages")
        ));
        if m.histogram("flightllm_itl_seconds").is_some_and(|h| h.count() > 0) {
            out.push_str(&format!(
                "\ndecode ITL mean/P50/P99/max {:.2}/{:.2}/{:.2}/{:.2} ms",
                m.gauge("flightllm_itl_mean_seconds") * 1e3,
                m.gauge("flightllm_itl_p50_seconds") * 1e3,
                m.gauge("flightllm_itl_p99_seconds") * 1e3,
                m.gauge("flightllm_itl_max_seconds") * 1e3
            ));
        }
        let prefix_hits = m.counter("flightllm_prefix_hits_total");
        if prefix_hits > 0 {
            out.push_str(&format!(
                "\nprefix cache: {prefix_hits} hits ({:.0}% of admissions), {} prompt tokens \
                 served from cache",
                m.gauge("flightllm_prefix_hit_ratio") * 100.0,
                m.counter("flightllm_prefix_cached_tokens_total")
            ));
        }
        let preemptions = m.counter("flightllm_preemptions_total");
        if preemptions > 0 {
            out.push_str(&format!(
                "\nswap tier: {preemptions} preemptions, {} pages out / {} pages in over DDR \
                 ({:.1} ms of swap traffic)",
                m.counter("flightllm_swapped_out_pages_total"),
                m.counter("flightllm_swapped_in_pages_total"),
                m.gauge("flightllm_swap_seconds") * 1e3
            ));
        }
        let adoptions = m.counter("flightllm_prefix_adoptions_total");
        let migrations = m.counter("flightllm_migrations_total");
        if adoptions > 0 || migrations > 0 {
            out.push_str(&format!(
                "\nfleet memory: {adoptions} prefix adoptions, {migrations} migrations \
                 ({} pages moved, {:.1} ms of inter-board transfer)",
                m.counter("flightllm_migrated_pages_total"),
                m.gauge("flightllm_transfer_seconds") * 1e3
            ));
        }
        out
    }
}

/// The offline serving client: replays a pre-collected trace through
/// the shared engine core (`service::EngineCore`) on the virtual clock.
/// Live traffic goes through `service::Service` / `service::LiveService`
/// instead — same loop, fed by a request channel.
pub struct Server<B: ModelBackend> {
    core: EngineCore<B>,
}

impl<B: ModelBackend> Server<B> {
    pub fn new(backend: B, cfg: SchedulerConfig, sampler: Sampler) -> Self {
        Self { core: EngineCore::new(backend, Scheduler::new(cfg), sampler, ClockMode::Virtual) }
    }

    /// The scheduler (inspection; the serving loop owns mutation).
    pub fn scheduler(&self) -> &Scheduler {
        self.core.scheduler()
    }

    /// The model backend (inspection — e.g. `SimBackend` step-pricing
    /// table stats for the serve summary).
    pub fn backend(&self) -> &B {
        self.core.backend()
    }

    /// Install a flight recorder.  Every replayed request's lifecycle
    /// and every engine step lands in its bounded ring; recording only
    /// READS engine state, so the token streams and `ServeStats` are
    /// bit-identical with or without one.
    pub fn set_recorder(&mut self, rec: Recorder) {
        self.core.set_recorder(Some(rec));
    }

    /// The installed flight recorder, if any — lets a caller land
    /// backend-specific events (e.g. the `SimBackend` cost table
    /// stats) on the ring before draining it.
    pub fn recorder(&self) -> Option<&Recorder> {
        self.core.recorder()
    }

    /// Drain the recorded events (chronological), if a recorder is
    /// installed.  The recorder stays installed for the next run.
    pub fn take_event_log(&mut self) -> Option<EventLog> {
        self.core.take_event_log()
    }

    /// Run a whole trace to completion (offline replay: all requests are
    /// known upfront; `arrival_s` gates admission against the serving
    /// clock, so a request submitted late still queues realistically).
    /// A NaN arrival sorts last (`total_cmp`) instead of panicking.
    pub fn run_trace(&mut self, mut trace: Vec<Request>) -> Result<ServeStats> {
        trace.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        for r in trace {
            self.core.submit(r, None);
        }
        let host_t0 = Instant::now();
        while self.core.tick()? != Tick::Drained {}
        let mut stats = self.core.stats_snapshot();
        stats.wall_s = host_t0.elapsed().as_secs_f64();
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testing::EchoBackend;
    use crate::workload::{generate_trace, TraceConfig};

    fn req(id: u64, arrival_s: f64, plen: usize, dlen: u32) -> Request {
        Request {
            id,
            arrival_s,
            prompt: (0..plen as u32).collect(),
            max_new_tokens: dlen,
        }
    }

    #[test]
    fn serves_trace_to_completion_with_correct_tokens() {
        let mut server = Server::new(
            EchoBackend::new(64),
            SchedulerConfig { max_seq: 128, ..Default::default() },
            Sampler::greedy(),
        );
        let trace = generate_trace(&TraceConfig {
            n_requests: 5,
            vocab: 64,
            prompt_len_choices: vec![4, 8],
            decode_len_choices: vec![4],
            ..Default::default()
        });
        let expected: Vec<(u64, u32)> = trace
            .iter()
            .map(|r| (r.id, (*r.prompt.last().unwrap() + 1) % 64))
            .collect();
        let stats = server.run_trace(trace).unwrap();
        assert_eq!(stats.results.len(), 5);
        for (id, first) in expected {
            let r = stats.results.iter().find(|r| r.id == id).unwrap();
            assert_eq!(r.tokens[0], first, "first token must be prompt+1");
            // Echo model: strictly increasing mod vocab.
            for w in r.tokens.windows(2) {
                assert_eq!(w[1], (w[0] + 1) % 64);
            }
            assert_eq!(r.tokens.len(), 4);
            assert!(!r.evicted);
            assert!(!r.cancelled);
        }
        assert!(stats.decode_steps >= 5 * 3);
        assert!(stats.served_s > 0.0);
        assert!(stats.peak_kv_pages > 0, "prompt pages were live at some point");
        assert_eq!(stats.prefix_hits, 0, "caching off by default");
        assert!(!stats.itl_s.is_empty(), "decode gaps were sampled");
    }

    #[test]
    fn multibatch_completes_all_and_raises_throughput() {
        let trace_cfg = TraceConfig {
            n_requests: 12,
            vocab: 32,
            prompt_len_choices: vec![4],
            decode_len_choices: vec![8],
            rate_per_s: 1e6, // near-simultaneous arrivals: batching matters
            ..Default::default()
        };
        let run = |max_batch: usize| {
            let mut server = Server::new(
                EchoBackend::new(32),
                SchedulerConfig { max_batch, max_seq: 64, ..Default::default() },
                Sampler::greedy(),
            );
            server.run_trace(generate_trace(&trace_cfg)).unwrap()
        };
        let s1 = run(1);
        let s4 = run(4);
        assert_eq!(s1.results.len(), 12);
        assert_eq!(s4.results.len(), 12);
        // Four sequences share each decode step: aggregate tokens/s and
        // end-to-end drain time must both improve.
        assert!(s4.decode_tps() > 2.0 * s1.decode_tps());
        assert!(s4.served_s < s1.served_s);
        // More residents at once: the KV footprint peak must be higher.
        assert!(s4.peak_kv_pages > s1.peak_kv_pages);
    }

    /// Regression (TTFT): time-to-first-token is measured from request
    /// arrival, so a queued request's TTFT includes its queueing delay.
    #[test]
    fn ttft_includes_queueing_delay() {
        let mut server = Server::new(
            EchoBackend::new(16),
            SchedulerConfig { max_batch: 1, max_seq: 64, ..Default::default() },
            Sampler::greedy(),
        );
        let trace = vec![req(0, 0.0, 4, 4), req(1, 0.0, 4, 4)];
        let stats = server.run_trace(trace).unwrap();
        let a = stats.results.iter().find(|r| r.id == 0).unwrap();
        let b = stats.results.iter().find(|r| r.id == 1).unwrap();
        // A: prefill at 2ms, 3 decode steps → done at 5ms.
        assert!((a.ttft_s - 0.002).abs() < 1e-9, "A ttft = {}", a.ttft_s);
        assert!((a.latency_s - 0.005).abs() < 1e-9);
        assert!((a.queue_s - 0.0).abs() < 1e-9);
        // B waits for A (5ms), prefills by 7ms, finishes at 10ms.
        assert!((b.queue_s - 0.005).abs() < 1e-9, "B queued = {}", b.queue_s);
        assert!((b.ttft_s - 0.007).abs() < 1e-9, "B ttft = {}", b.ttft_s);
        assert!((b.latency_s - 0.010).abs() < 1e-9);
        assert!((stats.served_s - 0.010).abs() < 1e-9);
    }

    /// Satellite: percentile accessors follow the ordered TTFT spread —
    /// P50 sits at the median, P99 at the worst queued request.
    #[test]
    fn percentiles_track_queueing_spread() {
        let mut server = Server::new(
            EchoBackend::new(16),
            SchedulerConfig { max_batch: 1, max_seq: 64, ..Default::default() },
            Sampler::greedy(),
        );
        // Four identical back-to-back requests at batch 1: TTFTs are
        // 2, 7, 12, 17 ms (each waits for its predecessors).
        let trace = (0..4).map(|i| req(i, 0.0, 4, 4)).collect();
        let stats = server.run_trace(trace).unwrap();
        assert_eq!(stats.results.len(), 4);
        let max_ttft = stats
            .results
            .iter()
            .map(|r| r.ttft_s)
            .fold(f64::NEG_INFINITY, f64::max);
        assert!((stats.p99_ttft_s() - max_ttft).abs() < 1e-12, "P99 = worst request");
        assert!(stats.p50_ttft_s() < stats.p99_ttft_s(), "spread is visible");
        assert!(stats.p50_latency_s() <= stats.p99_latency_s());
        assert!(stats.p50_ttft_s() > 0.0);
    }

    /// The metrics registry is the single source the summary formats
    /// from: headline counters/gauges must round-trip the stats
    /// helpers exactly, the histograms must hold one sample per
    /// completion, and the Prometheus exposition must carry the same
    /// series.
    #[test]
    fn metrics_registry_mirrors_stats_and_feeds_summary() {
        let mut server = Server::new(
            EchoBackend::new(16),
            SchedulerConfig { max_batch: 1, max_seq: 64, ..Default::default() },
            Sampler::greedy(),
        );
        let trace = (0..4).map(|i| req(i, 0.0, 4, 4)).collect();
        let stats = server.run_trace(trace).unwrap();
        let m = stats.metrics_registry();
        assert_eq!(m.counter("flightllm_requests_completed_total"), 4);
        assert_eq!(m.counter("flightllm_engine_steps_total"), stats.steps);
        assert_eq!(
            m.gauge("flightllm_decode_tokens_per_second").to_bits(),
            stats.decode_tps().to_bits()
        );
        assert_eq!(
            m.gauge("flightllm_ttft_p99_seconds").to_bits(),
            stats.p99_ttft_s().to_bits()
        );
        let ttft = m.histogram("flightllm_ttft_seconds").unwrap();
        assert_eq!(ttft.count(), 4);
        assert!((ttft.sum() - stats.mean_ttft_s() * 4.0).abs() < 1e-12);
        let text = m.prometheus_text();
        assert!(text.contains("flightllm_requests_completed_total 4\n"));
        assert!(text.contains("flightllm_ttft_seconds_bucket{le=\"+Inf\"} 4\n"));
        // The summary's headline line formats the same registry values.
        let summary = stats.summary("virtual");
        assert!(summary.starts_with(&format!(
            "completed 4 requests in {:.3}s virtual ({} engine steps)\n",
            stats.served_s, stats.steps
        )));
    }

    /// Satellite: every percentile/mean helper is well-defined on a
    /// zero-completion run — zeros across the board, no NaN, no panic.
    #[test]
    fn empty_stats_yield_zeros_not_nan() {
        let stats = ServeStats::default();
        let vals = [
            stats.decode_tps(),
            stats.mixed_decode_tps(),
            stats.mean_latency_s(),
            stats.mean_ttft_s(),
            stats.mean_queue_s(),
            stats.p50_ttft_s(),
            stats.p99_ttft_s(),
            stats.p50_latency_s(),
            stats.p99_latency_s(),
            stats.mean_itl_s(),
            stats.p50_itl_s(),
            stats.p99_itl_s(),
            stats.max_itl_s(),
            stats.prefix_hit_rate(),
        ];
        for (i, v) in vals.iter().enumerate() {
            assert_eq!(*v, 0.0, "helper {i} must be 0.0 on empty stats");
            assert!(!v.is_nan(), "helper {i} must not be NaN");
        }
        // The summary printer must not panic either.
        let text = stats.summary("virtual");
        assert!(text.contains("completed 0 requests"));
        assert!(!text.contains("NaN"));
    }

    /// Satellite (percentile convention): nearest-rank with a CEIL on
    /// the rank — P50 of {1, 2} is 1, not the max the old `.round()`
    /// returned.  Small-N behavior is pinned down so fleet-merged
    /// percentiles are comparable across shard counts.
    #[test]
    fn percentiles_use_ceil_nearest_rank_on_small_samples() {
        // N = 1: every percentile is the one sample.
        assert_eq!(percentile_of(&[5.0], 50.0), 5.0);
        assert_eq!(percentile_of(&[5.0], 99.0), 5.0);
        // N = 2: P50 = ceil(1.0) = rank 1 = the LOWER sample (the old
        // round() picked rank round(0.5) of 0..=1 — the max).
        assert_eq!(percentile_of(&[2.0, 1.0], 50.0), 1.0);
        assert_eq!(percentile_of(&[2.0, 1.0], 99.0), 2.0);
        // N = 3: P50 = ceil(1.5) = rank 2 = the median; P99 = max.
        assert_eq!(percentile_of(&[3.0, 1.0, 2.0], 50.0), 2.0);
        assert_eq!(percentile_of(&[3.0, 1.0, 2.0], 99.0), 3.0);
        // Degenerate q values stay in range.
        assert_eq!(percentile_of(&[3.0, 1.0, 2.0], 0.0), 1.0);
        assert_eq!(percentile_of(&[3.0, 1.0, 2.0], 100.0), 3.0);
    }

    /// Satellite (mixed-step decode throughput): a chunked-prefill
    /// -saturated run has NO pure decode steps — every decode shares
    /// its iteration with a prefill chunk.  The old `decode_tps`
    /// reported ~0 tok/s despite the decoded tokens; the mixed-step
    /// counters keep the rate visible and `decode_tps` falls back.
    #[test]
    fn mixed_step_decodes_keep_throughput_visible() {
        let mut server = Server::new(
            EchoBackend::new(32),
            SchedulerConfig {
                max_batch: 2,
                max_seq: 512,
                prefill_chunk: 8,
                ..Default::default()
            },
            Sampler::greedy(),
        );
        // A decodes 10 tokens, every one of them alongside one of B's
        // prefill chunks (B's 200-token prompt runs as 25 chunks); B's
        // budget of 1 is spent by its final-chunk token, so B never
        // takes a pure decode step either.
        let trace = vec![req(0, 0.0, 4, 10), req(1, 0.0, 200, 1)];
        let stats = server.run_trace(trace).unwrap();
        assert_eq!(stats.results.len(), 2);
        assert_eq!(stats.decode_steps, 0, "no pure decode step ever ran");
        assert!(stats.mixed_decodes >= 9, "decodes ran alongside chunks");
        assert!(stats.mixed_time_s > 0.0);
        assert!(stats.decode_tps() > 0.0, "saturated run must not report zero decode throughput");
        assert_eq!(stats.decode_tps(), stats.mixed_decode_tps());
        let summary = stats.summary("virtual");
        assert!(summary.contains("mixed-step decodes"));
    }

    /// Satellite (fleet merge): percentiles of merged stats come from
    /// the POOLED samples, not averaged per-shard percentiles, and the
    /// counters/clocks combine the way independent boards do.
    #[test]
    fn merge_pools_samples_and_combines_counters() {
        let mk = |latencies: &[f64], served_s: f64| {
            let mut s = ServeStats {
                served_s,
                steps: 10,
                decode_steps: 4,
                decode_time_s: 0.5,
                peak_kv_pages: 3,
                admissions: latencies.len() as u64,
                ..Default::default()
            };
            for (i, &l) in latencies.iter().enumerate() {
                s.results.push(RequestResult {
                    id: i as u64,
                    prompt_len: 4,
                    tokens: vec![1],
                    latency_s: l,
                    ttft_s: l,
                    queue_s: 0.0,
                    evicted: false,
                    cancelled: false,
                });
            }
            s
        };
        let a = mk(&[1.0, 2.0], 2.0);
        let b = mk(&[10.0, 20.0], 5.0);
        let m = ServeStats::merge(&[a.clone(), b.clone()]);
        assert_eq!(m.results.len(), 4);
        assert_eq!(m.served_s, 5.0, "fleet clock = max over lanes");
        assert_eq!(m.steps, 20);
        assert_eq!(m.peak_kv_pages, 6, "per-board pools sum");
        assert_eq!(m.admissions, 4);
        // Pooled P99 is the worst request anywhere in the fleet — NOT
        // the mean of the two per-shard P99s (10.5 here).
        assert_eq!(m.p99_ttft_s(), 20.0);
        let averaged = (a.p99_ttft_s() + b.p99_ttft_s()) / 2.0;
        assert!(m.p99_ttft_s() > averaged);
        // Pooled P50 = ceil-rank 2 of {1, 2, 10, 20}.
        assert_eq!(m.p50_ttft_s(), 2.0);
    }

    /// Satellite (fleet-memory counters): adoption/migration counters
    /// sum across shards, surface in the Prometheus exposition, and the
    /// summary gains its fleet-memory section only when nonzero.
    #[test]
    fn fleet_memory_counters_merge_and_surface() {
        let a = ServeStats { prefix_adoptions: 2, ..Default::default() };
        let b = ServeStats {
            migrations: 1,
            migrated_pages: 3,
            transfer_time_s: 0.25,
            ..Default::default()
        };
        let m = ServeStats::merge(&[a, b]);
        assert_eq!(m.prefix_adoptions, 2);
        assert_eq!(m.migrations, 1);
        assert_eq!(m.migrated_pages, 3);
        assert_eq!(m.transfer_time_s, 0.25);
        let reg = m.metrics_registry();
        assert_eq!(reg.counter("flightllm_prefix_adoptions_total"), 2);
        assert_eq!(reg.counter("flightllm_migrations_total"), 1);
        assert_eq!(reg.counter("flightllm_migrated_pages_total"), 3);
        assert_eq!(reg.gauge("flightllm_transfer_seconds"), 0.25);
        let text = reg.prometheus_text();
        assert!(text.contains("flightllm_prefix_adoptions_total 2\n"));
        assert!(text.contains("flightllm_migrations_total 1\n"));
        let summary = m.summary("virtual");
        assert!(summary.contains("fleet memory: 2 prefix adoptions, 1 migrations"));
        assert!(summary.contains("(3 pages moved, 250.0 ms of inter-board transfer)"));
        // A run without fleet traffic keeps the summary clean.
        assert!(!ServeStats::default().summary("virtual").contains("fleet memory"));
    }

    /// Satellite: the ITL buffer is a bounded ring — a long-lived
    /// service keeps the most recent samples and flat memory.
    #[test]
    fn itl_ring_caps_memory_and_keeps_recent_samples() {
        let mut stats = ServeStats::default();
        for i in 0..(ITL_SAMPLE_CAP + 10) {
            stats.record_itl(i as f64);
        }
        assert_eq!(stats.itl_s.len(), ITL_SAMPLE_CAP, "capped");
        assert_eq!(stats.itl_total, (ITL_SAMPLE_CAP + 10) as u64, "all gaps counted");
        // The 10 oldest samples were overwritten by the newest 10.
        assert_eq!(stats.itl_s[0], ITL_SAMPLE_CAP as f64);
        assert_eq!(stats.itl_s[9], (ITL_SAMPLE_CAP + 9) as f64);
        assert_eq!(stats.itl_s[10], 10.0);
        assert_eq!(stats.max_itl_s(), (ITL_SAMPLE_CAP + 9) as f64);
    }

    #[test]
    fn idle_machine_fast_forwards_to_arrival() {
        let mut server = Server::new(
            EchoBackend::new(16),
            SchedulerConfig::default(),
            Sampler::greedy(),
        );
        let stats = server.run_trace(vec![req(0, 3.0, 4, 4)]).unwrap();
        let r = &stats.results[0];
        assert!((r.ttft_s - 0.002).abs() < 1e-9, "no queueing when idle");
        assert!((r.latency_s - 0.005).abs() < 1e-9);
        assert!((stats.served_s - 3.005).abs() < 1e-9, "clock jumped to arrival");
    }

    /// Regression (idle retirement): a context-capped sequence is retired
    /// alone — other running sequences keep decoding to completion. The
    /// old Idle branch retired EVERY running sequence.
    #[test]
    fn context_capped_sequence_retires_without_killing_others() {
        let mut server = Server::new(
            EchoBackend::new(32),
            SchedulerConfig { max_batch: 2, max_seq: 16, ..Default::default() },
            Sampler::greedy(),
        );
        // A's prompt fills the whole context (truncated 24 → 16): it caps
        // right after prefill with one token. B decodes its full budget.
        let trace = vec![req(0, 0.0, 24, 8), req(1, 0.0, 4, 8)];
        let stats = server.run_trace(trace).unwrap();
        let a = stats.results.iter().find(|r| r.id == 0).unwrap();
        let b = stats.results.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(a.prompt_len, 16, "prompt truncated consistently");
        assert_eq!(a.tokens.len(), 1, "capped after prefill");
        assert_eq!(b.tokens.len(), 8, "B must NOT be retired early");
    }

    /// Regression (KV desync): pool exhaustion evicts the sequence with
    /// its tokens intact, and the freed pages serve the next request.
    #[test]
    fn kv_exhaustion_evicts_and_frees_pages() {
        let mut server = Server::new(
            EchoBackend::new(32),
            SchedulerConfig {
                max_batch: 1,
                kv_pages: 2,
                page_tokens: 4,
                max_seq: 64,
                ..Default::default()
            },
            Sampler::greedy(),
        );
        let trace = vec![req(0, 0.0, 4, 100), req(1, 0.0, 4, 100)];
        let stats = server.run_trace(trace).unwrap();
        assert_eq!(stats.results.len(), 2, "both requests produce results");
        for r in &stats.results {
            assert!(r.evicted, "pool of 8 tokens cannot hold 104");
            // prefill 4 tokens + first token + 4 appended before the
            // 9th token fails to fit.
            assert_eq!(r.tokens.len(), 6);
        }
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.peak_kv_pages, 2, "the whole pool was in use");
    }

    /// Satellite (truthful overload stats): a KV-truncated request is
    /// excluded from the TTFT/latency aggregates and surfaced as
    /// `preempted_truncated` instead — its artificially short latency
    /// must not make an overloaded run look fast.
    #[test]
    fn truncated_requests_do_not_pollute_latency_aggregates() {
        let mut server = Server::new(
            EchoBackend::new(32),
            SchedulerConfig {
                max_batch: 1,
                kv_pages: 2,
                page_tokens: 4,
                max_seq: 64,
                ..Default::default()
            },
            Sampler::greedy(),
        );
        // Request 0 completes inside the pool; request 1 overruns it.
        let trace = vec![req(0, 0.0, 4, 2), req(1, 0.0, 4, 100)];
        let stats = server.run_trace(trace).unwrap();
        assert_eq!(stats.results.len(), 2);
        let ok = stats.results.iter().find(|r| r.id == 0).unwrap();
        let cut = stats.results.iter().find(|r| r.id == 1).unwrap();
        assert!(!ok.evicted && cut.evicted);
        assert_eq!(stats.preempted_truncated(), 1);
        // The aggregates are the COMPLETED request's numbers exactly.
        assert_eq!(stats.mean_latency_s(), ok.latency_s);
        assert_eq!(stats.mean_ttft_s(), ok.ttft_s);
        assert_eq!(stats.p99_latency_s(), ok.latency_s);
        let summary = stats.summary("virtual");
        assert!(summary.contains("preempted_truncated 1"));
        assert!(summary.contains("completed 1 requests"));
    }

    /// Satellite: a NaN arrival must not panic the serving loop — the
    /// request sorts last (`total_cmp`), the arrival is pinned to 0.0
    /// at submit, and every aggregate stays finite (the old code
    /// panicked in the sort; an unsanitized NaN would silently poison
    /// the means and percentiles instead).
    #[test]
    fn nan_arrival_is_served_without_panicking() {
        let mut server = Server::new(
            EchoBackend::new(16),
            SchedulerConfig { max_batch: 1, max_seq: 64, ..Default::default() },
            Sampler::greedy(),
        );
        let mut bad = req(1, 0.0, 4, 2);
        bad.arrival_s = f64::NAN;
        let stats = server.run_trace(vec![req(0, 0.0, 4, 2), bad]).unwrap();
        assert_eq!(stats.results.len(), 2, "both requests served");
        let ok = stats.results.iter().find(|r| r.id == 0).unwrap();
        assert_eq!(ok.tokens.len(), 2);
        let sanitized = stats.results.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(sanitized.tokens.len(), 2, "NaN arrival still generates tokens");
        assert!(sanitized.latency_s.is_finite(), "arrival pinned to 0.0 at submit");
        assert!(sanitized.ttft_s.is_finite());
        assert!(stats.mean_latency_s().is_finite(), "aggregates stay truthful");
        assert!(stats.p99_ttft_s().is_finite());
        assert!(stats.p99_latency_s().is_finite());
        assert!(!stats.summary("virtual").contains("NaN"));
    }

    /// Tentpole through the offline client: with swap enabled, an
    /// overloaded pool preempts instead of truncating — every request
    /// completes with tokens byte-identical to an over-provisioned run,
    /// the preemption traffic is counted, and serving takes strictly
    /// longer than the big-pool run (spilling is not free).
    #[test]
    fn swap_serving_completes_overload_token_identically() {
        let run = |kv_pages: usize, swap: bool| {
            let mut server = Server::new(
                EchoBackend::new(32),
                SchedulerConfig {
                    max_batch: 2,
                    kv_pages,
                    page_tokens: 4,
                    max_seq: 64,
                    swap,
                    ..Default::default()
                },
                Sampler::greedy(),
            );
            let trace = vec![req(0, 0.0, 4, 12), req(1, 0.0, 4, 12)];
            server.run_trace(trace).unwrap()
        };
        let big = run(64, false);
        let swapped = run(4, true);
        let lossy = run(4, false);
        assert_eq!(big.results.len(), 2);
        assert_eq!(swapped.results.len(), 2);
        assert!(big.results.iter().all(|r| !r.evicted && r.tokens.len() == 12));
        assert!(
            swapped.results.iter().all(|r| !r.evicted && r.tokens.len() == 12),
            "swap must eliminate truncation"
        );
        for a in &big.results {
            let b = swapped.results.iter().find(|r| r.id == a.id).unwrap();
            assert_eq!(a.tokens, b.tokens, "request {} resumes byte-identically", a.id);
        }
        assert!(swapped.preemptions > 0, "the small pool must have preempted");
        assert!(swapped.swapped_out_pages > 0 && swapped.swapped_in_pages > 0);
        assert!(
            swapped.served_s > big.served_s,
            "preemption serializes work: {} vs {}",
            swapped.served_s,
            big.served_s
        );
        assert_eq!(swapped.preempted_truncated(), 0);
        // The legacy baseline on the same pool loses both requests.
        assert_eq!(lossy.preempted_truncated(), 2);
        assert!(lossy.results.iter().all(|r| r.tokens.len() < 12));
    }

    #[test]
    fn oversized_for_pool_is_rejected_not_looped() {
        let mut server = Server::new(
            EchoBackend::new(32),
            SchedulerConfig {
                max_batch: 1,
                kv_pages: 2,
                page_tokens: 4,
                max_seq: 64,
                ..Default::default()
            },
            Sampler::greedy(),
        );
        // 32-token prompt needs 8 pages; the pool has 2. The request
        // behind it must still be served.
        let trace = vec![req(0, 0.0, 32, 4), req(1, 0.1, 4, 2)];
        let stats = server.run_trace(trace).unwrap();
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.results.len(), 1);
        assert_eq!(stats.results[0].id, 1);
        assert_eq!(stats.results[0].tokens.len(), 2);
    }

    #[test]
    fn serving_is_deterministic_across_runs() {
        let trace_cfg = TraceConfig {
            n_requests: 10,
            vocab: 64,
            prompt_len_choices: vec![4, 8, 16],
            decode_len_choices: vec![4, 8],
            seed: 3,
            ..Default::default()
        };
        let run = || {
            let mut server = Server::new(
                EchoBackend::new(64),
                SchedulerConfig { max_batch: 3, max_seq: 64, ..Default::default() },
                Sampler::greedy(),
            );
            server.run_trace(generate_trace(&trace_cfg)).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.results.len(), b.results.len());
        assert_eq!(a.served_s.to_bits(), b.served_s.to_bits());
        assert_eq!(a.peak_kv_pages, b.peak_kv_pages);
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.ttft_s.to_bits(), y.ttft_s.to_bits());
            assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits());
        }
    }

    /// Chunked prefill is a pure scheduling change: the same trace
    /// produces byte-identical tokens at any chunk size, and a chunked
    /// prompt takes one backend iteration per chunk.
    #[test]
    fn chunked_prefill_preserves_tokens() {
        let run = |prefill_chunk: usize| {
            let mut server = Server::new(
                EchoBackend::new(64),
                SchedulerConfig {
                    max_batch: 2,
                    max_seq: 128,
                    prefill_chunk,
                    ..Default::default()
                },
                Sampler::greedy(),
            );
            let trace = vec![req(0, 0.0, 40, 6), req(1, 0.0, 8, 6)];
            server.run_trace(trace).unwrap()
        };
        let whole = run(0);
        let chunked = run(16);
        assert_eq!(whole.results.len(), 2);
        assert_eq!(chunked.results.len(), 2);
        for a in &whole.results {
            let b = chunked.results.iter().find(|r| r.id == a.id).unwrap();
            assert_eq!(a.tokens, b.tokens, "chunking must not change tokens");
        }
        // 40 tokens at 16/iteration = 3 chunks (vs 1 unchunked): the
        // chunked run needs more engine steps for the same tokens.
        assert!(chunked.steps > whole.steps);
    }

    /// Prefix caching through the full serving loop: shared-prompt
    /// requests hit the cache, the hit surfaces in ServeStats, and the
    /// backend sees the cached_ctx on its prefill slot.
    #[test]
    fn prefix_hits_surface_in_serve_stats() {
        let mut server = Server::new(
            EchoBackend::new(32),
            SchedulerConfig {
                max_batch: 2,
                kv_pages: 16,
                page_tokens: 4,
                max_seq: 64,
                prefix_cache: true,
                ..Default::default()
            },
            Sampler::greedy(),
        );
        // Same 8-token prompt twice: the second admit shares page 0.
        let trace = vec![req(0, 0.0, 8, 2), req(1, 0.0, 8, 2)];
        let stats = server.run_trace(trace).unwrap();
        assert_eq!(stats.results.len(), 2);
        assert_eq!(stats.prefix_hits, 1, "second request hits");
        assert_eq!(stats.prefix_cached_tokens, 4, "one full page served");
        assert!(stats.prefix_hit_rate() > 0.0);
        // Identical prompts → identical generated tokens either way.
        assert_eq!(stats.results[0].tokens, stats.results[1].tokens);
    }
}
