//! The serving loop: drain a request trace through a `ModelBackend`
//! under the scheduler's policy, producing real tokens and per-request
//! latency statistics.
//!
//! `ModelBackend` abstracts the execution engine so the loop is testable
//! without artifacts; the real implementation is `runtime::ModelRuntime`
//! (PJRT executables) wired up in the serve example / CLI.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::workload::Request;

use super::sampler::Sampler;
use super::scheduler::{Action, Scheduler, SchedulerConfig};

/// Opaque per-sequence model state (the KV cache handle).
pub trait ModelBackend {
    type KvState;

    /// Run prefill; returns (logits, kv).
    fn prefill(&self, prompt: &[i32]) -> Result<(Vec<f32>, Self::KvState)>;

    /// One decode step; returns (logits, new kv).
    fn decode(&self, token: i32, kv: &Self::KvState, pos: i32)
        -> Result<(Vec<f32>, Self::KvState)>;
}

/// Completed-request record.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    /// Wall-clock seconds from admission to completion.
    pub latency_s: f64,
    /// Time to first token (prefill), seconds.
    pub ttft_s: f64,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub results: Vec<RequestResult>,
    pub wall_s: f64,
    pub decode_steps: u64,
    pub decode_time_s: f64,
}

impl ServeStats {
    pub fn decode_tps(&self) -> f64 {
        if self.decode_time_s <= 0.0 {
            return 0.0;
        }
        self.decode_steps as f64 / self.decode_time_s
    }

    pub fn mean_latency_s(&self) -> f64 {
        if self.results.is_empty() {
            return 0.0;
        }
        self.results.iter().map(|r| r.latency_s).sum::<f64>() / self.results.len() as f64
    }

    pub fn mean_ttft_s(&self) -> f64 {
        if self.results.is_empty() {
            return 0.0;
        }
        self.results.iter().map(|r| r.ttft_s).sum::<f64>() / self.results.len() as f64
    }
}

/// The serving coordinator.
pub struct Server<B: ModelBackend> {
    backend: B,
    scheduler: Scheduler,
    sampler: Sampler,
}

impl<B: ModelBackend> Server<B> {
    pub fn new(backend: B, cfg: SchedulerConfig, sampler: Sampler) -> Self {
        Self { backend, scheduler: Scheduler::new(cfg), sampler }
    }

    /// Run a whole trace to completion (offline replay: all requests are
    /// available; arrival times order admission).
    pub fn run_trace(&mut self, mut trace: Vec<Request>) -> Result<ServeStats> {
        trace.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        for r in trace {
            self.scheduler.submit(r);
        }
        let mut stats = ServeStats::default();
        let t0 = Instant::now();
        // Live per-sequence model state.
        let mut kv: HashMap<u64, B::KvState> = HashMap::new();
        let mut starts: HashMap<u64, (Instant, Instant)> = HashMap::new(); // (admit, first_token)

        loop {
            match self.scheduler.next_action(t0.elapsed().as_secs_f64()) {
                Action::Prefill { seq } => {
                    let admit_t = Instant::now();
                    let (prompt, _plen) = {
                        let s = self
                            .scheduler
                            .seq_mut(seq)
                            .expect("scheduled sequence exists");
                        let p: Vec<i32> = s.req.prompt.iter().map(|&t| t as i32).collect();
                        (p, s.ctx)
                    };
                    let (logits, state) = self.backend.prefill(&prompt)?;
                    let tok = self.sampler.sample(&logits);
                    kv.insert(seq, state);
                    starts.insert(seq, (admit_t, Instant::now()));
                    self.scheduler.on_prefill_done(seq, tok);
                }
                Action::Decode { seq } => {
                    let (last, ctx) = {
                        let s = self.scheduler.seq_mut(seq).unwrap();
                        (*s.generated.last().unwrap() as i32, s.ctx)
                    };
                    let t = Instant::now();
                    let state = &kv[&seq];
                    let (logits, new_state) = self.backend.decode(last, state, ctx as i32)?;
                    stats.decode_time_s += t.elapsed().as_secs_f64();
                    stats.decode_steps += 1;
                    let tok = self.sampler.sample(&logits);
                    kv.insert(seq, new_state);
                    if self.scheduler.on_decode_done(seq, tok) {
                        self.finish(seq, &mut kv, &mut starts, &mut stats);
                    }
                }
                Action::Idle => {
                    if self.scheduler.is_drained() {
                        break;
                    }
                    // Blocked sequences at context cap: retire them.
                    let stuck: Vec<u64> = self
                        .scheduler
                        .running()
                        .iter()
                        .map(|s| s.req.id)
                        .collect();
                    if stuck.is_empty() {
                        break;
                    }
                    for seq in stuck {
                        self.finish(seq, &mut kv, &mut starts, &mut stats);
                    }
                }
            }
        }
        stats.wall_s = t0.elapsed().as_secs_f64();
        Ok(stats)
    }

    fn finish(
        &mut self,
        seq: u64,
        kv: &mut HashMap<u64, B::KvState>,
        starts: &mut HashMap<u64, (Instant, Instant)>,
        stats: &mut ServeStats,
    ) {
        if let Some(s) = self.scheduler.retire(seq) {
            kv.remove(&seq);
            let (admit, first) = starts.remove(&seq).unwrap_or((Instant::now(), Instant::now()));
            stats.results.push(RequestResult {
                id: seq,
                prompt_len: s.req.prompt.len(),
                tokens: s.generated,
                latency_s: admit.elapsed().as_secs_f64(),
                ttft_s: first.duration_since(admit).as_secs_f64(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate_trace, TraceConfig};

    /// A deterministic toy backend: logits favor (last_token + 1) % V.
    struct EchoBackend {
        vocab: usize,
    }

    impl ModelBackend for EchoBackend {
        type KvState = u32; // pretend-kv: the running checksum

        fn prefill(&self, prompt: &[i32]) -> Result<(Vec<f32>, u32)> {
            let last = *prompt.last().unwrap_or(&0) as usize;
            let mut logits = vec![0.0f32; self.vocab];
            logits[(last + 1) % self.vocab] = 10.0;
            Ok((logits, prompt.len() as u32))
        }

        fn decode(&self, token: i32, kv: &u32, _pos: i32) -> Result<(Vec<f32>, u32)> {
            let mut logits = vec![0.0f32; self.vocab];
            logits[(token as usize + 1) % self.vocab] = 10.0;
            Ok((logits, kv + 1))
        }
    }

    #[test]
    fn serves_trace_to_completion_with_correct_tokens() {
        let backend = EchoBackend { vocab: 64 };
        let mut server = Server::new(
            backend,
            SchedulerConfig { max_seq: 128, ..Default::default() },
            Sampler::greedy(),
        );
        let trace = generate_trace(&TraceConfig {
            n_requests: 5,
            vocab: 64,
            prompt_len_choices: vec![4, 8],
            decode_len_choices: vec![4],
            ..Default::default()
        });
        let expected: Vec<(u64, u32)> = trace
            .iter()
            .map(|r| (r.id, (*r.prompt.last().unwrap() + 1) % 64))
            .collect();
        let stats = server.run_trace(trace).unwrap();
        assert_eq!(stats.results.len(), 5);
        for (id, first) in expected {
            let r = stats.results.iter().find(|r| r.id == id).unwrap();
            assert_eq!(r.tokens[0], first, "first token must be prompt+1");
            // Echo model: strictly increasing mod vocab.
            for w in r.tokens.windows(2) {
                assert_eq!(w[1], (w[0] + 1) % 64);
            }
            assert_eq!(r.tokens.len(), 4);
        }
        assert!(stats.decode_steps >= 5 * 3);
    }

    #[test]
    fn multibatch_interleaves_but_completes_all() {
        let backend = EchoBackend { vocab: 32 };
        let mut server = Server::new(
            backend,
            SchedulerConfig { max_batch: 4, max_seq: 64, ..Default::default() },
            Sampler::greedy(),
        );
        let trace = generate_trace(&TraceConfig {
            n_requests: 12,
            vocab: 32,
            prompt_len_choices: vec![4],
            decode_len_choices: vec![8],
            ..Default::default()
        });
        let stats = server.run_trace(trace).unwrap();
        assert_eq!(stats.results.len(), 12);
        assert!(stats.decode_tps() > 0.0);
    }
}
