//! The serving loop: a continuous-batching engine on a virtual clock.
//!
//! Every iteration the scheduler admits arrived requests and hands back
//! the runnable set; the backend executes ONE batched step over it
//! (prefilling new sequences, decoding the rest) and reports how many
//! seconds of model time the step took.  The virtual clock advances by
//! that amount, which makes admission, TTFT and per-request latency
//! deterministic functions of the trace and the backend's timing model:
//! the `sim::Engine`-backed backend reports the FlightLLM accelerator's
//! latencies, while the PJRT runtime backend reports measured host time.
//!
//! Prefix caching: a `Prefill` slot carries `cached_ctx`, the prompt
//! tokens already materialized in shared KV pages — a backend only has
//! to run the remaining suffix.  `ServeStats` reports the hit counters
//! and the peak page footprint so cache-on/off runs can be compared.
//!
//! TTFT and latency are measured from request ARRIVAL, so queueing delay
//! is included (the paper's serving scenario, §1).

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::workload::Request;

use super::sampler::Sampler;
use super::scheduler::{DecodeOutcome, Scheduler, SchedulerConfig};

/// One sequence's share of a batched engine iteration.
#[derive(Debug, Clone)]
pub enum SeqWork {
    /// First iteration: run the prompt through the model.  The first
    /// `cached_ctx` tokens are already in (shared) KV pages: the backend
    /// only needs to compute the suffix, but sees the full prompt for
    /// positioning and (on recompute-everything backends) parity.
    Prefill { prompt: Vec<i32>, cached_ctx: usize },
    /// One decode step: feed the last sampled token at position `pos`.
    Decode { last: i32, pos: i32 },
}

/// A slot in a batched step.
#[derive(Debug, Clone)]
pub struct SeqSlot {
    pub seq: u64,
    pub work: SeqWork,
}

/// What one batched step produced.
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// Per-slot logits, same order as the input batch.
    pub logits: Vec<Vec<f32>>,
    /// Seconds of model time the step took (virtual for the simulator,
    /// measured wall time for the PJRT runtime).
    pub step_s: f64,
}

/// The execution engine behind the serving loop.  Implementations keep
/// their own per-sequence KV state, keyed by `SeqSlot::seq`.
pub trait ModelBackend {
    /// Run one engine iteration over `batch` (mixed prefill/decode).
    fn step(&mut self, batch: &[SeqSlot]) -> Result<StepOutput>;

    /// Drop any per-sequence state held for a retired sequence.
    fn release(&mut self, _seq: u64) {}
}

/// Completed-request record.  All times are on the serving clock
/// (virtual seconds for simulated backends).
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    /// Seconds from request arrival to last token.
    pub latency_s: f64,
    /// Seconds from request arrival to first token (includes queueing).
    pub ttft_s: f64,
    /// Seconds the request waited in the queue before admission.
    pub queue_s: f64,
    /// True if the sequence was cut short by KV-pool exhaustion.
    pub evicted: bool,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub results: Vec<RequestResult>,
    /// Serving-clock seconds to drain the trace.
    pub served_s: f64,
    /// Host wall seconds actually spent.
    pub wall_s: f64,
    /// Batched engine iterations executed.
    pub steps: u64,
    /// Decode slot-executions in PURE decode steps (no prefill slot in
    /// the batch).  Mixed steps are excluded so `decode_tps` samples
    /// steady-state decode throughput instead of absorbing prefill cost.
    pub decode_steps: u64,
    /// Serving-clock seconds of those pure decode steps.
    pub decode_time_s: f64,
    /// Requests rejected at admission (prompt cannot fit the KV pool).
    pub rejected: u64,
    /// Admissions that reused at least one cached prefix page.
    pub prefix_hits: u64,
    /// Prompt tokens served from the prefix cache (prefill skipped).
    pub prefix_cached_tokens: u64,
    /// Peak pages holding live sequence data (shared pages count once;
    /// retained cache pages excluded) — the KV-capacity figure of merit.
    pub peak_kv_pages: usize,
}

impl ServeStats {
    /// Aggregate decode throughput, tokens/s on the serving clock.
    pub fn decode_tps(&self) -> f64 {
        if self.decode_time_s <= 0.0 {
            return 0.0;
        }
        self.decode_steps as f64 / self.decode_time_s
    }

    pub fn mean_latency_s(&self) -> f64 {
        mean(self.results.iter().map(|r| r.latency_s))
    }

    pub fn mean_ttft_s(&self) -> f64 {
        mean(self.results.iter().map(|r| r.ttft_s))
    }

    pub fn mean_queue_s(&self) -> f64 {
        mean(self.results.iter().map(|r| r.queue_s))
    }

    /// The `q`-th percentile (nearest-rank on the sorted sample) of a
    /// per-request metric; 0.0 when no requests completed.
    fn percentile(&self, q: f64, f: impl Fn(&RequestResult) -> f64) -> f64 {
        if self.results.is_empty() {
            return 0.0;
        }
        let mut vals: Vec<f64> = self.results.iter().map(f).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
        let idx = ((q / 100.0) * (vals.len() - 1) as f64).round() as usize;
        vals[idx.min(vals.len() - 1)]
    }

    pub fn p50_ttft_s(&self) -> f64 {
        self.percentile(50.0, |r| r.ttft_s)
    }

    pub fn p99_ttft_s(&self) -> f64 {
        self.percentile(99.0, |r| r.ttft_s)
    }

    pub fn p50_latency_s(&self) -> f64 {
        self.percentile(50.0, |r| r.latency_s)
    }

    pub fn p99_latency_s(&self) -> f64 {
        self.percentile(99.0, |r| r.latency_s)
    }

    /// Fraction of completed requests that hit the prefix cache.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.results.is_empty() {
            return 0.0;
        }
        self.prefix_hits as f64 / self.results.len() as f64
    }

    /// Human-readable summary (one printer for the CLI and examples).
    /// `clock_label` names the serving clock: "virtual" or "measured".
    pub fn summary(&self, clock_label: &str) -> String {
        let mut out = format!(
            "completed {} requests in {:.3}s {clock_label} ({} engine steps)\n",
            self.results.len(),
            self.served_s,
            self.steps
        );
        if self.rejected > 0 {
            out.push_str(&format!(
                "rejected {} requests (prompt cannot fit the KV pool)\n",
                self.rejected
            ));
        }
        out.push_str(&format!(
            "decode throughput {:.1} tok/s, mean TTFT {:.1} ms (queue {:.1} ms), \
             mean latency {:.1} ms\n",
            self.decode_tps(),
            self.mean_ttft_s() * 1e3,
            self.mean_queue_s() * 1e3,
            self.mean_latency_s() * 1e3
        ));
        out.push_str(&format!(
            "TTFT P50/P99 {:.1}/{:.1} ms, latency P50/P99 {:.1}/{:.1} ms, \
             peak KV {} pages",
            self.p50_ttft_s() * 1e3,
            self.p99_ttft_s() * 1e3,
            self.p50_latency_s() * 1e3,
            self.p99_latency_s() * 1e3,
            self.peak_kv_pages
        ));
        if self.prefix_hits > 0 {
            out.push_str(&format!(
                "\nprefix cache: {} hits ({:.0}% of requests), {} prompt tokens \
                 served from cache",
                self.prefix_hits,
                self.prefix_hit_rate() * 100.0,
                self.prefix_cached_tokens
            ));
        }
        out
    }
}

fn mean(it: impl Iterator<Item = f64>) -> f64 {
    let (mut sum, mut n) = (0.0, 0u64);
    for v in it {
        sum += v;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// The serving coordinator.
pub struct Server<B: ModelBackend> {
    backend: B,
    scheduler: Scheduler,
    sampler: Sampler,
}

impl<B: ModelBackend> Server<B> {
    pub fn new(backend: B, cfg: SchedulerConfig, sampler: Sampler) -> Self {
        Self { backend, scheduler: Scheduler::new(cfg), sampler }
    }

    /// The scheduler (inspection; the serving loop owns mutation).
    pub fn scheduler(&self) -> &Scheduler {
        &self.scheduler
    }

    /// Run a whole trace to completion (offline replay: all requests are
    /// known upfront; `arrival_s` gates admission against the serving
    /// clock, so a request submitted late still queues realistically).
    pub fn run_trace(&mut self, mut trace: Vec<Request>) -> Result<ServeStats> {
        trace.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        let arrivals: HashMap<u64, f64> = trace.iter().map(|r| (r.id, r.arrival_s)).collect();
        for r in trace {
            self.scheduler.submit(r);
        }
        let mut stats = ServeStats::default();
        let host_t0 = Instant::now();
        let mut clock = 0.0f64; // serving-clock seconds
        let mut first_token_s: HashMap<u64, f64> = HashMap::new();

        loop {
            let batch = self.scheduler.schedule(clock);
            // Admission just allocated prompt pages: sample the footprint.
            stats.peak_kv_pages = stats.peak_kv_pages.max(self.scheduler.pool.used_pages());
            if batch.is_empty() {
                if self.scheduler.is_drained() {
                    break;
                }
                // Residents that are genuinely finished (done or at the
                // context cap) are retired — and ONLY those.
                let stuck: Vec<u64> = self
                    .scheduler
                    .running()
                    .iter()
                    .filter(|s| s.done() || s.context_capped(self.scheduler.cfg.max_seq))
                    .map(|s| s.req.id)
                    .collect();
                if !stuck.is_empty() {
                    for seq in stuck {
                        self.finish(seq, false, clock, &arrivals, &mut first_token_s, &mut stats);
                    }
                    continue;
                }
                if self.scheduler.running().is_empty() {
                    if let Some(t) = self.scheduler.next_arrival_s() {
                        if t > clock {
                            // Machine idle: fast-forward to the next arrival.
                            clock = t;
                            continue;
                        }
                        // Arrived, machine empty, still unadmittable: the
                        // prompt can never fit the KV pool. Reject it
                        // explicitly instead of looping forever.
                        let _ = self.scheduler.reject_front();
                        stats.rejected += 1;
                        continue;
                    }
                }
                bail!("scheduler stalled: nothing runnable but trace not drained");
            }

            // Build the batched step from scheduler state.
            let slots: Vec<SeqSlot> = batch
                .iter()
                .map(|&id| {
                    let s = self.scheduler.seq(id).expect("scheduled sequence exists");
                    let work = if !s.prefilled {
                        SeqWork::Prefill {
                            prompt: s.req.prompt.iter().map(|&t| t as i32).collect(),
                            cached_ctx: s.cached_ctx,
                        }
                    } else {
                        SeqWork::Decode {
                            last: *s.generated.last().expect("prefilled seq has a token")
                                as i32,
                            pos: s.ctx as i32,
                        }
                    };
                    SeqSlot { seq: id, work }
                })
                .collect();

            let out = self.backend.step(&slots)?;
            ensure!(
                out.logits.len() == slots.len(),
                "backend returned {} logit rows for a batch of {}",
                out.logits.len(),
                slots.len()
            );
            clock += out.step_s.max(0.0);
            stats.steps += 1;
            let n_decode = slots
                .iter()
                .filter(|s| matches!(s.work, SeqWork::Decode { .. }))
                .count() as u64;
            // Only pure decode steps sample throughput: a mixed step's
            // cost is dominated by its prefills and would deflate tok/s.
            if n_decode == slots.len() as u64 {
                stats.decode_steps += n_decode;
                stats.decode_time_s += out.step_s.max(0.0);
            }

            // Sample each slot's token and record it with the scheduler.
            let mut finished: Vec<(u64, bool)> = Vec::new();
            for (slot, logits) in slots.iter().zip(&out.logits) {
                let tok = self.sampler.sample(logits);
                match slot.work {
                    SeqWork::Prefill { .. } => {
                        self.scheduler.on_prefill_done(slot.seq, tok);
                        first_token_s.insert(slot.seq, clock);
                    }
                    SeqWork::Decode { .. } => {
                        if self.scheduler.on_decode_done(slot.seq, tok)
                            == DecodeOutcome::EvictedKvFull
                        {
                            finished.push((slot.seq, true));
                        }
                    }
                }
            }
            // Decode appends may have opened (or CoW-copied) pages.
            stats.peak_kv_pages = stats.peak_kv_pages.max(self.scheduler.pool.used_pages());
            // Sweep completed sequences (token budget reached, or context
            // cap hit — including prompts that fill the context at prefill).
            let max_seq = self.scheduler.cfg.max_seq;
            finished.extend(
                self.scheduler
                    .running()
                    .iter()
                    .filter(|s| s.done() || s.context_capped(max_seq))
                    .map(|s| (s.req.id, false)),
            );
            for (seq, evicted) in finished {
                self.finish(seq, evicted, clock, &arrivals, &mut first_token_s, &mut stats);
            }
        }
        stats.served_s = clock;
        stats.wall_s = host_t0.elapsed().as_secs_f64();
        let pool = self.scheduler.pool.stats();
        stats.prefix_hits = pool.prefix_hits;
        stats.prefix_cached_tokens = pool.cached_tokens_served;
        Ok(stats)
    }

    fn finish(
        &mut self,
        seq: u64,
        evicted: bool,
        clock: f64,
        arrivals: &HashMap<u64, f64>,
        first_token_s: &mut HashMap<u64, f64>,
        stats: &mut ServeStats,
    ) {
        if let Some(s) = self.scheduler.retire(seq) {
            self.backend.release(seq);
            let arrival = arrivals.get(&seq).copied().unwrap_or(0.0);
            let first = first_token_s.remove(&seq).unwrap_or(clock);
            stats.results.push(RequestResult {
                id: seq,
                prompt_len: s.req.prompt.len(),
                tokens: s.generated,
                latency_s: clock - arrival,
                ttft_s: first - arrival,
                queue_s: s.admitted_s - arrival,
                evicted,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate_trace, TraceConfig};

    /// A deterministic toy backend: logits favor (last_token + 1) % V.
    /// Step cost is flat per phase — prefills charge `prefill_s` each,
    /// any number of decode slots share one `decode_s` (so batching
    /// visibly improves aggregate throughput).
    struct EchoBackend {
        vocab: usize,
        prefill_s: f64,
        decode_s: f64,
    }

    impl EchoBackend {
        fn new(vocab: usize) -> Self {
            Self { vocab, prefill_s: 2e-3, decode_s: 1e-3 }
        }
    }

    impl ModelBackend for EchoBackend {
        fn step(&mut self, batch: &[SeqSlot]) -> Result<StepOutput> {
            let mut step_s = 0.0;
            let mut any_decode = false;
            let logits = batch
                .iter()
                .map(|slot| {
                    let last = match &slot.work {
                        SeqWork::Prefill { prompt, .. } => {
                            step_s += self.prefill_s;
                            *prompt.last().unwrap_or(&0)
                        }
                        SeqWork::Decode { last, .. } => {
                            any_decode = true;
                            *last
                        }
                    } as usize;
                    let mut l = vec![0.0f32; self.vocab];
                    l[(last + 1) % self.vocab] = 10.0;
                    l
                })
                .collect();
            if any_decode {
                step_s += self.decode_s;
            }
            Ok(StepOutput { logits, step_s })
        }
    }

    fn req(id: u64, arrival_s: f64, plen: usize, dlen: u32) -> Request {
        Request {
            id,
            arrival_s,
            prompt: (0..plen as u32).collect(),
            max_new_tokens: dlen,
        }
    }

    #[test]
    fn serves_trace_to_completion_with_correct_tokens() {
        let mut server = Server::new(
            EchoBackend::new(64),
            SchedulerConfig { max_seq: 128, ..Default::default() },
            Sampler::greedy(),
        );
        let trace = generate_trace(&TraceConfig {
            n_requests: 5,
            vocab: 64,
            prompt_len_choices: vec![4, 8],
            decode_len_choices: vec![4],
            ..Default::default()
        });
        let expected: Vec<(u64, u32)> = trace
            .iter()
            .map(|r| (r.id, (*r.prompt.last().unwrap() + 1) % 64))
            .collect();
        let stats = server.run_trace(trace).unwrap();
        assert_eq!(stats.results.len(), 5);
        for (id, first) in expected {
            let r = stats.results.iter().find(|r| r.id == id).unwrap();
            assert_eq!(r.tokens[0], first, "first token must be prompt+1");
            // Echo model: strictly increasing mod vocab.
            for w in r.tokens.windows(2) {
                assert_eq!(w[1], (w[0] + 1) % 64);
            }
            assert_eq!(r.tokens.len(), 4);
            assert!(!r.evicted);
        }
        assert!(stats.decode_steps >= 5 * 3);
        assert!(stats.served_s > 0.0);
        assert!(stats.peak_kv_pages > 0, "prompt pages were live at some point");
        assert_eq!(stats.prefix_hits, 0, "caching off by default");
    }

    #[test]
    fn multibatch_completes_all_and_raises_throughput() {
        let trace_cfg = TraceConfig {
            n_requests: 12,
            vocab: 32,
            prompt_len_choices: vec![4],
            decode_len_choices: vec![8],
            rate_per_s: 1e6, // near-simultaneous arrivals: batching matters
            ..Default::default()
        };
        let run = |max_batch: usize| {
            let mut server = Server::new(
                EchoBackend::new(32),
                SchedulerConfig { max_batch, max_seq: 64, ..Default::default() },
                Sampler::greedy(),
            );
            server.run_trace(generate_trace(&trace_cfg)).unwrap()
        };
        let s1 = run(1);
        let s4 = run(4);
        assert_eq!(s1.results.len(), 12);
        assert_eq!(s4.results.len(), 12);
        // Four sequences share each decode step: aggregate tokens/s and
        // end-to-end drain time must both improve.
        assert!(s4.decode_tps() > 2.0 * s1.decode_tps());
        assert!(s4.served_s < s1.served_s);
        // More residents at once: the KV footprint peak must be higher.
        assert!(s4.peak_kv_pages > s1.peak_kv_pages);
    }

    /// Regression (TTFT): time-to-first-token is measured from request
    /// arrival, so a queued request's TTFT includes its queueing delay.
    #[test]
    fn ttft_includes_queueing_delay() {
        let mut server = Server::new(
            EchoBackend::new(16),
            SchedulerConfig { max_batch: 1, max_seq: 64, ..Default::default() },
            Sampler::greedy(),
        );
        let trace = vec![req(0, 0.0, 4, 4), req(1, 0.0, 4, 4)];
        let stats = server.run_trace(trace).unwrap();
        let a = stats.results.iter().find(|r| r.id == 0).unwrap();
        let b = stats.results.iter().find(|r| r.id == 1).unwrap();
        // A: prefill at 2ms, 3 decode steps → done at 5ms.
        assert!((a.ttft_s - 0.002).abs() < 1e-9, "A ttft = {}", a.ttft_s);
        assert!((a.latency_s - 0.005).abs() < 1e-9);
        assert!((a.queue_s - 0.0).abs() < 1e-9);
        // B waits for A (5ms), prefills by 7ms, finishes at 10ms.
        assert!((b.queue_s - 0.005).abs() < 1e-9, "B queued = {}", b.queue_s);
        assert!((b.ttft_s - 0.007).abs() < 1e-9, "B ttft = {}", b.ttft_s);
        assert!((b.latency_s - 0.010).abs() < 1e-9);
        assert!((stats.served_s - 0.010).abs() < 1e-9);
    }

    /// Satellite: percentile accessors follow the ordered TTFT spread —
    /// P50 sits at the median, P99 at the worst queued request.
    #[test]
    fn percentiles_track_queueing_spread() {
        let mut server = Server::new(
            EchoBackend::new(16),
            SchedulerConfig { max_batch: 1, max_seq: 64, ..Default::default() },
            Sampler::greedy(),
        );
        // Four identical back-to-back requests at batch 1: TTFTs are
        // 2, 7, 12, 17 ms (each waits for its predecessors).
        let trace = (0..4).map(|i| req(i, 0.0, 4, 4)).collect();
        let stats = server.run_trace(trace).unwrap();
        assert_eq!(stats.results.len(), 4);
        let max_ttft = stats
            .results
            .iter()
            .map(|r| r.ttft_s)
            .fold(f64::NEG_INFINITY, f64::max);
        assert!((stats.p99_ttft_s() - max_ttft).abs() < 1e-12, "P99 = worst request");
        assert!(stats.p50_ttft_s() < stats.p99_ttft_s(), "spread is visible");
        assert!(stats.p50_latency_s() <= stats.p99_latency_s());
        assert!(stats.p50_ttft_s() > 0.0);
        // Empty stats stay well-defined.
        assert_eq!(ServeStats::default().p99_ttft_s(), 0.0);
    }

    #[test]
    fn idle_machine_fast_forwards_to_arrival() {
        let mut server = Server::new(
            EchoBackend::new(16),
            SchedulerConfig::default(),
            Sampler::greedy(),
        );
        let stats = server.run_trace(vec![req(0, 3.0, 4, 4)]).unwrap();
        let r = &stats.results[0];
        assert!((r.ttft_s - 0.002).abs() < 1e-9, "no queueing when idle");
        assert!((r.latency_s - 0.005).abs() < 1e-9);
        assert!((stats.served_s - 3.005).abs() < 1e-9, "clock jumped to arrival");
    }

    /// Regression (idle retirement): a context-capped sequence is retired
    /// alone — other running sequences keep decoding to completion. The
    /// old Idle branch retired EVERY running sequence.
    #[test]
    fn context_capped_sequence_retires_without_killing_others() {
        let mut server = Server::new(
            EchoBackend::new(32),
            SchedulerConfig { max_batch: 2, max_seq: 16, ..Default::default() },
            Sampler::greedy(),
        );
        // A's prompt fills the whole context (truncated 24 → 16): it caps
        // right after prefill with one token. B decodes its full budget.
        let trace = vec![req(0, 0.0, 24, 8), req(1, 0.0, 4, 8)];
        let stats = server.run_trace(trace).unwrap();
        let a = stats.results.iter().find(|r| r.id == 0).unwrap();
        let b = stats.results.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(a.prompt_len, 16, "prompt truncated consistently");
        assert_eq!(a.tokens.len(), 1, "capped after prefill");
        assert_eq!(b.tokens.len(), 8, "B must NOT be retired early");
    }

    /// Regression (KV desync): pool exhaustion evicts the sequence with
    /// its tokens intact, and the freed pages serve the next request.
    #[test]
    fn kv_exhaustion_evicts_and_frees_pages() {
        let mut server = Server::new(
            EchoBackend::new(32),
            SchedulerConfig {
                max_batch: 1,
                kv_pages: 2,
                page_tokens: 4,
                max_seq: 64,
                ..Default::default()
            },
            Sampler::greedy(),
        );
        let trace = vec![req(0, 0.0, 4, 100), req(1, 0.0, 4, 100)];
        let stats = server.run_trace(trace).unwrap();
        assert_eq!(stats.results.len(), 2, "both requests produce results");
        for r in &stats.results {
            assert!(r.evicted, "pool of 8 tokens cannot hold 104");
            // prefill 4 tokens + first token + 4 appended before the
            // 9th token fails to fit.
            assert_eq!(r.tokens.len(), 6);
        }
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.peak_kv_pages, 2, "the whole pool was in use");
    }

    #[test]
    fn oversized_for_pool_is_rejected_not_looped() {
        let mut server = Server::new(
            EchoBackend::new(32),
            SchedulerConfig {
                max_batch: 1,
                kv_pages: 2,
                page_tokens: 4,
                max_seq: 64,
                ..Default::default()
            },
            Sampler::greedy(),
        );
        // 32-token prompt needs 8 pages; the pool has 2. The request
        // behind it must still be served.
        let trace = vec![req(0, 0.0, 32, 4), req(1, 0.1, 4, 2)];
        let stats = server.run_trace(trace).unwrap();
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.results.len(), 1);
        assert_eq!(stats.results[0].id, 1);
        assert_eq!(stats.results[0].tokens.len(), 2);
    }

    #[test]
    fn serving_is_deterministic_across_runs() {
        let trace_cfg = TraceConfig {
            n_requests: 10,
            vocab: 64,
            prompt_len_choices: vec![4, 8, 16],
            decode_len_choices: vec![4, 8],
            seed: 3,
            ..Default::default()
        };
        let run = || {
            let mut server = Server::new(
                EchoBackend::new(64),
                SchedulerConfig { max_batch: 3, max_seq: 64, ..Default::default() },
                Sampler::greedy(),
            );
            server.run_trace(generate_trace(&trace_cfg)).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.results.len(), b.results.len());
        assert_eq!(a.served_s.to_bits(), b.served_s.to_bits());
        assert_eq!(a.peak_kv_pages, b.peak_kv_pages);
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.ttft_s.to_bits(), y.ttft_s.to_bits());
            assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits());
        }
    }

    /// Prefix caching through the full serving loop: shared-prompt
    /// requests hit the cache, the hit surfaces in ServeStats, and the
    /// backend sees the cached_ctx on its prefill slot.
    #[test]
    fn prefix_hits_surface_in_serve_stats() {
        let mut server = Server::new(
            EchoBackend::new(32),
            SchedulerConfig {
                max_batch: 2,
                kv_pages: 16,
                page_tokens: 4,
                max_seq: 64,
                prefix_cache: true,
            },
            Sampler::greedy(),
        );
        // Same 8-token prompt twice: the second admit shares page 0.
        let trace = vec![req(0, 0.0, 8, 2), req(1, 0.0, 8, 2)];
        let stats = server.run_trace(trace).unwrap();
        assert_eq!(stats.results.len(), 2);
        assert_eq!(stats.prefix_hits, 1, "second request hits");
        assert_eq!(stats.prefix_cached_tokens, 4, "one full page served");
        assert!(stats.prefix_hit_rate() > 0.0);
        // Identical prompts → identical generated tokens either way.
        assert_eq!(stats.results[0].tokens, stats.results[1].tokens);
    }
}
