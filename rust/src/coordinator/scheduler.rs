//! Continuous-batching scheduler.
//!
//! Every engine iteration the scheduler admits newly-arrived requests
//! (oldest first, while a batch slot and KV pages are free) and returns
//! the whole runnable set — unprefilled sequences run their prompt,
//! prefilled ones take one decode step.  `max_batch = 1` degenerates to
//! the paper's latency-oriented batch-size-1 regime (§1); larger values
//! give the Fig. 15 multi-batch mode.
//!
//! With `prefix_cache` on, admission consults the pool's prefix index:
//! a prompt whose full-page prefix is already materialized shares those
//! pages and is charged only its uncached suffix against free pages.
//! `SeqState::cached_ctx` records how many prompt tokens the backend may
//! skip at prefill.
//!
//! Accounting invariant (checked by `check_accounting` and the property
//! tests below): for every running sequence, `SeqState.ctx` equals the
//! KV pool's token count — the scheduler never believes in KV the pool
//! does not hold, cached or not.

use std::collections::VecDeque;

use crate::workload::Request;

use super::kv_cache::PagePool;

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Concurrent sequences in flight (batch size; paper default 1).
    pub max_batch: usize,
    /// KV page pool geometry.
    pub kv_pages: usize,
    pub page_tokens: usize,
    /// Hard cap on context (model max_seq).
    pub max_seq: usize,
    /// Share full-page prompt prefixes across sequences (CoW paged KV).
    pub prefix_cache: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            max_batch: 1,
            kv_pages: 64,
            page_tokens: 16,
            max_seq: 256,
            prefix_cache: false,
        }
    }
}

/// A running sequence.
#[derive(Debug)]
pub struct SeqState {
    pub req: Request,
    /// Tokens generated so far.
    pub generated: Vec<u32>,
    /// Context length currently in the KV cache (== pool tokens).
    pub ctx: usize,
    /// Prompt tokens served from the prefix cache at admission: the
    /// backend only prefills the remaining suffix.
    pub cached_ctx: usize,
    /// Whether prefill has run.
    pub prefilled: bool,
    /// Virtual time the request was admitted.
    pub admitted_s: f64,
}

impl SeqState {
    pub fn done(&self) -> bool {
        self.prefilled && self.generated.len() >= self.req.max_new_tokens as usize
    }

    /// The KV cache holds `max_seq` tokens: no further decode possible.
    pub fn context_capped(&self, max_seq: usize) -> bool {
        self.ctx >= max_seq
    }

    /// Still has work to run this iteration.
    pub fn runnable(&self, max_seq: usize) -> bool {
        !self.prefilled || (!self.done() && !self.context_capped(max_seq))
    }
}

/// What one decode step did to a sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeOutcome {
    /// Still generating.
    Running,
    /// Reached its token budget or the context cap.
    Finished,
    /// The KV pool could not grow: the sequence must be retired now.
    /// `ctx` was NOT advanced, so scheduler context and pool tokens stay
    /// in sync (the produced token is still recorded).
    EvictedKvFull,
}

#[derive(Debug)]
pub struct Scheduler {
    pub cfg: SchedulerConfig,
    waiting: VecDeque<Request>,
    running: Vec<SeqState>,
    pub pool: PagePool,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        let pool = if cfg.prefix_cache {
            PagePool::with_prefix_cache(cfg.kv_pages, cfg.page_tokens)
        } else {
            PagePool::new(cfg.kv_pages, cfg.page_tokens)
        };
        Self { cfg, waiting: VecDeque::new(), running: Vec::new(), pool }
    }

    /// Queue a request.  Prompts longer than `max_seq` are truncated HERE
    /// so admission accounting, the backend's prefill, and the KV pool
    /// all see the same length (an oversized prompt can otherwise never
    /// be served — its KV would not fit the model's cache).
    pub fn submit(&mut self, mut req: Request) {
        req.prompt.truncate(self.cfg.max_seq);
        self.waiting.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.waiting.len()
    }

    pub fn running(&self) -> &[SeqState] {
        &self.running
    }

    pub fn seq(&self, seq: u64) -> Option<&SeqState> {
        self.running.iter().find(|s| s.req.id == seq)
    }

    pub fn seq_mut(&mut self, seq: u64) -> Option<&mut SeqState> {
        self.running.iter_mut().find(|s| s.req.id == seq)
    }

    /// Arrival time of the oldest waiting request (the serving loop
    /// fast-forwards its virtual clock to this when idle).
    pub fn next_arrival_s(&self) -> Option<f64> {
        self.waiting.front().map(|r| r.arrival_s)
    }

    /// Admit arrived requests while capacity allows, then return the ids
    /// runnable this iteration (admission order; unprefilled sequences
    /// run prefill, the rest one decode step each).  Admission charges
    /// only the uncached prompt suffix: a cached full-page prefix is
    /// shared, not reallocated.
    pub fn schedule(&mut self, now_s: f64) -> Vec<u64> {
        while self.running.len() < self.cfg.max_batch {
            let Some(req) = self.waiting.front() else { break };
            if req.arrival_s > now_s || !self.pool.can_admit(&req.prompt) {
                break;
            }
            let req = self.waiting.pop_front().unwrap();
            let plen = req.prompt.len();
            let outcome = self
                .pool
                .admit(req.id, &req.prompt)
                .expect("can_admit guaranteed admission");
            self.running.push(SeqState {
                req,
                generated: Vec::new(),
                ctx: plen,
                cached_ctx: outcome.cached_tokens,
                prefilled: false,
                admitted_s: now_s,
            });
        }
        self.running
            .iter()
            .filter(|s| s.runnable(self.cfg.max_seq))
            .map(|s| s.req.id)
            .collect()
    }

    /// Pop the oldest waiting request without admitting it.  The serving
    /// loop uses this to reject a request that cannot fit the KV pool
    /// even on an empty machine.
    pub fn reject_front(&mut self) -> Option<Request> {
        self.waiting.pop_front()
    }

    /// Record a prefill completion (first token produced).
    pub fn on_prefill_done(&mut self, seq: u64, first_token: u32) {
        if let Some(s) = self.seq_mut(seq) {
            s.prefilled = true;
            s.generated.push(first_token);
        }
    }

    /// Record a decode step.  The KV pool grows first; on exhaustion the
    /// sequence is reported for eviction instead of silently desyncing
    /// `ctx` from the pool's token count.
    pub fn on_decode_done(&mut self, seq: u64, token: u32) -> DecodeOutcome {
        match self.pool.append(seq) {
            Ok(()) => {
                let max_seq = self.cfg.max_seq;
                if let Some(s) = self.seq_mut(seq) {
                    s.ctx += 1;
                    s.generated.push(token);
                    if s.done() || s.context_capped(max_seq) {
                        return DecodeOutcome::Finished;
                    }
                }
                DecodeOutcome::Running
            }
            Err(_) => {
                // The token was produced; record it, but leave ctx equal
                // to the pool's token count and hand the sequence back
                // for retirement.
                if let Some(s) = self.seq_mut(seq) {
                    s.generated.push(token);
                }
                DecodeOutcome::EvictedKvFull
            }
        }
    }

    /// Remove a finished sequence, releasing its pages.  A failed
    /// release means the scheduler and pool disagree about who exists —
    /// a page-leak bug, so it must not pass silently.
    pub fn retire(&mut self, seq: u64) -> Option<SeqState> {
        let idx = self.running.iter().position(|s| s.req.id == seq)?;
        let s = self.running.swap_remove(idx);
        let released = self.pool.release(seq);
        debug_assert!(
            released.is_ok(),
            "retire({seq}): KV release failed: {released:?}"
        );
        Some(s)
    }

    pub fn is_drained(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }

    /// The scheduler↔pool accounting invariant: every running sequence's
    /// `ctx` equals its pool token count, and the pool itself is sound
    /// (every page free, retained, or shared with an accurate refcount).
    pub fn check_accounting(&self) -> bool {
        self.running
            .iter()
            .all(|s| self.pool.seq(s.req.id).is_some_and(|p| p.tokens == s.ctx))
            && self.pool.check_invariants()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::workload::{
        generate_shared_prefix_trace, generate_trace, SharedPrefixConfig, TraceConfig,
    };

    fn req(id: u64, plen: usize, dlen: u32) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            prompt: vec![1; plen],
            max_new_tokens: dlen,
        }
    }

    #[test]
    fn single_batch_runs_one_request_to_completion() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        s.submit(req(0, 16, 3));
        s.submit(req(1, 16, 3));
        assert_eq!(s.schedule(0.0), vec![0], "batch=1 admits only request 0");
        s.on_prefill_done(0, 7);
        assert_eq!(s.schedule(0.0), vec![0]);
        assert_eq!(s.on_decode_done(0, 8), DecodeOutcome::Running);
        assert_eq!(s.on_decode_done(0, 9), DecodeOutcome::Finished); // 3 tokens
        s.retire(0);
        assert_eq!(s.schedule(0.0), vec![1]);
        assert!(!s.seq(1).unwrap().prefilled);
    }

    #[test]
    fn multibatch_runs_all_sequences_every_iteration() {
        let mut s = Scheduler::new(SchedulerConfig { max_batch: 2, ..Default::default() });
        s.submit(req(0, 16, 8));
        s.submit(req(1, 16, 8));
        assert_eq!(s.schedule(0.0), vec![0, 1], "both admitted in one iteration");
        s.on_prefill_done(0, 1);
        s.on_prefill_done(1, 1);
        // Continuous batching: every iteration decodes the whole batch.
        assert_eq!(s.schedule(0.0), vec![0, 1]);
    }

    #[test]
    fn admission_gated_by_arrival_time() {
        let mut s = Scheduler::new(SchedulerConfig { max_batch: 2, ..Default::default() });
        let mut r = req(0, 8, 2);
        r.arrival_s = 5.0;
        s.submit(r);
        assert!(s.schedule(0.0).is_empty(), "not arrived yet");
        assert_eq!(s.next_arrival_s(), Some(5.0));
        assert_eq!(s.schedule(5.0), vec![0]);
        assert_eq!(s.seq(0).unwrap().admitted_s, 5.0);
    }

    #[test]
    fn admission_blocked_by_kv_capacity() {
        let cfg = SchedulerConfig {
            max_batch: 4,
            kv_pages: 2,
            page_tokens: 16,
            max_seq: 256,
            ..Default::default()
        };
        let mut s = Scheduler::new(cfg);
        s.submit(req(0, 32, 4)); // takes both pages
        s.submit(req(1, 16, 4));
        assert_eq!(s.schedule(0.0), vec![0]);
        s.on_prefill_done(0, 1);
        // No pages left: request 1 can't be admitted; 0 keeps decoding.
        assert_eq!(s.schedule(0.0), vec![0]);
        assert_eq!(s.pending(), 1);
    }

    #[test]
    fn context_cap_finishes_sequence() {
        let cfg = SchedulerConfig { max_seq: 18, ..Default::default() };
        let mut s = Scheduler::new(cfg);
        s.submit(req(0, 16, 100));
        s.schedule(0.0);
        s.on_prefill_done(0, 1);
        assert_eq!(s.on_decode_done(0, 2), DecodeOutcome::Running); // ctx 17
        assert_eq!(s.on_decode_done(0, 3), DecodeOutcome::Finished); // ctx 18
    }

    /// Satellite: `reject_front` pops exactly the head request, touches
    /// no pool state, and leaves the queue serving the next request.
    #[test]
    fn reject_front_pops_head_without_touching_pool() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        s.submit(req(0, 8, 2));
        s.submit(req(1, 8, 2));
        let rejected = s.reject_front().expect("head exists");
        assert_eq!(rejected.id, 0);
        assert_eq!(s.pending(), 1);
        assert!(s.running().is_empty());
        assert_eq!(s.pool.used_pages(), 0, "rejection allocates nothing");
        assert!(s.check_accounting());
        assert_eq!(s.schedule(0.0), vec![1], "queue moves on to the next request");
        assert!(s.reject_front().is_none() || s.pending() == 0);
    }

    /// Regression (KV desync): when the pool cannot grow, the sequence is
    /// evicted and `ctx` stays equal to the pool's token count — the old
    /// code pushed the token anyway and stalled with ctx != pool tokens.
    #[test]
    fn kv_exhaustion_evicts_instead_of_desyncing() {
        let cfg = SchedulerConfig {
            max_batch: 1,
            kv_pages: 2,
            page_tokens: 4,
            max_seq: 64,
            ..Default::default()
        };
        let mut s = Scheduler::new(cfg);
        s.submit(req(0, 7, 100)); // 2 pages, 1 token of slack
        assert_eq!(s.schedule(0.0), vec![0]);
        s.on_prefill_done(0, 1);
        assert_eq!(s.on_decode_done(0, 2), DecodeOutcome::Running); // token 8 fills page 2
        assert!(s.check_accounting());
        assert_eq!(s.on_decode_done(0, 3), DecodeOutcome::EvictedKvFull);
        let seq = s.seq(0).unwrap();
        assert_eq!(seq.ctx, 8, "ctx must not advance past the pool");
        assert_eq!(s.pool.seq(0).unwrap().tokens, 8);
        assert_eq!(seq.generated.len(), 3, "produced tokens are kept");
        assert!(s.check_accounting());
        s.retire(0);
        assert_eq!(s.pool.used_pages(), 0);
    }

    /// Regression (truncation mismatch): an oversized prompt is truncated
    /// once at submit, so admission accounting, the prompt the backend
    /// prefills, and the pool token count all agree.
    #[test]
    fn oversized_prompt_truncated_consistently() {
        let cfg = SchedulerConfig { max_seq: 16, ..Default::default() };
        let mut s = Scheduler::new(cfg);
        s.submit(req(0, 40, 4));
        assert_eq!(s.schedule(0.0), vec![0]);
        let seq = s.seq(0).unwrap();
        assert_eq!(seq.req.prompt.len(), 16, "prompt truncated to max_seq");
        assert_eq!(seq.ctx, 16);
        assert_eq!(s.pool.seq(0).unwrap().tokens, 16);
        assert!(seq.context_capped(16), "full-context prompt caps immediately");
        assert!(s.check_accounting());
    }

    /// With prefix caching on, a second admission of the same prompt
    /// charges only the uncached suffix and records `cached_ctx` — while
    /// ctx still equals the pool's full token count.
    #[test]
    fn admission_charges_only_uncached_suffix() {
        let cfg = SchedulerConfig {
            max_batch: 4,
            kv_pages: 3,
            page_tokens: 16,
            max_seq: 256,
            prefix_cache: true,
        };
        let mut s = Scheduler::new(cfg);
        let prompt: Vec<u32> = (0..32).collect();
        s.submit(Request { id: 0, arrival_s: 0.0, prompt: prompt.clone(), max_new_tokens: 4 });
        s.submit(Request { id: 1, arrival_s: 0.0, prompt, max_new_tokens: 4 });
        // 3 pages serve both 2-page prompts: seq 1 shares seq 0's first
        // page, so only one fresh page is charged.
        assert_eq!(s.schedule(0.0), vec![0, 1]);
        assert_eq!(s.seq(0).unwrap().cached_ctx, 0, "cold cache");
        assert_eq!(s.seq(1).unwrap().cached_ctx, 16, "first page served from cache");
        assert_eq!(s.seq(1).unwrap().ctx, 32, "ctx counts the WHOLE prompt");
        assert_eq!(s.pool.seq(1).unwrap().tokens, 32);
        assert!(s.check_accounting());
    }

    #[test]
    fn property_scheduler_never_starves() {
        // Every submitted request eventually completes under any
        // interleaving of batch sizes and lengths.
        proptest::check_with("scheduler liveness", 64, |r| {
            let cfg = SchedulerConfig {
                max_batch: 1 + r.below(4) as usize,
                kv_pages: 32,
                page_tokens: 8,
                max_seq: 64,
                ..Default::default()
            };
            let mut s = Scheduler::new(cfg);
            let trace = generate_trace(&TraceConfig {
                n_requests: 6,
                prompt_len_choices: vec![4, 8, 16],
                decode_len_choices: vec![2, 4, 8],
                seed: r.next_u64(),
                ..Default::default()
            });
            let total = trace.len();
            for t in trace {
                s.submit(t);
            }
            drive_to_drain(&mut s, total);
        });
    }

    /// The ctx == pool-tokens property, extended to SHARING: a
    /// shared-prefix trace through a prefix-cached scheduler keeps the
    /// accounting invariant (now covering refcounts and retained pages)
    /// on every step, and every request still completes.
    #[test]
    fn property_accounting_holds_under_prefix_sharing() {
        proptest::check_with("prefix-cache scheduler accounting", 64, |r| {
            let cfg = SchedulerConfig {
                max_batch: 1 + r.below(4) as usize,
                kv_pages: 24 + r.below(24) as usize,
                page_tokens: 8,
                max_seq: 128,
                prefix_cache: true,
            };
            let mut s = Scheduler::new(cfg);
            let trace = generate_shared_prefix_trace(&SharedPrefixConfig {
                n_groups: 2,
                prefix_len: 24,
                tail_len_choices: vec![2, 6, 10],
                decode_len_choices: vec![2, 4],
                n_requests: 6,
                rate_per_s: 50.0,
                vocab: 64,
                seed: r.next_u64(),
            });
            let total = trace.len();
            for t in trace {
                s.submit(t);
            }
            drive_to_drain(&mut s, total);
        });
    }

    /// Shared driver for the liveness/accounting properties: run the
    /// scheduler to drain, checking `check_accounting` after EVERY step.
    fn drive_to_drain(s: &mut Scheduler, total: usize) {
        let mut finished = 0;
        let mut now = 0.0f64;
        for _ in 0..10_000 {
            let batch = s.schedule(now);
            assert!(s.check_accounting(), "desync right after admission");
            if batch.is_empty() {
                if s.is_drained() {
                    break;
                }
                let t = s.next_arrival_s().expect("no arrivals but not drained");
                assert!(t > now, "stalled with arrived work");
                now = t;
                continue;
            }
            for id in batch {
                let prefilled = s.seq(id).unwrap().prefilled;
                if !prefilled {
                    s.on_prefill_done(id, 1);
                } else {
                    match s.on_decode_done(id, 2) {
                        DecodeOutcome::Running => {}
                        DecodeOutcome::Finished | DecodeOutcome::EvictedKvFull => {
                            s.retire(id);
                            finished += 1;
                        }
                    }
                }
                // The core property: scheduler ctx == pool tokens after
                // EVERY step, for every sequence — shared pages included.
                assert!(s.check_accounting(), "ctx/pool desync");
            }
            now += 0.01;
        }
        assert_eq!(finished, total, "all requests must finish");
        assert!(s.is_drained());
        assert!(s.pool.check_invariants());
    }
}
