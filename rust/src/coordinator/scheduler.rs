//! Prefill/decode scheduler.
//!
//! Policy (latency-oriented, §1's batch-size-1 regime): admit the oldest
//! waiting request whenever a batch slot and KV pages are available;
//! decode running sequences round-robin; a new prefill preempts nothing
//! (prefill happens when a slot opens).  `max_batch > 1` gives the
//! Fig. 15 multi-batch mode.

use std::collections::VecDeque;

use crate::workload::Request;

use super::kv_cache::PagePool;

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Concurrent sequences in decode (batch size; paper default 1).
    pub max_batch: usize,
    /// KV page pool geometry.
    pub kv_pages: usize,
    pub page_tokens: usize,
    /// Hard cap on context (model max_seq).
    pub max_seq: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self { max_batch: 1, kv_pages: 64, page_tokens: 16, max_seq: 256 }
    }
}

/// A running sequence.
#[derive(Debug)]
pub struct SeqState {
    pub req: Request,
    /// Tokens generated so far.
    pub generated: Vec<u32>,
    /// Context length currently in the KV cache.
    pub ctx: usize,
    /// Whether prefill has run.
    pub prefilled: bool,
    /// Time the request was admitted (set by the server).
    pub admitted_s: f64,
}

impl SeqState {
    pub fn done(&self) -> bool {
        self.prefilled && self.generated.len() >= self.req.max_new_tokens as usize
    }
}

/// What the scheduler wants executed next.
#[derive(Debug, PartialEq, Eq)]
pub enum Action {
    /// Run prefill for sequence `seq`.
    Prefill { seq: u64 },
    /// Run one decode step for sequence `seq`.
    Decode { seq: u64 },
    /// Nothing runnable (queue empty or blocked on capacity).
    Idle,
}

#[derive(Debug)]
pub struct Scheduler {
    pub cfg: SchedulerConfig,
    waiting: VecDeque<Request>,
    running: Vec<SeqState>,
    pub pool: PagePool,
    rr_cursor: usize,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        let pool = PagePool::new(cfg.kv_pages, cfg.page_tokens);
        Self { cfg, waiting: VecDeque::new(), running: Vec::new(), pool, rr_cursor: 0 }
    }

    pub fn submit(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.waiting.len()
    }

    pub fn running(&self) -> &[SeqState] {
        &self.running
    }

    pub fn seq_mut(&mut self, seq: u64) -> Option<&mut SeqState> {
        self.running.iter_mut().find(|s| s.req.id == seq)
    }

    /// Decide the next action. Admission: oldest waiting request enters
    /// when a batch slot is free and its prompt fits the KV pool.
    pub fn next_action(&mut self, now_s: f64) -> Action {
        // Admit if possible.
        if self.running.len() < self.cfg.max_batch {
            if let Some(req) = self.waiting.front() {
                let plen = req.prompt.len().min(self.cfg.max_seq);
                if self.pool.can_grow(req.id, plen) {
                    let req = self.waiting.pop_front().unwrap();
                    self.pool
                        .admit(req.id, plen)
                        .expect("can_grow guaranteed admission");
                    let id = req.id;
                    self.running.push(SeqState {
                        req,
                        generated: Vec::new(),
                        ctx: plen,
                        prefilled: false,
                        admitted_s: now_s,
                    });
                    return Action::Prefill { seq: id };
                }
            }
        }
        // Any admitted-but-not-prefilled sequence (shouldn't linger, but
        // be robust to callers that interleave).
        if let Some(s) = self.running.iter().find(|s| !s.prefilled) {
            return Action::Prefill { seq: s.req.id };
        }
        // Round-robin decode across running sequences.
        if self.running.is_empty() {
            return Action::Idle;
        }
        let n = self.running.len();
        for k in 0..n {
            let i = (self.rr_cursor + k) % n;
            if !self.running[i].done() && self.running[i].ctx < self.cfg.max_seq {
                self.rr_cursor = (i + 1) % n;
                return Action::Decode { seq: self.running[i].req.id };
            }
        }
        Action::Idle
    }

    /// Record a prefill completion (first token produced).
    pub fn on_prefill_done(&mut self, seq: u64, first_token: u32) {
        if let Some(s) = self.seq_mut(seq) {
            s.prefilled = true;
            s.generated.push(first_token);
        }
    }

    /// Record a decode step; returns true if the sequence just finished.
    pub fn on_decode_done(&mut self, seq: u64, token: u32) -> bool {
        let page = self.pool.append(seq).is_ok();
        if let Some(s) = self.seq_mut(seq) {
            if page {
                s.ctx += 1;
            }
            s.generated.push(token);
            if s.done() || s.ctx >= self.cfg.max_seq {
                return true;
            }
        }
        false
    }

    /// Remove a finished sequence, releasing its pages.
    pub fn retire(&mut self, seq: u64) -> Option<SeqState> {
        let idx = self.running.iter().position(|s| s.req.id == seq)?;
        let s = self.running.swap_remove(idx);
        let _ = self.pool.release(seq);
        if self.rr_cursor >= self.running.len() {
            self.rr_cursor = 0;
        }
        Some(s)
    }

    pub fn is_drained(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::workload::{generate_trace, TraceConfig};

    fn req(id: u64, plen: usize, dlen: u32) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            prompt: vec![1; plen],
            max_new_tokens: dlen,
        }
    }

    #[test]
    fn single_batch_runs_one_request_to_completion() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        s.submit(req(0, 16, 3));
        s.submit(req(1, 16, 3));
        assert_eq!(s.next_action(0.0), Action::Prefill { seq: 0 });
        s.on_prefill_done(0, 7);
        // batch=1: request 1 must NOT be admitted while 0 runs.
        assert_eq!(s.next_action(0.0), Action::Decode { seq: 0 });
        assert!(!s.on_decode_done(0, 8));
        assert_eq!(s.next_action(0.0), Action::Decode { seq: 0 });
        assert!(s.on_decode_done(0, 9)); // 3 tokens total → done
        s.retire(0);
        assert_eq!(s.next_action(0.0), Action::Prefill { seq: 1 });
    }

    #[test]
    fn multibatch_round_robins() {
        let mut s = Scheduler::new(SchedulerConfig { max_batch: 2, ..Default::default() });
        s.submit(req(0, 16, 8));
        s.submit(req(1, 16, 8));
        assert_eq!(s.next_action(0.0), Action::Prefill { seq: 0 });
        s.on_prefill_done(0, 1);
        assert_eq!(s.next_action(0.0), Action::Prefill { seq: 1 });
        s.on_prefill_done(1, 1);
        let a = s.next_action(0.0);
        let b = s.next_action(0.0);
        assert_ne!(a, b, "round-robin must alternate: {a:?} vs {b:?}");
    }

    #[test]
    fn admission_blocked_by_kv_capacity() {
        let cfg = SchedulerConfig {
            max_batch: 4,
            kv_pages: 2,
            page_tokens: 16,
            max_seq: 256,
        };
        let mut s = Scheduler::new(cfg);
        s.submit(req(0, 32, 4)); // takes both pages
        s.submit(req(1, 16, 4));
        assert_eq!(s.next_action(0.0), Action::Prefill { seq: 0 });
        s.on_prefill_done(0, 1);
        // No pages left: request 1 can't be admitted; 0 decodes instead.
        assert!(matches!(s.next_action(0.0), Action::Decode { seq: 0 }));
    }

    #[test]
    fn context_cap_finishes_sequence() {
        let cfg = SchedulerConfig { max_seq: 18, ..Default::default() };
        let mut s = Scheduler::new(cfg);
        s.submit(req(0, 16, 100));
        s.next_action(0.0);
        s.on_prefill_done(0, 1);
        s.next_action(0.0);
        assert!(!s.on_decode_done(0, 2)); // ctx 17
        s.next_action(0.0);
        assert!(s.on_decode_done(0, 3)); // ctx 18 == max_seq → finished
    }

    #[test]
    fn property_scheduler_never_starves() {
        // Every submitted request eventually completes under any
        // interleaving of batch sizes and lengths.
        proptest::check_with("scheduler liveness", 64, |r| {
            let cfg = SchedulerConfig {
                max_batch: 1 + r.below(4) as usize,
                kv_pages: 32,
                page_tokens: 8,
                max_seq: 64,
            };
            let mut s = Scheduler::new(cfg);
            let trace = generate_trace(&TraceConfig {
                n_requests: 6,
                prompt_len_choices: vec![4, 8, 16],
                decode_len_choices: vec![2, 4, 8],
                seed: r.next_u64(),
                ..Default::default()
            });
            let total = trace.len();
            for t in trace {
                s.submit(t);
            }
            let mut finished = 0;
            for step in 0..10_000 {
                match s.next_action(step as f64) {
                    Action::Prefill { seq } => s.on_prefill_done(seq, 1),
                    Action::Decode { seq } => {
                        if s.on_decode_done(seq, 2) {
                            s.retire(seq);
                            finished += 1;
                        }
                    }
                    Action::Idle => break,
                }
            }
            assert_eq!(finished, total, "all requests must finish");
            assert!(s.is_drained());
            assert!(s.pool.check_invariants());
        });
    }
}
